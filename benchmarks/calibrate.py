"""Offline calibration of core.netmodel constants against the paper's
measured ratios (run once; fitted values are hard-coded in netmodel.py).

Random-restart coordinate search in log-space, per cluster, minimizing
the max relative error across that cluster's claims, under physical
bounds (alpha within 1-120us, beta below line rate, etc.).

Usage: PYTHONPATH=src python -m benchmarks.calibrate
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.tfgrpc_bench import BenchConfig
from repro.core import netmodel as nm
from repro.core.payload import generate_spec

SKEW = generate_spec(BenchConfig(scheme="skew"))
UNI = generate_spec(BenchConfig(scheme="uniform"))

# (name, line-rate bytes/s cap)
SPECS = {
    "eth40g":    5.0e9, "ipoib_edr": 12.5e9, "rdma_edr": 12.5e9,
    "eth10g":    1.25e9, "ipoib_fdr": 7.0e9, "rdma_fdr": 7.0e9,
}

CLUSTERS = {
    "A": {
        "nets": ("eth40g", "ipoib_edr", "rdma_edr"),
        "claims": [
            ("red_lat", "rdma_edr", "eth40g", SKEW, 0.59),
            ("red_lat", "rdma_edr", "ipoib_edr", SKEW, 0.56),
            ("bw_ratio", "rdma_edr", "ipoib_edr", SKEW, 2.14),
            ("tp_ratio", "rdma_edr", "eth40g", UNI, 4.10),
            ("tp_ratio", "rdma_edr", "ipoib_edr", UNI, 3.43),
            # fig8 also shows eth40g ~ ipoib on cluster A ("almost similar")
            ("red_lat", "ipoib_edr", "eth40g", SKEW, 0.02),
        ],
    },
    "B": {
        "nets": ("eth10g", "ipoib_fdr", "rdma_fdr"),
        "claims": [
            ("red_lat", "rdma_fdr", "eth10g", SKEW, 0.78),
            ("red_lat", "rdma_fdr", "ipoib_fdr", SKEW, 0.69),
            ("red_lat", "ipoib_fdr", "eth10g", SKEW, 0.27),
            ("bw_ratio", "rdma_fdr", "ipoib_fdr", SKEW, 3.2),
            ("tp_ratio", "rdma_fdr", "eth10g", UNI, 5.9),
        ],
    },
}


def build(params: dict) -> dict:
    return {name: nm.NetworkModel(name, alpha_s=p[0], beta_Bps=p[1],
                                  rpc_overhead_s=p[2], cpu_copy_Bps=p[3])
            for name, p in params.items()}


def claim_value(nets, kind, a, b, spec):
    if kind == "red_lat":
        return 1.0 - nets[a].rtt(spec) / nets[b].rtt(spec)
    if kind == "bw_ratio":
        return nets[a].bandwidth(spec) / nets[b].bandwidth(spec)
    if kind == "tp_ratio":
        return (nets[a].ps_throughput(spec, 2, 3)
                / nets[b].ps_throughput(spec, 2, 3))
    raise ValueError(kind)


def loss(params, cluster):
    nets = build(params)
    errs = []
    for kind, a, b, spec, target in CLUSTERS[cluster]["claims"]:
        v = claim_value(nets, kind, a, b, spec)
        denom = abs(target) if abs(target) > 0.05 else 1.0
        errs.append(abs(v - target) / denom)
    return max(errs), errs


def fit(cluster: str, iters: int = 40000, seed: int = 0):
    rng = np.random.default_rng(seed)
    names = CLUSTERS[cluster]["nets"]

    def sample():
        out = {}
        for n in names:
            is_rdma = n.startswith("rdma")
            alpha = rng.uniform(2e-6 if is_rdma else 15e-6,
                                20e-6 if is_rdma else 120e-6)
            beta = rng.uniform(0.15, 0.98) * SPECS[n]
            over = rng.uniform(2e-6 if is_rdma else 20e-6,
                               30e-6 if is_rdma else 150e-6)
            cpu = float("inf") if is_rdma else rng.uniform(2e9, 4e10)
            out[n] = [alpha, beta, over, cpu]
        return out

    best, best_p = np.inf, None
    for _ in range(iters):
        p = sample()
        l, _ = loss(p, cluster)
        if l < best:
            best, best_p = l, p
    # local refinement (clamped to physical bounds)
    for _ in range(20000):
        p = {}
        for n, vals in best_p.items():
            a, b, o, c = [v * np.exp(rng.normal(0, 0.05))
                          if np.isfinite(v) else v for v in vals]
            b = min(b, 0.98 * SPECS[n])  # never above line rate
            p[n] = [a, b, o, c]
        l, _ = loss(p, cluster)
        if l < best:
            best, best_p = l, p
    return best, best_p


def main():
    for cluster in ("A", "B"):
        best, p = fit(cluster)
        print(f"cluster {cluster}: max rel err {best*100:.1f}%")
        for n, (a, b, o, c) in p.items():
            cpu = "inf" if not np.isfinite(c) else f"{c:.3g}"
            print(f'    "{n}": NetworkModel("{n}", alpha_s={a:.3g}, '
                  f'beta_Bps={b:.4g}, rpc_overhead_s={o:.3g}, '
                  f'cpu_copy_Bps={cpu}),')
        nets = build(p)
        for kind, a, b, spec, target in CLUSTERS[cluster]["claims"]:
            v = claim_value(nets, kind, a, b, spec)
            print(f"    {kind:9s} {a:10s} vs {b:10s} target={target:5.2f} "
                  f"model={v:5.2f}")


if __name__ == "__main__":
    main()
