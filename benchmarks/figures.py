"""One benchmark per paper table/figure (deliverable d).

Each ``fig*`` function returns a list of CSV rows
(name, us_per_call, derived...). Two kinds of numbers appear:
  measured_*  — real wall-clock on host devices (the container's
                "cluster"; relative trends)
  model_*     — alpha-beta projections for the paper's clusters
                (calibrated in core.netmodel; the reproduction numbers)
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.configs.tfgrpc_bench import BenchConfig, PS_THROUGHPUT_CONFIG
from repro.core import bench as bench_lib
from repro.core.netmodel import CLUSTER_A, CLUSTER_B, NETWORKS
from repro.core.payload import PayloadSpec, generate_spec

FAST = dict(warmup_s=0.15, duration_s=0.4)

Row = Dict[str, object]


def _row(name: str, us: float, **derived) -> Row:
    return {"name": name, "us_per_call": us, **derived}


def fig7_p2p_latency_serialized() -> List[Row]:
    """Fig 7: 64KB serialized payload latency across Cluster A networks;
    claim: serialization overhead is constant across networks."""
    spec = generate_spec(BenchConfig(
        scheme="uniform", iovec_count=4, categories=("medium",),
        medium_bytes=16 * 1024))  # 4 x 16KB = 64KB payload
    rows = []
    for net in CLUSTER_A:
        n = NETWORKS[net]
        ser = n.rtt(spec, serialized=True)
        raw = n.rtt(spec, serialized=False)
        rows.append(_row(f"fig7/model/{net}", ser * 1e6,
                         serialization_overhead_us=(ser - raw) * 1e6))
    st = bench_lib.p2p_latency(BenchConfig(
        mode="serialized", scheme="uniform", iovec_count=4,
        categories=("medium",), medium_bytes=16 * 1024, **FAST))
    rows.append(_row("fig7/measured/host", st.mean_s * 1e6,
                     iters=st.n_iters))
    return rows


def fig8_9_p2p_latency(cluster: str) -> List[Row]:
    """Figs 8/9: non-serialized P2P latency, three payload schemes."""
    nets = CLUSTER_A if cluster == "A" else CLUSTER_B
    rows = []
    for scheme in ("uniform", "random", "skew"):
        spec = generate_spec(BenchConfig(scheme=scheme))
        for net in nets:
            rows.append(_row(f"fig{'8' if cluster == 'A' else '9'}/model/"
                             f"{scheme}/{net}",
                             NETWORKS[net].rtt(spec) * 1e6,
                             payload_bytes=spec.total_bytes))
        st = bench_lib.p2p_latency(BenchConfig(scheme=scheme, **FAST))
        rows.append(_row(f"fig{'8' if cluster == 'A' else '9'}/measured/"
                         f"{scheme}/host", st.mean_s * 1e6))
    return rows


def fig10_latency_vs_iovec_count() -> List[Row]:
    """Fig 10: Large-only payloads, iovec count 2..10, IPoIB vs RDMA."""
    rows = []
    for count in range(2, 11, 2):
        cfg = BenchConfig(scheme="uniform", iovec_count=count,
                          categories=("large",))
        spec = generate_spec(cfg)
        for net in ("ipoib_edr", "rdma_edr"):
            rows.append(_row(f"fig10/model/{net}/iovec{count}",
                             NETWORKS[net].rtt(spec) * 1e6,
                             payload_mb=spec.total_bytes / 1e6))
        st = bench_lib.p2p_latency(BenchConfig(
            scheme="uniform", iovec_count=count, categories=("large",),
            **FAST))
        rows.append(_row(f"fig10/measured/host/iovec{count}",
                         st.mean_s * 1e6))
    return rows


def fig11_12_bandwidth(cluster: str) -> List[Row]:
    nets = CLUSTER_A if cluster == "A" else CLUSTER_B
    fig = "11" if cluster == "A" else "12"
    rows = []
    for scheme in ("uniform", "random", "skew"):
        spec = generate_spec(BenchConfig(scheme=scheme))
        for net in nets:
            bw = NETWORKS[net].bandwidth(spec)
            rows.append(_row(f"fig{fig}/model/{scheme}/{net}",
                             spec.total_bytes / (bw * 1e6) * 1e6,
                             MBps=bw))
        st = bench_lib.p2p_bandwidth(BenchConfig(scheme=scheme, **FAST))
        rows.append(_row(f"fig{fig}/measured/{scheme}/host",
                         st.mean_s * 1e6, MBps=st.derived["MBps"]))
    return rows


def fig13_14_ps_throughput(cluster: str) -> List[Row]:
    nets = CLUSTER_A if cluster == "A" else CLUSTER_B
    fig = "13" if cluster == "A" else "14"
    rows = []
    for scheme in ("uniform", "random", "skew"):
        spec = generate_spec(BenchConfig(scheme=scheme))
        for net in nets:
            tp = NETWORKS[net].ps_throughput(spec, 2, 3)
            rows.append(_row(f"fig{fig}/model/{scheme}/{net}",
                             1e6 / tp, rpcs_per_s=tp))
        cfg = BenchConfig(benchmark="ps_throughput", num_ps=2,
                          num_workers=3, scheme=scheme, **FAST)
        st = bench_lib.ps_throughput(cfg)
        rows.append(_row(f"fig{fig}/measured/{scheme}/host",
                         st.mean_s * 1e6,
                         rpcs_per_s=st.derived["rpcs_per_s"]))
    return rows


def paper_claims() -> List[Row]:
    """The headline ratios vs the paper's reported numbers."""
    from repro.core.netmodel import paper_ratio_report
    rows = []
    for k, v in paper_ratio_report().items():
        rows.append(_row(f"claims/{k}", 0.0, target=v["target"],
                         model=round(v["model"], 3),
                         rel_err=round(v["rel_err"], 3)))
    return rows


def arch_payload_ps() -> List[Row]:
    """Framework tie-in: PS-throughput with payloads derived from the
    assigned architectures' parameter histograms (core.payload.from_arch)
    — what a PS round for each model family actually looks like."""
    from repro.configs import get_config
    from repro.core.payload import from_arch
    rows = []
    for arch in ("qwen3-8b", "mixtral-8x7b", "kimi-k2-1t-a32b",
                 "rwkv6-1.6b"):
        spec = from_arch(get_config(arch))
        for net in ("rdma_edr", "tpu_ici", "tpu_dcn"):
            tp = NETWORKS[net].ps_throughput(spec, 2, 3)
            rows.append(_row(f"arch_ps/{arch}/{net}", 1e6 / tp,
                             rpcs_per_s=tp,
                             payload_mb=spec.total_bytes / 1e6))
    return rows


def fsdp_primitive() -> List[Row]:
    """The SPMD-native PS (all_gather + psum_scatter), measured on host
    devices — the primitive pair our fsdp/ps_mode training emits."""
    import jax
    from repro.core import channels as ch
    mesh = ch.make_net_mesh()
    spec = generate_spec(BenchConfig())
    bufs = ch.device_payload(mesh, spec)
    fn = ch.fsdp_pull_push_fn(mesh, spec.n_buffers)
    times = bench_lib._timed_loop(fn, bufs, 0.15, 0.4)
    ici = NETWORKS["tpu_ici"]
    n = mesh.shape[ch.AXIS]
    per_dev = spec.total_bytes
    model_s = 2 * per_dev * (n - 1) / n / ici.beta_Bps
    return [_row("fsdp_pull_push/measured/host",
                 float(np.mean(times)) * 1e6, devices=n),
            _row("fsdp_pull_push/model/tpu_ici", model_s * 1e6,
                 payload_bytes=per_dev)]


def extension_dcn_channel() -> List[Row]:
    """Beyond-paper (the paper's future work asks for other channels):
    cross-POD P2P — the DCN hop of the multi-pod mesh. Measured on host
    devices split into two 'pods'; projected for ICI vs DCN vs the
    paper's best NIC."""
    import jax
    from repro.core import channels as ch
    mesh = ch.make_net_mesh()
    n = mesh.shape[ch.AXIS]
    spec = generate_spec(BenchConfig(scheme="skew"))
    bufs = ch.device_payload(mesh, spec)
    rows = []
    # intra-"pod" (neighbors 0->1) vs cross-"pod" (0 -> n/2)
    for name, dst in (("intra_pod", 1), ("cross_pod", n // 2)):
        fn = ch.p2p_echo_fn(mesh, spec.n_buffers, src=0, dst=dst)
        times = bench_lib._timed_loop(fn, bufs, 0.15, 0.4)
        rows.append(_row(f"ext_dcn/measured/{name}",
                         float(np.mean(times)) * 1e6))
    for net in ("tpu_ici", "tpu_dcn", "rdma_edr"):
        rows.append(_row(f"ext_dcn/model/{net}",
                         NETWORKS[net].rtt(spec) * 1e6,
                         payload_mb=spec.total_bytes / 1e6))
    return rows


def extension_grad_compression() -> List[Row]:
    """Beyond-paper: DP gradient compression with error feedback —
    convergence cost of shrinking the PS 'push' payload 2x (bf16) / 4x
    (int8 numerics). 30 real train steps on a reduced qwen3."""
    import dataclasses
    import jax
    from repro.configs import get_reduced_config, get_shape
    from repro.data.pipeline import device_batch, host_batch
    from repro.launch import steps as steps_lib
    from repro.models import init_params
    from repro.optim import optimizer as O
    from repro.parallel import NO_MESH

    shape = dataclasses.replace(get_shape("train_4k"), seq_len=64,
                                global_batch=4)
    rows = []
    for comp in (None, "bf16", "int8"):
        cfg = get_reduced_config("qwen3-8b", n_layers=2)
        cfg = cfg.replace(train=dataclasses.replace(
            cfg.train, grad_compression=comp, learning_rate=3e-3))
        params = init_params(jax.random.PRNGKey(0), cfg)
        opt = O.init_opt_state(cfg.train, params)
        step = steps_lib.make_train_step(NO_MESH, cfg, donate=False)
        loss = None
        for i in range(30):
            b = device_batch(NO_MESH, host_batch(cfg, shape, i))
            params, opt, m = step(params, opt, b)
            loss = float(m["loss"])
        wire = {None: 1.0, "bf16": 0.5, "int8": 0.25}[comp]
        rows.append(_row(f"ext_compress/{comp or 'fp32'}", 0.0,
                         final_loss=round(loss, 4),
                         push_wire_fraction=wire))
    return rows


ALL_FIGURES = {
    "fig7": fig7_p2p_latency_serialized,
    "fig8_clusterA": lambda: fig8_9_p2p_latency("A"),
    "fig9_clusterB": lambda: fig8_9_p2p_latency("B"),
    "fig10": fig10_latency_vs_iovec_count,
    "fig11_clusterA": lambda: fig11_12_bandwidth("A"),
    "fig12_clusterB": lambda: fig11_12_bandwidth("B"),
    "fig13_clusterA": lambda: fig13_14_ps_throughput("A"),
    "fig14_clusterB": lambda: fig13_14_ps_throughput("B"),
    "paper_claims": paper_claims,
    "arch_payload_ps": arch_payload_ps,
    "fsdp_primitive": fsdp_primitive,
    "extension_dcn": extension_dcn_channel,
    "extension_compression": extension_grad_compression,
}
