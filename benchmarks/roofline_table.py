"""Render the §Roofline / §Dry-run tables in EXPERIMENTS.md from the
cached results/dryrun/*.json (no recompilation).

Usage: PYTHONPATH=src python -m benchmarks.roofline_table [--md]
"""
from __future__ import annotations

import glob
import json
import os
import sys
from typing import Dict, List

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun")

ARCH_ORDER = ["hubert-xlarge", "mixtral-8x7b", "kimi-k2-1t-a32b",
              "qwen1.5-4b", "nemotron-4-15b", "qwen3-8b", "gemma2-9b",
              "internvl2-76b", "rwkv6-1.6b", "jamba-1.5-large-398b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str, variant: str = "") -> List[Dict]:
    out = []
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            v = f"__{variant}" if variant else ""
            p = os.path.join(RESULTS, f"{arch}__{shape}__{mesh}{v}.json")
            if os.path.exists(p):
                with open(p) as f:
                    out.append(json.load(f))
    return out


def fmt_si(x: float) -> str:
    for div, suf in ((1e12, "T"), (1e9, "G"), (1e6, "M"), (1e3, "K")):
        if abs(x) >= div:
            return f"{x/div:.2f}{suf}"
    return f"{x:.1f}"


def roofline_rows(variant: str = "") -> List[Dict]:
    rows = []
    for r in load("pod16x16", variant):
        if not r.get("ok") or "roofline" not in r:
            continue
        rf = r["roofline"]
        ma = r.get("memory_analysis", {})
        arg_gb = ma.get("argument_size_in_bytes", 0) / 1e9
        tmp_gb = ma.get("temp_size_in_bytes", 0) / 1e9
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "compute_s": rf["compute_s"], "memory_s": rf["memory_s"],
            "collective_s": rf["collective_s"],
            "dominant": rf["dominant"],
            "frac": rf["roofline_fraction"],
            "model_flops": rf["model_flops"],
            "hlo_flops": rf["hlo_total_flops"],
            "useful": rf["useful_flops_ratio"],
            "args_gb": arg_gb, "temp_gb": tmp_gb,
            "compile_s": r.get("compile_s", 0),
        })
    return rows


def main() -> None:
    rows = roofline_rows()
    print("| arch | shape | compute s | memory s | collective s | "
          "dominant | roofline frac | MODEL/HLO flops | args GB/dev | "
          "temp GB/dev |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        print(f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3g} | "
              f"{r['memory_s']:.3g} | {r['collective_s']:.3g} | "
              f"**{r['dominant']}** | {r['frac']:.3f} | "
              f"{r['useful']:.2f} | {r['args_gb']:.2f} | "
              f"{r['temp_gb']:.2f} |")
    # dry-run proof table
    print()
    print("| arch | shape | pod16x16 | pod2x16x16 | collectives "
          "(single-pod full HLO) |")
    print("|---|---|---|---|---|")
    multi = {(r["arch"], r["shape"]): r for r in load("pod2x16x16")}
    for r in load("pod16x16"):
        key = (r["arch"], r["shape"])
        m = multi.get(key, {})
        c = r.get("collectives_full_hlo", {}).get("counts", {})
        cs = " ".join(f"{k}:{v}" for k, v in sorted(c.items()))
        ok1 = "OK" if r.get("ok") else r.get("skipped", "FAIL")
        ok2 = "OK" if m.get("ok") else m.get("skipped", "FAIL")
        print(f"| {r['arch']} | {r['shape']} | {ok1} "
              f"({r.get('compile_s', 0):.1f}s) | {ok2} "
              f"({m.get('compile_s', 0):.1f}s) | {cs} |")


if __name__ == "__main__":
    main()
