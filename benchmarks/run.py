import os
if "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    # the communication benchmarks need a device fabric; 8 host devices
    # (set before any jax import — this is the benchmark entry point)
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=8")

"""Benchmark harness entry point (deliverable d).

One function per paper table/figure (benchmarks/figures.py) plus the
framework tie-ins. Prints ``name,us_per_call,derived`` CSV.

Usage: PYTHONPATH=src python -m benchmarks.run [figure ...]
"""
import sys


def main() -> None:
    from benchmarks.figures import ALL_FIGURES
    wanted = sys.argv[1:] or list(ALL_FIGURES)
    print("name,us_per_call,derived")
    failures = []
    for name in wanted:
        fn = ALL_FIGURES[name]
        try:
            rows = fn()
        except Exception as e:  # noqa: BLE001
            failures.append((name, e))
            print(f"{name},ERROR,{type(e).__name__}: {e}")
            continue
        for r in rows:
            derived = ";".join(f"{k}={v}" for k, v in r.items()
                               if k not in ("name", "us_per_call"))
            print(f"{r['name']},{r['us_per_call']:.2f},{derived}")
    if failures:
        raise SystemExit(f"{len(failures)} benchmark(s) failed")


if __name__ == "__main__":
    main()
