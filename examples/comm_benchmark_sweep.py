"""Paper §4 in one script: the full scheme x network x benchmark sweep
(figures 7-14), printed as one table.

    PYTHONPATH=src python examples/comm_benchmark_sweep.py [--quick]
"""
import os
if "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys  # noqa: E402

from benchmarks.figures import ALL_FIGURES  # noqa: E402

quick = "--quick" in sys.argv
names = (["fig7", "paper_claims"] if quick else list(ALL_FIGURES))
for name in names:
    print(f"==== {name} " + "=" * (60 - len(name)))
    for row in ALL_FIGURES[name]():
        extras = " ".join(f"{k}={v}" for k, v in row.items()
                          if k not in ("name", "us_per_call"))
        print(f"  {row['name']:42s} {row['us_per_call']:12.2f} us  "
              f"{extras}")
