"""Communication scaling sweeps, driven through the `bench_comm` CLI.

Earlier revisions of this example re-implemented the paper's sweep
loops by hand over `benchmarks.figures`; the suite has since grown
cross-product sweep axes, so the example now *is* one `bench_comm`
invocation: the streaming fabric families (ring / incast) crossed with
the worker-count and chunk-count scaling axes — the paper §4 scaling
story in a single table (per-row `rpc_metrics` included in --json).

    PYTHONPATH=src python examples/comm_benchmark_sweep.py [--quick]
        [--transport simulated|cluster|loopback|collective]
        [--network rdma_edr] [--json rows.json]

The default `simulated` transport prices every cell analytically, so
the full 2x4x4 cross-product runs in seconds; `--transport cluster`
routes the same sweep over a multi-endpoint cluster transport instead
(per-link pricing, per-endpoint metrics). The paper's per-figure
tables still live in `benchmarks/figures.py`.
"""
import os

if "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    # device fabric for collective cells; set before any jax import
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=8")

import argparse  # noqa: E402
from typing import List, Optional  # noqa: E402


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="tiny warmup/duration (smoke-test config)")
    ap.add_argument("--transport", default="simulated")
    ap.add_argument("--network", default=None)
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args(argv)

    from repro.launch import bench_comm

    warmup, duration = ("0.05", "0.1") if args.quick else ("0.5", "2.0")
    bench_args = [
        "--sweep", "benchmark,workers,stream_chunks",
        "--transport", args.transport,
        "--warmup", warmup, "--duration", duration,
    ]
    if args.network:
        bench_args += ["--network", args.network]
    if args.json:
        bench_args += ["--json", args.json]
    bench_comm.main(bench_args)


if __name__ == "__main__":
    main()
