"""Quickstart: the paper's three micro-benchmarks in 30 lines.

    PYTHONPATH=src python examples/quickstart.py

Runs TF-gRPC-P2P-Latency / -Bandwidth / -PS-Throughput with the paper's
default payloads on the host-device fabric and prints measured numbers
next to the calibrated projections for the paper's clusters + TPU
fabrics.
"""
import os
if "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

from repro.configs.tfgrpc_bench import BenchConfig  # noqa: E402
from repro.core import bench  # noqa: E402

CFG = dict(warmup_s=0.3, duration_s=1.0)

for scheme in ("uniform", "random", "skew"):
    st = bench.p2p_latency(BenchConfig(scheme=scheme, **CFG))
    proj = {k: f"{v*1e6:.0f}us" for k, v in st.model_projection.items()
            if k in ("eth40g", "ipoib_edr", "rdma_edr", "tpu_ici")}
    print(f"P2P-Latency   [{scheme:7s}] host={st.mean_s*1e6:8.1f}us "
          f"p95={st.p95_s*1e6:8.1f}us  projections={proj}")

st = bench.p2p_bandwidth(BenchConfig(scheme="skew", **CFG))
print(f"P2P-Bandwidth [skew   ] host={st.derived['MBps']:8.1f} MB/s "
      f"projections(MB/s)="
      f"{ {k: round(v) for k, v in st.model_projection.items()} }")

st = bench.ps_throughput(BenchConfig(
    benchmark="ps_throughput", num_ps=2, num_workers=3, **CFG))
print(f"PS-Throughput [2PSx3W ] host={st.derived['rpcs_per_s']:8.1f} "
      f"RPC/s  projections(RPC/s)="
      f"{ {k: round(v) for k, v in st.model_projection.items()} }")
print(f"resources: cpu_util={st.resources.cpu_util:.2f} "
      f"rss_peak={st.resources.rss_peak_bytes/1e6:.0f}MB")
