"""Serve a small model with batched requests (prefill + decode loop with
KV caches / recurrent states).

    PYTHONPATH=src python examples/serve_batched.py [--arch rwkv6-1.6b]
"""
import sys
import time

import jax
import numpy as np

from repro.configs import get_reduced_config
from repro.models import init_params
from repro.parallel import NO_MESH
from repro.serve.engine import ServeConfig, ServeEngine

arch = sys.argv[sys.argv.index("--arch") + 1] if "--arch" in sys.argv \
    else "qwen3-8b"
acfg = get_reduced_config(arch)
params = init_params(jax.random.PRNGKey(0), acfg)
engine = ServeEngine(NO_MESH, acfg, params,
                     ServeConfig(max_seq=96, max_new_tokens=16,
                                 temperature=0.8))

rng = np.random.default_rng(0)
for i, batch in enumerate((2, 4, 8)):
    prompts = rng.integers(0, acfg.model.vocab_size, (batch, 24),
                           dtype=np.int32)
    t0 = time.perf_counter()
    out = engine.generate(prompts)
    dt = time.perf_counter() - t0
    print(f"[{arch}] request {i}: batch={batch} generated {out.shape[1]} "
          f"tokens/seq in {dt*1e3:.0f} ms ({out.size/dt:.0f} tok/s)")
    print(f"   first seq: {out[0].tolist()}")
