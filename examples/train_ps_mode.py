"""End-to-end training driver: a ~100M-parameter qwen3-family model in
parameter-server (FSDP/ZeRO-3) mode with checkpoint/restart, on a
(2 data x 2 model) host-device mesh — the traffic pattern the paper's
PS-throughput benchmark models.

    PYTHONPATH=src python examples/train_ps_mode.py           # full
    PYTHONPATH=src python examples/train_ps_mode.py --tiny    # CPU smoke

The full configuration (~100M params, a few hundred steps) is sized for
a real accelerator; --tiny shrinks dims for the 1-core CPU container.
"""
import os
if "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import dataclasses  # noqa: E402
import sys  # noqa: E402
import tempfile  # noqa: E402

import jax  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.configs.base import (AttentionConfig, ShapeSpec,  # noqa: E402
                                TrainConfig)
from repro.data.pipeline import DataConfig  # noqa: E402
from repro.launch.mesh import make_test_mesh  # noqa: E402
from repro.parallel.sharding import make_ctx  # noqa: E402
from repro.train.trainer import Trainer, TrainerConfig  # noqa: E402

TINY = "--tiny" in sys.argv

base = get_config("qwen3-8b")
if TINY:
    model = dataclasses.replace(
        base.model, num_layers=2, d_model=64, d_ff=160, vocab_size=512,
        attention=AttentionConfig(n_heads=4, n_kv_heads=2, d_head=16))
    shape = ShapeSpec("tiny", seq_len=32, global_batch=4, kind="train")
    steps = 6
else:
    # ~100M params: 10L, d=640, kv-grouped attention, 50k vocab
    model = dataclasses.replace(
        base.model, num_layers=10, d_model=640, d_ff=1920,
        vocab_size=50304,
        attention=AttentionConfig(n_heads=10, n_kv_heads=2, d_head=64))
    shape = ShapeSpec("train_100m", seq_len=512, global_batch=8,
                      kind="train")
    steps = 200

acfg = base.replace(
    model=model,
    train=dataclasses.replace(base.train, param_dtype="float32",
                              compute_dtype="float32",
                              learning_rate=1e-3),
    parallel=dataclasses.replace(base.parallel, fsdp=True, ps_mode=True))
print(f"model: {acfg.model.num_params()/1e6:.1f}M params, PS(fsdp) mode")

mesh = make_test_mesh(2, 2)
ctx = make_ctx(acfg, mesh)
ckpt_dir = tempfile.mkdtemp(prefix="repro_ps_")
tcfg = TrainerConfig(total_steps=steps, ckpt_dir=ckpt_dir, ckpt_every=5,
                     log_every=1 if TINY else 10)
with mesh:
    tr = Trainer(ctx, acfg, shape, tcfg, DataConfig(seed=0))
    tr.train()
losses = [r.loss for r in tr.history]
print(f"loss: {losses[0]:.4f} -> {losses[-1]:.4f} over {len(losses)} steps")

# restart from checkpoint (fault-tolerance path)
with mesh:
    tr2 = Trainer(ctx, acfg, shape,
                  dataclasses.replace(tcfg, total_steps=steps + 2),
                  DataConfig(seed=0))
    tr2.train()
print(f"resumed from step {tr2.history[0].step} after restart "
      f"(loss {tr2.history[-1].loss:.4f})")
