"""Sharded, atomic checkpointing with elastic restore.

Layout:  <dir>/step_<N>.tmp/ -> fsync'd leaf .npy files + manifest.json
         -> atomic rename to <dir>/step_<N>/ (the COMMIT point).
Partially-written checkpoints are never visible under the final name;
``latest_step`` only ever sees committed ones, so a crash mid-save is
recovered by falling back to the previous step (tested).

Elastic restore: leaves are loaded as host numpy and re-placed with the
*target* shardings, so the restart mesh may differ from the save mesh
(e.g. 512 -> 256 chips after losing a pod).
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

SEP = "$"


def _flatten_with_names(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]

    def name(path):
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            elif hasattr(p, "name"):
                parts.append(str(p.name))
            else:
                parts.append(str(p))
        return SEP.join(parts)

    return [(name(path), leaf) for path, leaf in flat]


def save(ckpt_dir: str, step: int, tree, extra: Optional[Dict] = None
         ) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"step_{step}.tmp")
    final = os.path.join(ckpt_dir, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    names = []
    for name, leaf in _flatten_with_names(tree):
        arr = np.asarray(jax.device_get(leaf))
        fn = os.path.join(tmp, name + ".npy")
        with open(fn, "wb") as f:
            np.save(f, arr)
            f.flush()
            os.fsync(f.fileno())
        names.append(name)
    manifest = {"step": step, "leaves": names, "extra": extra or {}}
    mf = os.path.join(tmp, "manifest.json")
    with open(mf, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)           # COMMIT
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", d)
        if m and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def _expand_prefix(prefix, target):
    """Broadcast a prefix pytree (e.g. (param_shardings, None)) over the
    full target structure."""
    if type(prefix) is type(target):
        if isinstance(prefix, dict):
            return {k: _expand_prefix(prefix[k], target[k]) for k in target}
        if isinstance(prefix, (list, tuple)) and len(prefix) == len(target):
            vals = [_expand_prefix(p, t) for p, t in zip(prefix, target)]
            return (type(prefix)(*vals) if hasattr(prefix, "_fields")
                    else type(prefix)(vals))
    # prefix is a leaf (NamedSharding / None): broadcast over the subtree
    return jax.tree.map(lambda _: prefix, target)


def restore(ckpt_dir: str, step: int, target, shardings=None) -> Any:
    """Load into the structure of ``target``; place with ``shardings`` —
    a matching pytree, a PREFIX pytree, or None."""
    path = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    named = dict(_flatten_with_names(target))
    assert set(named) == set(manifest["leaves"]), (
        "checkpoint/target structure mismatch: "
        f"{set(named) ^ set(manifest['leaves'])}")
    flat_t, tdef = jax.tree.flatten(target)
    if shardings is None:
        sh_flat = [None] * len(flat_t)
    else:
        expanded = _expand_prefix(shardings, target)
        sh_flat = [s for s, _ in zip(jax.tree.leaves(
            expanded, is_leaf=lambda x: x is None), flat_t)]
        if len(sh_flat) != len(flat_t):
            sh_flat = jax.tree.leaves(expanded,
                                      is_leaf=lambda x: x is None)
        assert len(sh_flat) == len(flat_t), (len(sh_flat), len(flat_t))
    names = [n for n, _ in _flatten_with_names(target)]
    loaded = []
    for name, tgt, sh in zip(names, flat_t, sh_flat):
        arr = np.load(os.path.join(path, name + ".npy"))
        assert tuple(arr.shape) == tuple(tgt.shape), (name, arr.shape,
                                                      tgt.shape)
        if sh is not None:
            loaded.append(jax.device_put(arr.astype(tgt.dtype), sh))
        else:
            loaded.append(jax.numpy.asarray(arr.astype(tgt.dtype)))
    return jax.tree.unflatten(tdef, loaded), manifest["extra"]


def prune(ckpt_dir: str, keep: int = 3) -> None:
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(s for s in (latest_step(ckpt_dir),) if s is not None)
    all_steps = sorted(
        int(m.group(1)) for d in os.listdir(ckpt_dir)
        if (m := re.fullmatch(r"step_(\d+)", d)))
    for s in all_steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"),
                      ignore_errors=True)
    del steps
