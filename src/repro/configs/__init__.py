from repro.configs.base import (SHAPES, ArchConfig, AttentionConfig,
                                ModelConfig, MoEConfig, ParallelConfig,
                                ShapeSpec, SSMConfig, TrainConfig, reduced)
from repro.configs.registry import (cells, get_config, get_reduced_config,
                                    get_shape, list_archs)

__all__ = [
    "SHAPES", "ArchConfig", "AttentionConfig", "ModelConfig", "MoEConfig",
    "ParallelConfig", "ShapeSpec", "SSMConfig", "TrainConfig", "reduced",
    "cells", "get_config", "get_reduced_config", "get_shape", "list_archs",
]
