"""Config system for the repro framework.

Plain frozen dataclasses; every architecture in ``src/repro/configs/``
builds an :class:`ArchConfig` from these. Configs are pure data — no jax
imports here, so importing a config never touches device state.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Attention / block flavors
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class AttentionConfig:
    n_heads: int
    n_kv_heads: int
    d_head: int
    causal: bool = True
    qkv_bias: bool = False
    qk_norm: bool = False                  # qwen3-style per-head RMSNorm on q,k
    logit_softcap: Optional[float] = None  # gemma2-style tanh soft-capping
    sliding_window: Optional[int] = None   # SWA window (tokens), None = full
    rope_theta: float = 10_000.0
    use_rope: bool = True


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # 'tp'  : experts replicated on the expert dim, TP-sharded on d_ff
    # 'ep'  : experts sharded over the model axis (expert parallelism)
    expert_sharding: str = "tp"
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    """RWKV-6 / Mamba style recurrent block parameters."""
    kind: str = "rwkv6"        # 'rwkv6' | 'mamba'
    d_state: int = 16          # mamba state dim
    d_conv: int = 4            # mamba local conv width
    expand: int = 2            # mamba inner expansion
    head_size: int = 64        # rwkv6 head size


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encoder
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    attention: Optional[AttentionConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # FFN activation: 'swiglu' | 'geglu' | 'gelu' | 'sq_relu'
    ffn_activation: str = "swiglu"
    norm: str = "rmsnorm"           # 'rmsnorm' | 'layernorm'
    tie_embeddings: bool = False
    final_logit_softcap: Optional[float] = None
    # layer pattern, repeated cyclically; entries: 'attn' | 'mamba' | 'rwkv'
    # e.g. jamba 1:7 -> ('mamba',)*4 + ('attn',) + ('mamba',)*3
    layer_pattern: Tuple[str, ...] = ("attn",)
    # which positions in the pattern use MoE FFN (all if moe and empty)
    moe_pattern: Tuple[bool, ...] = ()
    # gemma2-style alternating local/global window per pattern position:
    # None = use attention.sliding_window everywhere
    window_pattern: Optional[Tuple[Optional[int], ...]] = None
    # encoder-only models have no decode path
    is_encoder: bool = False
    # [audio]/[vlm]: stub frontend supplies embeddings directly
    frontend: Optional[str] = None  # None | 'audio_frames' | 'vision_patches'
    max_position_embeddings: int = 1_048_576

    @property
    def pattern_period(self) -> int:
        return len(self.layer_pattern)

    @property
    def n_periods(self) -> int:
        assert self.num_layers % self.pattern_period == 0, (
            f"{self.name}: num_layers={self.num_layers} not divisible by "
            f"pattern period {self.pattern_period}")
        return self.num_layers // self.pattern_period

    def moe_at(self, pos: int) -> bool:
        if self.moe is None:
            return False
        if not self.moe_pattern:
            return True
        return self.moe_pattern[pos % self.pattern_period]

    def window_at(self, pos: int) -> Optional[int]:
        if self.window_pattern is None:
            return self.attention.sliding_window if self.attention else None
        return self.window_pattern[pos % self.pattern_period]

    # ---------------- parameter counting (for roofline / payloads) ---------
    def param_counts(self) -> dict:
        """Analytic parameter count per component, in elements."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        counts = {"embed": v * d}
        if not self.tie_embeddings and not self.is_encoder:
            counts["lm_head"] = v * d
        per_layer = 0.0
        att = self.attention
        for pos in range(self.pattern_period):
            kind = self.layer_pattern[pos]
            layer = 0
            if kind == "attn":
                hq = att.n_heads * att.d_head
                hkv = att.n_kv_heads * att.d_head
                layer += d * hq + 2 * d * hkv + hq * d  # q,k,v,o
                if att.qkv_bias:
                    layer += hq + 2 * hkv
            elif kind == "mamba":
                di = self.ssm.expand * d
                layer += d * 2 * di                  # in_proj
                layer += di * self.ssm.d_conv        # conv
                layer += di * (2 * self.ssm.d_state + 1) + di  # x_proj-ish + dt
                layer += di * d                      # out_proj
            elif kind == "rwkv":
                layer += 4 * d * d + 6 * d           # r,k,v,o + mixes
                layer += d * d                       # gate
            # FFN
            if self.moe_at(pos):
                e = self.moe
                n_mat = 3 if self.ffn_activation in ("swiglu", "geglu") else 2
                layer += e.num_experts * n_mat * d * e.d_ff_expert
                layer += d * e.num_experts           # router
            else:
                n_mat = 3 if self.ffn_activation in ("swiglu", "geglu") else 2
                layer += n_mat * d * f
            layer += 2 * d                           # two norms
            per_layer += layer
        counts["layers"] = per_layer * self.n_periods
        counts["final_norm"] = d
        return counts

    def num_params(self) -> int:
        return int(sum(self.param_counts().values()))

    def num_active_params(self) -> int:
        """Active params per token (MoE counts top_k experts only)."""
        if self.moe is None:
            return self.num_params()
        e = self.moe
        n_mat = 3 if self.ffn_activation in ("swiglu", "geglu") else 2
        dead = 0.0
        for pos in range(self.pattern_period):
            if self.moe_at(pos):
                dead += (e.num_experts - e.top_k) * n_mat * \
                    self.d_model * e.d_ff_expert
        return int(self.num_params() - dead * self.n_periods)


# ---------------------------------------------------------------------------
# Shapes (assigned input-shape set)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k":    ShapeSpec("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeSpec("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeSpec("long_500k",   524_288, 1,   "decode"),
}


# ---------------------------------------------------------------------------
# Training / runtime
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "adamw"        # 'adamw' | 'adafactor' | 'sgd'
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 100
    # DP gradient compression: None | 'bf16' | 'int8'
    grad_compression: Optional[str] = None
    remat: bool = True
    remat_policy: str = "nothing_saveable"  # or 'dots_saveable'
    scan_layers: bool = True
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # real-TPU hot paths (Pallas). Off for the CPU dry-run: Mosaic
    # kernels do not lower on the CPU backend.
    use_flash_kernel: bool = False
    use_rwkv_kernel: bool = False


@dataclass(frozen=True)
class ParallelConfig:
    """Logical→physical axis mapping knobs (see parallel/sharding.py)."""
    fsdp: bool = False              # shard params over the data axis (ZeRO-3 / PS mode)
    ps_mode: bool = False           # explicit pull/push parameter-server phasing
    seq_shard_prefill: bool = True  # shard long-seq activations over 'data'
    seq_shard_kv_decode: bool = True  # shard KV cache seq dim when batch < data axis
    expert_sharding: Optional[str] = None  # override MoEConfig.expert_sharding


@dataclass(frozen=True)
class ArchConfig:
    model: ModelConfig
    train: TrainConfig = field(default_factory=TrainConfig)
    parallel: ParallelConfig = field(default_factory=ParallelConfig)
    # shapes this arch supports (by name); filled by registry defaults
    shapes: Tuple[str, ...] = ("train_4k", "prefill_32k", "decode_32k")
    skip_reasons: Tuple[Tuple[str, str], ...] = ()

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


def reduced(cfg: ArchConfig,
            n_layers: Optional[int] = None,
            d_model: int = 64,
            vocab: int = 128) -> ArchConfig:
    """A tiny same-family config for CPU smoke tests."""
    m = cfg.model
    period = m.pattern_period
    nl = n_layers or max(period, 2 if period == 1 else period)
    nl = ((nl + period - 1) // period) * period
    att = None
    if m.attention is not None:
        att = dataclasses.replace(
            m.attention, n_heads=4,
            n_kv_heads=min(4, max(1, m.attention.n_kv_heads * 4 // m.attention.n_heads)),
            d_head=16,
            sliding_window=(64 if m.attention.sliding_window else None))
    moe = None
    if m.moe is not None:
        # dropless capacity so reduced-config tests are batch-shape exact
        moe = dataclasses.replace(m.moe, num_experts=4,
                                  top_k=min(2, m.moe.top_k), d_ff_expert=96,
                                  capacity_factor=4.0 / min(2, m.moe.top_k))
    ssm = m.ssm
    if ssm is not None:
        ssm = dataclasses.replace(ssm, head_size=16)
    wp = None
    if m.window_pattern is not None:
        wp = tuple(64 if w else None for w in m.window_pattern)
    model = dataclasses.replace(
        m, num_layers=nl, d_model=d_model, d_ff=160, vocab_size=vocab,
        attention=att, moe=moe, ssm=ssm, window_pattern=wp,
        max_position_embeddings=4096)
    train = dataclasses.replace(cfg.train, param_dtype="float32",
                                compute_dtype="float32")
    return cfg.replace(model=model, train=train)
