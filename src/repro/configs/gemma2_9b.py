"""gemma2-9b [dense] — 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000; local(4096)+global alternating attention, logit softcap,
GeGLU. [arXiv:2408.00118]

long_500k runs: local layers' KV caches are window-bounded (4096);
global layers hold the full (sequence-sharded) cache — decode-time
attention is linear in context length.
"""
from repro.configs.base import (ArchConfig, AttentionConfig, ModelConfig,
                                TrainConfig)

CONFIG = ArchConfig(
    model=ModelConfig(
        name="gemma2-9b",
        family="dense",
        num_layers=42,
        d_model=3584,
        d_ff=14336,
        vocab_size=256000,
        attention=AttentionConfig(
            n_heads=16, n_kv_heads=8, d_head=256,
            logit_softcap=50.0),
        ffn_activation="geglu",
        final_logit_softcap=30.0,
        layer_pattern=("attn", "attn"),
        window_pattern=(4096, None),   # local, global alternating
        tie_embeddings=True,
    ),
    train=TrainConfig(),
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)
