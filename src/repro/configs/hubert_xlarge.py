"""hubert-xlarge [audio] — encoder-only, wav2vec2-style backbone.

48L d_model=1280 16H (GQA kv=16) d_ff=5120 vocab=504
[arXiv:2106.07447]. Modality frontend is a stub: input_specs() provides
precomputed frame embeddings. Encoder-only => no decode shapes.
"""
from repro.configs.base import (ArchConfig, AttentionConfig, ModelConfig,
                                TrainConfig)

CONFIG = ArchConfig(
    model=ModelConfig(
        name="hubert-xlarge",
        family="encoder",
        num_layers=48,
        d_model=1280,
        d_ff=5120,
        vocab_size=504,
        attention=AttentionConfig(
            n_heads=16, n_kv_heads=16, d_head=80,
            causal=False, use_rope=False, qkv_bias=True),
        ffn_activation="gelu",
        norm="layernorm",
        is_encoder=True,
        frontend="audio_frames",
        tie_embeddings=True,
    ),
    train=TrainConfig(),
    shapes=("train_4k", "prefill_32k"),
    skip_reasons=(
        ("decode_32k", "encoder-only: no autoregressive decode step"),
        ("long_500k", "encoder-only: no autoregressive decode step"),
    ),
)
