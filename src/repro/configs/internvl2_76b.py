"""internvl2-76b [vlm] — 80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256. InternViT + LLaMA3-70B-class language backbone.
[arXiv:2404.16821]. Backbone only: the vision frontend is a stub —
input_specs() provides precomputed patch embeddings.
"""
from repro.configs.base import (ArchConfig, AttentionConfig, ModelConfig,
                                ParallelConfig, TrainConfig)

CONFIG = ArchConfig(
    model=ModelConfig(
        name="internvl2-76b",
        family="dense",
        num_layers=80,
        d_model=8192,
        d_ff=28672,
        vocab_size=128256,
        attention=AttentionConfig(
            n_heads=64, n_kv_heads=8, d_head=128, rope_theta=5e5),
        ffn_activation="swiglu",
        frontend="vision_patches",
    ),
    train=TrainConfig(remat_policy="nothing_saveable"),
    parallel=ParallelConfig(fsdp=True),
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    skip_reasons=(
        ("long_500k", "pure full-attention arch; skipped per shape-sheet rule"),
    ),
)
