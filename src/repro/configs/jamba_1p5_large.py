"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16 experts top-2; Mamba:attention 1:7
interleave (1 attention layer per 8), MoE every other layer.
[arXiv:2403.19887]. Hybrid (mostly constant-state) => long_500k runs;
the 1-in-8 attention layers keep a sequence-sharded full cache.
"""
from repro.configs.base import (ArchConfig, AttentionConfig, ModelConfig,
                                MoEConfig, ParallelConfig, SSMConfig,
                                TrainConfig)

CONFIG = ArchConfig(
    model=ModelConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        num_layers=72,
        d_model=8192,
        d_ff=24576,
        vocab_size=65536,
        attention=AttentionConfig(
            n_heads=64, n_kv_heads=8, d_head=128, use_rope=False),
        moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=24576),
        ssm=SSMConfig(kind="mamba", d_state=16, d_conv=4, expand=2),
        ffn_activation="swiglu",
        # Jamba period-8 block: attn at position 4, mamba elsewhere
        layer_pattern=("mamba", "mamba", "mamba", "mamba",
                       "attn", "mamba", "mamba", "mamba"),
        # MoE on odd positions (every other layer)
        moe_pattern=(False, True, False, True, False, True, False, True),
    ),
    train=TrainConfig(optimizer="adafactor"),
    parallel=ParallelConfig(fsdp=True),
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)
