"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8) d_ff=2048
vocab=163840, MoE 384 experts top-8. Trillion-param MoE (paper-table).
[arXiv:2501.kimi2]. Full attention => long_500k skipped per shape sheet.

Adafactor optimizer: 1T params * (4B adam m + 4B v + 4B master) does not
fit 512 v5e chips; factored second moment does (see DESIGN.md).
"""
from repro.configs.base import (ArchConfig, AttentionConfig, ModelConfig,
                                MoEConfig, ParallelConfig, TrainConfig)

CONFIG = ArchConfig(
    model=ModelConfig(
        name="kimi-k2-1t-a32b",
        family="moe",
        num_layers=61,
        d_model=7168,
        d_ff=2048,            # expert hidden size (fine-grained experts)
        vocab_size=163840,
        attention=AttentionConfig(
            n_heads=64, n_kv_heads=8, d_head=112, rope_theta=5e7),
        moe=MoEConfig(num_experts=384, top_k=8, d_ff_expert=2048,
                      expert_sharding="ep"),
        ffn_activation="swiglu",
    ),
    train=TrainConfig(optimizer="adafactor", remat_policy="nothing_saveable"),
    parallel=ParallelConfig(fsdp=True),
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    skip_reasons=(
        ("long_500k", "full-attention arch: 512k dense prefill is quadratic; "
                      "skipped per shape-sheet rule"),
    ),
)
