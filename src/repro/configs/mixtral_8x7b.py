"""mixtral-8x7b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, MoE 8 experts top-2, sliding-window attention (4096).
[arXiv:2401.04088]. SWA bounds the KV cache => long_500k runnable.
"""
from repro.configs.base import (ArchConfig, AttentionConfig, ModelConfig,
                                MoEConfig, TrainConfig)

CONFIG = ArchConfig(
    model=ModelConfig(
        name="mixtral-8x7b",
        family="moe",
        num_layers=32,
        d_model=4096,
        d_ff=14336,
        vocab_size=32000,
        attention=AttentionConfig(
            n_heads=32, n_kv_heads=8, d_head=128,
            sliding_window=4096, rope_theta=1e6),
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=14336),
        ffn_activation="swiglu",
    ),
    train=TrainConfig(),
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)
