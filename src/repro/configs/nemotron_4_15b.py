"""nemotron-4-15b [dense] — 32L d_model=6144 48H (GQA kv=8) d_ff=24576
vocab=256000, squared-ReLU MLP (no GLU). [arXiv:2402.16819]
"""
from repro.configs.base import (ArchConfig, AttentionConfig, ModelConfig,
                                TrainConfig)

CONFIG = ArchConfig(
    model=ModelConfig(
        name="nemotron-4-15b",
        family="dense",
        num_layers=32,
        d_model=6144,
        d_ff=24576,
        vocab_size=256000,
        attention=AttentionConfig(n_heads=48, n_kv_heads=8, d_head=128),
        ffn_activation="sq_relu",
        norm="layernorm",
    ),
    train=TrainConfig(),
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    skip_reasons=(
        ("long_500k", "pure full-attention arch; skipped per shape-sheet rule"),
    ),
)
