"""qwen1.5-4b [dense] — 40L d_model=2560 20H (GQA kv=20) d_ff=6912
vocab=151936, QKV bias. [hf:Qwen/Qwen1.5-4B].

20 heads on a 16-way model axis: GSPMD uneven sharding (pad) on the head
dim; FFN (6912) and vocab (151936) shard evenly.
"""
from repro.configs.base import (ArchConfig, AttentionConfig, ModelConfig,
                                TrainConfig)

CONFIG = ArchConfig(
    model=ModelConfig(
        name="qwen1.5-4b",
        family="dense",
        num_layers=40,
        d_model=2560,
        d_ff=6912,
        vocab_size=151936,
        attention=AttentionConfig(
            n_heads=20, n_kv_heads=20, d_head=128, qkv_bias=True),
        ffn_activation="swiglu",
    ),
    train=TrainConfig(),
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    skip_reasons=(
        ("long_500k", "pure full-attention arch; skipped per shape-sheet rule"),
    ),
)
