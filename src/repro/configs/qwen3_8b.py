"""qwen3-8b [dense] — 36L d_model=4096 32H (GQA kv=8) d_ff=12288
vocab=151936, qk_norm. [hf:Qwen/Qwen3-8B]
"""
from repro.configs.base import (ArchConfig, AttentionConfig, ModelConfig,
                                TrainConfig)

CONFIG = ArchConfig(
    model=ModelConfig(
        name="qwen3-8b",
        family="dense",
        num_layers=36,
        d_model=4096,
        d_ff=12288,
        vocab_size=151936,
        attention=AttentionConfig(
            n_heads=32, n_kv_heads=8, d_head=128, qk_norm=True,
            rope_theta=1e6),
        ffn_activation="swiglu",
    ),
    train=TrainConfig(),
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    skip_reasons=(
        ("long_500k", "pure full-attention arch; skipped per shape-sheet rule"),
    ),
)
