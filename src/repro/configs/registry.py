"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import SHAPES, ArchConfig, ShapeSpec, reduced

_ARCH_MODULES = {
    "hubert-xlarge":        "repro.configs.hubert_xlarge",
    "mixtral-8x7b":         "repro.configs.mixtral_8x7b",
    "kimi-k2-1t-a32b":      "repro.configs.kimi_k2_1t_a32b",
    "qwen1.5-4b":           "repro.configs.qwen15_4b",
    "nemotron-4-15b":       "repro.configs.nemotron_4_15b",
    "qwen3-8b":             "repro.configs.qwen3_8b",
    "gemma2-9b":            "repro.configs.gemma2_9b",
    "internvl2-76b":        "repro.configs.internvl2_76b",
    "rwkv6-1.6b":           "repro.configs.rwkv6_1b6",
    "jamba-1.5-large-398b": "repro.configs.jamba_1p5_large",
}


def list_archs() -> List[str]:
    return list(_ARCH_MODULES)


def get_config(arch: str) -> ArchConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list_archs()}")
    mod = importlib.import_module(_ARCH_MODULES[arch])
    cfg: ArchConfig = mod.CONFIG
    assert cfg.model.name == arch, (cfg.model.name, arch)
    return cfg


def get_reduced_config(arch: str, **kw) -> ArchConfig:
    return reduced(get_config(arch), **kw)


def get_shape(name: str) -> ShapeSpec:
    return SHAPES[name]


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells; 40 assigned, minus documented skips."""
    out = []
    for arch in list_archs():
        cfg = get_config(arch)
        skips: Dict[str, str] = dict(cfg.skip_reasons)
        for shape in SHAPES:
            if shape in cfg.shapes:
                out.append((arch, shape, None))
            elif include_skipped:
                out.append((arch, shape, skips.get(shape, "unsupported")))
    return out
