"""rwkv6-1.6b [ssm] — 24L d_model=2048 (attention-free) d_ff=7168
vocab=65536. RWKV-6 "Finch": data-dependent decay linear recurrence.
[arXiv:2404.05892]. Constant-size recurrent state => long_500k runs.
"""
from repro.configs.base import (ArchConfig, ModelConfig, SSMConfig,
                                TrainConfig)

CONFIG = ArchConfig(
    model=ModelConfig(
        name="rwkv6-1.6b",
        family="ssm",
        num_layers=24,
        d_model=2048,
        d_ff=7168,
        vocab_size=65536,
        ssm=SSMConfig(kind="rwkv6", head_size=64),
        ffn_activation="sq_relu",   # rwkv channel-mix uses squared relu
        norm="layernorm",
        layer_pattern=("rwkv",),
    ),
    train=TrainConfig(),
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)
