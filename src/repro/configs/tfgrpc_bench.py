"""The paper's own configuration space (TF-gRPC-Bench, Table 1 + Table 2).

Buffer-size categories and benchmark defaults exactly as published;
consumed by repro.core (payload generator + benchmark drivers).
"""
from dataclasses import dataclass, field
from typing import Optional, Tuple


# Table 1 — iovec buffer size categories (bytes)
SMALL_DEFAULT = 10
MEDIUM_DEFAULT = 10 * 1024
LARGE_DEFAULT = 1 * 1024 * 1024
SMALL_RANGE = (1, 1024)                         # [1 B, 1 KB)
MEDIUM_RANGE = (1024, 1024 * 1024)              # [1 KB, 1 MB)
LARGE_RANGE = (1024 * 1024, 10 * 1024 * 1024)   # [1 MB, 10 MB]

# Skew scheme default composition (paper §3.2): 60% Large / 30% Medium / 10% Small
SKEW_FRACTIONS = {"large": 0.6, "medium": 0.3, "small": 0.1}
# §3.2: "users have the option to generate the payload in Small or
# Medium biased manner too" — same 60/30/10 split, rotated.
SKEW_BIAS_FRACTIONS = {
    "large":  {"large": 0.6, "medium": 0.3, "small": 0.1},
    "medium": {"medium": 0.6, "large": 0.3, "small": 0.1},
    "small":  {"small": 0.6, "medium": 0.3, "large": 0.1},
}


@dataclass(frozen=True)
class BenchConfig:
    """Table 2 — configurable parameters of TF-gRPC-Bench, extended with
    the rpc-fabric benchmark families (fully_connected / ring / incast
    + transport)."""
    # p2p_latency | p2p_bandwidth | ps_throughput | fully_connected
    # | ring | incast | allreduce | train_step
    benchmark: str = "p2p_latency"
    num_ps: int = 1
    num_workers: int = 1
    mode: str = "non_serialized"     # non_serialized | serialized
    # wire mode of the rpc datapath: serialized | scatter_gather |
    # zero_copy. None derives it from `mode` (serialized ->
    # "serialized", non_serialized -> "scatter_gather"); set it
    # explicitly to reach the zero-copy shared-buffer-pool tier.
    # An explicit value wins over `mode`.
    wire_mode: Optional[str] = None
    scheme: str = "uniform"          # uniform | random | skew
    skew_bias: str = "large"         # large | medium | small (skew only)
    iovec_count: int = 10
    small_bytes: int = SMALL_DEFAULT
    medium_bytes: int = MEDIUM_DEFAULT
    large_bytes: int = LARGE_DEFAULT
    categories: Tuple[str, ...] = ("small", "medium", "large")
    warmup_s: float = 2.0
    duration_s: float = 10.0
    seed: int = 0
    dtype: str = "uint8"
    network: Optional[str] = None    # key into core.netmodel.NETWORKS
    # rpc fabric transport: collective | loopback | simulated | cluster
    # (fabric families only; the three paper benchmarks are collective)
    transport: str = "collective"
    # cluster transport topology: a repro.rpc.ClusterSpec (or dict/JSON
    # accepted by rpc.as_cluster_spec). None synthesizes a homogeneous
    # cluster of the needed size on `network`
    cluster_spec: Optional[object] = None
    # chunks per stream for the ring/incast streaming families
    stream_chunks: int = 4
    # incast asymmetry: the fetch payload is this fraction/multiple of
    # the push payload (1.0 = symmetric; 0.25 models a small variable
    # pull against a large gradient push)
    fetch_ratio: float = 1.0
    # allreduce/train_step families: the collective schedule
    # (ring | tree | rsag, keys of netmodel.ALLREDUCE_ALGOS)
    algo: str = "ring"
    # train_step family: gradient-synchronization layout
    # (ps = sharded parameter servers; allreduce = cfg.algo collective)
    train_mode: str = "allreduce"
    # failure-semantics axes (fabric families only): a default per-call
    # deadline (relative seconds, propagated to servers in the frame
    # header) and a per-endpoint admission limit — both surface their
    # shed/rejected/retry counts in the rpc_metrics report
    deadline_s: Optional[float] = None
    admission_limit: Optional[int] = None
    # explicit payload override (e.g. --arch): a core.payload.PayloadSpec;
    # when set, the S/M/L generator fields above are ignored
    payload_spec: Optional[object] = None
    # attach a rpc.Tracer to the fabric even on measured transports
    # (modeled transports always trace — spans cost nothing on the
    # modeled clock); bench_comm --trace exports the Chrome JSON
    trace: bool = False

    @property
    def resolved_wire_mode(self) -> str:
        """The effective wire mode: explicit ``wire_mode`` wins, else
        derived from the paper's two-valued ``mode`` field."""
        if self.wire_mode is not None:
            return self.wire_mode
        return ("serialized" if self.mode == "serialized"
                else "scatter_gather")


# §4.5 experiment: 2 parameter servers, 3 workers
PS_THROUGHPUT_CONFIG = BenchConfig(
    benchmark="ps_throughput", num_ps=2, num_workers=3)
