"""repro.core — the paper's contribution: the TF-gRPC-Bench
micro-benchmark suite, adapted to TPU/JAX (see DESIGN.md).

The bench drivers are lazy (PEP 562): payload/netmodel are pure
numpy, and importing them (e.g. from repro.rpc's simulated transport)
must not drag in jax.
"""
from repro.core.netmodel import NETWORKS, NetworkModel, paper_ratio_report
from repro.core.payload import PayloadSpec, from_arch, generate_spec

__all__ = ["BenchStats", "fully_connected", "incast", "p2p_bandwidth",
           "p2p_latency", "ps_throughput", "ring", "run", "NETWORKS",
           "NetworkModel", "paper_ratio_report", "PayloadSpec",
           "from_arch", "generate_spec"]

_BENCH_EXPORTS = {"BenchStats", "fully_connected", "incast",
                  "p2p_bandwidth", "p2p_latency", "ps_throughput",
                  "ring", "run"}


def __getattr__(name):
    if name in _BENCH_EXPORTS:
        from repro.core import bench
        return getattr(bench, name)
    raise AttributeError(f"module 'repro.core' has no attribute {name!r}")
