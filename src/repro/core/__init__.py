"""repro.core — the paper's contribution: the TF-gRPC-Bench
micro-benchmark suite, adapted to TPU/JAX (see DESIGN.md)."""
from repro.core.bench import (BenchStats, p2p_bandwidth, p2p_latency,
                              ps_throughput, run)
from repro.core.netmodel import NETWORKS, NetworkModel, paper_ratio_report
from repro.core.payload import PayloadSpec, from_arch, generate_spec

__all__ = ["BenchStats", "p2p_bandwidth", "p2p_latency", "ps_throughput",
           "run", "NETWORKS", "NetworkModel", "paper_ratio_report",
           "PayloadSpec", "from_arch", "generate_spec"]
