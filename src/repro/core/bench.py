"""The three TF-gRPC-Bench micro-benchmarks (paper §3.2), as drivers over
repro.core.channels, with the paper's warmup/duration protocol and the
netmodel projection alongside the measured host numbers.

  TF-gRPC-P2P-Latency    -> p2p_latency()
  TF-gRPC-P2P-Bandwidth  -> p2p_bandwidth()
  TF-gRPC-PS-Throughput  -> ps_throughput()
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.configs.tfgrpc_bench import BenchConfig
from repro.core import channels as ch
from repro.core.netmodel import NETWORKS
from repro.core.payload import PayloadSpec, generate_spec
from repro.core.resource import ResourceMonitor, ResourceReport


@dataclass
class BenchStats:
    name: str
    config: BenchConfig
    spec: PayloadSpec
    n_iters: int
    mean_s: float
    p50_s: float
    p95_s: float
    min_s: float
    max_s: float
    derived: Dict[str, float] = field(default_factory=dict)
    resources: Optional[ResourceReport] = None
    model_projection: Dict[str, float] = field(default_factory=dict)

    def row(self) -> str:
        d = ",".join(f"{k}={v:.6g}" for k, v in self.derived.items())
        return (f"{self.name},{self.mean_s*1e6:.2f},{d}")


def _timed_loop(fn: Callable, args, warmup_s: float, duration_s: float,
                min_iters: int = 5) -> List[float]:
    """Paper protocol: warm up for warmup_s, then measure for duration_s."""
    out = fn(*args)
    jax.block_until_ready(out)
    t_end = time.perf_counter() + warmup_s
    while time.perf_counter() < t_end:
        jax.block_until_ready(fn(*args))
    times: List[float] = []
    t_stop = time.perf_counter() + duration_s
    while time.perf_counter() < t_stop or len(times) < min_iters:
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return times


def _stats(name, cfg, spec, times, derived, res=None) -> BenchStats:
    a = np.asarray(times)
    st = BenchStats(
        name=name, config=cfg, spec=spec, n_iters=len(a),
        mean_s=float(a.mean()), p50_s=float(np.percentile(a, 50)),
        p95_s=float(np.percentile(a, 95)), min_s=float(a.min()),
        max_s=float(a.max()), derived=derived, resources=res)
    for net_name, net in NETWORKS.items():
        serialized = cfg.mode == "serialized"
        if name == "p2p_latency":
            st.model_projection[net_name] = net.rtt(spec,
                                                    serialized=serialized)
        elif name == "p2p_bandwidth":
            st.model_projection[net_name] = net.bandwidth(
                spec, serialized=serialized)
        else:
            st.model_projection[net_name] = net.ps_throughput(
                spec, cfg.num_ps, cfg.num_workers, serialized=serialized)
    return st


def _prep(cfg: BenchConfig, need: int):
    mesh = ch.make_net_mesh()
    n = mesh.shape[ch.AXIS]
    if n < need:
        raise RuntimeError(
            f"{cfg.benchmark} needs >= {need} devices, have {n}; run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=<n>")
    spec = generate_spec(cfg)
    bufs = ch.device_payload(mesh, spec, seed=cfg.seed)
    return mesh, spec, bufs


def p2p_latency(cfg: BenchConfig) -> BenchStats:
    mesh, spec, bufs = _prep(cfg, 2)
    fn = ch.p2p_echo_fn(mesh, spec.n_buffers,
                        serialized=(cfg.mode == "serialized"))
    with ResourceMonitor() as mon:
        times = _timed_loop(fn, bufs, cfg.warmup_s, cfg.duration_s)
    return _stats("p2p_latency", cfg, spec, times,
                  {"rtt_us": float(np.mean(times)) * 1e6}, mon.report)


def p2p_bandwidth(cfg: BenchConfig) -> BenchStats:
    mesh, spec, bufs = _prep(cfg, 2)
    fn = ch.p2p_send_fn(mesh, spec.n_buffers,
                        serialized=(cfg.mode == "serialized"))
    with ResourceMonitor() as mon:
        times = _timed_loop(fn, bufs, cfg.warmup_s, cfg.duration_s)
    mbps = spec.total_bytes / np.mean(times) / 1e6
    return _stats("p2p_bandwidth", cfg, spec, times,
                  {"MBps": float(mbps)}, mon.report)


def ps_throughput(cfg: BenchConfig) -> BenchStats:
    need = cfg.num_ps + cfg.num_workers
    mesh, spec, bufs = _prep(cfg, need)
    fn = ch.ps_round_fn(mesh, spec.n_buffers, cfg.num_ps, cfg.num_workers,
                        serialized=(cfg.mode == "serialized"))
    with ResourceMonitor() as mon:
        times = _timed_loop(fn, bufs, cfg.warmup_s, cfg.duration_s)
    rpcs = ch.rpcs_per_round(cfg.num_ps, cfg.num_workers)
    return _stats("ps_throughput", cfg, spec, times,
                  {"rpcs_per_s": rpcs / float(np.mean(times))}, mon.report)


def run(cfg: BenchConfig) -> BenchStats:
    return {"p2p_latency": p2p_latency,
            "p2p_bandwidth": p2p_bandwidth,
            "ps_throughput": ps_throughput}[cfg.benchmark](cfg)
