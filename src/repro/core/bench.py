"""The TF-gRPC-Bench micro-benchmarks (paper §3.2) plus the rpc-fabric
families, as drivers over repro.core.channels and repro.rpc, with the
paper's warmup/duration protocol and the netmodel projection alongside
the measured host numbers.

  TF-gRPC-P2P-Latency    -> p2p_latency()
  TF-gRPC-P2P-Bandwidth  -> p2p_bandwidth()
  TF-gRPC-PS-Throughput  -> ps_throughput()
  fully_connected        -> fully_connected()   (rpc fabric; transport =
  ring                   -> ring()               collective | loopback |
  incast                 -> incast()             simulated)
  allreduce              -> allreduce()          (cfg.algo schedule)
  train_step             -> train_step()         (cfg.train_mode layout)

ring/incast are streaming families: each worker moves
``cfg.stream_chunks`` chunk frames per stream (ring: to its successor;
incast: bidi into one server that streams the fetch back). allreduce
runs one ``rpc.collectives`` schedule (ring | tree | rsag) over the
payload; train_step runs one ``train.fabric_train.FabricTrainStep``
data-parallel SGD step, either through sharded parameter servers
(``cfg.train_mode = "ps"``) or a cfg.algo allreduce — sweeping workers
across the two train modes locates the PS -> allreduce crossover.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.configs.tfgrpc_bench import BenchConfig
from repro.core import channels as ch
from repro.core.netmodel import ALLREDUCE_ALGOS, NETWORKS, WIRE_MODES
from repro.core.payload import PayloadSpec, generate_spec
from repro.core.resource import ResourceMonitor, ResourceReport


@dataclass
class BenchStats:
    name: str
    config: BenchConfig
    spec: PayloadSpec
    n_iters: int
    mean_s: float
    p50_s: float
    p95_s: float
    min_s: float
    max_s: float
    derived: Dict[str, float] = field(default_factory=dict)
    resources: Optional[ResourceReport] = None
    model_projection: Dict[str, float] = field(default_factory=dict)
    # per-method interceptor metrics (fabric families): call counts +
    # latency percentiles from the MetricsInterceptor on the fabric
    rpc_metrics: Dict[str, dict] = field(default_factory=dict)
    # per-method phase-level latency breakdown (fabric families, from
    # the fabric Tracer): {method: {calls, end_to_end_s, phases: {...}}}
    rpc_phases: Dict[str, dict] = field(default_factory=dict)
    # the rpc.Tracer the run recorded into (None when untraced) — holds
    # the span trees; export_chrome() writes the Perfetto-loadable JSON
    tracer: Optional[object] = None

    def row(self) -> str:
        d = ",".join(f"{k}={v:.6g}" for k, v in self.derived.items())
        return (f"{self.name},{self.mean_s*1e6:.2f},{d}")


def _timed_loop(fn: Callable, args, warmup_s: float, duration_s: float,
                min_iters: int = 5) -> List[float]:
    """Paper protocol: warm up for warmup_s, then measure for duration_s."""
    out = fn(*args)
    jax.block_until_ready(out)
    t_end = time.perf_counter() + warmup_s
    while time.perf_counter() < t_end:
        jax.block_until_ready(fn(*args))
    times: List[float] = []
    t_stop = time.perf_counter() + duration_s
    while time.perf_counter() < t_stop or len(times) < min_iters:
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return times


def _stats(name, cfg, spec, times, derived, res=None) -> BenchStats:
    a = np.asarray(times)
    st = BenchStats(
        name=name, config=cfg, spec=spec, n_iters=len(a),
        mean_s=float(a.mean()), p50_s=float(np.percentile(a, 50)),
        p95_s=float(np.percentile(a, 95)), min_s=float(a.min()),
        max_s=float(a.max()), derived=derived, resources=res)
    for net_name, net in NETWORKS.items():
        mode = cfg.resolved_wire_mode
        if name == "p2p_latency":
            st.model_projection[net_name] = net.rtt(spec, mode=mode)
        elif name == "p2p_bandwidth":
            st.model_projection[net_name] = net.bandwidth(
                spec, mode=mode)
        elif name == "fully_connected":
            st.model_projection[net_name] = net.fc_throughput(
                spec, cfg.num_workers, mode=mode)
        elif name == "ring":
            st.model_projection[net_name] = net.ring_throughput(
                spec, cfg.num_workers, n_chunks=cfg.stream_chunks,
                mode=mode)
        elif name == "incast":
            st.model_projection[net_name] = net.incast_throughput(
                spec, cfg.num_workers, n_chunks=cfg.stream_chunks,
                mode=mode, fetch_ratio=cfg.fetch_ratio)
        elif name == "allreduce":
            t = net.allreduce_time(cfg.algo, spec.total_bytes,
                                   cfg.num_workers, mode=mode)
            st.model_projection[net_name] = \
                allreduce_rpcs_per_round(cfg.algo, cfg.num_workers) / t
        elif name == "train_step":
            from repro.train.fabric_train import train_step_time
            st.model_projection[net_name] = 1.0 / train_step_time(
                net, cfg.train_mode, _grad_params(cfg, spec) * 4,
                cfg.num_workers, n_ps=cfg.num_ps, algo=cfg.algo,
                mode=mode)
        else:
            st.model_projection[net_name] = net.ps_throughput(
                spec, cfg.num_ps, cfg.num_workers, mode=mode)
    return st


def _check_collective_mode(cfg: BenchConfig) -> None:
    """The collective transport lowers frames onto device ppermute
    schedules — there is no shared host buffer pool to point descriptors
    at, so the zero-copy tier is undefined there. Loud error (a SKIPPED
    sweep cell) instead of silently pricing it as scatter-gather."""
    if cfg.resolved_wire_mode == "zero_copy":
        raise RuntimeError(
            "wire_mode=zero_copy is not supported on the collective "
            "transport; use --transport loopback|simulated|cluster")


def _prep(cfg: BenchConfig, need: int):
    _check_collective_mode(cfg)
    mesh = ch.make_net_mesh()
    n = mesh.shape[ch.AXIS]
    if n < need:
        raise RuntimeError(
            f"{cfg.benchmark} needs >= {need} devices, have {n}; run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=<n>")
    spec = generate_spec(cfg)
    bufs = ch.device_payload(mesh, spec, seed=cfg.seed)
    return mesh, spec, bufs


def p2p_latency(cfg: BenchConfig) -> BenchStats:
    mesh, spec, bufs = _prep(cfg, 2)
    fn = ch.p2p_echo_fn(mesh, spec.n_buffers,
                        serialized=(cfg.mode == "serialized"))
    with ResourceMonitor() as mon:
        times = _timed_loop(fn, bufs, cfg.warmup_s, cfg.duration_s)
    return _stats("p2p_latency", cfg, spec, times,
                  {"rtt_us": float(np.mean(times)) * 1e6}, mon.report)


def p2p_bandwidth(cfg: BenchConfig) -> BenchStats:
    mesh, spec, bufs = _prep(cfg, 2)
    fn = ch.p2p_send_fn(mesh, spec.n_buffers,
                        serialized=(cfg.mode == "serialized"))
    with ResourceMonitor() as mon:
        times = _timed_loop(fn, bufs, cfg.warmup_s, cfg.duration_s)
    mbps = spec.total_bytes / np.mean(times) / 1e6
    return _stats("p2p_bandwidth", cfg, spec, times,
                  {"MBps": float(mbps)}, mon.report)


def ps_throughput(cfg: BenchConfig) -> BenchStats:
    need = cfg.num_ps + cfg.num_workers
    mesh, spec, bufs = _prep(cfg, need)
    fn = ch.ps_round_fn(mesh, spec.n_buffers, cfg.num_ps, cfg.num_workers,
                        serialized=(cfg.mode == "serialized"))
    with ResourceMonitor() as mon:
        times = _timed_loop(fn, bufs, cfg.warmup_s, cfg.duration_s)
    rpcs = ch.rpcs_per_round(cfg.num_ps, cfg.num_workers)
    return _stats("ps_throughput", cfg, spec, times,
                  {"rpcs_per_s": rpcs / float(np.mean(times))}, mon.report)


def _resolve_cluster(cfg: BenchConfig, n_endpoints: int, family: str):
    """The ClusterSpec a ``--transport cluster`` run binds: the given
    spec (which must cover the benchmark's endpoint count), or a
    synthesized homogeneous cluster on cfg.network."""
    from repro.rpc.cluster import as_cluster_spec, homogeneous
    if cfg.cluster_spec is None:
        return homogeneous(n_endpoints, cfg.network or "eth40g")
    cluster = as_cluster_spec(cfg.cluster_spec)
    if cluster.n_endpoints != n_endpoints:
        # the exchanges span every fabric endpoint, so a mismatched
        # spec would silently benchmark a different topology
        raise RuntimeError(
            f"{family}/cluster needs exactly {n_endpoints} endpoints "
            f"(incl. the server for incast), the cluster spec has "
            f"{cluster.n_endpoints}")
    return cluster


def _make_fabric(cfg: BenchConfig, spec: PayloadSpec, n_endpoints: int,
                 family: str):
    """Build the rpc fabric (+ materialized bufs where the transport
    moves real bytes, + the MetricsInterceptor every fabric benchmark
    reports from) for one fabric-family benchmark under cfg.transport.
    Windows are sized so a whole stream (cfg.stream_chunks payloads,
    fetch asymmetry included) fits in flight per channel — the
    benchmark measures the traffic pattern, not an arbitrarily small
    default window; shrink RpcFabric windows directly to study
    back-pressure."""
    from repro import rpc as rpclib
    from repro.core.payload import materialize

    serialized = cfg.resolved_wire_mode == "serialized"
    bufs = None
    per_endpoint = False
    endpoint_name = None
    if cfg.transport == "collective":
        _check_collective_mode(cfg)
        mesh = ch.make_net_mesh()
        if mesh.shape[ch.AXIS] < n_endpoints:
            raise RuntimeError(
                f"{family}/collective needs >= {n_endpoints} devices, "
                f"have {mesh.shape[ch.AXIS]}; run under "
                f"XLA_FLAGS=--xla_force_host_platform_device_count=<n>")
        transport = rpclib.make_transport(
            "collective", n_endpoints, mesh=mesh, spec=spec,
            serialized=serialized, seed=cfg.seed)
    elif cfg.transport == "loopback":
        transport = rpclib.make_transport("loopback", n_endpoints)
        bufs = materialize(spec, seed=cfg.seed)
    elif cfg.transport == "simulated":
        # unknown names raise inside make_transport
        transport = rpclib.make_transport(
            "simulated", n_endpoints, network=cfg.network or "eth40g")
    elif cfg.transport == "cluster":
        cluster = _resolve_cluster(cfg, n_endpoints, family)
        transport = rpclib.make_transport("cluster", cluster=cluster)
        # cluster rows report metrics broken down per endpoint pair
        per_endpoint, endpoint_name = True, transport.endpoint_name
    else:
        raise ValueError(f"unknown transport {cfg.transport!r}")
    chunks = max(1, cfg.stream_chunks)
    per_chunk = int(spec.total_bytes * max(1.0, cfg.fetch_ratio))
    metrics = rpclib.MetricsInterceptor(per_endpoint=per_endpoint,
                                        endpoint_name=endpoint_name)
    # failure-semantics axes: --deadline-s installs a default deadline
    # (propagated to servers, which shed expired work — a terminal
    # deadline outcome, never retried, surfacing as shed /
    # deadline_exceeded counts); --admission-limit installs server-side
    # admission control fed by the same metrics, plus a
    # RetryInterceptor so its transient rejections re-try on later
    # (drained) flights. Either axis puts the metrics in the server
    # chain so shed/rejected counts land in rpc_metrics.
    client_ics = [metrics]
    server_ics = []
    if cfg.deadline_s is not None:
        client_ics.append(rpclib.DeadlineInterceptor(cfg.deadline_s))
        server_ics = [metrics]
    if cfg.admission_limit is not None:
        server_ics = [metrics,
                      rpclib.AdmissionInterceptor(cfg.admission_limit,
                                                  metrics=metrics)]
        client_ics.append(rpclib.RetryInterceptor(max_attempts=4))
    # modeled transports always carry a Tracer (spans cost nothing on
    # the modeled clock and feed the --json phase breakdown); measured
    # transports only trace when asked, so the hot loop stays clean
    tracer = None
    if cfg.trace or getattr(transport, "modeled", False):
        tracer = rpclib.Tracer()
    fabric = rpclib.RpcFabric(
        transport,
        window_bytes=max(4 * 1024 * 1024, (chunks + 1) * per_chunk),
        window_msgs=max(32, chunks + 1),
        client_interceptors=client_ics,
        server_interceptors=server_ics,
        tracer=tracer)
    return fabric, bufs, metrics


def _cluster_projection(st: BenchStats, cfg: BenchConfig, fabric,
                        spec: PayloadSpec, n_chunks: int = 1) -> None:
    """Attach the per-link closed-form throughput of the bound cluster
    (the analytic number a ``--transport cluster`` run must match) as
    the ``cluster`` model projection."""
    if cfg.transport != "cluster":
        return
    from repro.rpc import cluster as cluster_lib
    cl = fabric.transport.cluster
    if any(ep.window is not None for ep in cl.endpoints):
        # endpoint-advertised windows split streams across flights, so
        # the one-flight closed form no longer applies — publish no
        # number rather than one the run is not expected to match
        return
    mode = cfg.resolved_wire_mode
    sizes = list(spec.sizes)
    if st.name == "fully_connected":
        t = cluster_lib.cluster_fc_round_time(cl, sizes, mode=mode)
    elif st.name == "ring":
        t = cluster_lib.cluster_ring_round_time(
            cl, sizes, n_chunks=n_chunks, mode=mode)
    elif st.name == "allreduce":
        t = cluster_lib.cluster_allreduce_time(cl, cfg.algo,
                                               spec.total_bytes,
                                               mode=mode)
    elif st.name == "train_step":
        if cfg.train_mode != "allreduce":
            # no per-link closed form for the sharded-PS step yet —
            # publish no number rather than one the run won't match
            return
        t = cluster_lib.cluster_allreduce_time(
            cl, cfg.algo, _grad_params(cfg, spec) * 4, itemsize=4,
            mode=mode)
        st.model_projection["cluster"] = 1.0 / t
        return
    else:
        t = cluster_lib.cluster_incast_round_time(
            cl, sizes, n_chunks=n_chunks, mode=mode,
            fetch_ratio=cfg.fetch_ratio)
    st.model_projection["cluster"] = st.derived["rpcs_per_round"] / t


def _attach_trace(st: BenchStats, fabric) -> None:
    """Publish the fabric Tracer's per-phase latency breakdown (and the
    tracer itself, for Chrome export) on the stats row."""
    tracer = getattr(fabric, "tracer", None)
    if tracer is None:
        return
    st.tracer = tracer
    st.rpc_phases = tracer.phase_breakdown()


def _fabric_bench(cfg: BenchConfig, exchange, fabric,
                  metrics=None) -> List[float]:
    """Measured-vs-modeled timing protocol shared by the fabric
    families: modeled transports are exact (no warmup loop needed).
    ``metrics`` (the fabric's MetricsInterceptor) is reset after
    warmup so the published percentiles cover only measured
    iterations — never the compile/touch call."""
    if fabric.transport.modeled:
        return [exchange().elapsed_s for _ in range(3)]
    exchange()                                       # compile/touch
    t_end = time.perf_counter() + cfg.warmup_s
    while time.perf_counter() < t_end:
        exchange()
    if metrics is not None:
        metrics.reset()
    times, t_stop = [], time.perf_counter() + cfg.duration_s
    while time.perf_counter() < t_stop or len(times) < 5:
        times.append(exchange().elapsed_s)
    return times


def fully_connected(cfg: BenchConfig) -> BenchStats:
    """Every worker exchanges the payload with every other worker
    through the rpc fabric (paper §2's process architecture, the
    pattern the original three benchmarks never covered)."""
    if cfg.num_workers < 2:
        raise RuntimeError("fully_connected needs --num-workers >= 2")
    from repro import rpc as rpclib
    spec = generate_spec(cfg)
    fabric, bufs, metrics = _make_fabric(cfg, spec, cfg.num_workers,
                                         "fully_connected")
    wire_mode = cfg.resolved_wire_mode

    def exchange():
        return rpclib.fully_connected_exchange(
            fabric, list(spec.sizes), bufs=bufs, wire_mode=wire_mode)

    rpcs = ch.fc_rpcs_per_round(cfg.num_workers)
    with ResourceMonitor() as mon:
        times = _fabric_bench(cfg, exchange, fabric, metrics)
    st = _stats("fully_connected", cfg, spec, times,
                {"rpcs_per_s": rpcs / float(np.mean(times)),
                 "rpcs_per_round": float(rpcs)}, mon.report)
    st.rpc_metrics = metrics.snapshot()
    _attach_trace(st, fabric)
    _cluster_projection(st, cfg, fabric, spec)
    return st


def ring(cfg: BenchConfig) -> BenchStats:
    """Every worker streams cfg.stream_chunks payload chunks to its
    successor on the ring — the rotation schedule of
    channels.ring_schedule, all workers concurrently."""
    if cfg.num_workers < 2:
        raise RuntimeError("ring needs --num-workers >= 2")
    from repro import rpc as rpclib
    spec = generate_spec(cfg)
    n_chunks = max(1, cfg.stream_chunks)
    fabric, bufs, metrics = _make_fabric(cfg, spec, cfg.num_workers,
                                         "ring")
    wire_mode = cfg.resolved_wire_mode

    def exchange():
        return rpclib.ring_exchange(fabric, list(spec.sizes),
                                    n_chunks=n_chunks, bufs=bufs,
                                    wire_mode=wire_mode)

    rpcs = ch.ring_rpcs_per_round(cfg.num_workers, n_chunks)
    with ResourceMonitor() as mon:
        times = _fabric_bench(cfg, exchange, fabric, metrics)
    st = _stats("ring", cfg, spec, times,
                {"rpcs_per_s": rpcs / float(np.mean(times)),
                 "rpcs_per_round": float(rpcs),
                 "chunks_per_stream": float(n_chunks)}, mon.report)
    st.rpc_metrics = metrics.snapshot()
    _attach_trace(st, fabric)
    _cluster_projection(st, cfg, fabric, spec, n_chunks=n_chunks)
    return st


def incast(cfg: BenchConfig) -> BenchStats:
    """cfg.num_workers workers stream cfg.stream_chunks payload chunks
    each into ONE server endpoint, which streams a fetch sized
    ``cfg.fetch_ratio`` of the push payload back per stream (the
    Cori-style parameter-server hotspot: N-way ingress + N-way fetch
    egress on one node, push/fetch asymmetry configurable)."""
    if cfg.num_workers < 1:
        raise RuntimeError("incast needs --num-workers >= 1")
    if cfg.fetch_ratio <= 0:
        raise RuntimeError("incast needs --fetch-ratio > 0")
    from repro import rpc as rpclib
    spec = generate_spec(cfg)
    n_chunks = max(1, cfg.stream_chunks)
    # endpoint 0 is the server; workers are 1..num_workers
    fabric, bufs, metrics = _make_fabric(cfg, spec, cfg.num_workers + 1,
                                         "incast")
    wire_mode = cfg.resolved_wire_mode

    def exchange():
        return rpclib.incast_exchange(fabric, list(spec.sizes),
                                      n_chunks=n_chunks, bufs=bufs,
                                      wire_mode=wire_mode,
                                      fetch_ratio=cfg.fetch_ratio)

    rpcs = ch.incast_rpcs_per_round(cfg.num_workers, n_chunks)
    with ResourceMonitor() as mon:
        times = _fabric_bench(cfg, exchange, fabric, metrics)
    st = _stats("incast", cfg, spec, times,
                {"rpcs_per_s": rpcs / float(np.mean(times)),
                 "rpcs_per_round": float(rpcs),
                 "chunks_per_stream": float(n_chunks),
                 "fetch_ratio": float(cfg.fetch_ratio)}, mon.report)
    st.rpc_metrics = metrics.snapshot()
    _attach_trace(st, fabric)
    _cluster_projection(st, cfg, fabric, spec, n_chunks=n_chunks)
    return st


def allreduce_rpcs_per_round(algo: str, n_workers: int) -> int:
    """Messages one full allreduce moves: ring rotates one chunk per
    worker for 2(n-1) steps, tree sends n-1 reduce + n-1 broadcast
    full payloads, rsag is two (n-1)-wide all-to-all flights."""
    n = n_workers
    if algo == "ring":
        return 2 * n * (n - 1)
    if algo == "tree":
        return 2 * (n - 1)
    if algo == "rsag":
        return 2 * n * (n - 1)
    raise ValueError(f"unknown allreduce algo {algo!r}")


def _grad_params(cfg: BenchConfig, spec: PayloadSpec) -> int:
    """train_step: the synthetic gradient's float32 element count —
    the benchmark payload reinterpreted as a gradient, floored so
    every worker/PS shard holds at least one element."""
    return max(cfg.num_workers, cfg.num_ps, 1, spec.total_bytes // 4)


def _reject_collective(cfg: BenchConfig, family: str) -> None:
    """The collective transport lowers the fixed exchange schedules
    onto device ppermute programs; the collective/train families build
    their own per-step schedules over real host buffers, which has no
    lowering there. Loud error (a SKIPPED sweep cell), like the
    zero-copy gate."""
    if cfg.transport == "collective":
        raise RuntimeError(
            f"{family} does not run on the collective transport; use "
            f"--transport loopback|simulated|cluster")


def allreduce(cfg: BenchConfig) -> BenchStats:
    """One cfg.algo allreduce of the payload across cfg.num_workers
    fabric endpoints (rpc.collectives): modeled transports match the
    netmodel/cluster closed forms exactly; loopback reduces real
    float32 gradients through the measured datapath."""
    if cfg.num_workers < 2:
        raise RuntimeError("allreduce needs --num-workers >= 2")
    _reject_collective(cfg, "allreduce")
    from repro import rpc as rpclib
    if cfg.algo not in rpclib.ALLREDUCE_ALGOS:
        raise RuntimeError(f"unknown --algo {cfg.algo!r}; choose from "
                           f"{', '.join(rpclib.ALLREDUCE_ALGOS)}")
    spec = generate_spec(cfg)
    fabric, _, metrics = _make_fabric(cfg, spec, cfg.num_workers,
                                      "allreduce")
    wire_mode = cfg.resolved_wire_mode
    if cfg.transport == "loopback":
        # measured path: reduce real seeded gradients
        rng = np.random.default_rng(cfg.seed)
        elems = _grad_params(cfg, spec)
        data = [rng.standard_normal(elems).astype(np.float32)
                for _ in range(cfg.num_workers)]

        def exchange():
            return rpclib.allreduce(fabric, cfg.algo,
                                    data=[d.copy() for d in data],
                                    itemsize=4, wire_mode=wire_mode)
    else:
        def exchange():
            return rpclib.allreduce(fabric, cfg.algo, spec.total_bytes,
                                    wire_mode=wire_mode)

    rpcs = allreduce_rpcs_per_round(cfg.algo, cfg.num_workers)
    with ResourceMonitor() as mon:
        times = _fabric_bench(cfg, exchange, fabric, metrics)
    st = _stats("allreduce", cfg, spec, times,
                {"rpcs_per_s": rpcs / float(np.mean(times)),
                 "rpcs_per_round": float(rpcs),
                 "algo_steps": float(2 * (cfg.num_workers - 1)
                                     if cfg.algo == "ring" else
                                     2 * max(1, (cfg.num_workers - 1)
                                             .bit_length())
                                     if cfg.algo == "tree" else 2)},
                mon.report)
    st.rpc_metrics = metrics.snapshot()
    _attach_trace(st, fabric)
    _cluster_projection(st, cfg, fabric, spec)
    return st


def train_step(cfg: BenchConfig) -> BenchStats:
    """One data-parallel SGD step per iteration
    (train.fabric_train.FabricTrainStep): the payload reinterpreted as
    a float32 gradient, synchronized through sharded parameter servers
    (cfg.train_mode = "ps": endpoints = num_ps + num_workers) or a
    cfg.algo allreduce (endpoints = num_workers). Sweeping workers
    across both train modes locates the PS -> allreduce crossover."""
    _reject_collective(cfg, "train_step")
    if cfg.train_mode not in ("ps", "allreduce"):
        raise RuntimeError(f"unknown --train-mode {cfg.train_mode!r}; "
                           f"choose from ps, allreduce")
    if cfg.train_mode == "ps":
        if cfg.num_ps < 1 or cfg.num_workers < 1:
            raise RuntimeError("train_step/ps needs --num-ps >= 1 and "
                               "--num-workers >= 1")
        n_endpoints = cfg.num_ps + cfg.num_workers
    else:
        if cfg.num_workers < 2:
            raise RuntimeError(
                "train_step/allreduce needs --num-workers >= 2")
        n_endpoints = cfg.num_workers
    from repro.train.fabric_train import (FabricTrainConfig,
                                          FabricTrainStep)
    spec = generate_spec(cfg)
    fabric, _, metrics = _make_fabric(cfg, spec, n_endpoints,
                                      "train_step")
    n_params = _grad_params(cfg, spec)
    trainer = FabricTrainStep(fabric, FabricTrainConfig(
        mode=cfg.train_mode, algo=cfg.algo, n_ps=cfg.num_ps,
        n_params=n_params, seed=cfg.seed,
        wire_mode=cfg.resolved_wire_mode))
    with ResourceMonitor() as mon:
        times = _fabric_bench(cfg, trainer.step, fabric, metrics)
    st = _stats("train_step", cfg, spec, times,
                {"steps_per_s": 1.0 / float(np.mean(times)),
                 "grad_MB": n_params * 4 / 1e6,
                 "steps_run": float(trainer.step_count)}, mon.report)
    st.rpc_metrics = metrics.snapshot()
    _attach_trace(st, fabric)
    _cluster_projection(st, cfg, fabric, spec)
    return st


BENCHMARKS: Dict[str, Callable[[BenchConfig], BenchStats]] = {
    "p2p_latency": p2p_latency,
    "p2p_bandwidth": p2p_bandwidth,
    "ps_throughput": ps_throughput,
    "fully_connected": fully_connected,
    "ring": ring,
    "incast": incast,
    "allreduce": allreduce,
    "train_step": train_step,
}

#: benchmarks that run over the rpc fabric (honor cfg.transport)
FABRIC_BENCHMARKS = ("fully_connected", "ring", "incast", "allreduce",
                     "train_step")


def run(cfg: BenchConfig) -> BenchStats:
    return BENCHMARKS[cfg.benchmark](cfg)


# ---------------------------------------------------------------------------
# Perf-baseline telemetry: deterministic modeled numbers for all six
# benchmark families, committed to benchmarks/BENCH_fabric.json and
# re-derived in CI. The paper families use the netmodel closed forms;
# the fabric families run the simulated transport (exact on the modeled
# clock) — so a fresh run diffs clean against the committed file unless
# the pricing model or the fabric's behavior actually changed.

BASELINE_SCHEMA = 3

#: the original three fabric exchange families — the generic baseline
#: rows; allreduce/train_step get per-algo / per-train-mode rows
_BASELINE_EXCHANGES = ("fully_connected", "ring", "incast")

#: the committed PS -> allreduce crossover sweep (train_step family):
#: one 64 KiB gradient, 2 PS, ring allreduce, eth40g — the worker
#: band where the paper's PS layout wins and the point where the
#: collective takes over for good
CROSSOVER_GRAD_BYTES = 65536
CROSSOVER_WORKERS = (8, 16, 32, 64, 128)


def collect_train_crossover(network: str = "eth40g",
                            num_ps: int = 2) -> dict:
    """Modeled train_step round times, PS vs ring allreduce, along the
    workers axis (exact closed forms; the simulated transport matches
    them bit-for-bit, held by tests/test_fabric_train.py)."""
    from repro.train.fabric_train import train_step_time
    net = NETWORKS[network]
    points = []
    for w in CROSSOVER_WORKERS:
        ps = train_step_time(net, "ps", CROSSOVER_GRAD_BYTES, w,
                             n_ps=num_ps)
        ar = train_step_time(net, "allreduce", CROSSOVER_GRAD_BYTES, w,
                             algo="ring")
        points.append({"workers": w, "ps_s": ps, "allreduce_s": ar,
                       "winner": "ps" if ps < ar else "allreduce"})
    wins_from = None
    for p in reversed(points):
        if p["winner"] != "allreduce":
            break
        wins_from = p["workers"]
    return {"network": network, "num_ps": num_ps, "algo": "ring",
            "grad_bytes": CROSSOVER_GRAD_BYTES, "points": points,
            "allreduce_wins_from": wins_from}

#: measured flush-loop hot-path numbers (dev container, PR 9): the
#: zero-copy datapath work profiled and trimmed the numpy pack path
#: (preallocated output instead of per-buffer np.pad + np.concatenate),
#: the uint8 coercion fast path, and SimulatedTransport.deliver's
#: per-message dict churn (one accumulator dict instead of four).
#: Informational — check_baseline compares only families/wire_modes.
PERF_NOTES = {
    "encode_serialized_us_per_frame": {"before": 117.7, "after": 18.2},
    "simulated_deliver_64msg_us_per_flight": {"before": 445.7,
                                              "after": 112.0},
    "loopback_fc_serialized_ms_per_round": {"before": 8.26,
                                            "after": 5.4},
    "loopback_fc_scatter_gather_ms_per_round": {"before": 6.49,
                                                "after": 5.0},
}


def collect_baseline(network: str = "eth40g", num_ps: int = 2,
                     num_workers: int = 4, iovec_count: int = 10,
                     scheme: str = "uniform", mode: str = "non_serialized",
                     stream_chunks: int = 4, fetch_ratio: float = 1.0,
                     seed: int = 0) -> dict:
    """Modeled round time + throughput of every benchmark family.

    The returned dict records the exact config it was collected under,
    so ``check_baseline`` can re-run the identical configuration.
    """
    config = dict(network=network, num_ps=num_ps, num_workers=num_workers,
                  iovec_count=iovec_count, scheme=scheme, mode=mode,
                  stream_chunks=stream_chunks, fetch_ratio=fetch_ratio,
                  seed=seed)
    base = BenchConfig(num_ps=num_ps, num_workers=num_workers, mode=mode,
                       scheme=scheme, iovec_count=iovec_count, seed=seed,
                       network=network, transport="simulated",
                       stream_chunks=stream_chunks, fetch_ratio=fetch_ratio)
    spec = generate_spec(base)
    net = NETWORKS[network]
    serialized = mode == "serialized"
    rtt = net.rtt(spec, serialized=serialized)
    mbps = net.bandwidth(spec, serialized=serialized)
    families: Dict[str, dict] = {
        "p2p_latency": {"round_time_s": rtt, "throughput": 1.0 / rtt,
                        "metric": "rounds_per_s"},
        "p2p_bandwidth": {
            "round_time_s": spec.total_bytes / (mbps * 1e6),
            "throughput": mbps, "metric": "MBps"},
        "ps_throughput": {
            "round_time_s": net.ps_round_time(spec, num_ps, num_workers,
                                              serialized=serialized),
            "throughput": net.ps_throughput(spec, num_ps, num_workers,
                                            serialized=serialized),
            "metric": "rpcs_per_s"},
    }
    for fam in _BASELINE_EXCHANGES:
        st = run(replace(base, benchmark=fam))
        families[fam] = {"round_time_s": st.mean_s,
                         "throughput": st.derived["rpcs_per_s"],
                         "metric": "rpcs_per_s"}
    for algo in ALLREDUCE_ALGOS:
        st = run(replace(base, benchmark="allreduce", algo=algo))
        families[f"allreduce_{algo}"] = {
            "round_time_s": st.mean_s,
            "throughput": st.derived["rpcs_per_s"],
            "metric": "rpcs_per_s"}
    for tm in ("ps", "allreduce"):
        st = run(replace(base, benchmark="train_step", train_mode=tm))
        families[f"train_step_{tm}"] = {
            "round_time_s": st.mean_s,
            "throughput": st.derived["steps_per_s"],
            "metric": "steps_per_s"}
    # per-wire-mode coverage (schema 2): the paper's three-way
    # Ethernet/IPoIB/RDMA analogue as serialized / scatter_gather /
    # zero_copy — closed forms for the paper families, exact simulated
    # runs for the fabric families
    wire_modes: Dict[str, dict] = {}
    for wm in WIRE_MODES:
        mrtt = net.rtt(spec, mode=wm)
        mbw = net.bandwidth(spec, mode=wm)
        entry: Dict[str, dict] = {
            "p2p_latency": {"round_time_s": mrtt,
                            "throughput": 1.0 / mrtt,
                            "metric": "rounds_per_s"},
            "p2p_bandwidth": {
                "round_time_s": spec.total_bytes / (mbw * 1e6),
                "throughput": mbw, "metric": "MBps"},
            "ps_throughput": {
                "round_time_s": net.ps_round_time(spec, num_ps,
                                                  num_workers, mode=wm),
                "throughput": net.ps_throughput(spec, num_ps,
                                                num_workers, mode=wm),
                "metric": "rpcs_per_s"},
        }
        for fam in _BASELINE_EXCHANGES:
            st = run(replace(base, benchmark=fam, wire_mode=wm))
            entry[fam] = {"round_time_s": st.mean_s,
                          "throughput": st.derived["rpcs_per_s"],
                          "metric": "rpcs_per_s"}
        for algo in ALLREDUCE_ALGOS:
            st = run(replace(base, benchmark="allreduce", algo=algo,
                             wire_mode=wm))
            entry[f"allreduce_{algo}"] = {
                "round_time_s": st.mean_s,
                "throughput": st.derived["rpcs_per_s"],
                "metric": "rpcs_per_s"}
        for tm in ("ps", "allreduce"):
            st = run(replace(base, benchmark="train_step",
                             train_mode=tm, wire_mode=wm))
            entry[f"train_step_{tm}"] = {
                "round_time_s": st.mean_s,
                "throughput": st.derived["steps_per_s"],
                "metric": "steps_per_s"}
        wire_modes[wm] = entry
    return {"schema": BASELINE_SCHEMA, "config": config,
            "families": families, "wire_modes": wire_modes,
            "train_crossover": collect_train_crossover(network=network,
                                                       num_ps=num_ps),
            "perf_notes": PERF_NOTES}


def check_baseline(baseline: dict, rel_tol: float = 0.01) -> List[str]:
    """Diff a committed baseline dict against a fresh collection under
    its recorded config. Returns human-readable drift lines (empty =
    the run still matches within ``rel_tol`` relative tolerance)."""
    fresh = collect_baseline(**baseline.get("config", {}))
    problems: List[str] = []

    def diff(want: dict, got, label: str) -> None:
        if got is None:
            problems.append(f"{label}: family missing from fresh run")
            return
        for key in ("round_time_s", "throughput"):
            a, b = float(want[key]), float(got[key])
            rel = abs(b - a) / max(abs(a), 1e-30)
            if rel > rel_tol:
                problems.append(
                    f"{label}.{key}: baseline {a:.6g} vs fresh {b:.6g} "
                    f"(rel drift {rel:.3%} > tol {rel_tol:.3%})")

    for fam, want in baseline.get("families", {}).items():
        diff(want, fresh["families"].get(fam), fam)
    for wm, fams in baseline.get("wire_modes", {}).items():
        fresh_wm = fresh["wire_modes"].get(wm, {})
        for fam, want in fams.items():
            diff(want, fresh_wm.get(fam), f"{wm}/{fam}")
    cross = baseline.get("train_crossover")
    if cross is not None:
        got = collect_train_crossover(network=cross["network"],
                                      num_ps=cross["num_ps"])
        if got["allreduce_wins_from"] != cross["allreduce_wins_from"]:
            problems.append(
                f"train_crossover.allreduce_wins_from: baseline "
                f"{cross['allreduce_wins_from']} vs fresh "
                f"{got['allreduce_wins_from']}")
        fresh_pts = {p["workers"]: p for p in got["points"]}
        for p in cross["points"]:
            q = fresh_pts.get(p["workers"])
            label = f"train_crossover.w{p['workers']}"
            if q is None:
                problems.append(f"{label}: missing from fresh run")
                continue
            if q["winner"] != p["winner"]:
                problems.append(f"{label}.winner: baseline "
                                f"{p['winner']} vs fresh {q['winner']}")
            for key in ("ps_s", "allreduce_s"):
                a, b = float(p[key]), float(q[key])
                rel = abs(b - a) / max(abs(a), 1e-30)
                if rel > rel_tol:
                    problems.append(
                        f"{label}.{key}: baseline {a:.6g} vs fresh "
                        f"{b:.6g} (rel drift {rel:.3%} > tol "
                        f"{rel_tol:.3%})")
    return problems
