"""The TF-gRPC-Bench micro-benchmarks (paper §3.2) plus the rpc-fabric
fully-connected family, as drivers over repro.core.channels and
repro.rpc, with the paper's warmup/duration protocol and the netmodel
projection alongside the measured host numbers.

  TF-gRPC-P2P-Latency    -> p2p_latency()
  TF-gRPC-P2P-Bandwidth  -> p2p_bandwidth()
  TF-gRPC-PS-Throughput  -> ps_throughput()
  fully_connected        -> fully_connected()   (rpc fabric; transport =
                            collective | loopback | simulated)
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.configs.tfgrpc_bench import BenchConfig
from repro.core import channels as ch
from repro.core.netmodel import NETWORKS
from repro.core.payload import PayloadSpec, generate_spec
from repro.core.resource import ResourceMonitor, ResourceReport


@dataclass
class BenchStats:
    name: str
    config: BenchConfig
    spec: PayloadSpec
    n_iters: int
    mean_s: float
    p50_s: float
    p95_s: float
    min_s: float
    max_s: float
    derived: Dict[str, float] = field(default_factory=dict)
    resources: Optional[ResourceReport] = None
    model_projection: Dict[str, float] = field(default_factory=dict)

    def row(self) -> str:
        d = ",".join(f"{k}={v:.6g}" for k, v in self.derived.items())
        return (f"{self.name},{self.mean_s*1e6:.2f},{d}")


def _timed_loop(fn: Callable, args, warmup_s: float, duration_s: float,
                min_iters: int = 5) -> List[float]:
    """Paper protocol: warm up for warmup_s, then measure for duration_s."""
    out = fn(*args)
    jax.block_until_ready(out)
    t_end = time.perf_counter() + warmup_s
    while time.perf_counter() < t_end:
        jax.block_until_ready(fn(*args))
    times: List[float] = []
    t_stop = time.perf_counter() + duration_s
    while time.perf_counter() < t_stop or len(times) < min_iters:
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return times


def _stats(name, cfg, spec, times, derived, res=None) -> BenchStats:
    a = np.asarray(times)
    st = BenchStats(
        name=name, config=cfg, spec=spec, n_iters=len(a),
        mean_s=float(a.mean()), p50_s=float(np.percentile(a, 50)),
        p95_s=float(np.percentile(a, 95)), min_s=float(a.min()),
        max_s=float(a.max()), derived=derived, resources=res)
    for net_name, net in NETWORKS.items():
        serialized = cfg.mode == "serialized"
        if name == "p2p_latency":
            st.model_projection[net_name] = net.rtt(spec,
                                                    serialized=serialized)
        elif name == "p2p_bandwidth":
            st.model_projection[net_name] = net.bandwidth(
                spec, serialized=serialized)
        elif name == "fully_connected":
            st.model_projection[net_name] = net.fc_throughput(
                spec, cfg.num_workers, serialized=serialized)
        else:
            st.model_projection[net_name] = net.ps_throughput(
                spec, cfg.num_ps, cfg.num_workers, serialized=serialized)
    return st


def _prep(cfg: BenchConfig, need: int):
    mesh = ch.make_net_mesh()
    n = mesh.shape[ch.AXIS]
    if n < need:
        raise RuntimeError(
            f"{cfg.benchmark} needs >= {need} devices, have {n}; run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=<n>")
    spec = generate_spec(cfg)
    bufs = ch.device_payload(mesh, spec, seed=cfg.seed)
    return mesh, spec, bufs


def p2p_latency(cfg: BenchConfig) -> BenchStats:
    mesh, spec, bufs = _prep(cfg, 2)
    fn = ch.p2p_echo_fn(mesh, spec.n_buffers,
                        serialized=(cfg.mode == "serialized"))
    with ResourceMonitor() as mon:
        times = _timed_loop(fn, bufs, cfg.warmup_s, cfg.duration_s)
    return _stats("p2p_latency", cfg, spec, times,
                  {"rtt_us": float(np.mean(times)) * 1e6}, mon.report)


def p2p_bandwidth(cfg: BenchConfig) -> BenchStats:
    mesh, spec, bufs = _prep(cfg, 2)
    fn = ch.p2p_send_fn(mesh, spec.n_buffers,
                        serialized=(cfg.mode == "serialized"))
    with ResourceMonitor() as mon:
        times = _timed_loop(fn, bufs, cfg.warmup_s, cfg.duration_s)
    mbps = spec.total_bytes / np.mean(times) / 1e6
    return _stats("p2p_bandwidth", cfg, spec, times,
                  {"MBps": float(mbps)}, mon.report)


def ps_throughput(cfg: BenchConfig) -> BenchStats:
    need = cfg.num_ps + cfg.num_workers
    mesh, spec, bufs = _prep(cfg, need)
    fn = ch.ps_round_fn(mesh, spec.n_buffers, cfg.num_ps, cfg.num_workers,
                        serialized=(cfg.mode == "serialized"))
    with ResourceMonitor() as mon:
        times = _timed_loop(fn, bufs, cfg.warmup_s, cfg.duration_s)
    rpcs = ch.rpcs_per_round(cfg.num_ps, cfg.num_workers)
    return _stats("ps_throughput", cfg, spec, times,
                  {"rpcs_per_s": rpcs / float(np.mean(times))}, mon.report)


def _make_fc_fabric(cfg: BenchConfig, spec: PayloadSpec):
    """Build the rpc fabric + per-iteration exchange closure for the
    fully_connected benchmark under cfg.transport."""
    from repro import rpc as rpclib
    from repro.core.netmodel import NETWORKS
    from repro.core.payload import materialize

    n = cfg.num_workers
    serialized = cfg.mode == "serialized"
    bufs = None
    if cfg.transport == "collective":
        mesh = ch.make_net_mesh()
        if mesh.shape[ch.AXIS] < n:
            raise RuntimeError(
                f"fully_connected/collective needs >= {n} devices, have "
                f"{mesh.shape[ch.AXIS]}; run under "
                f"XLA_FLAGS=--xla_force_host_platform_device_count=<n>")
        transport = rpclib.CollectiveTransport(
            mesh, spec, serialized=serialized, n_endpoints=n,
            seed=cfg.seed)
    elif cfg.transport == "loopback":
        transport = rpclib.LoopbackTransport(n)
        bufs = materialize(spec, seed=cfg.seed)
    elif cfg.transport == "simulated":
        net_name = cfg.network or "eth40g"
        if net_name not in NETWORKS:
            raise ValueError(f"unknown --network {net_name!r}; choose "
                             f"from {sorted(NETWORKS)}")
        transport = rpclib.SimulatedTransport(n, NETWORKS[net_name])
    else:
        raise ValueError(f"unknown transport {cfg.transport!r}")
    fabric = rpclib.RpcFabric(transport)

    def exchange() -> "rpclib.FlightReport":
        return rpclib.fully_connected_exchange(fabric, list(spec.sizes),
                                               bufs=bufs,
                                               serialized=serialized)

    return fabric, exchange


def fully_connected(cfg: BenchConfig) -> BenchStats:
    """Every worker exchanges the payload with every other worker
    through the rpc fabric (paper §2's process architecture, the
    pattern the original three benchmarks never covered)."""
    if cfg.num_workers < 2:
        raise RuntimeError("fully_connected needs --num-workers >= 2")
    spec = generate_spec(cfg)
    fabric, exchange = _make_fc_fabric(cfg, spec)
    rpcs = ch.fc_rpcs_per_round(cfg.num_workers)
    with ResourceMonitor() as mon:
        if fabric.transport.modeled:
            # analytic transport: one exchange is exact; no warmup loop
            times = [exchange().elapsed_s for _ in range(3)]
        else:
            exchange()                                   # compile/touch
            t_end = time.perf_counter() + cfg.warmup_s
            while time.perf_counter() < t_end:
                exchange()
            times, t_stop = [], time.perf_counter() + cfg.duration_s
            while time.perf_counter() < t_stop or len(times) < 5:
                times.append(exchange().elapsed_s)
    return _stats("fully_connected", cfg, spec, times,
                  {"rpcs_per_s": rpcs / float(np.mean(times)),
                   "rpcs_per_round": float(rpcs)}, mon.report)


def run(cfg: BenchConfig) -> BenchStats:
    return {"p2p_latency": p2p_latency,
            "p2p_bandwidth": p2p_bandwidth,
            "ps_throughput": ps_throughput,
            "fully_connected": fully_connected}[cfg.benchmark](cfg)
