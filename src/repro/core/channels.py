"""Communication channels: the TPU/JAX realization of the paper's gRPC
primitives (DESIGN.md §2).

 - P2P echo / one-way send  -> ``jax.lax.ppermute`` on a 1-D device axis
 - PS pull (variable fetch) -> multicast ppermute PS -> every worker
 - PS push (tensor update)  -> worker -> every PS (multicast ppermute)

Payloads are lists of uint8 buffers (iovec analogue), shape (N, size)
sharded over the ``net`` axis so each device owns one row.
Non-serialized mode issues one collective per buffer (scatter/gather
semantics); serialized mode packs all buffers into one contiguous
transfer first (repro.core.serialization).

These channels run for real on host devices (benchmarks force
``--xla_force_host_platform_device_count``) — wall-clock numbers are
host-platform, the *relative* trends + the netmodel give the projection
(EXPERIMENTS.md §Comm).
"""
from __future__ import annotations

import functools
from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import serialization as ser
from repro.core.compat import shard_map_unchecked
from repro.core.payload import PayloadSpec, materialize

AXIS = "net"


def make_net_mesh(n_devices: int = 0) -> Mesh:
    devs = jax.devices()
    n = n_devices or len(devs)
    assert n <= len(devs), (n, len(devs))
    return jax.make_mesh((n,), (AXIS,), devices=devs[:n])


def device_payload(mesh: Mesh, spec: PayloadSpec, *, seed: int = 0
                   ) -> List[jax.Array]:
    """Materialize one payload row per device: list of (N, size) uint8."""
    n = mesh.shape[AXIS]
    host = materialize(spec, seed=seed, tpu_align=True)
    sharding = NamedSharding(mesh, P(AXIS))
    return [jax.device_put(np.broadcast_to(b, (n,) + b.shape).copy(),
                           sharding) for b in host]


# ---------------------------------------------------------------------------
# P2P
# ---------------------------------------------------------------------------

def _shmap(mesh, fn, n_in):
    return shard_map_unchecked(fn, mesh=mesh,
                               in_specs=tuple([P(AXIS)] * n_in),
                               out_specs=P(AXIS))


def permute_rounds_fn(mesh: Mesh, n_buffers: int,
                      rounds: Sequence[Sequence[Tuple[int, int]]],
                      serialized: bool = False) -> Callable:
    """Run a sequence of ppermute rounds over one payload: the common
    lowering every channel (and the rpc collective transport) compiles
    to. One collective per buffer per round (non-serialized) or
    pack -> one collective per round -> unpack (serialized)."""
    rounds = [list(r) for r in rounds]

    def go(*bufs):
        if serialized:
            packed, meta = ser.pack(bufs)
            for perm in rounds:
                packed = jax.lax.ppermute(packed, AXIS, perm)
            return tuple(ser.unpack(packed, meta))
        out = []
        for b in bufs:
            for perm in rounds:
                b = jax.lax.ppermute(b, AXIS, perm)
            out.append(b)
        return tuple(out)

    return jax.jit(_shmap(mesh, go, n_buffers))


def p2p_echo_fn(mesh: Mesh, n_buffers: int, src: int = 0, dst: int = 1,
                serialized: bool = False) -> Callable:
    """Round trip src -> dst -> src."""
    return permute_rounds_fn(mesh, n_buffers, [[(src, dst)], [(dst, src)]],
                             serialized=serialized)


def p2p_send_fn(mesh: Mesh, n_buffers: int, src: int = 0, dst: int = 1,
                serialized: bool = False) -> Callable:
    """One-way payload + 64-byte ack back (bandwidth benchmark)."""
    fwd, bwd = [(src, dst)], [(dst, src)]

    def send(*bufs):
        if serialized:
            packed, meta = ser.pack(bufs)
            packed = jax.lax.ppermute(packed, AXIS, fwd)
            ack = jax.lax.ppermute(packed[..., :64], AXIS, bwd)
            return (packed, ack)
        out = [jax.lax.ppermute(b, AXIS, fwd) for b in bufs]
        ack = jax.lax.ppermute(out[0][..., :64], AXIS, bwd)
        return tuple(out) + (ack,)

    return jax.jit(_shmap(mesh, send, n_buffers))


# ---------------------------------------------------------------------------
# Parameter-server round
# ---------------------------------------------------------------------------

def bipartite_schedule(srcs: Sequence[int], dsts: Sequence[int]
                       ) -> List[List[Tuple[int, int]]]:
    """Edge-color K_{|srcs|,|dsts|}: a minimal sequence of ppermute rounds
    (each with unique sources AND destinations) covering every (src, dst)
    pair exactly once. Rounds = max(|srcs|, |dsts|)."""
    m, n = len(srcs), len(dsts)
    rounds = []
    if m <= n:
        for r in range(n):
            rounds.append([(srcs[i], dsts[(i + r) % n]) for i in range(m)])
    else:
        for r in range(m):
            rounds.append([(srcs[(j + r) % m], dsts[j]) for j in range(n)])
    return rounds


def ps_round_fn(mesh: Mesh, n_buffers: int, n_ps: int, n_workers: int,
                serialized: bool = False) -> Callable:
    """One PS round on devices [0..n_ps) = PS, [n_ps..n_ps+n_workers) =
    workers.

    pull: every PS sends its variable shard to every worker (the
          rendezvous'd tensor-fetch response), n_ps x n_workers messages
    push: every worker sends its update to every PS, n_workers x n_ps
          messages

    ppermute requires unique sources and destinations per collective, so
    the all-pairs exchange is scheduled as a round-robin edge coloring —
    which also matches the per-NIC serialization the netmodel assumes.
    """
    ps_ids = list(range(n_ps))
    w_ids = list(range(n_ps, n_ps + n_workers))
    assert n_ps + n_workers <= mesh.shape[AXIS]
    rounds = bipartite_schedule(ps_ids, w_ids) \
        + bipartite_schedule(w_ids, ps_ids)
    return permute_rounds_fn(mesh, n_buffers, rounds,
                             serialized=serialized)


def rpcs_per_round(n_ps: int, n_workers: int) -> int:
    """The paper counts one RPC per worker x PS interaction per round."""
    return n_ps * n_workers


# ---------------------------------------------------------------------------
# Fully-connected exchange (paper §2 process architecture: every worker
# talks to every other worker)
# ---------------------------------------------------------------------------

def all_to_all_schedule(n: int) -> List[List[Tuple[int, int]]]:
    """Round-robin schedule of the complete digraph K_n: n-1 rounds of
    shift-by-r permutations, each with unique sources and destinations,
    covering every ordered (src, dst) pair with src != dst exactly
    once."""
    assert n >= 2, n
    return [[(i, (i + r) % n) for i in range(n)] for r in range(1, n)]


def fully_connected_fn(mesh: Mesh, n_buffers: int, n_workers: int,
                       serialized: bool = False) -> Callable:
    """One full exchange: every endpoint sends the payload to every
    other endpoint (n_workers * (n_workers - 1) RPCs)."""
    return permute_rounds_fn(mesh, n_buffers,
                             all_to_all_schedule(n_workers),
                             serialized=serialized)


def fc_rpcs_per_round(n_workers: int) -> int:
    return n_workers * (n_workers - 1)


# ---------------------------------------------------------------------------
# Ring / incast streaming families (the rpc fabric's two stream-shaped
# traffic patterns; the rpc collective transport recovers these exact
# rounds from its greedy edge coloring)
# ---------------------------------------------------------------------------

def ring_schedule(n: int, n_chunks: int = 1
                  ) -> List[List[Tuple[int, int]]]:
    """Rotation schedule for a chunked ring stream: ``n_chunks`` rounds
    of the successor permutation i -> (i+1) % n. Every round is a full
    permutation (unique sources AND destinations), so a ring moves one
    chunk per worker per round regardless of n — including n == 2,
    where the round degenerates to the swap (0,1),(1,0)."""
    assert n >= 2, n
    assert n_chunks >= 1, n_chunks
    perm = [(i, (i + 1) % n) for i in range(n)]
    return [list(perm) for _ in range(n_chunks)]


def incast_schedule(n_workers: int, *, server: int = 0,
                    n_chunks: int = 1) -> List[List[Tuple[int, int]]]:
    """Serialized incast rounds: workers 1..n_workers each stream
    ``n_chunks`` chunks into one server endpoint. A single destination
    admits one message per round (the ppermute / single-port
    constraint), so the schedule is n_workers * n_chunks singleton
    rounds, chunk-major. ``n_workers == 1`` degenerates to a plain
    chunked P2P send."""
    assert n_workers >= 1, n_workers
    assert n_chunks >= 1, n_chunks
    workers = [w for w in range(n_workers + 1) if w != server][:n_workers]
    return [[(w, server)] for _ in range(n_chunks) for w in workers]


def ring_fn(mesh: Mesh, n_buffers: int, n_workers: int, *,
            n_chunks: int = 1, serialized: bool = False) -> Callable:
    """One chunked ring pass: every worker streams to its successor."""
    return permute_rounds_fn(mesh, n_buffers,
                             ring_schedule(n_workers, n_chunks),
                             serialized=serialized)


def ring_rpcs_per_round(n_workers: int, n_chunks: int = 1) -> int:
    return n_workers * n_chunks


def incast_rpcs_per_round(n_workers: int, n_chunks: int = 1) -> int:
    return n_workers * n_chunks


# ---------------------------------------------------------------------------
# Collective channels (the SPMD-native PS: FSDP pull/push, DESIGN §3.1)
# ---------------------------------------------------------------------------

def fsdp_pull_push_fn(mesh: Mesh, n_buffers: int) -> Callable:
    """all_gather (pull the full variable from its PS shards) followed by
    psum_scatter (push: reduce updates back onto the shards). This is the
    exact primitive pair GSPMD emits for our fsdp/ps_mode training; the
    suite measures it with model-free payloads."""

    def step(*bufs):
        outs = []
        for b in bufs:
            full = jax.lax.all_gather(b, AXIS, axis=0, tiled=True)
            upd = full.astype(jnp.float32) * 1.000001
            outs.append(jax.lax.psum_scatter(upd, AXIS, scatter_dimension=0,
                                             tiled=True).astype(b.dtype))
        return tuple(outs)

    return jax.jit(_shmap(mesh, step, n_buffers))
