"""jax version compatibility shims.

The container pins jax 0.4.x, where ``shard_map`` lives in
``jax.experimental.shard_map`` and the replication-check kwarg is
``check_rep``; on jax >= 0.6 it is ``jax.shard_map`` with ``check_vma``.
"""
from __future__ import annotations

import functools

import jax


def shard_map_unchecked(fn=None, *, mesh, in_specs, out_specs):
    """``shard_map`` with replication checking off, across jax versions.
    With ``fn`` omitted, returns a decorator."""
    if hasattr(jax, "shard_map"):
        sm = functools.partial(jax.shard_map, mesh=mesh,
                               in_specs=in_specs, out_specs=out_specs,
                               check_vma=False)
    else:
        from jax.experimental.shard_map import shard_map
        sm = functools.partial(shard_map, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs, check_rep=False)
    return sm if fn is None else sm(fn)
