"""Alpha-beta network models (latency-bandwidth) for the interconnects
the paper evaluates, plus the TPU fabrics this framework targets.

The container has no IB/RoCE NICs (and no TPU), so absolute wall-clock
numbers for the paper's clusters come from these models; the paper's
*measured ratios* (its headline claims) are the calibration targets:

  fig8  (A, skew,   latency):    RDMA -59% vs 40GbE, -56% vs IPoIB-EDR
  fig9  (B, skew,   latency):    RDMA -78% vs 10GbE, -69% vs IPoIB-FDR,
                                 IPoIB-FDR -27% vs 10GbE
  fig11 (A, skew,   bandwidth):  RDMA 2.14x IPoIB-EDR
  fig12 (B, skew,   bandwidth):  RDMA 3.2x  IPoIB-FDR
  fig13 (A, uniform,throughput): RDMA 4.1x 40GbE, 3.43x IPoIB-EDR
  fig14 (B, uniform,throughput): RDMA 5.9x 10GbE

Constants below were fitted offline (benchmarks/calibrate.py) to land
within ~12% of every ratio simultaneously; tests/test_netmodel.py holds
that tolerance. Message cost: t = alpha + bytes/beta per message, plus a
per-RPC processing overhead on the receiver (rpc_overhead) — the gRPC
core cost the paper isolates.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Sequence, Tuple

from repro.core.payload import PayloadSpec

#: the three wire modes, in paper order (Ethernet/IPoIB/RDMA analogue).
#: Must equal repro.rpc.framing.WIRE_MODES (pinned by tests) — defined
#: here too so the model layer never imports the rpc fabric.
WIRE_MODES = ("serialized", "scatter_gather", "zero_copy")


#: bytes of the little-endian int64 source tag prepended to every
#: reduce-scatter/allgather message (rsag needs source attribution for
#: a deterministic summation order; ring/tree infer it from topology)
ALLREDUCE_TAG_BYTES = 8

#: the allreduce algorithms, CLI order (``bench_comm --algo``)
ALLREDUCE_ALGOS = ("ring", "tree", "rsag")


def allreduce_chunk_sizes(total_bytes: int, n_workers: int, *,
                          itemsize: int = 1) -> Tuple[int, ...]:
    """Balanced contiguous partition of a ``total_bytes`` gradient into
    ``n_workers`` chunks on element (``itemsize``) boundaries: the first
    ``elems % n`` chunks get one extra element. Shared by the collective
    drivers and the closed forms below — exactness by construction."""
    if n_workers < 1:
        raise ValueError(f"n_workers must be >= 1, got {n_workers}")
    if itemsize < 1:
        raise ValueError(f"itemsize must be >= 1, got {itemsize}")
    if total_bytes < 0 or total_bytes % itemsize:
        raise ValueError(
            f"total_bytes ({total_bytes}) must be a non-negative "
            f"multiple of itemsize ({itemsize})")
    elems = total_bytes // itemsize
    base, rem = divmod(elems, n_workers)
    return tuple((base + (1 if c < rem else 0)) * itemsize
                 for c in range(n_workers))


def ring_allreduce_send_chunk(worker: int, step: int, n_workers: int
                              ) -> int:
    """The chunk index worker ``worker`` sends to its successor at ring
    step ``step``: steps ``0..n-2`` are the reduce-scatter rotation
    (chunk ``(i - s) % n``), steps ``n-1..2n-3`` the allgather rotation
    of the fully reduced chunks (chunk ``(i + 1 - t) % n``)."""
    n = n_workers
    if not 0 <= step < 2 * (n - 1):
        raise ValueError(f"step {step} outside ring schedule "
                         f"0..{2 * (n - 1) - 1}")
    if step < n - 1:
        return (worker - step) % n
    return (worker + 1 - (step - (n - 1))) % n


def tree_reduce_rounds(n_workers: int
                       ) -> Tuple[Tuple[Tuple[int, int], ...], ...]:
    """Binomial-tree reduce schedule: ``ceil(log2 n)`` rounds of
    disjoint (sender, receiver) pairs; round ``r`` pairs ``i + 2^r ->
    i`` for every ``i`` divisible by ``2^(r+1)`` with the sender in
    range. The broadcast half replays these rounds reversed with the
    pairs flipped."""
    rounds = []
    r = 1
    while r < n_workers:
        rounds.append(tuple((i + r, i) for i in range(0, n_workers, 2 * r)
                            if i + r < n_workers))
        r *= 2
    return tuple(rounds)


def resolve_wire_mode(serialized: bool = False,
                      mode: "str | None" = None) -> str:
    """Resolve the (legacy ``serialized`` bool, explicit ``mode``) pair
    every closed form accepts: an explicit mode wins, else the bool
    picks serialized vs scatter-gather."""
    if mode is None:
        return "serialized" if serialized else "scatter_gather"
    if mode not in WIRE_MODES:
        raise ValueError(f"unknown wire mode {mode!r}; "
                         f"expected one of {WIRE_MODES}")
    return mode


@dataclass(frozen=True)
class NetworkModel:
    name: str
    alpha_s: float          # per-message latency (s)
    beta_Bps: float         # effective bandwidth (bytes/s)
    rpc_overhead_s: float   # per-RPC software (gRPC core) overhead (s)
    # host-CPU copy rate for the RPC data path. Kernel-TCP networks copy
    # every byte through the host (and contend when several workers hit
    # one PS); RDMA is zero-copy => effectively infinite rate. This term
    # is what lets one parameter set reproduce BOTH the ~2.4x latency gap
    # and the ~4x PS-throughput gap the paper measures.
    cpu_copy_Bps: float = float("inf")
    serialization_Bps: float = 1.2e9  # protobuf pack rate (CPU-bound)
    # zero-copy mode: one-time registration (pinning) cost of a shared
    # buffer-pool region, amortized over its steady-state reuse — the
    # only copy-path cost a one-sided write pays per transfer
    registration_s: float = 3e-4
    pool_reuse: int = 64

    # ------------------------------------------------------------------
    def msg_time(self, nbytes: int) -> float:
        return self.alpha_s + nbytes / self.beta_Bps

    def copy_cost(self, spec: PayloadSpec, mode: str) -> float:
        """The per-mode copy-path cost of one payload transfer, on top
        of the shared wire term (alpha + bytes/beta + rpc overhead):

        serialized      pack+unpack: every byte through the protobuf
                        serializer at ``serialization_Bps``.
        scatter_gather  per-iovec fixed cost: one extra alpha per
                        4-buffer sendmsg/recvmsg batch beyond the first
                        (measured iovec-batching behaviour).
        zero_copy       registration only: the region pin cost
                        amortized over ``pool_reuse`` placements —
                        steady-state transfers touch no copy path.
        """
        if mode == "serialized":
            return spec.total_bytes / self.serialization_Bps
        if mode == "scatter_gather":
            n_batches = max(1, -(-spec.n_buffers // 4))
            return self.alpha_s * (n_batches - 1)
        if mode == "zero_copy":
            return self.registration_s / self.pool_reuse
        raise ValueError(f"unknown wire mode {mode!r}; "
                         f"expected one of {WIRE_MODES}")

    def _payload_time_raw(self, total_bytes: int, n_buffers: int,
                          mode: str) -> float:
        """:meth:`payload_time` on raw (total, n_buffers, mode) args —
        the transport flush-loop hot path prices every message through
        this, skipping PayloadSpec construction. Must stay arithmetic-
        identical to msg_time + rpc_overhead + copy_cost (the closed
        forms match the simulated transport bit-for-bit)."""
        base = (self.alpha_s + total_bytes / self.beta_Bps
                + self.rpc_overhead_s)
        if mode == "scatter_gather":
            n_batches = max(1, -(-n_buffers // 4))
            return base + self.alpha_s * (n_batches - 1)
        if mode == "serialized":
            return base + total_bytes / self.serialization_Bps
        if mode == "zero_copy":
            return base + self.registration_s / self.pool_reuse
        raise ValueError(f"unknown wire mode {mode!r}; "
                         f"expected one of {WIRE_MODES}")

    def payload_time(self, spec: PayloadSpec, *, serialized: bool = False,
                     mode: "str | None" = None) -> float:
        """One-way transfer time of one payload: the shared wire term
        (one alpha + bytes/beta + rpc overhead) plus the per-mode
        :meth:`copy_cost`. ``mode`` (a :data:`WIRE_MODES` name) wins
        over the legacy ``serialized`` bool."""
        mode = resolve_wire_mode(serialized, mode)
        return self._payload_time_raw(spec.total_bytes, spec.n_buffers,
                                      mode)

    def rtt(self, spec: PayloadSpec, *, serialized: bool = False,
            mode: "str | None" = None) -> float:
        """Echo RTT (paper's P2P latency benchmark: payload both ways)."""
        return 2.0 * self.payload_time(spec, serialized=serialized,
                                       mode=mode)

    def bandwidth(self, spec: PayloadSpec, *, serialized: bool = False,
                  mode: "str | None" = None) -> float:
        """MB/s of the one-way bandwidth benchmark (payload + tiny ack)."""
        t = self.payload_time(spec, serialized=serialized, mode=mode) \
            + self.msg_time(64)
        return spec.total_bytes / t / 1e6

    def ps_round_time(self, spec: PayloadSpec, n_ps: int, n_workers: int,
                      *, serialized: bool = False,
                      mode: "str | None" = None) -> float:
        """One PS round: every worker pushes its update to every PS and
        gets the ack/fetch back. PS ingress is the bottleneck: each PS
        serves n_workers RPCs; PSes work in parallel; per-PS RPCs
        serialize on its NIC/stack, and their host-side copies contend
        on the PS CPU (quadratic queueing term; zero for RDMA)."""
        per_rpc = (self.payload_time(spec, serialized=serialized,
                                     mode=mode)
                   + self.msg_time(64))
        contention = (n_workers * (n_workers - 1)
                      * spec.total_bytes / self.cpu_copy_Bps)
        return per_rpc * n_workers + contention

    def ps_throughput(self, spec: PayloadSpec, n_ps: int, n_workers: int,
                      *, serialized: bool = False,
                      mode: "str | None" = None) -> float:
        """Aggregate RPCs/s (paper fig 13/14)."""
        rpcs = n_ps * n_workers
        return rpcs / self.ps_round_time(spec, n_ps, n_workers,
                                         serialized=serialized, mode=mode)

    def egress_time(self, spec: PayloadSpec) -> float:
        """Sender-side cost of pumping one payload onto the wire (alpha
        and the RPC software overhead are charged at the receiver)."""
        return spec.total_bytes / self.beta_Bps

    def fc_round_time(self, spec: PayloadSpec, n_workers: int, *,
                      serialized: bool = False,
                      mode: "str | None" = None) -> float:
        """One fully-connected exchange: every endpoint sends the
        payload to every other (n*(n-1) RPCs). Receiver-bound like the
        PS round — each endpoint ingests n-1 RPCs serially on its
        NIC/stack, with the same quadratic host-copy contention term
        (zero for RDMA) — plus the endpoint's own n-1 payload egress.
        Matches rpc.SimulatedTransport pricing."""
        per_rpc = (self.payload_time(spec, serialized=serialized,
                                     mode=mode)
                   + self.msg_time(64))
        contention = ((n_workers - 1) * (n_workers - 2)
                      * spec.total_bytes / self.cpu_copy_Bps)
        egress = (n_workers - 1) * self.egress_time(spec)
        return per_rpc * (n_workers - 1) + contention + egress

    def fc_throughput(self, spec: PayloadSpec, n_workers: int, *,
                      serialized: bool = False,
                      mode: "str | None" = None) -> float:
        """Aggregate RPCs/s of the fully-connected exchange."""
        rpcs = n_workers * (n_workers - 1)
        return rpcs / self.fc_round_time(spec, n_workers,
                                         serialized=serialized, mode=mode)

    def ring_round_time(self, spec: PayloadSpec, n_workers: int, *,
                        n_chunks: int = 1,
                        serialized: bool = False,
                        mode: "str | None" = None) -> float:
        """One chunked ring pass: every worker streams n_chunks payload
        chunks to its successor, all workers concurrently. Each node
        ingests n_chunks messages from its predecessor (serial on its
        NIC/stack, quadratic host-copy contention among them) while
        pumping its own n_chunks chunks out — so ring time is
        independent of the worker count, the signature of the pattern.
        Matches rpc.SimulatedTransport pricing of rpc.ring_exchange
        exactly (one flight, chunk-major)."""
        del n_workers  # rings pipeline perfectly; kept for API symmetry
        per_rpc = (self.payload_time(spec, serialized=serialized,
                                     mode=mode)
                   + self.msg_time(64))
        contention = (n_chunks * (n_chunks - 1)
                      * spec.total_bytes / self.cpu_copy_Bps)
        egress = n_chunks * self.egress_time(spec)
        return per_rpc * n_chunks + contention + egress

    def ring_throughput(self, spec: PayloadSpec, n_workers: int, *,
                        n_chunks: int = 1,
                        serialized: bool = False,
                        mode: "str | None" = None) -> float:
        """Aggregate chunk-RPCs/s of the ring pass."""
        rpcs = n_workers * n_chunks
        return rpcs / self.ring_round_time(spec, n_workers,
                                           n_chunks=n_chunks,
                                           serialized=serialized,
                                           mode=mode)

    def incast_round_time(self, spec: PayloadSpec, n_workers: int, *,
                          n_chunks: int = 1,
                          serialized: bool = False,
                          mode: "str | None" = None,
                          fetch_ratio: float = 1.0) -> float:
        """The Cori-style PS hotspot: n_workers stream n_chunks payload
        chunks each into ONE server, which answers every stream with a
        fetch response sized ``fetch_ratio`` times the push payload
        (1.0 = symmetric; <1 a small variable pull against a large
        gradient push; >1 a fetch-heavy read). Push half: the server
        ingests n_workers * n_chunks messages serially with quadratic
        host-copy contention (the classic incast cliff). Fetch half:
        the server's own egress pump (n_workers * n_chunks fetch
        payloads out) races each worker's ingress of its n_chunks
        responses — without the egress term the fan-out half would be
        free no matter how many workers hang off the server. Matches
        rpc.SimulatedTransport pricing of rpc.incast_exchange exactly
        (push flight + fetch flight, asymmetric fetch sizes
        included)."""
        from repro.core.payload import classify, scale_sizes
        per_rpc = (self.payload_time(spec, serialized=serialized,
                                     mode=mode)
                   + self.msg_time(64))
        k = n_workers * n_chunks
        push = (per_rpc * k
                + k * (k - 1) * spec.total_bytes / self.cpu_copy_Bps)
        if fetch_ratio == 1.0:
            fspec = spec
        else:
            fsizes = tuple(scale_sizes(spec.sizes, fetch_ratio))
            fspec = PayloadSpec(sizes=fsizes, scheme=spec.scheme,
                                categories=tuple(classify(s)
                                                 for s in fsizes))
        per_fetch_rpc = (self.payload_time(fspec, serialized=serialized,
                                           mode=mode)
                         + self.msg_time(64))
        per_worker_fetch = (per_fetch_rpc * n_chunks
                            + n_chunks * (n_chunks - 1)
                            * fspec.total_bytes / self.cpu_copy_Bps)
        fetch = max(k * self.egress_time(fspec), per_worker_fetch)
        return push + fetch

    def with_link(self, *, bandwidth_Bps: float = None,
                  latency_s: float = None) -> "NetworkModel":
        """This model with per-link bandwidth/latency overrides — the
        resolved model of one directed cluster link. Only alpha/beta
        change; the host-side rates (cpu_copy, serialization, rpc
        overhead) stay the endpoint's own, which is what lets the
        per-link closed form below split contention into a link term
        and a cross-link host term without double counting."""
        if bandwidth_Bps is None and latency_s is None:
            return self
        return dataclasses.replace(
            self, name=f"{self.name}+link",
            beta_Bps=(bandwidth_Bps if bandwidth_Bps is not None
                      else self.beta_Bps),
            alpha_s=(latency_s if latency_s is not None
                     else self.alpha_s))

    def incast_throughput(self, spec: PayloadSpec, n_workers: int, *,
                          n_chunks: int = 1,
                          serialized: bool = False,
                          mode: "str | None" = None,
                          fetch_ratio: float = 1.0) -> float:
        """Aggregate pushed chunk-RPCs/s of the incast round."""
        rpcs = n_workers * n_chunks
        return rpcs / self.incast_round_time(spec, n_workers,
                                             n_chunks=n_chunks,
                                             serialized=serialized,
                                             mode=mode,
                                             fetch_ratio=fetch_ratio)

    # -- allreduce collectives (rpc.collectives drives these flights) --
    def _flight_elapsed(self, msgs, mode: str) -> float:
        """Elapsed time of one flight of ``(src, dst, sizes)`` messages
        — the exact accumulator arithmetic of
        ``rpc.SimulatedTransport.deliver`` (same per-endpoint rows,
        same accumulation order), so a closed form built on per-step
        message lists matches the transport bit-for-bit."""
        acc: Dict[int, list] = {}
        beta = self.beta_Bps
        ack = self.msg_time(64)
        for src, dst, sizes in msgs:
            row = acc.get(dst)
            if row is None:
                row = acc[dst] = [0.0, 0, 0, 0.0]
            nbytes = int(sum(sizes))
            row[0] += self._payload_time_raw(nbytes, len(sizes),
                                             mode) + ack
            row[1] += 1
            row[2] += nbytes
            row = acc.get(src)
            if row is None:
                row = acc[src] = [0.0, 0, 0, 0.0]
            row[3] += nbytes / beta
        elapsed = 0.0
        cpu_copy = self.cpu_copy_Bps
        for ingress, k, nbytes, egress in acc.values():
            t = ingress
            if k > 1:
                t += (k - 1) * nbytes / cpu_copy
            t += egress
            if t > elapsed:
                elapsed = t
        return elapsed

    def ring_allreduce_time(self, total_bytes: int, n_workers: int, *,
                            itemsize: int = 1, serialized: bool = False,
                            mode: "str | None" = None) -> float:
        """Ring allreduce of a ``total_bytes`` gradient across
        ``n_workers``: 2(n-1) rotation steps (reduce-scatter then
        allgather), each one flight in which every worker sends one
        balanced chunk to its successor. Per step every endpoint
        ingests exactly one chunk (no contention term) while pumping
        its own chunk out. Matches rpc.collectives.ring_allreduce on
        the simulated transport exactly."""
        n = n_workers
        if n < 2:
            return 0.0
        mode = resolve_wire_mode(serialized, mode)
        chunks = allreduce_chunk_sizes(total_bytes, n, itemsize=itemsize)
        total = 0.0
        for step in range(2 * (n - 1)):
            msgs = [(i, (i + 1) % n,
                     (chunks[ring_allreduce_send_chunk(i, step, n)],))
                    for i in range(n)]
            total += self._flight_elapsed(msgs, mode)
        return total

    def tree_allreduce_time(self, total_bytes: int, n_workers: int, *,
                            serialized: bool = False,
                            mode: "str | None" = None) -> float:
        """Binomial-tree allreduce: ``ceil(log2 n)`` reduce rounds
        (each a flight of disjoint full-payload pair sends toward
        worker 0) mirrored by the broadcast rounds back out. Latency-
        optimal at small payloads — 2 log n full-payload hops versus
        the ring's 2(n-1) chunk hops. Matches
        rpc.collectives.tree_allreduce on the simulated transport
        exactly."""
        if n_workers < 2:
            return 0.0
        mode = resolve_wire_mode(serialized, mode)
        rounds = tree_reduce_rounds(n_workers)
        sizes = (int(total_bytes),)
        total = 0.0
        for pairs in rounds:
            total += self._flight_elapsed(
                [(s, d, sizes) for s, d in pairs], mode)
        for pairs in reversed(rounds):
            total += self._flight_elapsed(
                [(d, s, sizes) for s, d in pairs], mode)
        return total

    def rsag_allreduce_time(self, total_bytes: int, n_workers: int, *,
                            itemsize: int = 1, serialized: bool = False,
                            mode: "str | None" = None) -> float:
        """Reduce-scatter + allgather in two all-to-all flights: every
        worker first sends chunk j (plus the int64 source tag) to
        worker j, which ingests n-1 tagged chunks — the quadratic
        host-copy contention the one-shot exchange pays and the ring
        amortizes — then every worker broadcasts its reduced chunk.
        Matches rpc.collectives.rsag_allreduce on the simulated
        transport exactly."""
        n = n_workers
        if n < 2:
            return 0.0
        mode = resolve_wire_mode(serialized, mode)
        chunks = allreduce_chunk_sizes(total_bytes, n, itemsize=itemsize)
        tag = ALLREDUCE_TAG_BYTES
        scatter = [(i, j, (tag, chunks[j]))
                   for i in range(n) for j in range(n) if j != i]
        gather = [(j, i, (tag, chunks[j]))
                  for j in range(n) for i in range(n) if i != j]
        return (self._flight_elapsed(scatter, mode)
                + self._flight_elapsed(gather, mode))

    def allreduce_time(self, algo: str, total_bytes: int,
                       n_workers: int, *, itemsize: int = 1,
                       serialized: bool = False,
                       mode: "str | None" = None) -> float:
        """Dispatch on the :data:`ALLREDUCE_ALGOS` name."""
        if algo == "ring":
            return self.ring_allreduce_time(
                total_bytes, n_workers, itemsize=itemsize,
                serialized=serialized, mode=mode)
        if algo == "tree":
            return self.tree_allreduce_time(
                total_bytes, n_workers, serialized=serialized, mode=mode)
        if algo == "rsag":
            return self.rsag_allreduce_time(
                total_bytes, n_workers, itemsize=itemsize,
                serialized=serialized, mode=mode)
        raise ValueError(f"unknown allreduce algo {algo!r}; "
                         f"expected one of {ALLREDUCE_ALGOS}")

    def allreduce_throughput(self, algo: str, total_bytes: int,
                             n_workers: int, *, itemsize: int = 1,
                             serialized: bool = False,
                             mode: "str | None" = None) -> float:
        """Algorithm bandwidth (reduced bytes/s): ``total_bytes`` over
        the closed-form allreduce time."""
        t = self.allreduce_time(algo, total_bytes, n_workers,
                                itemsize=itemsize, serialized=serialized,
                                mode=mode)
        return total_bytes / t if t > 0 else float("inf")


# ---------------------------------------------------------------------------
# per-link closed form (the cluster-transport analytic counterpart)
# ---------------------------------------------------------------------------
#
# A multi-endpoint cluster prices one *flight* per directed link: the
# messages riding link (src -> dst) serialize on that link's resolved
# model (the dst endpoint's base network with per-link bandwidth/latency
# overrides). Contention splits into two quadratic host-copy terms that
# together recover exactly the single-NIC receiver term of
# ``SimulatedTransport`` when every link into an endpoint shares the
# endpoint's base parameters:
#
#   per-link    k_l (k_l - 1) * avg_l / copy      (messages sharing one
#                                                  link's stack queue)
#   cross-link  [K (K-1) - sum_l k_l (k_l - 1)]   (copies from different
#               * avg / copy                       links contending on
#                                                  the one host CPU)
#
# with K the total cross-endpoint messages into the endpoint. Same-
# endpoint (src == dst) messages are loopback: one host memcpy at the
# cpu_copy rate — no alpha, no rpc overhead, no egress, which is what
# keeps local calls loopback-fast in a cluster-routed flight.
# ``repro.rpc.cluster.ClusterTransport`` must match this closed form
# exactly (held by tests/test_cluster_transport.py).

@dataclass(frozen=True)
class LinkLoad:
    """The messages one flight puts on one directed (src, dst) link.

    ``model`` is the link's *resolved* NetworkModel (dst endpoint base +
    per-link overrides); host-side rates in it are the dst endpoint's
    own. ``serialized``/``mode`` apply to every message of the load —
    split a link's messages into separate loads when modes mix. An
    explicit ``mode`` (a :data:`WIRE_MODES` name) wins over the legacy
    ``serialized`` bool."""
    src: int
    dst: int
    model: NetworkModel
    specs: Tuple[PayloadSpec, ...]
    serialized: bool = False
    mode: "str | None" = None

    @property
    def n_msgs(self) -> int:
        return len(self.specs)

    @property
    def total_bytes(self) -> int:
        return int(sum(s.total_bytes for s in self.specs))


def link_time(load: LinkLoad) -> float:
    """Receiver-side serialization of one link's messages (payload +
    64B ack each) on the link's resolved model."""
    return sum(load.model.payload_time(s, serialized=load.serialized,
                                       mode=load.mode)
               + load.model.msg_time(64) for s in load.specs)


def link_contention(load: LinkLoad) -> float:
    """The per-link quadratic host-copy term: k messages riding one
    link in one flight queue on that link's receiving stack."""
    k = load.n_msgs
    if k < 2:
        return 0.0
    return k * (k - 1) * (load.total_bytes / k) / load.model.cpu_copy_Bps


def cluster_flight_time(loads: Sequence[LinkLoad]) -> float:
    """Closed-form elapsed time of one cluster flight: per endpoint,
    ingress (link serialization + per-link contention + cross-link host
    contention + local memcpys) plus its own egress; the flight takes
    the max over endpoints."""
    ingress: Dict[int, float] = {}
    egress: Dict[int, float] = {}
    cross: Dict[int, list] = {}
    for ld in loads:
        if ld.src == ld.dst:
            # loopback-fast: host memcpy only
            ingress[ld.dst] = (ingress.get(ld.dst, 0.0)
                               + ld.total_bytes / ld.model.cpu_copy_Bps)
            continue
        ingress[ld.dst] = (ingress.get(ld.dst, 0.0)
                           + link_time(ld) + link_contention(ld))
        egress[ld.src] = (egress.get(ld.src, 0.0)
                          + ld.total_bytes / ld.model.beta_Bps)
        cross.setdefault(ld.dst, []).append(ld)
    for dst, lds in cross.items():
        k_tot = sum(ld.n_msgs for ld in lds)
        if k_tot < 2:
            continue
        pairs = (k_tot * (k_tot - 1)
                 - sum(ld.n_msgs * (ld.n_msgs - 1) for ld in lds))
        if pairs <= 0:
            continue
        bytes_tot = sum(ld.total_bytes for ld in lds)
        # host-side copy rate: identical across the endpoint's links
        # (with_link never overrides it), so any load's model serves
        ingress[dst] += (pairs * (bytes_tot / k_tot)
                         / lds[0].model.cpu_copy_Bps)
    return max((ingress.get(e, 0.0) + egress.get(e, 0.0)
                for e in set(ingress) | set(egress)), default=0.0)


# fitted constants (benchmarks/calibrate.py; cluster A max err 2.7%,
# cluster B max err 0.8% across the paper's claims)
NETWORKS: Dict[str, NetworkModel] = {
    # Cluster A (RI2): 40GbE, IPoIB over EDR(100G), RDMA-EDR
    "eth40g":    NetworkModel("eth40g", alpha_s=4.16e-05,
                              beta_Bps=4.705e+09, rpc_overhead_s=9.49e-05,
                              cpu_copy_Bps=9.69e+09),
    "ipoib_edr": NetworkModel("ipoib_edr", alpha_s=1.39e-05,
                              beta_Bps=4.889e+09, rpc_overhead_s=1.55e-04,
                              cpu_copy_Bps=1.27e+10),
    "rdma_edr":  NetworkModel("rdma_edr", alpha_s=1.86e-05,
                              beta_Bps=1.084e+10, rpc_overhead_s=2.69e-05),
    # Cluster B (Comet): 10GbE, IPoIB over FDR(56G), RDMA-FDR
    "eth10g":    NetworkModel("eth10g", alpha_s=5.68e-05,
                              beta_Bps=1.072e+09, rpc_overhead_s=1.35e-04,
                              cpu_copy_Bps=6.21e+09),
    "ipoib_fdr": NetworkModel("ipoib_fdr", alpha_s=3.86e-05,
                              beta_Bps=1.481e+09, rpc_overhead_s=1.34e-04,
                              cpu_copy_Bps=7.57e+09),
    "rdma_fdr":  NetworkModel("rdma_fdr", alpha_s=9.17e-06,
                              beta_Bps=4.619e+09, rpc_overhead_s=9.71e-06),
    # TPU fabrics (v5e targets for this framework)
    "tpu_ici":   NetworkModel("tpu_ici",   alpha_s=1e-6,  beta_Bps=5.0e10,
                              rpc_overhead_s=0.0, serialization_Bps=8e11),
    "tpu_dcn":   NetworkModel("tpu_dcn",   alpha_s=25e-6, beta_Bps=6.25e9,
                              rpc_overhead_s=0.0, serialization_Bps=8e11),
}

CLUSTER_A = ("eth40g", "ipoib_edr", "rdma_edr")
CLUSTER_B = ("eth10g", "ipoib_fdr", "rdma_fdr")


def paper_ratio_report() -> Dict[str, Dict[str, float]]:
    """Model-predicted values for every paper claim, with targets."""
    from repro.configs.tfgrpc_bench import BenchConfig
    from repro.core.payload import generate_spec

    skew = generate_spec(BenchConfig(scheme="skew"))
    uni = generate_spec(BenchConfig(scheme="uniform"))
    n = NETWORKS

    def red(a, b):  # latency reduction of a vs b
        return 1.0 - n[a].rtt(skew) / n[b].rtt(skew)

    out = {
        "fig8_rdma_vs_eth40g":  {"target": 0.59, "model": red("rdma_edr", "eth40g")},
        "fig8_rdma_vs_ipoib":   {"target": 0.56, "model": red("rdma_edr", "ipoib_edr")},
        "fig9_rdma_vs_eth10g":  {"target": 0.78, "model": 1 - n["rdma_fdr"].rtt(skew) / n["eth10g"].rtt(skew)},
        "fig9_rdma_vs_ipoib":   {"target": 0.69, "model": 1 - n["rdma_fdr"].rtt(skew) / n["ipoib_fdr"].rtt(skew)},
        "fig9_ipoib_vs_eth10g": {"target": 0.27, "model": 1 - n["ipoib_fdr"].rtt(skew) / n["eth10g"].rtt(skew)},
        "fig11_bw_rdma_x_ipoib": {"target": 2.14, "model": n["rdma_edr"].bandwidth(skew) / n["ipoib_edr"].bandwidth(skew)},
        "fig12_bw_rdma_x_ipoib": {"target": 3.2, "model": n["rdma_fdr"].bandwidth(skew) / n["ipoib_fdr"].bandwidth(skew)},
        "fig13_tp_rdma_x_eth40g": {"target": 4.1, "model": n["rdma_edr"].ps_throughput(uni, 2, 3) / n["eth40g"].ps_throughput(uni, 2, 3)},
        "fig13_tp_rdma_x_ipoib": {"target": 3.43, "model": n["rdma_edr"].ps_throughput(uni, 2, 3) / n["ipoib_edr"].ps_throughput(uni, 2, 3)},
        "fig14_tp_rdma_x_eth10g": {"target": 5.9, "model": n["rdma_fdr"].ps_throughput(uni, 2, 3) / n["eth10g"].ps_throughput(uni, 2, 3)},
        "fig7_serialization_constant": {
            "target": 1.0,
            "model": ((n["eth40g"].payload_time(uni, serialized=True)
                       - n["eth40g"].payload_time(uni, serialized=False))
                      / (n["rdma_edr"].payload_time(uni, serialized=True)
                         - n["rdma_edr"].payload_time(uni, serialized=False))),
        },
    }
    for v in out.values():
        v["rel_err"] = abs(v["model"] - v["target"]) / abs(v["target"])
    return out
