"""Payload generation — the paper's workload model (§2.3, §3.2).

A gRPC payload is a list of iovec buffers drawn from three size
categories (Table 1): Small (bytes), Medium (KB), Large (MB). The suite
generates payloads under three schemes observed in TensorFlow training
traffic (Figure 4):

  uniform — categories cycle evenly through the buffer list
  random  — categories drawn at random per buffer
  skew    — biased mix, default 60% Large / 30% Medium / 10% Small

``from_arch`` additionally derives a payload from a real architecture's
parameter-shape histogram (our framework tie-in: the PS traffic of e.g.
kimi-k2 is dominated by expert matrices => Medium/Large-heavy).

Buffers are padded to TPU lane granularity (128 elements) when
``tpu_align`` is set — the real iovec byte count is preserved separately
for reporting.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.configs import tfgrpc_bench as T
from repro.configs.base import ArchConfig

CATEGORIES = ("small", "medium", "large")


@dataclass(frozen=True)
class PayloadSpec:
    """Sizes (in bytes) of each iovec buffer in one gRPC payload."""
    sizes: Tuple[int, ...]
    scheme: str
    categories: Tuple[str, ...]

    @property
    def total_bytes(self) -> int:
        return int(sum(self.sizes))

    @property
    def n_buffers(self) -> int:
        return len(self.sizes)

    def category_of(self, i: int) -> str:
        return self.categories[i]


def _cat_size(cat: str, cfg: T.BenchConfig) -> int:
    return {"small": cfg.small_bytes, "medium": cfg.medium_bytes,
            "large": cfg.large_bytes}[cat]


def _check_ranges(cfg: T.BenchConfig) -> None:
    lo, hi = T.SMALL_RANGE
    assert lo <= cfg.small_bytes < hi, cfg.small_bytes
    lo, hi = T.MEDIUM_RANGE
    assert lo <= cfg.medium_bytes < hi, cfg.medium_bytes
    lo, hi = T.LARGE_RANGE
    assert lo <= cfg.large_bytes <= hi, cfg.large_bytes


def generate_spec(cfg: T.BenchConfig) -> PayloadSpec:
    """Build the buffer-size list for one payload under cfg.scheme, or
    return the explicit override (cfg.payload_spec, e.g. --arch)."""
    if cfg.payload_spec is not None:
        assert isinstance(cfg.payload_spec, PayloadSpec), cfg.payload_spec
        return cfg.payload_spec
    _check_ranges(cfg)
    cats = tuple(c for c in CATEGORIES if c in cfg.categories)
    assert cats, "need at least one buffer category"
    n = cfg.iovec_count
    rng = np.random.default_rng(cfg.seed)

    if cfg.scheme == "uniform":
        chosen = [cats[i % len(cats)] for i in range(n)]
    elif cfg.scheme == "random":
        assert len(cats) >= 2, "random scheme needs >=2 categories"
        chosen = list(rng.choice(cats, size=n))
    elif cfg.scheme == "skew":
        assert len(cats) >= 2, "skew scheme needs >=2 categories"
        fr = dict(T.SKEW_BIAS_FRACTIONS[cfg.skew_bias])
        # renormalize over the enabled categories
        tot = sum(fr[c] for c in cats)
        counts = {c: int(round(fr[c] / tot * n)) for c in cats}
        # distribute rounding remainder onto the most-biased category
        while sum(counts.values()) < n:
            counts[max(cats, key=lambda c: fr[c])] += 1
        while sum(counts.values()) > n:
            counts[min(cats, key=lambda c: fr[c])] -= 1
        chosen = [c for c in CATEGORIES if c in cats
                  for _ in range(counts[c])]
        rng.shuffle(chosen)
    else:
        raise ValueError(cfg.scheme)

    sizes = tuple(_cat_size(c, cfg) for c in chosen)
    return PayloadSpec(sizes=sizes, scheme=cfg.scheme, categories=tuple(chosen))


def materialize(spec: PayloadSpec, *, dtype=np.uint8, seed: int = 0,
                tpu_align: bool = False) -> List[np.ndarray]:
    """Concrete buffers for a spec. Alignment pads to 128B multiples."""
    rng = np.random.default_rng(seed)
    bufs = []
    for sz in spec.sizes:
        n = sz
        if tpu_align:
            n = max(128, -(-sz // 128) * 128)
        bufs.append(rng.integers(0, 255, size=n, dtype=np.uint8).view(dtype))
    return bufs


def scale_sizes(sizes: Sequence[int], ratio: float) -> List[int]:
    """Scale every iovec size by ``ratio`` (min 1 byte each) — the
    incast push/fetch asymmetry knob. ``ratio=1.0`` is the identity,
    so symmetric paths stay byte-exact."""
    assert ratio > 0, ratio
    return [max(1, int(round(s * ratio))) for s in sizes]


def classify(nbytes: int) -> str:
    if nbytes < T.SMALL_RANGE[1]:
        return "small"
    if nbytes < T.MEDIUM_RANGE[1]:
        return "medium"
    return "large"


def from_arch(acfg: ArchConfig, *, max_buffers: int = 10,
              seed: int = 0) -> PayloadSpec:
    """Payload modeled on an architecture's real parameter tensors: one
    'variable fetch' worth of buffers sampled from the arch's per-tensor
    byte-size histogram (4 bytes/elem, fp32 master copies — what a PS
    actually serves)."""
    counts = acfg.model.param_counts()
    cfg_m = acfg.model
    sizes_pool: List[int] = []
    # embedding rows are fetched in slices; model the slice, not the table
    sizes_pool.append(min(counts["embed"] * 4 // max(cfg_m.vocab_size, 1)
                          * 1024, 8 * 1024 * 1024))
    per_layer = counts["layers"] / max(cfg_m.num_layers, 1)
    # a layer's tensors: a few matrices around d_model*d_ff and d_model^2
    d, f = cfg_m.d_model, cfg_m.d_ff
    sizes_pool += [d * d * 4, d * f * 4, d * 4, 2 * d * 4]
    if cfg_m.moe is not None:
        sizes_pool.append(d * cfg_m.moe.d_ff_expert * 4)   # one expert matrix
        sizes_pool.append(cfg_m.moe.num_experts * d // 64 * 4)  # router slice
    del per_layer
    rng = np.random.default_rng(seed)
    take = [int(sizes_pool[i % len(sizes_pool)])
            for i in range(max_buffers)]
    rng.shuffle(take)
    take = [min(max(s, 1), T.LARGE_RANGE[1]) for s in take]
    return PayloadSpec(sizes=tuple(take), scheme=f"arch:{cfg_m.name}",
                       categories=tuple(classify(s) for s in take))
