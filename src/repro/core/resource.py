"""Resource-utilization monitor (paper §3.1 "Resource Utilization").

Samples process RSS and CPU time on a background thread during a timed
window; no psutil dependency (reads /proc)."""
from __future__ import annotations

import os
import threading
import time
from dataclasses import dataclass, field
from typing import List, Optional


def _rss_bytes() -> int:
    try:
        with open("/proc/self/statm") as f:
            pages = int(f.read().split()[1])
        return pages * os.sysconf("SC_PAGE_SIZE")
    except (OSError, ValueError):
        return 0


@dataclass
class ResourceReport:
    duration_s: float
    cpu_time_s: float
    cpu_util: float           # cpu seconds / wall seconds
    rss_peak_bytes: int
    rss_mean_bytes: float
    samples: int


class ResourceMonitor:
    """with ResourceMonitor() as mon: ... ; mon.report"""

    def __init__(self, interval_s: float = 0.05):
        self.interval_s = interval_s
        self._stop = threading.Event()
        self._rss: List[int] = []
        self.report: Optional[ResourceReport] = None

    def _loop(self):
        while not self._stop.wait(self.interval_s):
            self._rss.append(_rss_bytes())

    def __enter__(self):
        self._t0 = time.perf_counter()
        t = os.times()
        self._cpu0 = t.user + t.system
        self._rss.append(_rss_bytes())
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()
        return self

    def __exit__(self, *exc):
        self._stop.set()
        self._thread.join(timeout=1.0)
        wall = time.perf_counter() - self._t0
        t = os.times()
        cpu = (t.user + t.system) - self._cpu0
        rss = self._rss or [0]
        self.report = ResourceReport(
            duration_s=wall, cpu_time_s=cpu,
            cpu_util=cpu / max(wall, 1e-9),
            rss_peak_bytes=max(rss),
            rss_mean_bytes=sum(rss) / len(rss),
            samples=len(rss))
        return False
