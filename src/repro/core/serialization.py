"""Payload packing — the TPU analogue of the paper's serialized mode.

protobuf serialization on gRPC ≈ a CPU-side copy into one contiguous
wire buffer. On TPU the analogous trade is: pay one extra HBM copy to
coalesce N iovec buffers into ONE collective (serialized), or launch N
collectives with no copy (non-serialized). ``pack``/``unpack`` here are
the pure-jnp reference; ``repro.kernels.payload_pack`` is the Pallas
version used on real TPUs.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp


def pack(bufs: Sequence[jax.Array]) -> Tuple[jax.Array, Tuple[Tuple[int, ...],
                                                              ...]]:
    """Concatenate per-device buffer rows into one contiguous buffer.

    bufs: sequence of (..., size_i) uint8. Returns (packed (..., sum),
    metadata of original trailing shapes)."""
    meta = tuple(b.shape[-1:] for b in bufs)
    flat = [b.reshape(b.shape[:-1] + (-1,)) for b in bufs]
    return jnp.concatenate(flat, axis=-1), meta


def unpack(packed: jax.Array, meta: Tuple[Tuple[int, ...], ...]
           ) -> List[jax.Array]:
    sizes = [m[0] for m in meta]
    offs, out = 0, []
    for s in sizes:
        out.append(jax.lax.slice_in_dim(packed, offs, offs + s, axis=-1))
        offs += s
    return out
