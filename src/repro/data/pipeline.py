"""Synthetic-but-deterministic data pipeline.

Step-indexed and seeded: batch(step) is a pure function of (seed, step,
shape), so restart/replay after a failure is exact (the fault-tolerance
contract in train.trainer). Token stream is Zipf-distributed (realistic
vocab skew for the embedding-gather traffic the benchmark suite models).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.parallel.sharding import ParallelCtx


@dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    zipf_a: float = 1.2        # vocab skew
    mask_fraction: float = 0.0  # fraction of labels masked (-1)


def _rng_for_step(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step]))


def host_batch(acfg: ArchConfig, shape: ShapeSpec, step: int,
               cfg: DataConfig = DataConfig()) -> Dict[str, np.ndarray]:
    """One global batch as host numpy arrays."""
    rng = _rng_for_step(cfg, step)
    B, S = shape.global_batch, shape.seq_len
    V = acfg.model.vocab_size
    # Zipf over the vocab, clipped
    toks = rng.zipf(cfg.zipf_a, size=(B, S + 1)).astype(np.int64)
    toks = np.minimum(toks - 1, V - 1).astype(np.int32)
    batch: Dict[str, np.ndarray] = {}
    if acfg.model.frontend is not None:
        d = acfg.model.d_model
        batch["embeds"] = rng.standard_normal(
            (B, S, d), dtype=np.float32).astype(np.float32)
    else:
        batch["tokens"] = toks[:, :S]
    labels = toks[:, 1:].copy()
    if cfg.mask_fraction > 0:
        drop = rng.random((B, S)) < cfg.mask_fraction
        labels[drop] = -1
    batch["labels"] = labels
    return batch


def device_batch(ctx: ParallelCtx, batch: Dict[str, np.ndarray]):
    """Place a host batch on the mesh, batch-sharded."""
    if ctx.mesh is None:
        return jax.tree.map(jax.numpy.asarray, batch)
    sh = NamedSharding(ctx.mesh, P(ctx.axis("batch")))

    def put(a):
        spec = P(*([ctx.axis("batch")] + [None] * (a.ndim - 1)))
        return jax.device_put(a, NamedSharding(ctx.mesh, spec))

    del sh
    return {k: put(v) for k, v in batch.items()}


def iterate(ctx: ParallelCtx, acfg: ArchConfig, shape: ShapeSpec,
            start_step: int = 0, cfg: DataConfig = DataConfig()
            ) -> Iterator[Dict]:
    step = start_step
    while True:
        yield device_batch(ctx, host_batch(acfg, shape, step, cfg))
        step += 1
