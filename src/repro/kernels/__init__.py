"""Pallas TPU kernels (validated via interpret=True on CPU):

  flash_attention — fused GQA attention (causal/SWA/softcap), the
                    transformer hot spot
  rwkv6_scan      — chunked data-dependent-decay WKV recurrence
  payload_pack    — iovec coalescing (the paper's serialized mode)
"""
