"""Flash attention for TPU (Pallas): fused online-softmax attention with
GQA head mapping, causal + sliding-window masking and gemma2-style logit
softcap.

Tiling: grid = (batch*q_heads, n_q_blocks, n_kv_blocks); the kv axis is
innermost so the (m, l, acc) running state lives in VMEM scratch across
kv steps. Q/K/V blocks stream HBM->VMEM via BlockSpecs; the KV BlockSpec
index_map folds the GQA group mapping (q head h reads kv head h // G),
so grouped K/V are never materialized per-q-head in HBM.

Block sizes default to (128, 128) — MXU-aligned (128 lanes) and small
enough that q(128xdh) + k,v(128xdh) + scores(128x128) + acc stay well
under VMEM for d_head <= 256.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, window: Optional[int],
               softcap: Optional[float], block_q: int, block_k: int,
               n_kv_blocks: int, seq_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)            # (bq, dh)
    k = k_ref[0].astype(jnp.float32)            # (bk, dh)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    ok = k_pos < seq_kv                          # kv padding
    if causal:
        ok &= q_pos >= k_pos
    if window is not None:
        ok &= (q_pos - k_pos) < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]                          # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    p = jnp.where(ok, p, 0.0)
    l_new = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
        p.astype(v.dtype), v).astype(jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_kernel(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           n_groups: int, causal: bool,
                           window: Optional[int], softcap: Optional[float],
                           scale: float, block_q: int = 128,
                           block_k: int = 128, seq_kv: int,
                           interpret: bool = False) -> jax.Array:
    """q: (B*H, Sq_pad, dh); k, v: (B*KV, Skv_pad, dh). ``seq_kv`` is the
    un-padded kv length (padding keys are masked). Returns (B*H, Sq_pad,
    dh)."""
    BH, Sq, dh = q.shape
    BKV, Skv, _ = k.shape
    H = (BH // BKV) * n_groups  # heads per batch... BH/BKV == G
    G = BH // BKV
    assert Sq % block_q == 0 and Skv % block_k == 0, (Sq, Skv)
    nq, nk = Sq // block_q, Skv // block_k

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=block_q, block_k=block_k, n_kv_blocks=nk,
        seq_kv=seq_kv)

    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, dh),
                         lambda bh, qi, ki, G=G: (bh // G, ki, 0)),
            pl.BlockSpec((1, block_k, dh),
                         lambda bh, qi, ki, G=G: (bh // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
