"""jit'd public wrapper for the flash attention kernel: layout handling,
padding to block multiples, GQA reshape, interpret-mode fallback on
non-TPU backends, and a custom_vjp whose backward recomputes through the
reference (remat-style backward; the fused bwd kernel is future work —
the fwd kernel is what serving uses)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import \
    flash_attention_kernel
from repro.kernels.flash_attention.ref import attention_ref


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def flash_attention(q, k, v, causal=True, window=None, softcap=None,
                    scale=None, block_q=128, block_k=128, interpret=None):
    """q: (B, Sq, H, dh); k, v: (B, Skv, KV, dh) -> (B, Sq, H, dh)."""
    return _fwd_impl(q, k, v, causal, window, softcap, scale, block_q,
                     block_k, interpret)


def _fwd_impl(q, k, v, causal, window, softcap, scale, block_q, block_k,
              interpret):
    B, Sq, H, dh = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    scale = scale if scale is not None else 1.0 / (dh ** 0.5)
    interpret = _interpret_default() if interpret is None else interpret
    block_q = min(block_q, max(8, Sq))
    block_k = min(block_k, max(8, Skv))

    pad_q = (-Sq) % block_q
    pad_k = (-Skv) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else k
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else v

    # (B, S, H, dh) -> (B*H, S, dh); kv heads stay un-repeated
    q2 = qp.transpose(0, 2, 1, 3).reshape(B * H, Sq + pad_q, dh)
    k2 = kp.transpose(0, 2, 1, 3).reshape(B * KV, Skv + pad_k, dh)
    v2 = vp.transpose(0, 2, 1, 3).reshape(B * KV, Skv + pad_k, dh)

    o = flash_attention_kernel(
        q2, k2, v2, n_groups=G, causal=causal, window=window,
        softcap=softcap, scale=scale, block_q=block_q, block_k=block_k,
        seq_kv=Skv, interpret=interpret)
    o = o.reshape(B, H, Sq + pad_q, dh).transpose(0, 2, 1, 3)
    return o[:, :Sq] if pad_q else o


def _fa_fwd(q, k, v, causal, window, softcap, scale, block_q, block_k,
            interpret):
    out = _fwd_impl(q, k, v, causal, window, softcap, scale, block_q,
                    block_k, interpret)
    return out, (q, k, v)


def _fa_bwd(causal, window, softcap, scale, block_q, block_k, interpret,
            res, g):
    q, k, v = res
    dh = q.shape[-1]
    s = scale if scale is not None else 1.0 / (dh ** 0.5)

    def ref(q, k, v):
        return attention_ref(q, k, v, causal=causal, window=window,
                             softcap=softcap, scale=s)

    _, vjp = jax.vjp(ref, q, k, v)
    return vjp(g)


flash_attention.defvjp(_fa_fwd, _fa_bwd)
