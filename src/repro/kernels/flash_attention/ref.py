"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool, window: Optional[int],
                  softcap: Optional[float], scale: float) -> jax.Array:
    """q: (B, Sq, H, dh); k, v: (B, Skv, KV, dh). Returns (B, Sq, H, dh).
    GQA via head repetition; full-materialized softmax in fp32."""
    B, Sq, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV
    kf = jnp.repeat(k, G, axis=2)
    vf = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bshd->bhqs", q.astype(jnp.float32),
                   kf.astype(jnp.float32)) * scale
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(k.shape[1])[None, :]
    ok = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        ok &= q_pos >= k_pos
    if window is not None:
        ok &= (q_pos - k_pos) < window
    s = jnp.where(ok[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqs,bshd->bqhd", p.astype(vf.dtype), vf)
