from repro.kernels.payload_pack.ops import pack, unpack
from repro.kernels.payload_pack.payload_pack import LANE
from repro.kernels.payload_pack.ref import pack_ref, unpack_ref

__all__ = ["LANE", "pack", "unpack", "pack_ref", "unpack_ref"]
