from repro.kernels.payload_pack.ops import pack, unpack
from repro.kernels.payload_pack.ref import pack_ref, unpack_ref

__all__ = ["pack", "unpack", "pack_ref", "unpack_ref"]
