"""jit'd wrappers with lane-alignment padding + interpret fallback."""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.payload_pack.payload_pack import (LANE, pack_kernel,
                                                     unpack_kernel)


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def pack(bufs: Sequence[jax.Array], *, interpret=None
         ) -> Tuple[jax.Array, Tuple[int, ...]]:
    """Returns (packed uint8, original sizes). Pads each buffer to the
    128-byte lane width; the metadata keeps true sizes for unpack."""
    interpret = _interpret_default() if interpret is None else interpret
    sizes = tuple(int(b.shape[-1]) for b in bufs)
    padded = [jnp.pad(b.reshape(-1), (0, (-b.shape[-1]) % LANE))
              for b in bufs]
    return pack_kernel(padded, interpret=interpret), sizes


def unpack(packed: jax.Array, sizes: Sequence[int], *, interpret=None
           ) -> List[jax.Array]:
    interpret = _interpret_default() if interpret is None else interpret
    padded_sizes = [s + ((-s) % LANE) for s in sizes]
    outs = unpack_kernel(packed, padded_sizes, interpret=interpret)
    return [o[:s] for o, s in zip(outs, sizes)]
