"""Payload pack/unpack for TPU (Pallas) — the paper's "serialized mode".

Coalesces N iovec buffers (each 128-byte aligned, the lane width) into a
single contiguous transfer buffer in one VMEM pass, and splits it back.
On gRPC this is protobuf serialization (a host copy); on TPU it is the
HBM copy you pay to turn N small collectives into one — the trade the
serialized/non-serialized benchmark modes measure.

Tiling: the output is walked in ``block`` chunks (grid = n_out_blocks);
for each output block, the kernel copies the overlapping span of every
input buffer. Buffer offsets are static, so the per-buffer copy bounds
fold to constants/clamps at trace time; input BlockSpecs stream only the
needed block of each input.
"""
from __future__ import annotations

import functools
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128


def _offsets(sizes: Sequence[int]) -> List[int]:
    offs, acc = [], 0
    for s in sizes:
        offs.append(acc)
        acc += s
    return offs + [acc]


def _pack_kernel(*refs, sizes: Tuple[int, ...], block: int):
    """refs = (*in_refs, o_ref). Output block bi covers
    [bi*block, (bi+1)*block); copy each input's overlap into it."""
    *in_refs, o_ref = refs
    bi = pl.program_id(0)
    out_lo = bi * block
    offs = _offsets(sizes)
    for j, ref in enumerate(in_refs):
        lo, hi = offs[j], offs[j + 1]
        # overlap of [lo, hi) with [out_lo, out_lo+block) — static per bi?
        # bi is dynamic: compute with lax ops on traced values.
        a = jnp.maximum(lo - out_lo, 0)            # start within out block
        b = jnp.minimum(hi - out_lo, block)        # end within out block
        src = jnp.maximum(out_lo - lo, 0)          # start within input
        # copy in LANE-sized chunks; sizes are LANE-aligned by contract
        n_lanes = (b - a) // LANE

        def body(i, _):
            o_ref[pl.ds(a + i * LANE, LANE)] = ref[pl.ds(src + i * LANE,
                                                         LANE)]
            return 0

        jax.lax.fori_loop(0, jnp.maximum(n_lanes, 0), body, 0)


def pack_kernel(bufs: Sequence[jax.Array], *, block: int = 16384,
                interpret: bool = False) -> jax.Array:
    """bufs: list of (size_i,) uint8, every size_i % 128 == 0.
    Returns (sum sizes,) uint8."""
    sizes = tuple(int(b.shape[0]) for b in bufs)
    for s in sizes:
        assert s % LANE == 0, s
    total = sum(sizes)
    # largest lane-multiple block <= requested that divides total
    import math
    block = math.gcd(total, min(block, total))
    assert block % LANE == 0, block
    grid = (total // block,)

    kernel = functools.partial(_pack_kernel, sizes=sizes, block=block)
    return pl.pallas_call(
        kernel,
        grid=grid,
        # inputs stay whole in VMEM-addressable windows (memory_space ANY
        # would be ideal; full blocks keep interpret/TPU paths identical)
        in_specs=[pl.BlockSpec(b.shape, lambda bi: (0,)) for b in bufs],
        out_specs=pl.BlockSpec((block,), lambda bi: (bi,)),
        out_shape=jax.ShapeDtypeStruct((total,), jnp.uint8),
        interpret=interpret,
    )(*bufs)


def _unpack_kernel(p_ref, *o_refs, sizes: Tuple[int, ...]):
    offs = _offsets(sizes)
    for j, ref in enumerate(o_refs):
        ref[...] = p_ref[pl.ds(offs[j], sizes[j])]


def unpack_kernel(packed: jax.Array, sizes: Sequence[int], *,
                  interpret: bool = False) -> List[jax.Array]:
    sizes = tuple(int(s) for s in sizes)
    kernel = functools.partial(_unpack_kernel, sizes=sizes)
    outs = pl.pallas_call(
        kernel,
        in_specs=[pl.BlockSpec(packed.shape, lambda: (0,))],
        out_specs=[pl.BlockSpec((s,), lambda: (0,)) for s in sizes],
        out_shape=[jax.ShapeDtypeStruct((s,), jnp.uint8) for s in sizes],
        interpret=interpret,
    )(packed)
    return list(outs)
