"""Pure-jnp oracle for payload pack/unpack (= core.serialization)."""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp


def pack_ref(bufs: Sequence[jax.Array]) -> jax.Array:
    return jnp.concatenate([b.reshape(-1) for b in bufs])


def unpack_ref(packed: jax.Array, sizes: Sequence[int]) -> List[jax.Array]:
    out, off = [], 0
    for s in sizes:
        out.append(jax.lax.slice_in_dim(packed, off, off + s))
        off += s
    return out
