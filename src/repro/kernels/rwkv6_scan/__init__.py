from repro.kernels.rwkv6_scan.ops import rwkv6_scan
from repro.kernels.rwkv6_scan.ref import rwkv6_ref

__all__ = ["rwkv6_scan", "rwkv6_ref"]
