"""jit'd wrapper: bonus-u diagonal term, padding, interpret fallback."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6_scan.ref import rwkv6_ref
from repro.kernels.rwkv6_scan.rwkv6_scan import rwkv6_scan_kernel


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def rwkv6_scan(r, k, v, log_w, s0, u=None, *, chunk: int = 64,
               interpret=None):
    """(BH, S, hs) inputs; returns (y, s_final). Handles S padding and the
    bonus-u diagonal (elementwise, outside the chunked kernel)."""
    interpret = _interpret_default() if interpret is None else interpret
    BH, S, hs = r.shape
    chunk = min(chunk, max(8, S))
    pad = (-S) % chunk
    if pad:
        zf = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0)))
        # zero k/v => no state writes; zero log_w => no decay
        r2, k2, v2, lw2 = zf(r), zf(k), zf(v), zf(log_w)
    else:
        r2, k2, v2, lw2 = r, k, v, log_w
    y, sT = rwkv6_scan_kernel(r2, k2, v2, lw2, s0, chunk=chunk,
                              interpret=interpret)
    if pad:
        y = y[:, :S]
    if u is not None:
        diag = jnp.sum(r * k * u[:, None, :], axis=-1, keepdims=True)
        y = y + diag * v
    return y, sT
