"""Pure-jnp oracle: exact sequential RWKV-6 recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rwkv6_ref(r, k, v, log_w, s0, u=None):
    """r/k/v/log_w: (BH, S, hs); s0: (BH, hs, hs); u: (BH, hs) or None.
    Sequential: y_t = r_t S_{t-1} (+ r_t diag(u) k_t^T v_t);
                S_t = diag(w_t) S_{t-1} + k_t^T v_t."""
    def step(s, xs):
        rt, kt, vt, lwt = xs                    # (BH, hs)
        outer = kt[:, :, None] * vt[:, None, :]  # (BH, hs, hs)
        y = jnp.einsum("bk,bkv->bv", rt, s)
        if u is not None:
            y = y + jnp.einsum("bk,bk,bkv->bv", rt, u, outer)
        s = s * jnp.exp(lwt)[:, :, None] + outer
        return s, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, log_w))
    sT, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1), sT
