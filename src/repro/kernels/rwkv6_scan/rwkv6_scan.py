"""RWKV-6 chunked WKV scan for TPU (Pallas).

The data-dependent per-channel decay recurrence
    S_t = diag(w_t) S_{t-1} + k_t^T v_t ;  y_t = r_t S_{t-1} (+ bonus)
is computed chunk-parallel: within a chunk of Lc tokens the pairwise
decay matrix D[t,i,c] = exp(cum_{t-1,c} - cum_{i,c}) (all exponents <= 0
by construction — overflow-free) feeds two matmuls; across chunks the
(hs x hs) state is carried in VMEM scratch while the grid walks the
chunk axis innermost. The diagonal (bonus-u) term is handled outside the
kernel by the wrapper (it is elementwise in t).

Tiling: grid = (B*H, n_chunks); blocks are (1, Lc, hs) slices of the
(B*H, S, hs) r/k/v/logw tensors. VMEM per step ~ Lc*Lc*hs*4B (the D
tensor): 1 MiB at Lc=hs=64.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _rwkv_kernel(r_ref, k_ref, v_ref, lw_ref, s0_ref, y_ref, sT_ref,
                 state_scr, *, chunk: int, n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = s0_ref[0].astype(jnp.float32)

    r = r_ref[0].astype(jnp.float32)        # (Lc, hs)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)      # (Lc, hs), <= 0

    cum = jnp.cumsum(lw, axis=0)            # inclusive
    cum_tm1 = cum - lw
    # D[t,i,c] = exp(cum_{t-1,c} - cum_{i,c}) for i < t (strict causal)
    dlog = cum_tm1[:, None, :] - cum[None, :, :]
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    i_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    mask = (i_idx < t_idx)[:, :, None]
    d = jnp.exp(jnp.where(mask, dlog, NEG_INF))
    a = jnp.sum(r[:, None, :] * k[None, :, :] * d, axis=-1)   # (Lc, Lc)

    st = state_scr[...]                      # (hs, hs)
    y_intra = jax.lax.dot(a.astype(v.dtype), v)
    y_inter = jax.lax.dot(r * jnp.exp(cum_tm1), st)
    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    decay_out = jnp.exp(cum[-1:, :] - cum)   # (Lc, hs), <= 1
    state_scr[...] = st * jnp.exp(cum[-1, :])[:, None] + jax.lax.dot(
        (k * decay_out).T, v)

    @pl.when(ci == n_chunks - 1)
    def _finish():
        sT_ref[0] = state_scr[...].astype(sT_ref.dtype)


def rwkv6_scan_kernel(r: jax.Array, k: jax.Array, v: jax.Array,
                      log_w: jax.Array, s0: jax.Array, *,
                      chunk: int = 64, interpret: bool = False):
    """r/k/v/log_w: (BH, S, hs) fp32; s0: (BH, hs, hs).
    Returns (y (BH, S, hs), s_final (BH, hs, hs)). S % chunk == 0."""
    BH, S, hs = r.shape
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    kernel = functools.partial(_rwkv_kernel, chunk=chunk, n_chunks=nc)
    blk = pl.BlockSpec((1, chunk, hs), lambda bh, ci: (bh, ci, 0))
    state_spec = pl.BlockSpec((1, hs, hs), lambda bh, ci: (bh, 0, 0))

    return pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[blk, blk, blk, blk, state_spec],
        out_specs=[blk, state_spec],
        out_shape=[jax.ShapeDtypeStruct((BH, S, hs), jnp.float32),
                   jax.ShapeDtypeStruct((BH, hs, hs), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((hs, hs), jnp.float32)],
        interpret=interpret,
    )(r, k, v, log_w, s0)
