import os
if "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    # device fabric for the channels; set before any jax import
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=8")

"""TF-gRPC-Bench CLI — the paper's Table 2, as flags, plus the
rpc-fabric fully_connected family.

  PYTHONPATH=src python -m repro.launch.bench_comm \
      --benchmark ps_throughput --num-ps 2 --num-workers 3 \
      --scheme skew --iovec-count 10 --mode non_serialized \
      --warmup 2 --duration 10 [--network rdma_edr] [--arch qwen3-8b]

  PYTHONPATH=src python -m repro.launch.bench_comm \
      --benchmark fully_connected --num-workers 4 --transport collective
  PYTHONPATH=src python -m repro.launch.bench_comm \
      --benchmark fully_connected --num-workers 64 --transport simulated

--arch derives the payload from that architecture's parameter histogram
instead of the S/M/L generator (core.payload.from_arch) and benchmarks
THAT payload. --transport picks the rpc-fabric datapath for
fully_connected: collective (measured ppermute), loopback (measured
shared-buffer memcpy), simulated (netmodel projection; endpoint counts
far beyond the host device count).
"""
import argparse


def main() -> None:
    ap = argparse.ArgumentParser(
        description="TF-gRPC-Bench micro-benchmark suite (paper Table 2)")
    ap.add_argument("--benchmark", default="p2p_latency",
                    choices=["p2p_latency", "p2p_bandwidth",
                             "ps_throughput", "fully_connected"])
    ap.add_argument("--num-ps", type=int, default=1)
    ap.add_argument("--num-workers", type=int, default=1)
    ap.add_argument("--transport", default="collective",
                    choices=["collective", "loopback", "simulated"])
    ap.add_argument("--mode", default="non_serialized",
                    choices=["non_serialized", "serialized"])
    ap.add_argument("--scheme", default="uniform",
                    choices=["uniform", "random", "skew"])
    ap.add_argument("--skew-bias", default="large",
                    choices=["large", "medium", "small"])
    ap.add_argument("--iovec-count", type=int, default=10)
    ap.add_argument("--small-bytes", type=int, default=10)
    ap.add_argument("--medium-bytes", type=int, default=10 * 1024)
    ap.add_argument("--large-bytes", type=int, default=1024 * 1024)
    ap.add_argument("--categories", default="small,medium,large")
    ap.add_argument("--warmup", type=float, default=2.0)
    ap.add_argument("--duration", type=float, default=10.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--network", default=None,
                    help="print only this network's projection")
    ap.add_argument("--arch", default=None,
                    help="payload from this arch's parameter histogram")
    args = ap.parse_args()

    from repro.configs.tfgrpc_bench import BenchConfig
    from repro.core import bench

    payload_spec = None
    if args.arch:
        from repro.configs import get_config
        from repro.core.payload import from_arch
        payload_spec = from_arch(get_config(args.arch), seed=args.seed)
        print(f"payload from {args.arch}: {payload_spec.n_buffers} "
              f"buffers, {payload_spec.total_bytes/1e6:.2f} MB "
              f"({', '.join(payload_spec.categories)})")

    cfg = BenchConfig(
        benchmark=args.benchmark, num_ps=args.num_ps,
        num_workers=args.num_workers, mode=args.mode, scheme=args.scheme,
        skew_bias=args.skew_bias, iovec_count=args.iovec_count,
        small_bytes=args.small_bytes, medium_bytes=args.medium_bytes,
        large_bytes=args.large_bytes,
        categories=tuple(args.categories.split(",")),
        warmup_s=args.warmup, duration_s=args.duration, seed=args.seed,
        network=args.network, transport=args.transport,
        payload_spec=payload_spec)

    st = bench.run(cfg)
    scheme = st.spec.scheme
    tail = "/" + cfg.skew_bias if scheme == "skew" else ""
    extra = f", {cfg.transport}" if cfg.benchmark == "fully_connected" \
        else ""
    print(f"benchmark      : {st.name} [{scheme}{tail}, {cfg.mode}"
          f"{extra}]")
    print(f"payload        : {st.spec.n_buffers} iovecs, "
          f"{st.spec.total_bytes/1e6:.3f} MB")
    projected = (cfg.benchmark == "fully_connected"
                 and cfg.transport == "simulated")
    label = "net projected " if projected else "host measured "
    if projected:
        print(f"sim network    : {cfg.network or 'eth40g'}")
    print(f"{label} : mean {st.mean_s*1e6:.1f} us  "
          f"p50 {st.p50_s*1e6:.1f}  p95 {st.p95_s*1e6:.1f}  "
          f"({st.n_iters} iters)")
    for k, v in st.derived.items():
        print(f"               : {k} = {v:.2f}")
    if st.resources:
        print(f"resources      : cpu_util {st.resources.cpu_util:.2f}  "
              f"rss_peak {st.resources.rss_peak_bytes/1e6:.0f} MB")
    nets = ([args.network] if args.network else
            sorted(st.model_projection))
    for n in nets:
        unit = {"p2p_latency": "s RTT", "p2p_bandwidth": "MB/s",
                "ps_throughput": "RPC/s",
                "fully_connected": "RPC/s"}[st.name]
        print(f"model {n:12s}: {st.model_projection[n]:.6g} {unit}")


if __name__ == "__main__":
    main()
