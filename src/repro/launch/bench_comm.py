import os
if "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    # device fabric for the channels; set before any jax import
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=8")

"""TF-gRPC-Bench CLI — the paper's Table 2, as flags, plus the
rpc-fabric families (fully_connected / ring / incast).

  PYTHONPATH=src python -m repro.launch.bench_comm \
      --benchmark ps_throughput --num-ps 2 --num-workers 3 \
      --scheme skew --iovec-count 10 --mode non_serialized \
      --warmup 2 --duration 10 [--network rdma_edr] [--arch qwen3-8b]

  PYTHONPATH=src python -m repro.launch.bench_comm \
      --benchmark ring --num-workers 4 --stream-chunks 4 \
      --transport collective
  PYTHONPATH=src python -m repro.launch.bench_comm \
      --benchmark incast --num-workers 64 --transport simulated

  # collectives + the PS -> allreduce training crossover
  PYTHONPATH=src python -m repro.launch.bench_comm \
      --benchmark allreduce --algo ring --num-workers 8 \
      --transport simulated
  PYTHONPATH=src python -m repro.launch.bench_comm \
      --benchmark train_step --train-mode ps --num-ps 2 \
      --num-workers 16 --transport simulated \
      --sweep workers,train_mode

  # cross-product sweep, one table (+ --json for machine-readable rows)
  PYTHONPATH=src python -m repro.launch.bench_comm \
      --sweep scheme,transport --benchmark incast --num-workers 4 \
      --warmup 0.2 --duration 0.5 --json incast_sweep.json

--arch derives the payload from that architecture's parameter histogram
instead of the S/M/L generator (core.payload.from_arch) and benchmarks
THAT payload. --transport picks the rpc-fabric datapath for the fabric
families: collective (measured ppermute), loopback (measured
shared-buffer memcpy), simulated (netmodel projection; endpoint counts
far beyond the host device count), cluster (per-link netmodel routing
over a multi-endpoint ClusterSpec — pass --cluster-spec with inline
JSON or a file path, or get a homogeneous cluster on --network;
cluster rows carry per-endpoint interceptor metrics). --fetch-ratio
sizes the incast fetch payload relative to the push (gradient-push vs
variable-pull asymmetry). --wire-mode picks the rpc datapath encoding
explicitly (serialized | scatter_gather | zero_copy; default derives
from --mode) — zero_copy places payloads in a pre-registered shared
BufferPool and ships (pool, offset, size) descriptors instead of
bytes. --sweep takes a comma-separated list of axes (scheme,
mode, wire_mode, payload, transport, benchmark, network, workers,
stream_chunks, algo, train_mode — workers and stream_chunks
generate scaling curves) and runs the full cross-product of their
values in one invocation; algo and train_mode sweep the collective
schedule and the train_step layout (PS vs allreduce — crossed with
workers, the PS -> allreduce crossover curve). Fabric-family rows
carry per-method
interceptor metrics (call counts + latency percentiles) under
"rpc_metrics" and the tracer's per-phase latency breakdown under
"rpc_phases" in the --json output; --json writes a versioned envelope
{"schema": 3, "rows": [...]} (3 added the open-loop workload row
shape; closed-loop rows are unchanged from schema 2).

--workload switches the CLI from the paper's closed-loop families to
the open-loop trace driver (repro.workload): synthesize a seeded
arrival process (--workload poisson|bursty|diurnal with --rate and
--duration-s) or replay a recorded trace (--workload trace
--trace-in PATH), fire it against a synthetic-engine serve cluster
(--num-ps/--num-workers/--cluster-spec, scheduler policy via
--sched-policy), and print the SLO table (p50/p99/p999 TTFT,
per-token, e2e; goodput under --deadline-s; shed/retry/preempt).
--trace-out records the workload (arrivals, shapes, fault windows)
for exact replay; --fault-bursts N carves N correlated burst-loss
windows into the trace. Open-loop flags are rejected for the
closed-loop families, and --trace-in is mutually exclusive with the
generator flags — a replayed trace IS the workload.

--trace OUT.json exports the run's span trees as Chrome trace-event
JSON (load in Perfetto / chrome://tracing; one track per endpoint).
--baseline PATH collects the deterministic modeled round-time /
throughput of all six families and writes the baseline file CI diffs;
--check-baseline PATH re-collects under the file's recorded config and
exits 1 on drift beyond --baseline-tolerance.
"""
import argparse
import json
import sys
from typing import List, Optional

FABRIC_BENCHMARKS = ("fully_connected", "ring", "incast", "allreduce",
                     "train_step")
#: fabric families that read --algo (the collective schedule)
ALGO_BENCHMARKS = ("allreduce", "train_step")
WORKLOAD_CHOICES = ("poisson", "bursty", "diurnal", "trace")
BENCHMARK_CHOICES = ("p2p_latency", "p2p_bandwidth", "ps_throughput",
                     "fully_connected", "ring", "incast", "allreduce",
                     "train_step")
TRANSPORT_CHOICES = ("collective", "loopback", "simulated", "cluster")

#: values an axis takes when swept (benchmark sweeps over the fabric
#: families: the three paper benchmarks ignore --transport so crossing
#: them with transports would repeat identical runs). workers and
#: stream_chunks are the scaling axes — one invocation yields a
#: worker-count or chunk-count curve.
SWEEP_AXES = {
    "scheme": ("uniform", "random", "skew"),
    "mode": ("non_serialized", "serialized"),
    "wire_mode": ("serialized", "scatter_gather", "zero_copy"),
    "payload": ("small", "medium", "large"),
    "transport": TRANSPORT_CHOICES,
    "benchmark": FABRIC_BENCHMARKS,
    "network": None,     # filled from netmodel.NETWORKS lazily
    "workers": (2, 4, 8, 16),
    "stream_chunks": (1, 2, 4, 8),
    "algo": ("ring", "tree", "rsag"),
    "train_mode": ("ps", "allreduce"),
}

#: sweep axis -> BenchConfig field (identity unless listed)
AXIS_FIELD = {"workers": "num_workers"}


def _metric(st) -> str:
    return {"p2p_latency": "rtt_us", "p2p_bandwidth": "MBps",
            "train_step": "steps_per_s"}.get(st.name, "rpcs_per_s")


def _effective_network(cfg) -> Optional[str]:
    """The network model that actually priced the run: simulated cells
    fall back to eth40g when --network is unset (bench._make_fabric),
    and the report must say so rather than show a null. A cluster cell
    with an explicit spec prices per endpoint/link — labeled
    'cluster'."""
    if cfg.benchmark in FABRIC_BENCHMARKS:
        if cfg.transport == "cluster":
            return ("cluster" if cfg.cluster_spec is not None
                    else cfg.network or "eth40g")
        if cfg.transport == "simulated":
            return cfg.network or "eth40g"
    return cfg.network




def _build_config(args, payload_spec, **overrides):
    from repro.configs.tfgrpc_bench import BenchConfig
    base = dict(
        benchmark=args.benchmark, num_ps=args.num_ps,
        num_workers=args.num_workers, mode=args.mode, scheme=args.scheme,
        skew_bias=args.skew_bias, iovec_count=args.iovec_count,
        small_bytes=args.small_bytes, medium_bytes=args.medium_bytes,
        large_bytes=args.large_bytes,
        categories=tuple(args.categories.split(",")),
        warmup_s=args.warmup, duration_s=args.duration, seed=args.seed,
        network=args.network, transport=args.transport,
        wire_mode=args.wire_mode,
        stream_chunks=args.stream_chunks, fetch_ratio=args.fetch_ratio,
        deadline_s=args.deadline_s, admission_limit=args.admission_limit,
        cluster_spec=args.cluster_spec, payload_spec=payload_spec,
        algo=args.algo or "ring",
        train_mode=args.train_mode or "allreduce",
        trace=args.trace is not None)
    base.update(overrides)
    return BenchConfig(**base)


def _print_single(st, cfg, args) -> None:
    scheme = st.spec.scheme
    tail = "/" + cfg.skew_bias if scheme == "skew" else ""
    extra = f", {cfg.transport}" if cfg.benchmark in FABRIC_BENCHMARKS \
        else ""
    wm = (f", wire={cfg.resolved_wire_mode}" if cfg.wire_mode is not None
          else "")
    print(f"benchmark      : {st.name} [{scheme}{tail}, {cfg.mode}"
          f"{wm}{extra}]")
    print(f"payload        : {st.spec.n_buffers} iovecs, "
          f"{st.spec.total_bytes/1e6:.3f} MB")
    if cfg.benchmark in ALGO_BENCHMARKS:
        tm = (f", train_mode={cfg.train_mode}"
              if cfg.benchmark == "train_step" else "")
        print(f"collective     : algo={cfg.algo}{tm}")
    projected = (cfg.benchmark in FABRIC_BENCHMARKS
                 and cfg.transport in ("simulated", "cluster"))
    label = "net projected " if projected else "host measured "
    if projected:
        print(f"sim network    : {_effective_network(cfg)}")
    print(f"{label} : mean {st.mean_s*1e6:.1f} us  "
          f"p50 {st.p50_s*1e6:.1f}  p95 {st.p95_s*1e6:.1f}  "
          f"({st.n_iters} iters)")
    for k, v in st.derived.items():
        print(f"               : {k} = {v:.2f}")
    if st.resources:
        print(f"resources      : cpu_util {st.resources.cpu_util:.2f}  "
              f"rss_peak {st.resources.rss_peak_bytes/1e6:.0f} MB")
    nets = ([args.network] if args.network else
            sorted(st.model_projection))
    for n in nets:
        unit = {"p2p_latency": "s RTT", "p2p_bandwidth": "MB/s",
                "train_step": "steps/s"}.get(st.name, "RPC/s")
        print(f"model {n:12s}: {st.model_projection[n]:.6g} {unit}")
    _print_phases(st)


def _print_phases(st) -> None:
    """Per-phase latency breakdown table (fabric families with a
    tracer): mean per-call time in each phase, per method."""
    if not st.rpc_phases:
        return
    from repro.rpc.tracing import PHASES
    print("phase breakdown (mean us/call):")
    for meth in sorted(st.rpc_phases):
        rec = st.rpc_phases[meth]
        calls = max(1, rec["calls"])
        cells = "  ".join(
            f"{p} {rec['phases'].get(p, 0.0) / calls * 1e6:.1f}"
            for p in PHASES if rec["phases"].get(p, 0.0) > 0.0)
        print(f"  {meth:24s} {rec['calls']} calls  "
              f"e2e {rec['end_to_end_s'] / calls * 1e6:.1f}  {cells}")


def run_sweep(args, axes: List[str], payload_spec) -> List[dict]:
    """Run the cross-product of the swept axes' values; every cell is
    one bench.run. Cells that cannot run in this environment (e.g. a
    collective cell needing more devices than the host has) are
    reported in the table rather than aborting the sweep."""
    import itertools

    from repro.core import bench
    from repro.core.netmodel import NETWORKS

    values = []
    for ax in axes:
        vals = SWEEP_AXES[ax]
        if ax == "network":
            vals = tuple(sorted(NETWORKS))
        if ax == "benchmark" and "stream_chunks" in axes:
            # crossing benchmark x stream_chunks only makes sense for
            # benchmarks that read the chunk count — fully_connected
            # would repeat identical rows dressed up as a curve
            vals = tuple(b for b in vals if b in ("ring", "incast"))
        if ax == "benchmark" and "algo" in axes:
            # likewise, only the collective families read --algo
            vals = tuple(b for b in vals if b in ALGO_BENCHMARKS)
        if ax == "benchmark" and "train_mode" in axes:
            vals = tuple(b for b in vals if b == "train_step")
        if ax == "payload":
            # the payload axis restricts the generator to ONE size
            # category per cell — a per-category S/M/L curve
            values.append([("categories", (v,)) for v in vals])
            continue
        values.append([(AXIS_FIELD.get(ax, ax), v) for v in vals])
    rows = []
    for combo in itertools.product(*values):
        overrides = dict(combo)
        cfg = _build_config(args, payload_spec, **overrides)
        row = {"benchmark": cfg.benchmark, "scheme": cfg.scheme,
               "mode": cfg.mode, "wire_mode": cfg.resolved_wire_mode,
               "network": _effective_network(cfg)}
        if "payload" in axes:
            row["payload"] = cfg.categories[0]
        if "workers" in axes:
            row["workers"] = cfg.num_workers
        if "stream_chunks" in axes:
            row["stream_chunks"] = cfg.stream_chunks
        if cfg.benchmark in ALGO_BENCHMARKS or "algo" in axes:
            row["algo"] = cfg.algo
        if cfg.benchmark == "train_step" or "train_mode" in axes:
            row["train_mode"] = cfg.train_mode
        if cfg.benchmark in FABRIC_BENCHMARKS:
            row["transport"] = cfg.transport
        try:
            st = bench.run(cfg)
        except (RuntimeError, ValueError) as e:
            row.update(error=str(e).split(";")[0])
            rows.append(row)
            continue
        m = _metric(st)
        row.update(mean_us=st.mean_s * 1e6, p95_us=st.p95_s * 1e6,
                   n_iters=st.n_iters, metric=m,
                   value=st.derived.get(m, st.derived.get("rpcs_per_s")))
        if st.rpc_metrics:
            row["rpc_metrics"] = st.rpc_metrics
        if st.rpc_phases:
            row["rpc_phases"] = st.rpc_phases
        rows.append(row)
    return rows


def _print_sweep(rows: List[dict]) -> None:
    cols = ["benchmark", "scheme", "mode", "wire_mode", "transport",
            "network"]
    for extra in ("payload", "workers", "stream_chunks", "algo",
                  "train_mode"):                           # swept axes
        if any(extra in r for r in rows):
            cols.append(extra)
    n_id = len(cols)                             # identity columns
    cols += ["mean_us", "metric", "value"]
    widths = {c: max(len(c), *(len(_cell(r, c)) for r in rows))
              for c in cols}
    print("  ".join(c.ljust(widths[c]) for c in cols))
    print("  ".join("-" * widths[c] for c in cols))
    for r in rows:
        if "error" in r:
            line = "  ".join(_cell(r, c).ljust(widths[c])
                             for c in cols[:n_id])
            print(f"{line}  SKIPPED: {r['error']}")
        else:
            print("  ".join(_cell(r, c).ljust(widths[c]) for c in cols))


def _cell(row: dict, col: str) -> str:
    v = row.get(col)
    if v is None:
        return "-"
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(
        description="TF-gRPC-Bench micro-benchmark suite (paper Table 2)")
    ap.add_argument("--benchmark", default="p2p_latency",
                    choices=list(BENCHMARK_CHOICES))
    ap.add_argument("--num-ps", type=int, default=1)
    ap.add_argument("--num-workers", type=int, default=1)
    ap.add_argument("--transport", default="collective",
                    choices=list(TRANSPORT_CHOICES))
    ap.add_argument("--cluster-spec", default=None, metavar="JSON|PATH",
                    help="cluster transport topology: inline ClusterSpec "
                         "JSON or a path to a JSON file (default: a "
                         "homogeneous cluster on --network)")
    ap.add_argument("--stream-chunks", type=int, default=4,
                    help="chunks per stream (ring/incast families)")
    ap.add_argument("--algo", default=None,
                    choices=["ring", "tree", "rsag"],
                    help="allreduce/train_step families: the "
                         "collective schedule (ring = bandwidth-"
                         "optimal rotation, tree = binomial "
                         "reduce+broadcast, rsag = reduce-scatter + "
                         "allgather; default ring)")
    ap.add_argument("--train-mode", default=None,
                    choices=["ps", "allreduce"],
                    help="train_step family: gradient-synchronization "
                         "layout — ps shards parameters across "
                         "--num-ps server endpoints (push/fetch "
                         "flights), allreduce reduces with the --algo "
                         "schedule across --num-workers (default "
                         "allreduce); sweep workers across both to "
                         "find the crossover")
    ap.add_argument("--fetch-ratio", type=float, default=1.0,
                    help="incast: fetch payload as a fraction/multiple "
                         "of the push payload (1.0 = symmetric)")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="fabric families: default per-call deadline "
                         "(relative s), propagated to servers in the "
                         "frame header — servers shed expired work; "
                         "shed/deadline counts land in rpc_metrics")
    ap.add_argument("--admission-limit", type=int, default=None,
                    help="fabric families: per-endpoint outstanding-"
                         "call cap enforced by server-side admission "
                         "control (rejected calls retry; rejected "
                         "counts land in rpc_metrics)")
    ap.add_argument("--workload", default=None,
                    choices=list(WORKLOAD_CHOICES),
                    help="open-loop workload mode: synthesize a seeded "
                         "arrival process (poisson/bursty/diurnal, "
                         "needs --rate and --duration-s) or replay a "
                         "recorded trace (trace, needs --trace-in) "
                         "against a synthetic serve cluster, and "
                         "report SLOs instead of closed-loop "
                         "throughput")
    ap.add_argument("--rate", type=float, default=None,
                    help="workload generators: offered load in req/s")
    ap.add_argument("--duration-s", type=float, default=None,
                    help="workload generators: trace horizon in "
                         "modeled seconds")
    ap.add_argument("--trace-in", default=None, metavar="PATH",
                    help="--workload trace: recorded trace to replay "
                         "(mutually exclusive with the generator "
                         "flags)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="workload mode: record the trace (arrivals, "
                         "shapes, fault windows) for exact replay")
    ap.add_argument("--prompt-dist", default="lognormal",
                    choices=["lognormal", "zipf", "small", "medium",
                             "large"],
                    help="workload generators: prompt-length sampler "
                         "(heavy-tailed lognormal/zipf, or a fixed "
                         "paper size category)")
    ap.add_argument("--sched-policy", default="fifo",
                    choices=["fifo", "sjf"],
                    help="workload mode: per-endpoint serve scheduler "
                         "admission policy")
    ap.add_argument("--dispatch-policy", default="round_robin",
                    choices=["round_robin", "least_loaded",
                             "scheduler_least_loaded"],
                    help="workload mode: sharded dispatch policy "
                         "across ps endpoints")
    ap.add_argument("--starvation-age-s", type=float, default=None,
                    help="workload mode, --sched-policy sjf: waits "
                         "past this age regain FIFO priority")
    ap.add_argument("--max-batch", type=int, default=8,
                    help="workload mode: per-endpoint continuous-"
                         "batching admission cap")
    ap.add_argument("--kv-blocks", type=int, default=None,
                    help="workload mode: per-endpoint KV-cache block "
                         "budget (None = unbounded; small values "
                         "exercise preemption)")
    ap.add_argument("--fault-bursts", type=int, default=0,
                    help="workload generators: carve this many "
                         "correlated burst-loss windows into the "
                         "trace (replayed with it)")
    ap.add_argument("--fault-burst-width-s", type=float, default=0.5,
                    help="width of each --fault-bursts window "
                         "(modeled seconds)")
    ap.add_argument("--mode", default="non_serialized",
                    choices=["non_serialized", "serialized"])
    ap.add_argument("--wire-mode", default=None,
                    choices=["serialized", "scatter_gather",
                             "zero_copy"],
                    help="rpc datapath encoding (default derives from "
                         "--mode: serialized -> serialized, "
                         "non_serialized -> scatter_gather); zero_copy "
                         "ships pre-registered shared-pool descriptors "
                         "instead of payload bytes (unsupported on "
                         "--transport collective)")
    ap.add_argument("--scheme", default="uniform",
                    choices=["uniform", "random", "skew"])
    ap.add_argument("--skew-bias", default="large",
                    choices=["large", "medium", "small"])
    ap.add_argument("--iovec-count", type=int, default=10)
    ap.add_argument("--small-bytes", type=int, default=10)
    ap.add_argument("--medium-bytes", type=int, default=10 * 1024)
    ap.add_argument("--large-bytes", type=int, default=1024 * 1024)
    ap.add_argument("--categories", default="small,medium,large")
    ap.add_argument("--warmup", type=float, default=2.0)
    ap.add_argument("--duration", type=float, default=10.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--network", default=None,
                    help="print only this network's projection")
    ap.add_argument("--arch", default=None,
                    help="payload from this arch's parameter histogram")
    ap.add_argument("--sweep", default=None, metavar="AXES",
                    help="comma-separated axes to cross-product in one "
                         f"run: {','.join(SWEEP_AXES)}")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the result rows as a versioned "
                         "JSON envelope {schema: 2, rows: [...]} "
                         "('-' for stdout)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="fabric families, single run: export the "
                         "run's span trees as Chrome trace-event JSON "
                         "(Perfetto / chrome://tracing)")
    ap.add_argument("--baseline", default=None, metavar="PATH",
                    help="collect the deterministic modeled baseline "
                         "(round time + throughput, all six families) "
                         "and write it to PATH, then exit")
    ap.add_argument("--check-baseline", default=None, metavar="PATH",
                    help="re-collect under PATH's recorded config and "
                         "exit 1 on drift beyond --baseline-tolerance")
    ap.add_argument("--baseline-tolerance", type=float, default=0.01,
                    help="relative drift tolerance for "
                         "--check-baseline (default 0.01 = 1%%)")
    args = ap.parse_args(argv)

    # --categories: validate against the payload generator's known
    # buffer categories instead of silently generating from nothing
    from repro.core.payload import CATEGORIES
    cats = tuple(c for c in args.categories.split(",") if c)
    unknown = [c for c in cats if c not in CATEGORIES]
    if unknown or not cats:
        ap.error(f"--categories: unknown categor"
                 f"{'y' if len(unknown) == 1 else 'ies'} "
                 f"{', '.join(repr(c) for c in unknown) or '(empty)'}; "
                 f"choose from {', '.join(CATEGORIES)}")
    args.categories = ",".join(cats)

    if args.mode == "serialized" and args.wire_mode in (
            "scatter_gather", "zero_copy"):
        ap.error(f"--wire-mode {args.wire_mode} contradicts --mode "
                 "serialized; drop one of the two flags")

    if args.fetch_ratio <= 0:
        ap.error(f"--fetch-ratio must be > 0, got {args.fetch_ratio}")
    if args.deadline_s is not None and args.deadline_s <= 0:
        ap.error(f"--deadline-s must be > 0, got {args.deadline_s}")
    if args.admission_limit is not None and args.admission_limit < 1:
        ap.error(f"--admission-limit must be >= 1, got "
                 f"{args.admission_limit}")
    if (args.deadline_s is not None or args.admission_limit is not None) \
            and args.benchmark not in FABRIC_BENCHMARKS \
            and args.sweep is None and args.workload is None:
        ap.error("--deadline-s/--admission-limit need a fabric "
                 f"benchmark ({', '.join(FABRIC_BENCHMARKS)}); got "
                 f"--benchmark {args.benchmark}")
    if args.algo is not None and args.benchmark not in ALGO_BENCHMARKS \
            and args.sweep is None and args.workload is None:
        ap.error(f"--algo needs a collective benchmark "
                 f"({', '.join(ALGO_BENCHMARKS)}); got --benchmark "
                 f"{args.benchmark}")
    if args.train_mode is not None and args.benchmark != "train_step" \
            and args.sweep is None and args.workload is None:
        ap.error(f"--train-mode needs --benchmark train_step; got "
                 f"--benchmark {args.benchmark}")
    if args.baseline_tolerance <= 0:
        ap.error(f"--baseline-tolerance must be > 0, got "
                 f"{args.baseline_tolerance}")
    if args.baseline is not None and args.check_baseline is not None:
        ap.error("--baseline and --check-baseline are mutually "
                 "exclusive (write a file OR diff against one)")
    if args.trace is not None:
        if args.sweep is not None:
            ap.error("--trace needs a single run, not --sweep (one "
                     "trace file per run)")
        if args.baseline is not None or args.check_baseline is not None:
            ap.error("--trace records a benchmark run's spans, but "
                     "--baseline/--check-baseline collect modeled "
                     "numbers without running a benchmark; drop one "
                     "of the flags")
        if args.benchmark not in FABRIC_BENCHMARKS:
            ap.error(f"--trace needs a fabric benchmark "
                     f"({', '.join(FABRIC_BENCHMARKS)}); got "
                     f"--benchmark {args.benchmark}")

    # open-loop workload flags vs the closed-loop paper families:
    # every combination is either meaningful or a loud error, never a
    # silently ignored flag
    if args.workload is None:
        used = [name for name, val in (
            ("--rate", args.rate),
            ("--duration-s", args.duration_s),
            ("--trace-in", args.trace_in),
            ("--trace-out", args.trace_out),
            ("--starvation-age-s", args.starvation_age_s),
            ("--kv-blocks", args.kv_blocks),
            ("--fault-bursts", args.fault_bursts or None),
        ) if val is not None]
        if args.sched_policy != "fifo":
            used.append("--sched-policy")
        if args.dispatch_policy != "round_robin":
            used.append("--dispatch-policy")
        if used:
            ap.error(f"{', '.join(used)}: open-loop workload flag"
                     f"{'s' if len(used) > 1 else ''} without "
                     f"--workload — the closed-loop paper families "
                     f"pace themselves on completions; pass "
                     f"--workload {{{', '.join(WORKLOAD_CHOICES)}}} "
                     f"for an open-loop run")
    else:
        for flag, val in (("--sweep", args.sweep),
                          ("--trace", args.trace),
                          ("--baseline", args.baseline),
                          ("--check-baseline", args.check_baseline),
                          ("--arch", args.arch),
                          ("--algo", args.algo),
                          ("--train-mode", args.train_mode)):
            if val is not None:
                ap.error(f"--workload is a standalone open-loop run; "
                         f"it cannot combine with {flag}")
        if args.fault_bursts < 0:
            ap.error(f"--fault-bursts must be >= 0, got "
                     f"{args.fault_bursts}")
        if args.fault_burst_width_s <= 0:
            ap.error(f"--fault-burst-width-s must be > 0, got "
                     f"{args.fault_burst_width_s}")
        if args.max_batch < 1:
            ap.error(f"--max-batch must be >= 1, got {args.max_batch}")
        if args.kv_blocks is not None and args.kv_blocks < 1:
            ap.error(f"--kv-blocks must be >= 1, got {args.kv_blocks}")
        if args.workload == "trace":
            if args.trace_in is None:
                ap.error("--workload trace replays a recorded trace; "
                         "pass --trace-in PATH")
            fixed = [n for n, v in (("--rate", args.rate),
                                    ("--duration-s", args.duration_s))
                     if v is not None]
            if args.fault_bursts:
                fixed.append("--fault-bursts")
            if fixed:
                ap.error(f"{', '.join(fixed)}: a replayed trace "
                         f"already fixes its arrivals and fault "
                         f"schedule; generator flags are mutually "
                         f"exclusive with --trace-in")
        else:
            if args.trace_in is not None:
                ap.error("--trace-in implies --workload trace; the "
                         f"{args.workload} generator synthesizes its "
                         "own arrivals")
            if args.rate is None or args.duration_s is None:
                ap.error(f"--workload {args.workload} is open-loop: "
                         f"it needs --rate (req/s) and --duration-s")
            if args.rate <= 0:
                ap.error(f"--rate must be > 0, got {args.rate}")
            if args.duration_s <= 0:
                ap.error(f"--duration-s must be > 0, got "
                         f"{args.duration_s}")

    axes = None
    if args.sweep is not None:
        axes = [a.strip() for a in args.sweep.split(",") if a.strip()]
        bad = [a for a in axes if a not in SWEEP_AXES]
        if bad or not axes:
            ap.error(f"--sweep: unknown axes {bad or '(empty)'}; choose "
                     f"from {', '.join(SWEEP_AXES)}")
        dups = sorted({a for a in axes if axes.count(a) > 1})
        if dups:
            ap.error(f"--sweep: duplicate ax"
                     f"{'is' if len(dups) == 1 else 'es'} "
                     f"{', '.join(repr(a) for a in dups)}; each axis "
                     f"may appear once")
        if "transport" in axes and "benchmark" not in axes \
                and args.benchmark not in FABRIC_BENCHMARKS:
            ap.error(f"--sweep transport needs a fabric benchmark "
                     f"({', '.join(FABRIC_BENCHMARKS)}); "
                     f"got --benchmark {args.benchmark}")
        # the scaling axes only scale benchmarks that read them —
        # sweeping them elsewhere would print identical rows dressed
        # up as a curve
        workers_ok = FABRIC_BENCHMARKS + ("ps_throughput",)
        if "workers" in axes and "benchmark" not in axes \
                and args.benchmark not in workers_ok:
            ap.error(f"--sweep workers needs a benchmark that scales "
                     f"with workers ({', '.join(workers_ok)}); "
                     f"got --benchmark {args.benchmark}")
        streaming_ok = ("ring", "incast")
        if "stream_chunks" in axes \
                and args.benchmark not in streaming_ok \
                and "benchmark" not in axes:
            ap.error(f"--sweep stream_chunks needs a streaming "
                     f"benchmark ({', '.join(streaming_ok)}); "
                     f"got --benchmark {args.benchmark}")
        if "algo" in axes and args.benchmark not in ALGO_BENCHMARKS \
                and "benchmark" not in axes:
            ap.error(f"--sweep algo needs a collective benchmark "
                     f"({', '.join(ALGO_BENCHMARKS)}); got "
                     f"--benchmark {args.benchmark}")
        if "train_mode" in axes and args.benchmark != "train_step" \
                and "benchmark" not in axes:
            ap.error(f"--sweep train_mode needs --benchmark "
                     f"train_step; got --benchmark {args.benchmark}")
        if "stream_chunks" in axes and ("algo" in axes
                                        or "train_mode" in axes):
            # no benchmark reads both the chunk count and the
            # collective axes — the cross-product would be empty
            ap.error("--sweep stream_chunks cannot cross algo/"
                     "train_mode: no benchmark reads both")

    if args.cluster_spec is not None:
        # parse + consistency in one place, before any work or output
        if args.transport != "cluster" \
                and not (axes and "transport" in axes) \
                and args.workload is None:
            ap.error("--cluster-spec needs --transport cluster, a "
                     "transport sweep axis, or --workload")
        from repro.rpc.cluster import load_cluster_spec
        try:
            args.cluster_spec = load_cluster_spec(args.cluster_spec)
        except (OSError, ValueError, KeyError, TypeError) as e:
            ap.error(f"--cluster-spec: {e}")

    if args.workload is not None:
        rows = run_workload(args, ap)
        _write_json(args, rows)
        return

    from repro.core import bench

    # baseline telemetry actions are standalone: collect/diff the
    # deterministic modeled numbers and exit without running a bench
    if args.check_baseline is not None:
        try:
            with open(args.check_baseline) as f:
                data = json.load(f)
        except (OSError, ValueError) as e:
            ap.error(f"--check-baseline: {e}")
        problems = bench.check_baseline(
            data, rel_tol=args.baseline_tolerance)
        if problems:
            for p in problems:
                print(f"BASELINE DRIFT: {p}")
            sys.exit(1)
        n_wm = len(data.get("wire_modes", {}))
        print(f"baseline OK: {len(data.get('families', {}))} families"
              f"{f' x {n_wm} wire modes' if n_wm else ''} "
              f"within {args.baseline_tolerance:.2%}")
        return
    if args.baseline is not None:
        kw = {"network": args.network} if args.network else {}
        data = bench.collect_baseline(**kw)
        text = json.dumps(data, indent=2, sort_keys=True)
        if args.baseline == "-":
            sys.stdout.write(text + "\n")
        else:
            with open(args.baseline, "w") as f:
                f.write(text + "\n")
            print(f"wrote baseline ({len(data['families'])} families, "
                  f"{data['config']['network']}) to {args.baseline}")
        return

    payload_spec = None
    if args.arch:
        from repro.configs import get_config
        from repro.core.payload import from_arch
        payload_spec = from_arch(get_config(args.arch), seed=args.seed)
        print(f"payload from {args.arch}: {payload_spec.n_buffers} "
              f"buffers, {payload_spec.total_bytes/1e6:.2f} MB "
              f"({', '.join(payload_spec.categories)})")

    if axes is not None:
        rows = run_sweep(args, axes, payload_spec)
        _print_sweep(rows)
    else:
        cfg = _build_config(args, payload_spec)
        st = bench.run(cfg)
        _print_single(st, cfg, args)
        m = _metric(st)
        rows = [{"benchmark": st.name, "scheme": st.spec.scheme,
                 "mode": cfg.mode, "transport": cfg.transport,
                 "network": _effective_network(cfg),
                 "mean_us": st.mean_s * 1e6,
                 "p95_us": st.p95_s * 1e6, "n_iters": st.n_iters,
                 "metric": m,
                 "value": st.derived.get(m,
                                         st.derived.get("rpcs_per_s"))}]
        if st.rpc_metrics:
            rows[0]["rpc_metrics"] = st.rpc_metrics
        if st.rpc_phases:
            rows[0]["rpc_phases"] = st.rpc_phases
        if args.trace:
            if st.tracer is None:
                ap.error(f"--trace: the {cfg.transport} run attached "
                         f"no tracer")
            st.tracer.export_chrome(args.trace)
            print(f"wrote Chrome trace ({len(st.tracer.spans())} "
                  f"spans) to {args.trace}")
    _write_json(args, rows)


def _write_json(args, rows: List[dict]) -> None:
    if not args.json:
        return
    text = json.dumps({"schema": 3, "rows": rows}, indent=2)
    if args.json == "-":
        sys.stdout.write(text + "\n")
    else:
        with open(args.json, "w") as f:
            f.write(text + "\n")
        print(f"wrote {len(rows)} row(s) to {args.json}")


def run_workload(args, ap) -> List[dict]:
    """Open-loop workload mode: build/replay the trace, serve it, and
    print the SLO table. Returns the schema-3 workload row."""
    from repro.workload import (Trace, correlated_burst_windows,
                                format_slo_table, serve_workload,
                                synthesize_trace)
    if args.workload == "trace":
        try:
            trace = Trace.load(args.trace_in)
        except (OSError, ValueError, KeyError, TypeError) as e:
            ap.error(f"--trace-in: {e}")
    else:
        trace = synthesize_trace(args.workload, args.rate,
                                 args.duration_s, seed=args.seed,
                                 prompt_kind=args.prompt_dist)
        if args.fault_bursts:
            correlated_burst_windows(
                trace, n_windows=args.fault_bursts,
                width_s=args.fault_burst_width_s)
    if args.trace_out:
        trace.save(args.trace_out)
        print(f"wrote trace ({len(trace)} events, "
              f"{len(trace.fault_windows)} fault windows) to "
              f"{args.trace_out}")
    try:
        run = serve_workload(
            trace, cluster=args.cluster_spec, n_ps=args.num_ps,
            n_workers=args.num_workers,
            dispatch_policy=args.dispatch_policy,
            sched_policy=args.sched_policy,
            starvation_age_s=args.starvation_age_s,
            max_batch=args.max_batch, kv_blocks=args.kv_blocks,
            deadline_s=args.deadline_s)
    except ValueError as e:
        ap.error(f"--workload: {e}")
    kind = trace.meta.get("kind", "trace")
    print(f"workload       : {kind} [{len(trace)} events over "
          f"{trace.duration_s:.3f} s, seed {trace.seed}]")
    print(f"serving        : {args.num_ps} ps x {args.num_workers} "
          f"workers, sched {args.sched_policy}, dispatch "
          f"{args.dispatch_policy}")
    if trace.fault_windows:
        print(f"fault windows  : {len(trace.fault_windows)} "
              f"correlated burst-loss window"
              f"{'s' if len(trace.fault_windows) > 1 else ''}")
    print(format_slo_table(run.report))
    return [{
        "benchmark": "workload", "workload": kind,
        "events": len(trace), "seed": trace.seed,
        "sched_policy": args.sched_policy,
        "dispatch_policy": args.dispatch_policy,
        "fault_windows": len(trace.fault_windows),
        "slo": run.report.to_dict(),
        "rpc_metrics": run.metrics.snapshot(gauges=True),
    }]


if __name__ == "__main__":
    main()
