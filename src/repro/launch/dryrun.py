import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape) cell:
  with mesh:
      lowered  = jax.jit(step, in_shardings=..., out_shardings=...) \
                    .lower(**input_specs(arch))
      compiled = lowered.compile()
      print(compiled.memory_analysis())
      print(compiled.cost_analysis())
on BOTH the single-pod (16,16)=(data,model) mesh and the 2-pod
(2,16,16)=(pod,data,model) mesh, plus the per-segment roofline terms
(launch/roofline.py) on the single-pod mesh. Results are cached as JSON
under results/dryrun/.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b \
      --shape train_4k [--multi-pod] [--roofline] [--force]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax

from repro.configs import SHAPES, cells, get_config, get_shape, list_archs
from repro.launch import hlo as hlo_lib
from repro.launch import roofline as roof_lib
from repro.launch import specs as specs_lib
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_production_mesh
from repro.parallel.sharding import make_ctx

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _cell_path(arch: str, shape: str, mesh_name: str,
               variant: str = "") -> str:
    v = f"__{variant}" if variant else ""
    return os.path.join(RESULTS_DIR, f"{arch}__{shape}__{mesh_name}{v}.json")


def _memory_analysis_dict(compiled) -> Dict[str, Any]:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # noqa: BLE001
        return {"available": False, "error": str(e)}
    if ma is None:
        return {"available": False}
    out = {"available": True}
    for k in ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "temp_size_in_bytes",
              "alias_size_in_bytes", "host_generated_code_size_in_bytes",
              "host_argument_size_in_bytes", "host_output_size_in_bytes",
              "host_temp_size_in_bytes", "peak_memory_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    out["repr"] = str(ma)
    return out


def _analytic_bytes_per_device(ctx, acfg, shape) -> Dict[str, float]:
    """Sharded resident bytes/device: params + optimizer + decode state."""
    import numpy as np

    def tree_bytes(sds_tree, logical_tree):
        from repro.parallel.sharding import logical_to_physical
        specs = logical_to_physical(ctx, logical_tree)
        total = 0.0
        for s, sp in zip(jax.tree.leaves(sds_tree), jax.tree.leaves(specs)):
            n = np.prod(s.shape) * s.dtype.itemsize
            div = 1
            for ax in sp:
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                for a in axes:
                    div *= ctx.mesh.shape[a]
            total += n / div
        return float(total)

    from repro.models import model as M
    out = {}
    psds = specs_lib.param_specs(acfg)
    out["params"] = tree_bytes(psds, M.param_logical_axes(acfg))
    if shape.kind == "train":
        # m/v mirror params (adamw) or factored (adafactor ~= params/64)
        mult = {"adamw": 2.0, "adafactor": 0.05, "sgd": 1.0}[
            acfg.train.optimizer]
        out["optimizer"] = out["params"] * mult
    if shape.kind == "decode":
        ssds = specs_lib.state_specs(ctx, acfg, shape)
        out["decode_state"] = tree_bytes(
            ssds, jax.tree.map(
                lambda lp: lp, M.state_logical_axes(acfg, shape.global_batch)))
    out["total"] = sum(out.values())
    return out


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             do_roofline: bool = False, force: bool = False,
             overrides: Optional[Dict] = None,
             variant: str = "") -> Dict[str, Any]:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    path = _cell_path(arch, shape_name, mesh_name, variant)
    if not force and os.path.exists(path):
        with open(path) as f:
            return json.load(f)

    acfg = get_config(arch)
    if overrides:
        acfg = _apply_overrides(acfg, overrides)
    shape = get_shape(shape_name)
    result: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "variant": variant, "ok": False,
    }
    skips = dict(acfg.skip_reasons)
    if shape_name not in acfg.shapes:
        result["skipped"] = skips.get(shape_name, "unsupported")
        _save(path, result)
        return result

    t_start = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        ctx = make_ctx(acfg, mesh)
        with mesh:
            if shape.kind == "train":
                step = steps_lib.make_train_step(ctx, acfg, donate=False)
                args = specs_lib.input_specs(ctx, acfg, shape)
            elif shape.kind == "prefill":
                step = steps_lib.make_prefill_step(ctx, acfg)
                args = specs_lib.input_specs(ctx, acfg, shape)
            else:
                step = steps_lib.make_decode_step(ctx, acfg,
                                                  shape.global_batch)
                args = specs_lib.input_specs(ctx, acfg, shape)
            t0 = time.time()
            lowered = step.lower(*args)
            result["lower_s"] = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            result["compile_s"] = time.time() - t0

            ma = _memory_analysis_dict(compiled)
            print(f"[{arch} x {shape_name} x {mesh_name}] "
                  f"memory_analysis: {ma.get('repr', ma)}")
            result["memory_analysis"] = ma
            ca = compiled.cost_analysis()
            if isinstance(ca, list):
                ca = ca[0]
            ca = {k: float(v) for k, v in (ca or {}).items()
                  if isinstance(v, (int, float))}
            print(f"[{arch} x {shape_name} x {mesh_name}] cost_analysis: "
                  f"flops={ca.get('flops')} bytes={ca.get('bytes accessed')}")
            result["cost_analysis"] = {
                k: ca[k] for k in ("flops", "bytes accessed",
                                   "transcendentals", "utilization operand 0")
                if k in ca}
            hlo_text = compiled.as_text()
            result["collectives_full_hlo"] = \
                hlo_lib.parse_collectives(hlo_text).summary()
            result["while_trip_counts"] = \
                hlo_lib.count_while_trip_factor(hlo_text)
            result["overlap"] = hlo_lib.overlap_stats(hlo_text)
            result["analytic_bytes_per_device"] = \
                _analytic_bytes_per_device(ctx, acfg, shape)

            if do_roofline and not multi_pod:
                segs = roof_lib.segment_costs(ctx, acfg, shape)
                rf = roof_lib.build_roofline(ctx, acfg, shape, mesh_name,
                                             segs)
                result["roofline"] = rf.to_dict()
        result["ok"] = True
    except Exception as e:  # noqa: BLE001
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-4000:]
    result["total_s"] = time.time() - t_start
    _save(path, result)
    return result


def _apply_overrides(acfg, overrides: Dict):
    """Hillclimb knobs: {'parallel.fsdp': True, 'train.remat': False, ...}"""
    for k, v in overrides.items():
        section, field_ = k.split(".", 1)
        sub = getattr(acfg, section)
        acfg = acfg.replace(**{section: dataclasses.replace(sub,
                                                            **{field_: v})})
    return acfg


def _save(path: str, result: Dict) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(result, f, indent=1, default=str)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--roofline", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    todo = []
    if args.all:
        for arch, shape, skip in cells(include_skipped=True):
            if skip is None:
                todo.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo.append((args.arch, args.shape))

    n_fail = 0
    for arch, shape in todo:
        r = run_cell(arch, shape, multi_pod=args.multi_pod,
                     do_roofline=args.roofline, force=args.force)
        status = "SKIP" if "skipped" in r else ("OK" if r["ok"] else "FAIL")
        print(f"{status}: {arch} x {shape} "
              f"(compile {r.get('compile_s', 0):.1f}s)")
        if status == "FAIL":
            n_fail += 1
            print(r.get("error"))
    if n_fail:
        raise SystemExit(f"{n_fail} cells failed")


if __name__ == "__main__":
    main()
