"""Post-SPMD HLO analysis: collective inventory + wire-byte estimates.

Parses ``compiled.as_text()`` (partitioned, optimized HLO — per-device
shapes) for every collective op. Wire bytes per device use standard
ring-algorithm factors with the group size n taken from replica_groups:

  all-reduce         2 (n-1)/n x bytes(out)
  all-gather           (n-1)/n x bytes(out)
  reduce-scatter       (n-1)   x bytes(out)   (input = n x out)
  all-to-all           (n-1)/n x bytes(out)
  collective-permute             bytes(out)
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    m = _SHAPE_RE.match(type_str.strip())
    if not m:
        return 0
    dt, dims = m.group(1), m.group(2)
    if dt not in DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * DTYPE_BYTES[dt]


def _result_bytes(line: str) -> int:
    """Bytes of the op's result — handles tuple results by summing."""
    m = re.search(r"=\s+(.*?)\s+(?:%?\w[\w\-.]*)\(", line)
    if not m:
        return 0
    t = m.group(1)
    if t.startswith("("):
        return sum(_shape_bytes(p) for p in t.strip("()").split(","))
    return _shape_bytes(t)


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return max(1, m.group(1).count(",") + 1)
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # [n_groups,group_size] iota form
        return max(1, int(m.group(2)))
    return 2


@dataclass
class CollectiveStats:
    counts: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    result_bytes: Dict[str, int] = field(
        default_factory=lambda: defaultdict(int))
    wire_bytes: Dict[str, float] = field(
        default_factory=lambda: defaultdict(float))
    ops: List[Tuple[str, int, int]] = field(default_factory=list)

    @property
    def total_wire_bytes(self) -> float:
        return float(sum(self.wire_bytes.values()))

    def scaled(self, factor: float) -> "CollectiveStats":
        out = CollectiveStats()
        for k in self.counts:
            out.counts[k] = int(self.counts[k] * factor)
            out.result_bytes[k] = int(self.result_bytes[k] * factor)
            out.wire_bytes[k] = self.wire_bytes[k] * factor
        return out

    def merged(self, other: "CollectiveStats") -> "CollectiveStats":
        out = CollectiveStats()
        for src in (self, other):
            for k in src.counts:
                out.counts[k] += src.counts[k]
                out.result_bytes[k] += src.result_bytes[k]
                out.wire_bytes[k] += src.wire_bytes[k]
        return out

    def summary(self) -> Dict:
        return {"counts": dict(self.counts),
                "result_bytes": dict(self.result_bytes),
                "wire_bytes": {k: float(v)
                               for k, v in self.wire_bytes.items()},
                "total_wire_bytes": self.total_wire_bytes}


def _wire_factor(kind: str, n: int) -> float:
    if kind == "all-reduce":
        return 2.0 * (n - 1) / n
    if kind == "all-gather":
        return (n - 1) / n
    if kind == "reduce-scatter":
        return float(n - 1)
    if kind == "all-to-all":
        return (n - 1) / n
    return 1.0  # collective-permute


_DEF_RE = re.compile(r"^%?([\w.\-]+)\s*=\s*\(?(\w+)\[")
_CALL_RE = re.compile(r"=\s+\S+\s+([\w\-]+)\(([^)]*)\)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->")


def _def_table(hlo_text: str):
    """name -> (result dtype, opcode, first-operand name, called-comp).
    Also returns the set of computations containing bf16 intermediates
    (fused convert round-trips hide the narrow dtype inside)."""
    table = {}
    bf16_comps = set()
    current = None
    for line in hlo_text.splitlines():
        s = line.strip()
        hdr = _COMP_HDR_RE.match(s)
        if hdr and "{" in s:
            current = hdr.group(1)
        if current and " bf16[" in s:
            bf16_comps.add(current)
        m = _DEF_RE.match(s)
        if not m:
            continue
        name, dtype = m.group(1), m.group(2)
        mc = _CALL_RE.search(s)
        mcalls = _CALLS_RE.search(s)
        op, arg0 = "", ""
        if mc:
            op = mc.group(1)
            args = [a.strip().lstrip("%") for a in mc.group(2).split(",")]
            arg0 = args[0] if args and args[0] else ""
        table[name] = (dtype, op, arg0,
                       mcalls.group(1) if mcalls else "")
    return table, bf16_comps


def _true_elem_dtype(name: str, table, hops: int = 4) -> Optional[str]:
    """Narrowest dtype along the convert/copy chain feeding a collective:
    XLA:CPU's float-normalization upcasts every bf16 value to f32 BEFORE
    SPMD partitioning, so collectives that would run bf16 on TPU appear
    as f32(convert(bf16(convert(f32 master)))). The wire dtype is the
    NARROWEST in the chain — the compute copy — not the original master
    (DESIGN.md hardware-adaptation; EXPERIMENTS.md §Roofline)."""
    table, bf16_comps = table
    seen = []
    for _ in range(hops):
        if name not in table:
            break
        dtype, op, arg0, calls = table[name]
        seen.append(dtype)
        # fused convert round-trip (f32->bf16->f32) hides bf16 inside the
        # fused computation
        if op == "fusion" and calls in bf16_comps and dtype == "f32":
            seen.append("bf16")
        if op in ("convert", "copy", "bitcast", "reshape", "transpose",
                  "fusion") and arg0 and arg0 in table:
            name = arg0
            continue
        break
    widths = [DTYPE_BYTES.get(d) for d in seen if d in DTYPE_BYTES]
    if not widths:
        return None
    narrowest = min(widths)
    for d in seen:
        if DTYPE_BYTES.get(d) == narrowest:
            return d
    return None


def parse_collectives(hlo_text: str) -> CollectiveStats:
    st = CollectiveStats()
    table = _def_table(hlo_text)  # (defs, bf16-computations)
    for line in hlo_text.splitlines():
        s = line.strip()
        for kind in COLLECTIVES:
            # match op invocation, incl. -start variants; skip -done
            if re.search(rf"\b{kind}(-start)?\(", s) and f"{kind}-done" \
                    not in s:
                rb = _result_bytes(s)
                n = _group_size(s)
                # dtype correction for XLA:CPU float normalization
                mc = _CALL_RE.search(s)
                md = _DEF_RE.match(s)
                if mc and md and md.group(2) == "f32":
                    arg0 = [a.strip().lstrip("%")
                            for a in mc.group(2).split(",")][0]
                    src = _true_elem_dtype(arg0, table)
                    if src in ("bf16", "f16"):
                        rb //= 2
                    elif src in ("s8", "u8", "f8e4m3fn", "f8e5m2"):
                        rb //= 4
                st.counts[kind] += 1
                st.result_bytes[kind] += rb
                st.wire_bytes[kind] += rb * _wire_factor(kind, n)
                st.ops.append((kind, rb, n))
                break
    return st


def count_while_trip_factor(hlo_text: str) -> List[int]:
    """Known trip counts of while loops (XLA annotates them)."""
    return [int(m) for m in
            re.findall(r'known_trip_count=\{"?n"?[:=]\s*"?(\d+)"?\}',
                       hlo_text)]


def overlap_stats(hlo_text: str) -> Dict[str, int]:
    """Compute/communication overlap evidence: async collectives
    (``*-start``/``*-done`` pairs) can hide behind compute; synchronous
    ones cannot. XLA's latency-hiding scheduler targets the async form —
    the ratio is the structural overlap headroom we report per cell."""
    async_n = sync_n = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        for kind in COLLECTIVES:
            if re.search(rf"\b{kind}-start\(", s):
                async_n += 1
                break
            if re.search(rf"\b{kind}\(", s) and f"{kind}-done" not in s:
                sync_n += 1
                break
    return {"async_collectives": async_n, "sync_collectives": sync_n}
