"""Mesh construction. ``make_production_mesh`` is a FUNCTION so importing
this module never touches jax device state (the dry-run must set
XLA_FLAGS before any device query)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: (16,16)=(data,model) = 256 chips (v5e pod).
    Multi-pod: (2,16,16)=(pod,data,model) = 512 chips; the 'pod' axis
    crosses DCN."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2,
                   pod: Optional[int] = None) -> Mesh:
    """Small mesh over however many host devices tests forced."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
