"""Roofline analysis (EXPERIMENTS.md §Roofline).

Because XLA's cost analysis counts a ``while`` (scan) body ONCE, the
full-model compile under-reports FLOPs/bytes by ~n_periods. Terms are
therefore assembled from per-SEGMENT lowerings compiled under the same
mesh/shardings:

    total = embed/loss segment + n_periods x period segment (+ optimizer)

Each segment is compiled post-SPMD, so cost_analysis FLOPs/bytes and the
parsed collective wire bytes are all PER DEVICE. Terms (seconds):

    compute    = flops_per_device / peak_flops
    memory     = bytes_per_device / hbm_bw
    collective = wire_bytes_per_device[ici] / ici_bw  (+ dcn term)

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI,
~6.25 GB/s/chip DCN (pod axis).
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.launch import hlo as hlo_lib
from repro.launch import specs as specs_lib
from repro.launch import steps as steps_lib
from repro.models import model as M
from repro.optim import optimizer as O
from repro.parallel.sharding import ParallelCtx, logical_to_physical

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link (1 link assumed per transfer)
DCN_BW = 6.25e9              # bytes/s / chip across pods


@dataclass
class SegmentCost:
    name: str
    flops: float
    bytes_accessed: float
    collectives: hlo_lib.CollectiveStats
    compile_s: float

    def scaled(self, k: float) -> "SegmentCost":
        return SegmentCost(self.name, self.flops * k,
                           self.bytes_accessed * k,
                           self.collectives.scaled(k), self.compile_s)


def extrapolate_two_point(c1: "SegmentCost", c2: "SegmentCost",
                          ratio: float) -> "SegmentCost":
    """cost(S) from lowerings at S1 and 2*S1 (ratio = S/S1): separates
    the FIXED per-layer part (weight all-gathers, optimizer-ish setup)
    from the PER-TOKEN part, so token scaling never multiplies weight
    movement (§Roofline methodology)."""
    def ext(v1, v2):
        per = max(v2 - v1, 0.0)
        fixed = max(v1 - per, 0.0)
        return fixed + per * ratio

    coll = hlo_lib.CollectiveStats()
    keys = set(c1.collectives.wire_bytes) | set(c2.collectives.wire_bytes)
    for k in keys:
        coll.wire_bytes[k] = ext(c1.collectives.wire_bytes.get(k, 0.0),
                                 c2.collectives.wire_bytes.get(k, 0.0))
        coll.result_bytes[k] = int(ext(
            c1.collectives.result_bytes.get(k, 0),
            c2.collectives.result_bytes.get(k, 0)))
        coll.counts[k] = int(ext(c1.collectives.counts.get(k, 0),
                                 c2.collectives.counts.get(k, 0)))
    return SegmentCost(c1.name, ext(c1.flops, c2.flops),
                       ext(c1.bytes_accessed, c2.bytes_accessed), coll,
                       c1.compile_s + c2.compile_s)


def _analyze(compiled, name: str, t0: float) -> SegmentCost:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    ca = ca or {}
    return SegmentCost(
        name=name,
        flops=float(ca.get("flops", 0.0)),
        bytes_accessed=float(ca.get("bytes accessed", 0.0)),
        collectives=hlo_lib.parse_collectives(compiled.as_text()),
        compile_s=time.time() - t0)


def _shard_tree(ctx, logical_tree):
    return jax.tree.map(lambda sp: NamedSharding(ctx.mesh, sp),
                        logical_to_physical(ctx, logical_tree))


def _period_slice_specs(acfg: ArchConfig, tree, stacked_logical):
    """SDS + shardings for ONE period's params/states (drop 'layers')."""
    one = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype), tree)
    logical = jax.tree.map(lambda lp: P(*list(lp)[1:]), stacked_logical)
    return one, logical



def _cast_pin(tree, shardings, dtype):
    """cast_floats + per-leaf sharding pin (see M.cast_params_for_compute)."""
    import jax as _jax
    import jax.numpy as _jnp

    def one(a, sh):
        if hasattr(a, "dtype") and a.dtype == _jnp.float32:
            a = a.astype(dtype)
        return _jax.lax.with_sharding_constraint(a, sh)
    return _jax.tree.map(one, tree, shardings)


def _position_signature(cfg, pos: int) -> Tuple:
    return (cfg.layer_pattern[pos], bool(cfg.moe_at(pos)),
            cfg.window_at(pos))


def segment_costs(ctx: ParallelCtx, acfg: ArchConfig, shape: ShapeSpec
                  ) -> Dict[str, SegmentCost]:
    """Compile per-POSITION segments (deduped by layer signature) plus the
    embed/head and optimizer segments under the cell's mesh; scale each
    to the full model. Scan-undercount handling:
      - attention: q-block scan unrolled (attn_lib.FORCE_UNROLL_Q)
      - rwkv/mamba: lowered at one chunk (S_seg = chunk) and scaled by
        S / S_seg — the chunked algorithm's cost is uniform per chunk
      - loss: chunked CE lowered with chunk = S (single iteration)
    """
    from repro.models import attention as attn_lib
    cfg = acfg.model
    B, S = shape.global_batch, shape.seq_len
    S_in = 1 if shape.kind == "decode" else S
    cdt = jnp.bfloat16 if acfg.train.compute_dtype == "bfloat16" \
        else jnp.float32

    segs: Dict[str, SegmentCost] = {}
    pspecs = specs_lib.param_specs(acfg)
    psh = _shard_tree(ctx, M.param_logical_axes(acfg))
    blocks_logical = M.param_logical_axes(acfg)["blocks"]
    bspec = ctx.axis("batch") if B % max(ctx.n_batch_shards, 1) == 0 \
        else None

    def x_pair(S_seg):
        sds = jax.ShapeDtypeStruct((B, S_seg, cfg.d_model), cdt)
        sh = NamedSharding(ctx.mesh, P(bspec, None, None))
        return sds, sh

    if shape.kind == "decode":
        st_full = specs_lib.state_specs(ctx, acfg, shape)
        st_logical = M.state_logical_axes(acfg, B)
        st_phys = logical_to_physical(
            ctx, jax.tree.map(lambda lp: P(*list(lp)[1:]), st_logical))

    # ---- per-position segments (deduped) --------------------------------
    sig_positions: Dict[Tuple, list] = {}
    for i in range(cfg.pattern_period):
        sig_positions.setdefault(_position_signature(cfg, i), []).append(i)

    attn_lib.FORCE_UNROLL_Q = True
    try:
        for sig, poss in sig_positions.items():
            i = poss[0]
            kind = sig[0]
            name = f"pos{i}:{kind}{'+moe' if sig[1] else ''}"
            pp_sds = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype),
                pspecs["blocks"][f"pos{i}"])
            pp_sh = jax.tree.map(
                lambda sp: NamedSharding(ctx.mesh, sp),
                logical_to_physical(ctx, jax.tree.map(
                    lambda lp: P(*list(lp)[1:]),
                    blocks_logical[f"pos{i}"])))

            def lower_at(S_seg, i=i, pp_sds=pp_sds, pp_sh=pp_sh):
                x_sds, x_sh = x_pair(S_seg)
                t0 = time.time()
                if shape.kind == "decode":
                    st_sds = jax.tree.map(
                        lambda s: jax.ShapeDtypeStruct(s.shape[1:],
                                                       s.dtype),
                        st_full[f"pos{i}"])
                    st_sh = jax.tree.map(
                        lambda sp: NamedSharding(ctx.mesh, sp),
                        st_phys[f"pos{i}"])

                    def pos_fn(pp, x, st):
                        pp = _cast_pin(pp, pp_sh, cdt)
                        x, ns, _ = M._apply_position(
                            ctx, cfg, i, pp, x, st, "decode", None, cdt)
                        return x, ns
                    lowered = jax.jit(
                        pos_fn, in_shardings=(pp_sh, x_sh, st_sh)).lower(
                        pp_sds, x_sds, st_sds)
                elif shape.kind == "train":
                    def pos_fn(pp, x, ct):
                        def f(pp, x):
                            pp = _cast_pin(pp, pp_sh, cdt)
                            pos = jnp.arange(S_seg, dtype=jnp.int32)
                            y, _, aux = M._apply_position(
                                ctx, cfg, i, pp, x, None, "train", pos,
                                cdt)
                            return jnp.sum(y.astype(jnp.float32)
                                           * ct.astype(jnp.float32)) + aux
                        return jax.grad(f, argnums=(0, 1))(pp, x)
                    lowered = jax.jit(
                        pos_fn, in_shardings=(pp_sh, x_sh, x_sh)).lower(
                        pp_sds, x_sds, x_sds)
                else:  # prefill
                    def pos_fn(pp, x):
                        pp = _cast_pin(pp, pp_sh, cdt)
                        pos = jnp.arange(S_seg, dtype=jnp.int32)
                        y, _, aux = M._apply_position(
                            ctx, cfg, i, pp, x, None, "train", pos, cdt)
                        return y, aux
                    lowered = jax.jit(pos_fn,
                                      in_shardings=(pp_sh, x_sh)).lower(
                        pp_sds, x_sds)
                return _analyze(lowered.compile(), name, t0)

            n_inst = len(poss) * cfg.n_periods
            if shape.kind != "decode" and kind in ("rwkv", "mamba") and \
                    S_in > 2 * (16 if kind == "rwkv" else 64):
                # two-point extrapolation: the inner chunk scan
                # undercounts, and naive (S/S_seg) scaling would multiply
                # per-layer weight collectives by the token ratio
                S1 = 16 if kind == "rwkv" else 64
                c1, c2 = lower_at(S1), lower_at(2 * S1)
                seg = extrapolate_two_point(c1, c2, S_in / S1)
            else:
                seg = lower_at(S_in)
            segs[name] = seg.scaled(n_inst)
            segs[name].compile_s = seg.compile_s
    finally:
        attn_lib.FORCE_UNROLL_Q = False

    # ---- embed + head(+loss) segment ------------------------------------
    head_keys = [k for k in ("embed", "lm_head", "final_norm")
                 if k in pspecs]
    hp_sds = {k: pspecs[k] for k in head_keys}
    hp_sh = {k: psh[k] for k in head_keys}
    x_sds, x_sh = x_pair(S_in)

    if shape.kind == "train":
        tok_sds = jax.ShapeDtypeStruct((B, S_in), jnp.int32)
        tok_sh = NamedSharding(ctx.mesh, P(bspec, None))

        def embed_head(hp, tokens, labels, x):
            def f(hp2, x2):
                hp2 = _cast_pin(hp2, hp_sh, cdt)
                if cfg.frontend is None:
                    # embed gather (fwd + scatter-add bwd) belongs here
                    e = jnp.take(hp2["embed"], tokens, axis=0).astype(cdt)
                    x2 = x2 + e
                hid = M.apply_norm(cfg, hp2["final_norm"], x2)
                # chunk = S: single loss iteration (no scan undercount)
                return M.loss_fn(ctx, acfg, hp2, hid, labels, chunk=S_in)
            return jax.grad(f, argnums=(0, 1))(hp, x)

        t0 = time.time()
        lowered = jax.jit(embed_head,
                          in_shardings=(hp_sh, tok_sh, tok_sh, x_sh)).lower(
            hp_sds, tok_sds, tok_sds, x_sds)
        segs["embed_head"] = _analyze(lowered.compile(), "embed_head", t0)
    else:
        def embed_head(hp, x):
            hp = _cast_pin(hp, hp_sh, cdt)
            hid = M.apply_norm(cfg, hp["final_norm"], x)
            last = hid if shape.kind == "decode" else hid[:, -1:]
            return M.logits_fn(ctx, acfg, {**hp}, last)
        t0 = time.time()
        lowered = jax.jit(embed_head, in_shardings=(hp_sh, x_sh)).lower(
            hp_sds, x_sds)
        segs["embed_head"] = _analyze(lowered.compile(), "embed_head", t0)

    # ---- optimizer segment (train only) ----------------------------------
    if shape.kind == "train":
        osds = specs_lib.opt_specs(acfg)

        def opt_fn(params, grads, ost):
            p2, o2, _ = O.apply_updates(acfg.train, params, grads, ost)
            return p2, o2
        t0 = time.time()
        lowered = jax.jit(opt_fn,
                          in_shardings=(psh, psh, None)).lower(
            pspecs, pspecs, osds)
        segs["optimizer"] = _analyze(lowered.compile(), "optimizer", t0)

    return segs


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    flops_per_device: float
    bytes_per_device: float
    ici_wire_bytes: float
    dcn_wire_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_total_flops: float     # global: per-device x chips
    segments: Dict[str, Any] = field(default_factory=dict)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """No-overlap upper bound."""
        return self.compute_s + self.memory_s + self.collective_s

    @property
    def roofline_fraction(self) -> float:
        """compute_term / max(all terms) — 1.0 means compute-bound at
        peak; lower means the dominant non-compute term wastes the MXU."""
        m = max(self.compute_s, self.memory_s, self.collective_s, 1e-30)
        return self.compute_s / m

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / max(self.hlo_total_flops, 1e-30)

    def to_dict(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "n_chips": self.n_chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "ici_wire_bytes": self.ici_wire_bytes,
            "dcn_wire_bytes": self.dcn_wire_bytes,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "step_time_s": self.step_time_s,
            "roofline_fraction": self.roofline_fraction,
            "model_flops": self.model_flops,
            "hlo_total_flops": self.hlo_total_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "segments": {k: {
                "flops": v.flops, "bytes": v.bytes_accessed,
                "collectives": v.collectives.summary(),
                "compile_s": v.compile_s} for k, v in
                self.segments.items()},
        }


def model_flops(acfg: ArchConfig, shape: ShapeSpec) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE); D = tokens processed.
    Decode: one token per sequence per step."""
    n = acfg.model.num_active_params()
    if shape.kind == "decode":
        tokens = shape.global_batch
    else:
        tokens = shape.global_batch * shape.seq_len
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * tokens


def build_roofline(ctx: ParallelCtx, acfg: ArchConfig, shape: ShapeSpec,
                   mesh_name: str,
                   segs: Dict[str, SegmentCost]) -> Roofline:
    # per-position segments arrive pre-scaled to the full model
    total_flops = total_bytes = 0.0
    coll = hlo_lib.CollectiveStats()
    for name, seg in segs.items():
        total_flops += seg.flops
        total_bytes += seg.bytes_accessed
        coll = coll.merged(seg.collectives)

    n_chips = ctx.mesh.devices.size
    # split wire bytes: collectives whose groups span the pod axis ride
    # DCN. Approximation: fsdp/batch collectives with group size ==
    # n_batch_shards when multi-pod include one DCN hop; we attribute
    # wire bytes proportionally to (pod_degree-1)/(group-1) when the pod
    # axis participates. With batch axes (pod, data), pods=2:
    pods = ctx.mesh.shape.get("pod", 1) if hasattr(ctx.mesh, "shape") else 1
    total_wire = coll.total_wire_bytes
    dcn_frac = 0.0
    if pods > 1:
        nb = ctx.n_batch_shards
        dcn_frac = (pods - 1) / max(nb - 1, 1)
    dcn_bytes = total_wire * dcn_frac
    ici_bytes = total_wire - dcn_bytes

    mf = model_flops(acfg, shape)
    return Roofline(
        arch=acfg.model.name, shape=shape.name, mesh=mesh_name,
        n_chips=n_chips,
        flops_per_device=total_flops,
        bytes_per_device=total_bytes,
        ici_wire_bytes=ici_bytes,
        dcn_wire_bytes=dcn_bytes,
        compute_s=total_flops / PEAK_FLOPS,
        memory_s=total_bytes / HBM_BW,
        collective_s=ici_bytes / ICI_BW + dcn_bytes / DCN_BW,
        model_flops=mf,
        hlo_total_flops=total_flops * n_chips,
        segments=segs)
