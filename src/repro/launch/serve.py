"""Serving launcher: load (or init) a model and answer batched requests.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --reduced \
      [--batch 4] [--prompt-len 16] [--new-tokens 8]

Requests travel through the rpc fabric (loopback transport, serialized
framing) by default, via the generated ``Serve`` stub's
server-streaming ``generate_stream`` method — one chunk per decoded
token — so serving traffic exercises the same RPC runtime the
communication benchmarks measure, streaming included. ``--unary`` uses
the unary ``generate`` method (whole block in one reply); --no-rpc
calls the engine directly.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced_config
from repro.models import init_params
from repro.parallel.sharding import make_ctx
from repro.serve.engine import ServeConfig, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--requests", type=int, default=3)
    ap.add_argument("--no-rpc", action="store_true",
                    help="bypass the rpc fabric, call the engine directly")
    ap.add_argument("--unary", action="store_true",
                    help="use the unary generate method instead of the "
                         "server-streaming generate_stream")
    args = ap.parse_args()

    acfg = (get_reduced_config(args.arch) if args.reduced
            else get_config(args.arch))
    assert not acfg.model.is_encoder, "encoder archs do not serve decode"
    ctx = make_ctx(acfg, None)
    params = init_params(jax.random.PRNGKey(0), acfg)
    engine = ServeEngine(ctx, acfg, params, ServeConfig(
        max_seq=args.prompt_len + args.new_tokens + 8,
        max_new_tokens=args.new_tokens, temperature=args.temperature))

    channel = None
    if not args.no_rpc:
        _, channel = engine.serve_loopback()

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        prompts = rng.integers(0, acfg.model.vocab_size,
                               (args.batch, args.prompt_len),
                               dtype=np.int32)
        t0 = time.perf_counter()
        if channel is None:
            out = engine.generate(prompts)
            via = "direct"
        elif args.unary:
            from repro.serve.engine import serve_stub
            out = serve_stub(channel).generate((prompts, 0)).result()
            via = "rpc/unary"
        else:
            from repro.serve.engine import rpc_generate_stream
            out = rpc_generate_stream(channel, prompts)
            via = f"rpc/stream({out.shape[1]} chunks)"
        dt = time.perf_counter() - t0
        tps = out.size / dt
        print(f"request {i} [{via}]: batch={args.batch} "
              f"new={out.shape[1]} {dt*1e3:.1f} ms ({tps:.1f} tok/s) "
              f"sample={out[0][:8].tolist()}")


if __name__ == "__main__":
    main()
