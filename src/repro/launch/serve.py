"""Serving launcher: load (or init) a model and answer batched requests.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --reduced \
      [--batch 4] [--prompt-len 16] [--new-tokens 8]

Requests travel through the rpc fabric (loopback transport, serialized
framing) by default, via the generated ``Serve`` stub's
server-streaming ``generate_stream`` method — one chunk per decoded
token — so serving traffic exercises the same RPC runtime the
communication benchmarks measure, streaming included. ``--unary`` uses
the unary ``generate`` method (whole block in one reply); --no-rpc
calls the engine directly.

``--transport cluster --cluster-spec <json>`` serves over a
multi-endpoint cluster transport instead: the engine's ``Serve``
service binds on every ``ps`` endpoint of the spec, every ``worker``
endpoint submits a generation request per round, and one flush drives
all of them concurrently — sharded across the PS endpoints under
``--policy round_robin|least_loaded`` — with per-link modeled timing
and per-endpoint interceptor metrics:

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --reduced \
      --transport cluster --cluster-spec cluster.json --unary

``--trace out.json`` attaches a ``rpc.Tracer`` to the serving fabric
(loopback or cluster) and exports every request's span tree — queue /
credit-stall / wire / server / reply phases, retries and shard
failovers included, plus the scheduler's waiting / prefill / decode /
preempted request phases — as Chrome trace-event JSON for Perfetto.

Each served endpoint runs a continuous-batching scheduler
(``repro.serve.scheduler``): ``--max-batch N`` caps concurrent decodes
per endpoint and ``--kv-blocks N`` sets the modeled KV-cache block
budget (exhaustion preempts + requeues the newest request). With the
cluster transport, ``--policy scheduler_least_loaded`` dispatches on
the endpoints' reported scheduler load instead of the client's own
outstanding-call counts. ``--sched-policy sjf`` admits
shortest-prompt-first instead of FIFO (``--starvation-age-s`` bounds
how long a long prompt can be bypassed); see ``docs/WORKLOAD.md`` for
driving a served cluster with recorded open-loop traces.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced_config
from repro.models import init_params
from repro.parallel.sharding import make_ctx
from repro.serve.engine import (DISPATCH_POLICIES, ServeConfig,
                                ServeEngine)
from repro.serve.scheduler import SCHED_POLICIES


def _export_trace(tracer, path: str) -> None:
    if tracer is None:
        return
    tracer.export_chrome(path)
    print(f"trace          : {len(tracer.spans())} spans -> {path}")


def _serve_cluster_rounds(engine: ServeEngine, cluster, args,
                          vocab_size: int) -> None:
    """One request per worker endpoint per round, all flushed (and so
    served) concurrently; PS sharding per --policy."""
    from repro import rpc as rpclib
    from repro.serve.engine import decode_token_chunk

    # metrics server-side too (shed/rejected counts feed admission
    # when the spec advertises limits), and retry so a dispatch a
    # shard's admission control rejects recovers on a later, drained
    # flight (single-PS specs have no shard to fail over to)
    metrics = rpclib.MetricsInterceptor(per_endpoint=True,
                                        endpoint_name=cluster.name_of)
    tracer = rpclib.Tracer() if args.trace else None
    fabric, stubs = engine.serve_cluster(
        cluster, policy=args.policy,
        client_interceptors=[metrics,
                             rpclib.RetryInterceptor(max_attempts=4)],
        server_interceptors=[metrics], tracer=tracer,
        max_batch=args.max_batch, kv_blocks=args.kv_blocks,
        sched_policy=args.sched_policy,
        starvation_age_s=args.starvation_age_s)
    rng = np.random.default_rng(0)
    print(f"cluster        : {len(stubs)} worker endpoint(s) -> "
          f"{len(next(iter(stubs.values())).servers)} ps endpoint(s), "
          f"policy={args.policy}")
    for i in range(args.requests):
        prompts = {w: rng.integers(0, vocab_size,
                                   (args.batch, args.prompt_len),
                                   dtype=np.int32) for w in stubs}
        t0 = time.perf_counter()
        if args.unary:
            calls = {w: stub.generate(prompts[w])
                     for w, stub in stubs.items()}
        else:
            calls = {w: stub.generate_stream(prompts[w])
                     for w, stub in stubs.items()}
        fabric.flush()            # every worker's request, one loop
        dt = time.perf_counter() - t0
        for w, call in calls.items():
            if args.unary:
                out = call.result()
            else:
                out = np.stack([decode_token_chunk(c)
                                for c in call.result()], axis=1)
            print(f"request {i} [{w}]: batch={args.batch} "
                  f"new={out.shape[1]} sample={out[0][:8].tolist()}")
        total = len(calls) * args.batch * args.new_tokens
        print(f"round {i}: {dt*1e3:.1f} ms wall "
              f"({total/dt:.1f} tok/s aggregate, modeled clock "
              f"{fabric.now()*1e3:.3f} ms)")
    per_ep = {k: v["calls"] for k, v in metrics.snapshot().items()
              if "@" in k and not k.startswith("server:")
              and not k.startswith("serve:")}
    print(f"per-endpoint   : {per_ep}")
    for ep, sched in engine.schedulers.items():
        st = sched.stats()
        print(f"scheduler [{ep}]: "
              f"admitted={st['admitted']} finished={st['finished']} "
              f"preempted={st['preempted']} requeued={st['requeued']} "
              f"peak_running={st['peak_running']}")
    _export_trace(tracer, args.trace)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--requests", type=int, default=3)
    ap.add_argument("--no-rpc", action="store_true",
                    help="bypass the rpc fabric, call the engine directly")
    ap.add_argument("--unary", action="store_true",
                    help="use the unary generate method instead of the "
                         "server-streaming generate_stream")
    ap.add_argument("--transport", default="loopback",
                    choices=("loopback", "cluster"),
                    help="rpc transport: loopback (single host) or "
                         "cluster (multi-endpoint, --cluster-spec)")
    ap.add_argument("--cluster-spec", default=None, metavar="JSON|PATH",
                    help="cluster topology: inline ClusterSpec JSON or "
                         "a JSON file path (cluster transport only)")
    ap.add_argument("--policy", default="round_robin",
                    choices=DISPATCH_POLICIES,
                    help="PS shard dispatch policy (cluster transport)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="export the serving fabric's span trees as "
                         "Chrome trace-event JSON (Perfetto)")
    ap.add_argument("--max-batch", type=int, default=None, metavar="N",
                    help="continuous-batching scheduler: max requests "
                         "decoding concurrently per endpoint "
                         "(default 8)")
    ap.add_argument("--kv-blocks", type=int, default=None, metavar="N",
                    help="continuous-batching scheduler: modeled "
                         "KV-cache budget in 16-token blocks per "
                         "endpoint (default unlimited; exhaustion "
                         "preempts + requeues)")
    ap.add_argument("--sched-policy", default="fifo",
                    choices=SCHED_POLICIES,
                    help="scheduler admission order: fifo (arrival "
                         "order) or sjf (shortest-prompt-first, FIFO "
                         "tiebreak; preempted requests and starved "
                         "waits keep priority)")
    ap.add_argument("--starvation-age-s", type=float, default=None,
                    metavar="S",
                    help="sjf only: waits older than this regain "
                         "strict FIFO priority (default: no escape "
                         "hatch)")
    args = ap.parse_args()

    if args.transport == "cluster" and args.cluster_spec is None:
        ap.error("--transport cluster needs --cluster-spec")
    if args.cluster_spec is not None and args.transport != "cluster":
        ap.error("--cluster-spec needs --transport cluster")
    if args.transport == "cluster" and args.no_rpc:
        ap.error("--no-rpc bypasses the fabric; it cannot combine with "
                 "--transport cluster")
    if args.trace and args.no_rpc:
        ap.error("--trace records fabric spans; it cannot combine with "
                 "--no-rpc")
    if args.no_rpc and (args.max_batch is not None
                        or args.kv_blocks is not None
                        or args.sched_policy != "fifo"
                        or args.starvation_age_s is not None):
        ap.error("--max-batch/--kv-blocks/--sched-policy/"
                 "--starvation-age-s configure the rpc endpoint "
                 "scheduler; they cannot combine with --no-rpc")
    if args.starvation_age_s is not None and args.sched_policy != "sjf":
        ap.error("--starvation-age-s is the sjf starvation escape "
                 "hatch; it needs --sched-policy sjf")
    if args.starvation_age_s is not None and args.starvation_age_s < 0:
        ap.error("--starvation-age-s must be >= 0")
    if args.max_batch is not None and args.max_batch < 1:
        ap.error("--max-batch must be >= 1")
    if args.kv_blocks is not None and args.kv_blocks < 1:
        ap.error("--kv-blocks must be >= 1")
    if args.max_batch is None:
        args.max_batch = 8

    cluster = None
    if args.transport == "cluster":
        # validate the topology BEFORE the (slow) model init
        from repro.rpc.cluster import load_cluster_spec
        try:
            cluster = load_cluster_spec(args.cluster_spec)
        except (OSError, ValueError, KeyError, TypeError) as e:
            ap.error(f"--cluster-spec: {e}")

    acfg = (get_reduced_config(args.arch) if args.reduced
            else get_config(args.arch))
    assert not acfg.model.is_encoder, "encoder archs do not serve decode"
    ctx = make_ctx(acfg, None)
    params = init_params(jax.random.PRNGKey(0), acfg)
    engine = ServeEngine(ctx, acfg, params, ServeConfig(
        max_seq=args.prompt_len + args.new_tokens + 8,
        max_new_tokens=args.new_tokens, temperature=args.temperature))

    if cluster is not None:
        _serve_cluster_rounds(engine, cluster, args,
                              acfg.model.vocab_size)
        return

    channel = None
    tracer = None
    if not args.no_rpc:
        from repro import rpc as rpclib
        tracer = rpclib.Tracer() if args.trace else None
        _, channel = engine.serve_loopback(
            tracer=tracer, max_batch=args.max_batch,
            kv_blocks=args.kv_blocks, sched_policy=args.sched_policy,
            starvation_age_s=args.starvation_age_s)

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        prompts = rng.integers(0, acfg.model.vocab_size,
                               (args.batch, args.prompt_len),
                               dtype=np.int32)
        t0 = time.perf_counter()
        if channel is None:
            out = engine.generate(prompts)
            via = "direct"
        elif args.unary:
            from repro.serve.engine import serve_stub
            out = serve_stub(channel).generate((prompts, 0)).result()
            via = "rpc/unary"
        else:
            from repro.serve.engine import rpc_generate_stream
            out = rpc_generate_stream(channel, prompts)
            via = f"rpc/stream({out.shape[1]} chunks)"
        dt = time.perf_counter() - t0
        tps = out.size / dt
        print(f"request {i} [{via}]: batch={args.batch} "
              f"new={out.shape[1]} {dt*1e3:.1f} ms ({tps:.1f} tok/s) "
              f"sample={out[0][:8].tolist()}")
    for ep, sched in engine.schedulers.items():
        st = sched.stats()
        print(f"scheduler [{ep}]: admitted={st['admitted']} "
              f"finished={st['finished']} preempted={st['preempted']} "
              f"requeued={st['requeued']} "
              f"peak_running={st['peak_running']}")
    if args.trace:
        _export_trace(tracer, args.trace)


if __name__ == "__main__":
    main()
