"""ShapeDtypeStruct stand-ins for every model input — the dry-run's
"data". Weak-type-correct, shardable, zero allocation."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models import model as M
from repro.optim import optimizer as O
from repro.parallel.sharding import ParallelCtx

SDS = jax.ShapeDtypeStruct


def batch_specs(acfg: ArchConfig, shape: ShapeSpec) -> Dict[str, SDS]:
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        S_in = 1
    else:
        S_in = S
    out: Dict[str, SDS] = {}
    if acfg.model.frontend is not None:
        out["embeds"] = SDS((B, S_in, acfg.model.d_model), jnp.bfloat16)
    else:
        out["tokens"] = SDS((B, S_in), jnp.int32)
    if shape.kind == "train":
        out["labels"] = SDS((B, S_in), jnp.int32)
    return out


def param_specs(acfg: ArchConfig, seed: int = 0):
    return jax.eval_shape(
        lambda k: M.init_params(k, acfg), jax.random.PRNGKey(seed))


def opt_specs(acfg: ArchConfig):
    p = param_specs(acfg)
    return jax.eval_shape(lambda q: O.init_opt_state(acfg.train, q), p)


def state_specs(ctx: ParallelCtx, acfg: ArchConfig, shape: ShapeSpec):
    """Decode-state stand-ins: KV caches sized to the shape's context."""
    return jax.eval_shape(
        lambda: M.init_states(ctx, acfg, shape.global_batch, shape.seq_len))


def input_specs(ctx: ParallelCtx, acfg: ArchConfig, shape: ShapeSpec
                ) -> Tuple[Any, ...]:
    """Arguments (as SDS pytrees) for the step function of shape.kind."""
    if shape.kind == "train":
        return (param_specs(acfg), opt_specs(acfg),
                batch_specs(acfg, shape))
    if shape.kind == "prefill":
        return (param_specs(acfg), batch_specs(acfg, shape))
    if shape.kind == "decode":
        b = batch_specs(acfg, shape)
        return (param_specs(acfg), state_specs(ctx, acfg, shape),
                b.get("tokens"), b.get("embeds"))
    raise ValueError(shape.kind)
