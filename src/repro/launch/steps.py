"""Jitted step functions (train / prefill / decode) with explicit
in/out shardings — shared by the trainer, the serve engine, and the
multi-pod dry-run.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models import model as M
from repro.optim import optimizer as O
from repro.parallel.sharding import ParallelCtx, logical_to_physical

Params = Any


# ---------------------------------------------------------------------------
# shardings
# ---------------------------------------------------------------------------

def param_shardings(ctx: ParallelCtx, acfg: ArchConfig):
    la = M.param_logical_axes(acfg)
    return logical_to_physical(ctx, la)


def batch_shardings(ctx: ParallelCtx, batch: Dict):
    def spec(a):
        return P(*([ctx.axis("batch")] + [None] * (a.ndim - 1)))
    return {k: spec(v) for k, v in batch.items()}


# ---------------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------------

def make_loss_fn(ctx: ParallelCtx, acfg: ArchConfig):
    def loss(params, batch):
        hidden, _, aux = M.forward(
            ctx, acfg, params, tokens=batch.get("tokens"),
            embeds=batch.get("embeds"), mode="train")
        ce = M.loss_fn(ctx, acfg, params, hidden, batch["labels"])
        return ce + aux, {"ce": ce, "aux": aux}
    return loss


def make_train_step(ctx: ParallelCtx, acfg: ArchConfig,
                    donate: bool = True):
    loss = make_loss_fn(ctx, acfg)

    def step(params, opt_state, batch):
        (_, metrics), grads = jax.value_and_grad(loss, has_aux=True)(
            params, batch)
        new_params, new_opt, om = O.apply_updates(acfg.train, params,
                                                  grads, opt_state)
        metrics.update(om)
        metrics["loss"] = metrics["ce"] + metrics["aux"]
        return new_params, new_opt, metrics

    if ctx.mesh is None:
        return jax.jit(step, donate_argnums=(0, 1) if donate else ())
    pss = param_shardings(ctx, acfg)
    to_sh = lambda tree: jax.tree.map(
        lambda sp: NamedSharding(ctx.mesh, sp), tree)
    return jax.jit(
        step,
        donate_argnums=(0, 1) if donate else (),
        in_shardings=(to_sh(pss), None, None),
        out_shardings=(to_sh(pss), None, None))


def make_prefill_step(ctx: ParallelCtx, acfg: ArchConfig,
                      max_seq: Optional[int] = None):
    def prefill(params, batch):
        hidden, states, _ = M.forward(
            ctx, acfg, params, tokens=batch.get("tokens"),
            embeds=batch.get("embeds"), mode="prefill", max_seq=max_seq)
        logits = M.logits_fn(ctx, acfg, params, hidden[:, -1:])
        return states, logits
    return jax.jit(prefill) if ctx.mesh is None else jax.jit(
        prefill,
        in_shardings=(jax.tree.map(
            lambda sp: NamedSharding(ctx.mesh, sp),
            param_shardings(ctx, acfg)), None))


def make_decode_step(ctx: ParallelCtx, acfg: ArchConfig, batch: int):
    def decode(params, states, tokens, embeds=None):
        hidden, new_states, _ = M.forward(
            ctx, acfg, params,
            tokens=tokens, embeds=embeds, states=states, mode="decode")
        logits = M.logits_fn(ctx, acfg, params, hidden)
        return new_states, logits

    if ctx.mesh is None:
        return jax.jit(decode, donate_argnums=(1,))
    pss = jax.tree.map(lambda sp: NamedSharding(ctx.mesh, sp),
                       param_shardings(ctx, acfg))
    sla = M.state_logical_axes(acfg, batch)
    # stacked over periods: state_logical_axes already includes 'layers'
    sss = jax.tree.map(lambda sp: NamedSharding(ctx.mesh, sp),
                       logical_to_physical(ctx, sla))
    return jax.jit(decode, donate_argnums=(1,),
                   in_shardings=(pss, sss, None, None),
                   out_shardings=(sss, None))
