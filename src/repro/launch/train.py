"""Production training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b \
      --shape train_4k [--reduced] [--steps N] [--ckpt-dir DIR] \
      [--mesh dxm] [--ps-mode] [--resume]

On real hardware the full config runs on the production mesh; on this
CPU container use --reduced (same family, small dims) and optionally a
small --mesh over forced host devices.
"""
from __future__ import annotations

import argparse
import dataclasses
import logging

import jax

from repro.configs import get_config, get_reduced_config, get_shape
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_mesh, make_production_mesh
from repro.parallel.sharding import make_ctx
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--global-batch", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--mesh", default=None,
                    help="e.g. 2x2 (data x model) over host devices; "
                         "'production' for (16,16)")
    ap.add_argument("--ps-mode", action="store_true",
                    help="parameter-server (ZeRO-3/fsdp) weight sharding")
    ap.add_argument("--grad-compression", default=None,
                    choices=[None, "bf16", "int8"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")

    acfg = (get_reduced_config(args.arch) if args.reduced
            else get_config(args.arch))
    if args.ps_mode:
        acfg = acfg.replace(parallel=dataclasses.replace(
            acfg.parallel, fsdp=True, ps_mode=True))
    if args.grad_compression:
        acfg = acfg.replace(train=dataclasses.replace(
            acfg.train, grad_compression=args.grad_compression))

    shape = get_shape(args.shape)
    if args.seq_len or args.global_batch:
        shape = dataclasses.replace(
            shape, seq_len=args.seq_len or shape.seq_len,
            global_batch=args.global_batch or shape.global_batch)

    mesh = None
    if args.mesh == "production":
        mesh = make_production_mesh()
    elif args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = make_mesh((d, m), ("data", "model"))
    ctx = make_ctx(acfg, mesh)

    tcfg = TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                         ckpt_every=args.ckpt_every)
    trainer = Trainer(ctx, acfg, shape, tcfg, DataConfig())
    if mesh is not None:
        with mesh:
            trainer.train(seed=args.seed)
    else:
        trainer.train(seed=args.seed)
    losses = [r.loss for r in trainer.history]
    print(f"done: {len(trainer.history)} steps, "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}, "
          f"stragglers={len(trainer.straggler_events)}")


if __name__ == "__main__":
    main()
