from repro.models.model import (forward, init_params, init_states,
                                logits_fn, loss_fn, param_logical_axes,
                                state_logical_axes)

__all__ = ["forward", "init_params", "init_states", "logits_fn", "loss_fn",
           "param_logical_axes", "state_logical_axes"]
