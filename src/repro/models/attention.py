"""Attention: GQA / qk-norm / sliding-window / logit-softcap, with a
q-blocked memory-bounded path for long sequences and a decode path that
reads a (possibly sequence-sharded) KV cache.

The jnp implementation here is the *compile/dry-run* path (and the
oracle for the Pallas flash kernel in ``repro.kernels.flash_attention``);
on real TPU the kernel replaces the inner block computation.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionConfig, ModelConfig
from repro.models.layers import apply_rope, dense_init, rms_norm_simple

NEG_INF = -1e30

# Cost-analysis hook (launch/roofline.py): scans under-count in XLA cost
# analysis, so segment lowerings unroll the q-block loop by raising the
# effective block size to the full sequence.
FORCE_UNROLL_Q = False


def init_attention(key, cfg: ModelConfig, att: AttentionConfig,
                   dtype) -> dict:
    d = cfg.d_model
    hq, hkv = att.n_heads * att.d_head, att.n_kv_heads * att.d_head
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, hq, dtype),
        "wk": dense_init(ks[1], d, hkv, dtype),
        "wv": dense_init(ks[2], d, hkv, dtype),
        "wo": dense_init(ks[3], hq, d, dtype),
    }
    if att.qkv_bias:
        p["bq"] = jnp.zeros((hq,), dtype)
        p["bk"] = jnp.zeros((hkv,), dtype)
        p["bv"] = jnp.zeros((hkv,), dtype)
    if att.qk_norm:
        p["q_norm"] = jnp.ones((att.d_head,), dtype)
        p["k_norm"] = jnp.ones((att.d_head,), dtype)
    return p


class KVCache(NamedTuple):
    k: jax.Array          # (B, S_cache, n_kv, d_head)
    v: jax.Array          # (B, S_cache, n_kv, d_head)
    # the *global* write cursor (tokens seen so far), traced scalar
    index: jax.Array


def _qkv(p: dict, att: AttentionConfig, x: jax.Array, positions: jax.Array):
    """x: (B,S,d) -> q (B,S,H,dh), k/v (B,S,KV,dh); RoPE applied."""
    B, S, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if att.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, att.n_heads, att.d_head)
    k = k.reshape(B, S, att.n_kv_heads, att.d_head)
    v = v.reshape(B, S, att.n_kv_heads, att.d_head)
    if att.qk_norm:
        q = rms_norm_simple(q, p["q_norm"])
        k = rms_norm_simple(k, p["k_norm"])
    if att.use_rope:
        q = apply_rope(q, positions, att.rope_theta)
        k = apply_rope(k, positions, att.rope_theta)
    return q, k, v


def repeat_kv(h: jax.Array, n_rep: int) -> jax.Array:
    """(B,S,KV,dh) -> (B,S,KV*n_rep,dh); broadcast, not materialized copy."""
    if n_rep == 1:
        return h
    B, S, KV, dh = h.shape
    h = jnp.broadcast_to(h[:, :, :, None, :], (B, S, KV, n_rep, dh))
    return h.reshape(B, S, KV * n_rep, dh)


def _mask_bias(q_pos: jax.Array, kv_pos: jax.Array, *, causal: bool,
               window: Optional[int], kv_valid: Optional[jax.Array]):
    """Additive mask (…,Sq,Skv) in fp32. q_pos (Sq,), kv_pos (Skv,)."""
    ok = jnp.ones((q_pos.shape[0], kv_pos.shape[0]), bool)
    if causal:
        ok &= q_pos[:, None] >= kv_pos[None, :]
    if window is not None:
        ok &= (q_pos[:, None] - kv_pos[None, :]) < window
    bias = jnp.where(ok, 0.0, NEG_INF)
    if kv_valid is not None:  # (B,Skv) -> (B,1,Sq,Skv) broadcastable
        bias = bias[None, :, :] + jnp.where(kv_valid, 0.0, NEG_INF)[:, None, :]
    return bias


def sdpa(q, k, v, bias, *, softcap_val: Optional[float]) -> jax.Array:
    """q (B,Sq,H,dh), k/v (B,Skv,H,dh), bias broadcastable to (B,H,Sq,Skv)."""
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    scores = jnp.einsum("bqhd,bshd->bhqs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap_val is not None:
        scores = jnp.tanh(scores / softcap_val) * softcap_val
    scores = scores + bias
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqs,bshd->bqhd", probs.astype(v.dtype), v)
    return out


def attention_forward(p: dict, att: AttentionConfig, x: jax.Array,
                      positions: jax.Array, *, window: Optional[int],
                      causal: bool, block_q: int = 1024,
                      return_kv: bool = False):
    """Full-sequence (train / prefill) attention, q-blocked when long."""
    B, S, d = x.shape
    if FORCE_UNROLL_Q:
        block_q = S
    q, k, v = _qkv(p, att, x, positions)
    n_rep = att.n_heads // att.n_kv_heads
    kf, vf = repeat_kv(k, n_rep), repeat_kv(v, n_rep)
    kv_pos = positions

    if S <= block_q:
        bias = _mask_bias(positions, kv_pos, causal=causal, window=window,
                          kv_valid=None)
        out = sdpa(q, kf, vf, bias[None, None], softcap_val=att.logit_softcap)
    else:
        assert S % block_q == 0, (S, block_q)
        nb = S // block_q
        qb = q.reshape(B, nb, block_q, att.n_heads, att.d_head)
        qb = jnp.moveaxis(qb, 1, 0)              # (nb, B, bq, H, dh)
        pb = positions.reshape(nb, block_q)

        def body(_, blk):
            qi, pi = blk
            bias = _mask_bias(pi, kv_pos, causal=causal, window=window,
                              kv_valid=None)
            return None, sdpa(qi, kf, vf, bias[None, None],
                              softcap_val=att.logit_softcap)

        _, ob = jax.lax.scan(body, None, (qb, pb))
        out = jnp.moveaxis(ob, 0, 1).reshape(B, S, att.n_heads, att.d_head)

    out = out.reshape(B, S, att.n_heads * att.d_head) @ p["wo"]
    if return_kv:
        return out, (k, v)
    return out


def attention_forward_flash(p: dict, att: AttentionConfig, x: jax.Array,
                            positions: jax.Array, *, window: Optional[int],
                            causal: bool, return_kv: bool = False):
    """attention_forward, but the inner softmax-attention runs in the
    Pallas flash kernel (real-TPU path; interpret-mode on CPU)."""
    from repro.kernels.flash_attention import flash_attention
    B, S, d = x.shape
    q, k, v = _qkv(p, att, x, positions)
    out = flash_attention(q, k, v, causal, window, att.logit_softcap)
    out = out.reshape(B, S, att.n_heads * att.d_head) @ p["wo"]
    if return_kv:
        return out, (k, v)
    return out


def attention_decode(p: dict, att: AttentionConfig, x: jax.Array,
                     cache: KVCache, *, window: Optional[int]
                     ) -> tuple[jax.Array, KVCache]:
    """One-token decode. x: (B,1,d); cache k/v: (B,Sc,KV,dh).

    For windowed layers the cache is a ring buffer of size >= window; for
    full layers Sc is the max context. ``cache.index`` is the global
    token position of the incoming token.
    """
    B, S1, d = x.shape
    assert S1 == 1
    Sc = cache.k.shape[1]
    pos = jnp.full((1,), cache.index, jnp.int32)
    q, k_new, v_new = _qkv(p, att, x, pos)

    slot = cache.index % Sc  # ring-buffer slot (== index when Sc >= context)
    k = jax.lax.dynamic_update_slice(cache.k, k_new.astype(cache.k.dtype),
                                     (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new.astype(cache.v.dtype),
                                     (0, slot, 0, 0))

    # Position of every cache slot, reconstructed from the ring layout:
    # the most recent position p <= index with p % Sc == slot.
    slots = jnp.arange(Sc, dtype=jnp.int32)
    slot_pos = cache.index - jnp.mod(cache.index - slots, Sc)
    valid = slot_pos >= 0
    if window is not None:
        valid &= (cache.index - slot_pos) < window

    # Grouped-layout attention: q reshaped (B, KV, G, dh), K/V NEVER
    # repeated to H heads. With the cache sequence-sharded (flash-
    # decoding layout) the softmax/out reductions over Sc psum only
    # (B,KV,G)-sized partials — materializing repeated KV instead forces
    # GSPMD into a full cache all-gather per token (§Perf hypothesis B1).
    G = att.n_heads // att.n_kv_heads
    qg = q.reshape(B, att.n_kv_heads, G, att.d_head)
    scale = 1.0 / jnp.sqrt(att.d_head).astype(jnp.float32)
    # K/V stay in cache dtype (bf16); accumulate in fp32 — upcasting the
    # cache would double the HBM traffic of the token's cache scan (§Perf
    # hypothesis B2).
    s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(k.dtype), k,
                   preferred_element_type=jnp.float32) * scale
    if att.logit_softcap is not None:
        s = jnp.tanh(s / att.logit_softcap) * att.logit_softcap
    s = s + jnp.where(valid, 0.0, NEG_INF)[None, None, None, :]
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - jax.lax.stop_gradient(m))
    probs = e / jnp.sum(e, axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,bskd->bkgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32).astype(v.dtype)
    out = out.reshape(B, 1, att.n_heads * att.d_head) @ p["wo"]
    return out, KVCache(k=k, v=v, index=cache.index + 1)


def init_cache(att: AttentionConfig, batch: int, max_seq: int,
               window: Optional[int], dtype) -> KVCache:
    Sc = min(max_seq, window) if window is not None else max_seq
    shape = (batch, Sc, att.n_kv_heads, att.d_head)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   index=jnp.zeros((), jnp.int32))
