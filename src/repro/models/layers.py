"""Shared layers: norms, activations, RoPE, FFN, initializers.

Pure-functional: every module is an ``init_*`` returning a params pytree
and an ``apply_*`` consuming it. Compute dtype is configurable; params
stay in ``param_dtype``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def truncated_normal(key, shape, scale, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def dense_init(key, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    return truncated_normal(key, (d_in, d_out), scale, dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, d: int, dtype) -> dict:
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def apply_norm(cfg: ModelConfig, p: dict, x: jax.Array,
               eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
        return (y * p["scale"].astype(jnp.float32)).astype(dt)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(dt)


def rms_norm_simple(x: jax.Array, scale: jax.Array,
                    eps: float = 1e-6) -> jax.Array:
    """Headwise qk-norm helper (no config)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(d_head: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32)
                            / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, n_heads, d_head); positions: (..., seq)."""
    d_head = x.shape[-1]
    freqs = rope_frequencies(d_head, theta)                 # (d_head/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, dh/2)
    cos = jnp.cos(angles)[..., None, :]                     # (..., S, 1, dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations / FFN
# ---------------------------------------------------------------------------

def activation(name: str, x: jax.Array) -> jax.Array:
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "silu":
        return jax.nn.silu(x)
    if name == "sq_relu":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(name)


def init_ffn(key, cfg: ModelConfig, d: int, f: int, dtype) -> dict:
    ks = jax.random.split(key, 3)
    if cfg.ffn_activation in ("swiglu", "geglu"):
        return {"w_gate": dense_init(ks[0], d, f, dtype),
                "w_up": dense_init(ks[1], d, f, dtype),
                "w_down": dense_init(ks[2], f, d, dtype)}
    return {"w_up": dense_init(ks[0], d, f, dtype),
            "w_down": dense_init(ks[1], f, d, dtype)}


def apply_ffn(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    act = cfg.ffn_activation
    if act in ("swiglu", "geglu"):
        inner = activation("silu" if act == "swiglu" else "gelu",
                           x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        inner = activation(act, x @ p["w_up"])
    return inner @ p["w_down"]


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return (jnp.tanh(x.astype(jnp.float32) / cap) * cap).astype(x.dtype)
