"""Unified model: dense / GQA / MoE / RWKV6 / Mamba-hybrid / encoder-only.

Layers are grouped into *periods* (the repeating ``layer_pattern`` of the
config — e.g. jamba's 8-layer Mamba/attention block, gemma2's local/global
pair) and the model scans over stacked period parameters, so the HLO holds
ONE period body regardless of depth. Each period position has its own
parameter subtree ("pos0", "pos1", …) because layer kinds differ inside a
period.

Modes:
  train   — full-sequence forward, loss; no state
  prefill — full-sequence forward; returns per-layer states (KV/SSM)
  decode  — single token with per-layer states
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as LP

from repro.configs.base import ArchConfig, ModelConfig
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.attention import KVCache
from repro.models.layers import (apply_ffn, apply_norm, dense_init, init_ffn,
                                 init_norm, softcap, truncated_normal)
from repro.parallel.sharding import NO_MESH, ParallelCtx

Params = Dict[str, Any]


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


def cast_floats(tree, dtype):
    """Cast fp32 leaves to the compute dtype (mixed precision: fp32
    masters live in the optimizer; compute, activations and therefore
    every weight all-gather / grad reduce-scatter move `dtype` bytes —
    without this, jnp promotion silently runs the whole model in fp32
    (§Perf hypothesis A4)."""
    return jax.tree.map(
        lambda a: a.astype(dtype)
        if hasattr(a, "dtype") and a.dtype == jnp.float32 else a, tree)


def cast_params_for_compute(ctx: ParallelCtx, acfg: ArchConfig,
                            params: Params, dtype) -> Params:
    """cast_floats + re-pin every leaf to its own sharding. The
    constraint keeps GSPMD from hoisting the FSDP weight all-gather
    ABOVE the convert (observed on XLA:CPU SPMD: gathers move fp32 bytes
    without it — 2x wire; §Perf hypothesis A6)."""
    params = cast_floats(params, dtype)
    if ctx.mesh is None:
        return params
    from repro.parallel.sharding import logical_to_physical
    specs = logical_to_physical(ctx, param_logical_axes(acfg))
    return jax.tree.map(
        lambda a, sp: jax.lax.with_sharding_constraint(
            a, jax.NamedSharding(ctx.mesh, sp)), params, specs)


# =========================================================================
# Init
# =========================================================================

def _init_position(key, cfg: ModelConfig, pos: int, dtype) -> Params:
    kind = cfg.layer_pattern[pos]
    ks = jax.random.split(key, 4)
    p: Params = {"norm1": init_norm(cfg, cfg.d_model, dtype),
                 "norm2": init_norm(cfg, cfg.d_model, dtype)}
    if kind == "attn":
        p["mixer"] = attn_lib.init_attention(ks[0], cfg, cfg.attention, dtype)
    elif kind == "mamba":
        p["mixer"] = ssm_lib.init_mamba(ks[0], cfg, cfg.ssm, dtype)
    elif kind == "rwkv":
        p["mixer"] = ssm_lib.init_rwkv6(ks[0], cfg, cfg.ssm, dtype)
    else:
        raise ValueError(kind)
    if kind == "rwkv":
        pass  # channel-mix lives inside the rwkv param set
    elif cfg.moe_at(pos):
        p["ffn"] = moe_lib.init_moe(ks[1], cfg, cfg.moe, dtype)
    else:
        p["ffn"] = init_ffn(ks[1], cfg, cfg.d_model, cfg.d_ff, dtype)
    return p


def init_params(key, acfg: ArchConfig) -> Params:
    cfg = acfg.model
    dtype = _dtype(acfg.train.param_dtype)
    k_embed, k_blocks, k_head = jax.random.split(key, 3)

    def init_period(k):
        pks = jax.random.split(k, cfg.pattern_period)
        return {f"pos{i}": _init_position(pks[i], cfg, i, dtype)
                for i in range(cfg.pattern_period)}

    period_keys = jax.random.split(k_blocks, cfg.n_periods)
    blocks = jax.vmap(init_period)(period_keys)

    params: Params = {
        "embed": truncated_normal(k_embed, (cfg.vocab_size, cfg.d_model),
                                  0.02, dtype),
        "blocks": blocks,
        "final_norm": init_norm(cfg, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, cfg.d_model, cfg.vocab_size,
                                       dtype)
    return params


# ---------------- logical sharding of every parameter ---------------------

def param_logical_axes(acfg: ArchConfig) -> Params:
    """Pytree matching init_params' structure; leaves are PartitionSpecs
    of *logical* axis names (see parallel.sharding.logical_to_physical)."""
    cfg = acfg.model

    def norm_axes(_cfg):
        return ({"scale": LP(None)} if _cfg.norm == "rmsnorm"
                else {"scale": LP(None), "bias": LP(None)})

    def pos_axes(pos: int) -> Params:
        kind = cfg.layer_pattern[pos]
        p: Params = {"norm1": norm_axes(cfg), "norm2": norm_axes(cfg)}
        if kind == "attn":
            att = cfg.attention
            m = {"wq": LP("fsdp", "heads"), "wk": LP("fsdp", "heads"),
                 "wv": LP("fsdp", "heads"), "wo": LP("heads", "fsdp")}
            if att.qkv_bias:
                m.update({"bq": LP("heads"), "bk": LP("heads"),
                          "bv": LP("heads")})
            if att.qk_norm:
                m.update({"q_norm": LP(None), "k_norm": LP(None)})
            p["mixer"] = m
        elif kind == "mamba":
            p["mixer"] = {
                "z_proj": LP("fsdp", "heads"),
                "x_proj": LP("fsdp", "heads"),
                "bc_proj": LP("fsdp", None),
                "dt_proj": LP("fsdp", None),
                "conv_w": LP(None, "heads"),
                "conv_b": LP("heads"),
                "conv_w_bc": LP(None, None),
                "conv_b_bc": LP(None), "a_log": LP(None),
                "d_skip": LP(None), "dt_bias": LP(None),
                "norm": LP("heads"), "out_proj": LP("heads", "fsdp")}
        elif kind == "rwkv":
            p["mixer"] = {
                "mu_w": LP(None), "mu_r": LP(None), "mu_k": LP(None),
                "mu_v": LP(None), "mu_g": LP(None),
                "w0": LP(None, None), "w_lora_a": LP("fsdp", None),
                "w_lora_b": LP(None, None), "u": LP(None, None),
                "wr": LP("fsdp", "heads"), "wk": LP("fsdp", "heads"),
                "wv": LP("fsdp", "heads"), "wg": LP("fsdp", "heads"),
                "wo": LP("heads", "fsdp"), "ln_x": LP(None),
                "mu_k_cm": LP(None), "mu_r_cm": LP(None),
                "wk_cm": LP("fsdp", "d_ff"), "wv_cm": LP("d_ff", "fsdp"),
                "wr_cm": LP("fsdp", "heads")}
        if kind != "rwkv":
            if cfg.moe_at(pos):
                es = (acfg.parallel.expert_sharding
                      or cfg.moe.expert_sharding)
                p["ffn"] = moe_lib.moe_param_logical_axes(es)
                if cfg.ffn_activation not in ("swiglu", "geglu"):
                    p["ffn"] = {k: v for k, v in p["ffn"].items()
                                if k != "w_gate"}
            else:
                f = {"w_up": LP("fsdp", "d_ff"), "w_down": LP("d_ff", "fsdp")}
                if cfg.ffn_activation in ("swiglu", "geglu"):
                    f["w_gate"] = LP("fsdp", "d_ff")
                p["ffn"] = f
        return p

    # stacked: prepend the layers axis to every leaf
    def stack(tree):
        return jax.tree.map(lambda lp: LP("layers", *lp), tree)

    # tiny vocabs (hubert's 504-label codebook) cannot shard over the
    # 16-way model axis — and gain nothing from it; replicate instead.
    vocab_ax = "vocab" if (cfg.vocab_size % 16 == 0
                           and cfg.vocab_size >= 4096) else None
    axes: Params = {
        "embed": LP(vocab_ax, "fsdp"),
        "blocks": stack({f"pos{i}": pos_axes(i)
                         for i in range(cfg.pattern_period)}),
        "final_norm": norm_axes(cfg),
    }
    if not cfg.tie_embeddings:
        axes["lm_head"] = LP("fsdp", vocab_ax)
    return axes


# =========================================================================
# Layer application
# =========================================================================

def _apply_position(ctx: ParallelCtx, cfg: ModelConfig, pos: int, p: Params,
                    x: jax.Array, state: Optional[Params], mode: str,
                    positions: jax.Array, compute_dtype,
                    max_seq: Optional[int] = None,
                    use_flash: bool = False, use_rwkv_k: bool = False
                    ) -> Tuple[jax.Array, Optional[Params], jax.Array]:
    """One layer. Returns (x, new_state, aux_loss)."""
    kind = cfg.layer_pattern[pos]
    window = cfg.window_at(pos)
    aux = jnp.zeros((), jnp.float32)
    mixer_state = state["mixer"] if state is not None else None

    h = apply_norm(cfg, p["norm1"], x)
    new_state: Optional[Params] = None
    if kind == "attn":
        att = cfg.attention
        fwd = (attn_lib.attention_forward_flash if use_flash
               else attn_lib.attention_forward)
        if mode == "decode":
            h, cache = attn_lib.attention_decode(p["mixer"], att, h,
                                                 mixer_state, window=window)
            new_state = {"mixer": cache}
        elif mode == "prefill":
            h, kv = fwd(p["mixer"], att, h, positions, window=window,
                        causal=att.causal, return_kv=True)
            new_state = {"mixer": _cache_from_prefill(kv, window, max_seq)}
        else:
            h = fwd(p["mixer"], att, h, positions, window=window,
                    causal=att.causal)
    elif kind == "mamba":
        if mode == "decode":
            h, s = ssm_lib.mamba_step(cfg, cfg.ssm, p["mixer"], h,
                                      mixer_state)
        else:
            h, s = ssm_lib.mamba_forward(cfg, cfg.ssm, p["mixer"], h,
                                         mixer_state)
        new_state = {"mixer": s} if mode != "train" else None
    elif kind == "rwkv":
        if mode == "decode":
            h, s = ssm_lib.rwkv6_time_mix_step(cfg, cfg.ssm, p["mixer"], h,
                                               mixer_state)
        else:
            h, s = ssm_lib.rwkv6_time_mix(cfg, cfg.ssm, p["mixer"], h,
                                          mixer_state,
                                          use_kernel=use_rwkv_k)
        new_state = {"mixer": s} if mode != "train" else None
    x = x + h.astype(x.dtype)
    x = _constrain_act(ctx, x)

    h2 = apply_norm(cfg, p["norm2"], x)
    if kind == "rwkv":
        cm_prev = state.get("shift_cm") if state is not None else None
        cm_state = ({"shift_cm": cm_prev} if cm_prev is not None else None)
        h2, cm_new = ssm_lib.rwkv6_channel_mix(p["mixer"], h2, cm_state)
        if new_state is not None:
            new_state["shift_cm"] = cm_new
    elif cfg.moe_at(pos):
        # decode is dropless (serving must not drop a live token's experts)
        h2, aux = moe_lib.apply_moe(ctx, cfg, cfg.moe, p["ffn"], h2,
                                    dropless=(mode == "decode"))
    else:
        h2 = apply_ffn(cfg, p["ffn"], h2)
    x = x + h2.astype(x.dtype)
    x = _constrain_act(ctx, x)
    return x, new_state, aux


def _constrain_act(ctx: ParallelCtx, x: jax.Array) -> jax.Array:
    """Activations: batch over (pod, data) when divisible, else replicated
    (single-stream decode)."""
    if x.shape[0] % max(ctx.n_batch_shards, 1) == 0:
        return ctx.constrain(x, "batch", None, None)
    return ctx.constrain(x, None, None, None)


def _cache_from_prefill(kv, window: Optional[int],
                        max_seq: int) -> KVCache:
    """Lay prefill K/V out as a ring buffer of Sc slots (slot = pos % Sc)."""
    k, v = kv
    B, S, KV, dh = k.shape
    Sc = min(max_seq, window) if window is not None else max_seq
    if Sc < S:      # windowed: keep the last Sc positions, ring layout
        k = jnp.roll(k[:, -Sc:], S % Sc, axis=1)
        v = jnp.roll(v[:, -Sc:], S % Sc, axis=1)
    elif Sc > S:    # room to grow: unwritten slots are masked by position
        pad = ((0, 0), (0, Sc - S), (0, 0), (0, 0))
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    return KVCache(k=k, v=v, index=jnp.asarray(S, jnp.int32))


# =========================================================================
# Full model
# =========================================================================

def _embed_in(ctx, cfg, params, tokens, embeds, compute_dtype):
    if cfg.frontend is not None:
        assert embeds is not None, f"{cfg.name} needs frontend embeds"
        x = embeds.astype(compute_dtype)
    else:
        x = jnp.take(params["embed"], tokens, axis=0).astype(compute_dtype)
    return _constrain_act(ctx, x)


def _scan_periods(ctx, acfg, params, x, states, mode, positions,
                  compute_dtype, max_seq=None):
    cfg = acfg.model
    aux0 = jnp.zeros((), jnp.float32)

    def period_body(x, per_params, per_states):
        new_states = {} if mode != "train" else None
        aux_sum = jnp.zeros((), jnp.float32)
        for i in range(cfg.pattern_period):
            st = per_states[f"pos{i}"] if per_states is not None else None
            x, ns, aux = _apply_position(ctx, cfg, i, per_params[f"pos{i}"],
                                         x, st, mode, positions,
                                         compute_dtype, max_seq,
                                         acfg.train.use_flash_kernel,
                                         acfg.train.use_rwkv_kernel)
            aux_sum = aux_sum + aux
            if new_states is not None:
                new_states[f"pos{i}"] = ns
        return x, new_states, aux_sum

    use_remat = (mode == "train" and acfg.train.remat)
    if use_remat:
        policy = (jax.checkpoint_policies.nothing_saveable
                  if acfg.train.remat_policy == "nothing_saveable"
                  else jax.checkpoint_policies.dots_saveable)
        period_body = jax.checkpoint(period_body, policy=policy,
                                     static_argnums=())

    if acfg.train.scan_layers and cfg.n_periods > 1:
        def scan_body(carry, xs):
            x, aux = carry
            per_params, per_states = xs
            x, ns, aux_p = period_body(x, per_params, per_states)
            return (x, aux + aux_p), ns

        xs = (params["blocks"], states if mode != "train" else None)
        (x, aux), new_states = jax.lax.scan(scan_body, (x, aux0), xs)
    else:
        aux = aux0
        new_states_list = []
        for li in range(cfg.n_periods):
            per_params = jax.tree.map(lambda a, li=li: a[li],
                                      params["blocks"])
            per_states = (jax.tree.map(lambda a, li=li: a[li], states)
                          if states is not None else None)
            x, ns, aux_p = period_body(x, per_params, per_states)
            aux = aux + aux_p
            new_states_list.append(ns)
        new_states = (jax.tree.map(lambda *xs: jnp.stack(xs),
                                   *new_states_list)
                      if mode != "train" else None)
    return x, new_states, aux


def forward(ctx: ParallelCtx, acfg: ArchConfig, params: Params, *,
            tokens: Optional[jax.Array] = None,
            embeds: Optional[jax.Array] = None,
            states: Optional[Params] = None,
            mode: str = "train",
            max_seq: Optional[int] = None
            ) -> Tuple[jax.Array, Optional[Params], jax.Array]:
    """Returns (hidden (B,S,d) after final norm, new_states, aux_loss).

    ``max_seq``: prefill only — KV-cache slot count to allocate (defaults
    to the prefill length itself, i.e. no room to decode further).
    """
    cfg = acfg.model
    compute_dtype = _dtype(acfg.train.compute_dtype)
    if compute_dtype != jnp.float32:
        params = cast_params_for_compute(ctx, acfg, params, compute_dtype)
    B, S = (tokens.shape if tokens is not None else embeds.shape[:2])
    if mode == "prefill" and max_seq is None:
        max_seq = S
    x = _embed_in(ctx, cfg, params, tokens, embeds, compute_dtype)
    if mode == "decode":
        positions = None  # attention reads positions from its cache index
    else:
        positions = jnp.arange(S, dtype=jnp.int32)
    x, new_states, aux = _scan_periods(ctx, acfg, params, x, states, mode,
                                       positions, compute_dtype, max_seq)
    x = apply_norm(cfg, params["final_norm"], x)
    return x, new_states, aux


def logits_fn(ctx: ParallelCtx, acfg: ArchConfig, params: Params,
              hidden: jax.Array) -> jax.Array:
    cfg = acfg.model
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = hidden @ head.astype(hidden.dtype)
    logits = softcap(logits, cfg.final_logit_softcap)
    return ctx.constrain(logits, "batch", None, "vocab")


def loss_fn(ctx: ParallelCtx, acfg: ArchConfig, params: Params,
            hidden: jax.Array, labels: jax.Array,
            chunk: int = 512) -> jax.Array:
    """Chunked (over seq) cross-entropy so (B,S,V) logits never fully
    materialize. labels: (B,S) int32, -1 = masked out."""
    cfg = acfg.model
    B, S, d = hidden.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    nb = S // chunk
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])

    hb = jnp.moveaxis(hidden.reshape(B, nb, chunk, d), 1, 0)
    lb = jnp.moveaxis(labels.reshape(B, nb, chunk), 1, 0)

    def body(carry, blk):
        h, y = blk
        logits = softcap(h @ head.astype(h.dtype), cfg.final_logit_softcap)
        logits = ctx.constrain(logits, "batch", None, "vocab")
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(jnp.maximum(y, 0), cfg.vocab_size,
                                dtype=jnp.float32)
        true_logit = jnp.sum(logits * onehot, axis=-1)
        mask = (y >= 0).astype(jnp.float32)
        nll = (lse - true_logit) * mask
        return (carry[0] + jnp.sum(nll), carry[1] + jnp.sum(mask)), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                                 (hb, lb))
    return tot / jnp.maximum(cnt, 1.0)


# =========================================================================
# State init (decode)
# =========================================================================

def init_states(ctx: ParallelCtx, acfg: ArchConfig, batch: int,
                max_seq: int) -> Params:
    """Fresh per-layer states, stacked over periods."""
    cfg = acfg.model
    cache_dtype = _dtype(acfg.train.compute_dtype)

    def one_position(pos: int):
        kind = cfg.layer_pattern[pos]
        window = cfg.window_at(pos)
        if kind == "attn":
            return {"mixer": attn_lib.init_cache(cfg.attention, batch,
                                                 max_seq, window,
                                                 cache_dtype)}
        if kind == "mamba":
            return {"mixer": ssm_lib.init_mamba_state(cfg, cfg.ssm, batch)}
        if kind == "rwkv":
            s = ssm_lib.init_rwkv_state(cfg, cfg.ssm, batch)
            return {"mixer": {"S": s["S"], "shift_tm": s["shift_tm"]},
                    "shift_cm": s["shift_cm"]}
        raise ValueError(kind)

    def stack_periods(tree):
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_periods,) + a.shape), tree)

    return stack_periods({f"pos{i}": one_position(i)
                          for i in range(cfg.pattern_period)})


def state_logical_axes(acfg: ArchConfig, batch: int) -> Params:
    """Logical axes for decode states (mirrors init_states).

    KV caches shard their SEQUENCE dim (flash-decoding style): over
    'model' for batched decode (batch rides (pod, data)), over the whole
    mesh for single-stream long-context decode. KV heads stay replicated
    — n_kv_heads rarely divides the model axis and jit in_shardings must
    divide exactly."""
    cfg = acfg.model
    single = batch == 1
    b_ax = None if single else "batch"
    s_ax = "kv_seq_all" if single else "kv_seq"

    def one_position(pos: int):
        kind = cfg.layer_pattern[pos]
        if kind == "attn":
            return {"mixer": KVCache(
                k=LP("layers", b_ax, s_ax, None, None),
                v=LP("layers", b_ax, s_ax, None, None),
                index=LP("layers"))}
        if kind == "mamba":
            return {"mixer": {"h": LP("layers", b_ax, "heads", None, None),
                              "conv": LP("layers", b_ax, None, "heads"),
                              "conv_bc": LP("layers", b_ax, None, None)}}
        if kind == "rwkv":
            return {"mixer": {"S": LP("layers", b_ax, "heads", None, None),
                              "shift_tm": LP("layers", b_ax, None)},
                    "shift_cm": LP("layers", b_ax, None)}
        raise ValueError(kind)

    return {f"pos{i}": one_position(i) for i in range(cfg.pattern_period)}
