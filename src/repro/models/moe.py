"""Mixture-of-Experts FFN: top-k routing, sort-based capacity dispatch.

Dispatch is the MegaBlocks-style sort formulation (no O(T*E*C) one-hot
dispatch tensor — that is infeasible at 384 experts): flatten the (token,
expert) assignments, argsort by expert, compute position-within-expert
from exclusive-cumsum bincounts, scatter into an (E, C, d) buffer, run
three batched expert GEMMs, gather back, combine with gate weights.
Overflowing tokens beyond capacity C = ceil(T*k/E * cf) are dropped
(standard capacity-factor semantics).

Sharding: the block runs under shard_map over (batch_axes..., model):
 - 'tp': experts replicated on E, tensor-parallel on d_ff (compute split
   over d_ff); combined token output psums over the model axis.
 - 'ep': experts sharded over the model axis (compute split over E);
   every model shard routes the (replicated-over-model) local tokens to
   its resident experts; combined token output psums over the model axis.
Both psum T*d per block. FSDP-sharded expert weights are all-gathered on
entry (the parameter-server "pull"); AD transposes that gather into a
reduce-scatter of the gradients (the "push") — see DESIGN.md §3.1.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, MoEConfig
from repro.core.compat import shard_map_unchecked
from repro.models.layers import activation, dense_init
from repro.parallel.sharding import ParallelCtx


def init_moe(key, cfg: ModelConfig, moe: MoEConfig, dtype) -> dict:
    d, f, E = cfg.d_model, moe.d_ff_expert, moe.num_experts
    ks = jax.random.split(key, 4)
    glu = cfg.ffn_activation in ("swiglu", "geglu")
    p = {"router": dense_init(ks[0], d, E, jnp.float32),
         "w_up": dense_init(ks[1], E * d, f, dtype).reshape(E, d, f),
         "w_down": dense_init(ks[2], E * f, d, dtype).reshape(E, f, d)}
    if glu:
        p["w_gate"] = dense_init(ks[3], E * d, f, dtype).reshape(E, d, f)
    return p


def moe_param_logical_axes(ctx_es: str) -> dict:
    e = "expert" if ctx_es == "ep" else None
    ff = None if ctx_es == "ep" else "d_ff"
    return {"router": P(None, None),
            "w_up": P(e, "fsdp", ff),
            "w_gate": P(e, "fsdp", ff),
            "w_down": P(e, ff, "fsdp")}


def _capacity(moe: MoEConfig, n_tokens: int, dropless: bool) -> int:
    if dropless:
        return n_tokens  # max per-expert load is n_tokens (top-k distinct)
    c = int(n_tokens * moe.top_k * moe.capacity_factor / moe.num_experts)
    c = max(4, -(-c // 4) * 4)     # >=4, multiple of 4
    return min(c, n_tokens)


def _dispatch_indices(expert_idx: jax.Array, n_experts: int,
                      capacity: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """expert_idx: (A,) flat assignments. Returns (sort order, destination
    row in the (E*C) buffer for each sorted assignment, keep mask)."""
    order = jnp.argsort(expert_idx, stable=True)
    sorted_e = expert_idx[order]
    counts = jnp.bincount(expert_idx, length=n_experts)
    start = jnp.cumsum(counts) - counts                  # exclusive cumsum
    pos_in_e = jnp.arange(expert_idx.shape[0]) - start[sorted_e]
    keep = pos_in_e < capacity
    dest = jnp.where(keep, sorted_e * capacity + pos_in_e,
                     n_experts * capacity)               # overflow row
    return order, dest, keep


def _expert_ffn(cfg: ModelConfig, p: dict, buf: jax.Array) -> jax.Array:
    """buf: (E, C, d) -> (E, C, d) through the per-expert FFN."""
    glu = cfg.ffn_activation in ("swiglu", "geglu")
    act = "silu" if cfg.ffn_activation == "swiglu" else (
        "gelu" if cfg.ffn_activation == "geglu" else cfg.ffn_activation)
    up = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    if glu:
        gate = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
        inner = activation(act, gate) * up
    else:
        inner = activation(act, up)
    return jnp.einsum("ecf,efd->ecd", inner, p["w_down"])


def _moe_local(cfg: ModelConfig, moe: MoEConfig, p: dict, x: jax.Array,
               *, n_local_experts: int, expert_offset: jax.Array,
               psum_axis: Optional[str], es: str,
               batch_axes: Tuple[str, ...],
               dropless: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Per-shard MoE over local tokens x: (T, d). Returns (out, aux_loss)."""
    T, d = x.shape
    E, k = moe.num_experts, moe.top_k
    C = _capacity(moe, T, dropless)

    logits = (x.astype(jnp.float32) @ p["router"])       # (T, E)
    gates = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(gates, k)               # (T, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    flat_e = top_i.reshape(-1)                           # (T*k,)
    flat_t = jnp.arange(T * k) // k
    flat_w = top_w.reshape(-1)

    if es == "ep":
        # keep only assignments for this shard's resident experts
        rel = flat_e - expert_offset
        in_range = (rel >= 0) & (rel < n_local_experts)
        eff_e = jnp.where(in_range, rel, n_local_experts)  # park out-of-range
        order, dest, keep = _dispatch_indices(eff_e, n_local_experts + 1, C)
        keep &= (eff_e[order] < n_local_experts)
        dest = jnp.where(keep, dest, n_local_experts * C)
    else:
        order, dest, keep = _dispatch_indices(flat_e, E, C)
        n_local_experts = E

    tok_sorted = flat_t[order]
    w_sorted = flat_w[order] * keep

    buf = jnp.zeros((n_local_experts * C + 1, d), x.dtype)
    buf = buf.at[dest].add(jnp.where(keep[:, None], x[tok_sorted], 0))
    buf = buf[:n_local_experts * C].reshape(n_local_experts, C, d)

    out_buf = _expert_ffn(cfg, p, buf).reshape(n_local_experts * C, d)
    out_buf = jnp.concatenate([out_buf, jnp.zeros((1, d), out_buf.dtype)])
    y_sorted = out_buf[dest] * w_sorted[:, None].astype(out_buf.dtype)
    y = jnp.zeros((T, d), x.dtype).at[tok_sorted].add(y_sorted.astype(x.dtype))

    if psum_axis is not None:
        y = jax.lax.psum(y, psum_axis)

    # Switch-style load-balance aux loss (local estimate, pmean'd).
    frac = jnp.zeros((E,), jnp.float32).at[flat_e].add(1.0) / (T * k)
    importance = jnp.mean(gates, axis=0)
    aux = E * jnp.sum(frac * importance) * moe.aux_loss_weight
    if batch_axes:
        aux = jax.lax.pmean(aux, batch_axes)
    if psum_axis is not None:
        aux = jax.lax.pmean(aux, psum_axis)
    return y, aux


def apply_moe(ctx: ParallelCtx, cfg: ModelConfig, moe: MoEConfig, p: dict,
              x: jax.Array, *, dropless: bool = False
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out (B,S,d), aux scalar)."""
    B, S, d = x.shape
    es = ctx.expert_sharding
    if ctx.mesh is None:
        out, aux = _moe_local(cfg, moe, p, x.reshape(B * S, d),
                              n_local_experts=moe.num_experts,
                              expert_offset=jnp.zeros((), jnp.int32),
                              psum_axis=None, es="tp", batch_axes=(),
                              dropless=dropless)
        return out.reshape(B, S, d), aux

    mx = ctx.model_axis
    la = moe_param_logical_axes(es)
    # shard_map requires exact divisibility on the batch dim; single-stream
    # decode (B < n_batch_shards) runs the token replicated instead.
    b_ax = ctx.axis("batch") if B % max(ctx.n_batch_shards, 1) == 0 else None
    batch_axes = ctx.batch_axes if b_ax is not None else ()
    in_specs = (P(b_ax, None, None),
                {k2: ctx.spec(*la[k2]) for k2 in p})
    out_specs = (P(b_ax, None, None), P())

    @shard_map_unchecked(mesh=ctx.mesh, in_specs=in_specs,
                         out_specs=out_specs)
    def sharded(xl, pl):
        Bl, Sl, _ = xl.shape
        if ctx.fsdp:  # PS pull: all-gather weight shards over the data axes
            for k2, axes in la.items():
                if k2 in pl and "fsdp" in axes:
                    dim = list(axes).index("fsdp")
                    pl[k2] = jax.lax.all_gather(
                        pl[k2], ctx.batch_axes, axis=dim, tiled=True)
        if es == "ep":
            n_local = moe.num_experts // ctx.n_model_shards
            off = jax.lax.axis_index(mx) * n_local
        else:
            n_local = moe.num_experts
            off = jnp.zeros((), jnp.int32)
        y, aux = _moe_local(cfg, moe, pl, xl.reshape(Bl * Sl, d),
                            n_local_experts=n_local, expert_offset=off,
                            psum_axis=mx, es=es, batch_axes=ctx.batch_axes,
                            dropless=dropless)
        return y.reshape(Bl, Sl, d), aux

    return sharded(x, p)
