"""Recurrent blocks: RWKV-6 ("Finch", data-dependent per-channel decay)
and Mamba in the SSD (scalar-per-head decay) formulation.

TPU adaptation (see DESIGN.md): both use the *chunked* linear-attention
formulation — intra-chunk work is dense matmuls (MXU-friendly), the
inter-chunk recurrence is a short ``lax.scan`` over chunks carrying the
state. All decay exponents are differences of inclusive cumulative log
decays and therefore <= 0: the chunked path is overflow-free by
construction. Single-token decode uses the exact recurrence.

Shapes: x (B, S, d). States:
  rwkv6: {"S": (B,H,K,V), "shift_tm": (B,d), "shift_cm": (B,d)}
  mamba: {"h": (B,H,P,N), "conv": (B, d_conv-1, di+2N)}
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.models.layers import dense_init

NEG_INF = -1e30


def _token_shift(x: jax.Array, prev: Optional[jax.Array]) -> jax.Array:
    """Shift right by one along seq; slot 0 filled from carry (or zeros)."""
    pad = jnp.zeros_like(x[:, :1]) if prev is None else prev[:, None, :]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


# =========================================================================
# RWKV-6
# =========================================================================

def init_rwkv6(key, cfg: ModelConfig, ssm: SSMConfig, dtype) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    hs = ssm.head_size
    H = d // hs
    ks = jax.random.split(key, 12)
    lora = 64
    decay_speed = jnp.linspace(-6.0, -2.0, d).reshape(H, hs)
    return {
        # time-mix
        "mu_w": jnp.full((d,), 0.5, dtype), "mu_r": jnp.full((d,), 0.5, dtype),
        "mu_k": jnp.full((d,), 0.5, dtype), "mu_v": jnp.full((d,), 0.5, dtype),
        "mu_g": jnp.full((d,), 0.5, dtype),
        "w0": decay_speed.astype(jnp.float32),            # (H, hs)
        "w_lora_a": dense_init(ks[0], d, lora, jnp.float32, scale=0.01),
        "w_lora_b": dense_init(ks[1], lora, d, jnp.float32, scale=0.01),
        "u": jnp.zeros((H, hs), jnp.float32),             # bonus
        "wr": dense_init(ks[2], d, d, dtype),
        "wk": dense_init(ks[3], d, d, dtype),
        "wv": dense_init(ks[4], d, d, dtype),
        "wg": dense_init(ks[5], d, d, dtype),
        "wo": dense_init(ks[6], d, d, dtype),
        "ln_x": jnp.ones((d,), dtype),                    # per-head groupnorm
        # channel-mix
        "mu_k_cm": jnp.full((d,), 0.5, dtype),
        "mu_r_cm": jnp.full((d,), 0.5, dtype),
        "wk_cm": dense_init(ks[7], d, f, dtype),
        "wv_cm": dense_init(ks[8], f, d, dtype),
        "wr_cm": dense_init(ks[9], d, d, dtype),
    }


def _rwkv6_rkvgw(p, x, xprev, H, hs):
    """Projections + data-dependent decay. Returns fp32 (B,S,H,hs) each."""
    B, S, d = x.shape

    def lerp(mu):
        return x + (xprev - x) * mu

    xw, xr, xk, xv, xg = (lerp(p[m]) for m in
                          ("mu_w", "mu_r", "mu_k", "mu_v", "mu_g"))
    w_raw = p["w0"].reshape(-1) + (
        jnp.tanh(xw.astype(jnp.float32) @ p["w_lora_a"]) @ p["w_lora_b"])
    log_w = -jnp.exp(w_raw)                               # (B,S,d), < 0
    r = (xr @ p["wr"]).astype(jnp.float32)
    k = (xk @ p["wk"]).astype(jnp.float32)
    v = (xv @ p["wv"]).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["wg"])

    rs = lambda t: t.reshape(B, S, H, hs)
    return rs(r), rs(k), rs(v), g, rs(log_w)


def rwkv6_chunked(r, k, v, log_w, u, state, chunk: int):
    """Chunked WKV. r,k,v,log_w: (B,S,H,hs) fp32; u: (H,hs);
    state: (B,H,K,V). Returns y (B,S,H,hs), new state."""
    B, S, H, hs = r.shape
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    cshape = (B, nc, chunk, H, hs)
    # (nc, B, H, chunk, hs)
    prep = lambda t: jnp.moveaxis(t.reshape(cshape).transpose(0, 1, 3, 2, 4),
                                  1, 0)
    rc, kc, vc, wc = prep(r), prep(k), prep(v), prep(log_w)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)  # strict i<t

    def body(S_st, blk):
        rb, kb, vb, lw = blk                              # (B,H,Lc,hs)
        cum = jnp.cumsum(lw, axis=2)                      # inclusive
        cum_tm1 = cum - lw
        # D[t,i,c] = exp(cum_{t-1,c} - cum_{i,c}) for i<t  (<=0 exponent)
        dlog = cum_tm1[:, :, :, None, :] - cum[:, :, None, :, :]
        dlog = jnp.where(tri[None, None, :, :, None], dlog, NEG_INF)
        A = jnp.einsum("bhtc,bhic,bhtic->bhti", rb, kb, jnp.exp(dlog))
        diag = jnp.sum(rb * kb * u[None, :, None, :], axis=-1)
        A = A + jnp.eye(chunk)[None, None] * diag[:, :, :, None]
        y_intra = jnp.einsum("bhti,bhiv->bhtv", A, vb)
        y_inter = jnp.einsum("bhtk,bhkv->bhtv", rb * jnp.exp(cum_tm1), S_st)
        # state update: decays to end of chunk, all exponents <= 0
        decay_out = jnp.exp(cum[:, :, -1:, :] - cum)      # (B,H,Lc,hs)
        S_new = S_st * jnp.exp(cum[:, :, -1, :])[..., None] + \
            jnp.einsum("bhik,bhiv->bhkv", kb * decay_out, vb)
        return S_new, y_intra + y_inter

    state, yc = jax.lax.scan(body, state, (rc, kc, vc, wc))
    y = jnp.moveaxis(yc, 0, 1).transpose(0, 1, 3, 2, 4).reshape(B, S, H, hs)
    return y, state


def _rwkv_groupnorm(y: jax.Array, scale: jax.Array, H: int,
                    eps: float = 64e-5) -> jax.Array:
    """Per-head LayerNorm (GroupNorm with H groups), RWKV convention."""
    B, S, d = y.shape
    yh = y.reshape(B, S, H, d // H).astype(jnp.float32)
    mean = jnp.mean(yh, axis=-1, keepdims=True)
    var = jnp.var(yh, axis=-1, keepdims=True)
    yh = (yh - mean) * jax.lax.rsqrt(var + eps)
    return yh.reshape(B, S, d) * scale.astype(jnp.float32)


def rwkv6_time_mix(cfg: ModelConfig, ssm: SSMConfig, p: dict, x: jax.Array,
                   state: Optional[dict], chunk: int = 16,
                   use_kernel: bool = False) -> Tuple[jax.Array, dict]:
    B, S, d = x.shape
    hs = ssm.head_size
    H = d // hs
    pad = (-S) % chunk
    x_orig = x
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    prev = state["shift_tm"] if state is not None else None
    xprev = _token_shift(x, prev)
    r, k, v, g, log_w = _rwkv6_rkvgw(p, x, xprev, H, hs)
    if pad:  # padded tail must not touch the state: zero adds, zero decay
        valid = (jnp.arange(S + pad) < S)[None, :, None, None]
        k = k * valid
        v = v * valid
        log_w = log_w * valid
    S0 = state["S"] if state is not None else jnp.zeros((B, H, hs, hs),
                                                        jnp.float32)
    if use_kernel:
        from repro.kernels.rwkv6_scan import rwkv6_scan
        Sp = S + pad
        fold = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, Sp, hs)
        u_b = jnp.broadcast_to(p["u"], (B, H, hs)).reshape(B * H, hs)
        yf, sT = rwkv6_scan(fold(r), fold(k), fold(v), fold(log_w),
                            S0.reshape(B * H, hs, hs), u_b, chunk=chunk)
        y = yf.reshape(B, H, Sp, hs).transpose(0, 2, 1, 3)
        S_new = sT.reshape(B, H, hs, hs)
    else:
        y, S_new = rwkv6_chunked(r, k, v, log_w, p["u"], S0, chunk)
    y = y[:, :S] if pad else y
    g = g[:, :S] if pad else g
    y = _rwkv_groupnorm(y.reshape(B, S, d), p["ln_x"], H)
    out = (y.astype(x.dtype) * g) @ p["wo"]
    new_state = {"S": S_new, "shift_tm": x_orig[:, -1, :]}
    return out, new_state


def rwkv6_time_mix_step(cfg: ModelConfig, ssm: SSMConfig, p: dict,
                        x: jax.Array, state: dict) -> Tuple[jax.Array, dict]:
    """Exact single-token recurrence. x: (B,1,d)."""
    B, _, d = x.shape
    hs = ssm.head_size
    H = d // hs
    xprev = state["shift_tm"][:, None, :]
    r, k, v, g, log_w = _rwkv6_rkvgw(p, x, xprev, H, hs)
    r, k, v, lw = (t[:, 0] for t in (r, k, v, log_w))     # (B,H,hs)
    outer = k[..., :, None] * v[..., None, :]             # (B,H,K,V)
    S0 = state["S"]
    y = jnp.einsum("bhk,bhkv->bhv", r, S0 + p["u"][None, :, :, None] * outer)
    S_new = S0 * jnp.exp(lw)[..., None] + outer
    y = _rwkv_groupnorm(y.reshape(B, 1, d), p["ln_x"], H)
    out = (y.astype(x.dtype) * g) @ p["wo"]
    return out, {"S": S_new, "shift_tm": x[:, -1, :]}


def rwkv6_channel_mix(p: dict, x: jax.Array, state: Optional[dict]
                      ) -> Tuple[jax.Array, jax.Array]:
    prev = state["shift_cm"] if state is not None else None
    xprev = _token_shift(x, prev)
    xk = x + (xprev - x) * p["mu_k_cm"]
    xr = x + (xprev - x) * p["mu_r_cm"]
    kk = jax.nn.relu(xk @ p["wk_cm"])
    out = jax.nn.sigmoid(xr @ p["wr_cm"]) * ((kk * kk) @ p["wv_cm"])
    return out, x[:, -1, :]


def init_rwkv_state(cfg: ModelConfig, ssm: SSMConfig, batch: int) -> dict:
    d = cfg.d_model
    H = d // ssm.head_size
    return {"S": jnp.zeros((batch, H, ssm.head_size, ssm.head_size),
                           jnp.float32),
            "shift_tm": jnp.zeros((batch, d), jnp.float32),
            "shift_cm": jnp.zeros((batch, d), jnp.float32)}


# =========================================================================
# Mamba (SSD formulation)
# =========================================================================

P_HEAD = 64  # SSD head size


def mamba_dims(cfg: ModelConfig, ssm: SSMConfig):
    di = ssm.expand * cfg.d_model
    H = di // P_HEAD
    N = ssm.d_state
    return di, H, N


def init_mamba(key, cfg: ModelConfig, ssm: SSMConfig, dtype) -> dict:
    d = cfg.d_model
    di, H, N = mamba_dims(cfg, ssm)
    ks = jax.random.split(key, 6)
    return {
        # separate column-parallel projections (NOT one fused in_proj):
        # slicing a fused model-sharded output at the z|x|B|C|dt
        # boundaries is not tile-aligned and forces GSPMD to reshard
        # (B,S,di)-sized activations (§Perf hypotheses A2/A3). x and BC
        # also get separate convs: x stays model-sharded, the tiny
        # (2N-channel) BC conv is replicated.
        "z_proj": dense_init(ks[0], d, di, dtype),
        "x_proj": dense_init(ks[4], d, di, dtype),
        "bc_proj": dense_init(ks[3], d, 2 * N, dtype),
        "dt_proj": dense_init(ks[5], d, H, dtype),
        "conv_w": truncated_conv_init(ks[1], ssm.d_conv, di, dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "conv_w_bc": truncated_conv_init(ks[2], ssm.d_conv, 2 * N, dtype),
        "conv_b_bc": jnp.zeros((2 * N,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "d_skip": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.linspace(1e-3, 1e-1, H))).astype(jnp.float32),
        "norm": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[2], di, d, dtype),
    }


def truncated_conv_init(key, width, channels, dtype):
    scale = 1.0 / jnp.sqrt(width)
    return (jax.random.truncated_normal(key, -2, 2, (width, channels),
                                        jnp.float32) * scale).astype(dtype)


def _causal_depthwise_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                           carry: Optional[jax.Array]) -> jax.Array:
    """x: (B,S,C); w: (W,C). Left-pad with carry (B,W-1,C) or zeros."""
    W = w.shape[0]
    pad = (jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
           if carry is None else carry.astype(x.dtype))
    xp = jnp.concatenate([pad, x], axis=1)               # (B, S+W-1, C)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(W))
    return jax.nn.silu(out + b)


def mamba_ssd_chunked(xh, B_, C_, log_a, h0, chunk: int):
    """xh: (B,S,H,P) dt-scaled inputs; B_,C_: (B,S,N); log_a: (B,S,H) <=0;
    h0: (B,H,P,N). Returns y (B,S,H,P), h_final."""
    B, S, H, Pd = xh.shape
    N = B_.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    mv = lambda t, shape: jnp.moveaxis(t.reshape(shape), 1, 0)
    xc = mv(xh, (B, nc, chunk, H, Pd))                    # (nc,B,Lc,H,P)
    Bc = mv(B_, (B, nc, chunk, N))
    Cc = mv(C_, (B, nc, chunk, N))
    ac = mv(log_a, (B, nc, chunk, H))
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))        # i<=t

    def body(h, blk):
        xb, Bb, Cb, ab = blk
        cum = jnp.cumsum(ab, axis=1)                      # (B,Lc,H) inclusive
        dlog = cum[:, :, None, :] - cum[:, None, :, :]    # [t,i,h]
        dlog = jnp.where(tri[None, :, :, None], dlog, NEG_INF)
        scores = jnp.einsum("btn,bin->bti", Cb, Bb)       # (B,Lc,Lc)
        M = scores[:, :, :, None] * jnp.exp(dlog)         # (B,Lc,Lc,H)
        y_intra = jnp.einsum("btih,bihp->bthp", M, xb)
        y_inter = jnp.einsum("btn,bhpn,bth->bthp", Cb, h, jnp.exp(cum))
        decay_out = jnp.exp(cum[:, -1:, :] - cum)         # (B,Lc,H)
        h_new = h * jnp.exp(cum[:, -1, :])[:, :, None, None] + \
            jnp.einsum("bih,bin,bihp->bhpn", decay_out, Bb, xb)
        return h_new, y_intra + y_inter

    h, yc = jax.lax.scan(body, h0, (xc, Bc, Cc, ac))
    return jnp.moveaxis(yc, 0, 1).reshape(B, S, H, Pd), h


def _mamba_proj(cfg, ssm, p, x):
    di, H, N = mamba_dims(cfg, ssm)
    z = x @ p["z_proj"]
    xs = x @ p["x_proj"]
    bc = x @ p["bc_proj"]
    dt = x @ p["dt_proj"]
    return z, xs, bc, dt, di, H, N


def _mamba_post(cfg, ssm, p, y, z, x_heads, B, S, di, H):
    y = y + p["d_skip"][None, None, :, None] * x_heads
    y = y.reshape(B, S, di)
    # gated RMSNorm
    yz = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(yz), axis=-1, keepdims=True)
    y = yz * jax.lax.rsqrt(var + 1e-6) * p["norm"].astype(jnp.float32)
    # cast down BEFORE the row-parallel projection: its partial-sum
    # all-reduce (and the MXU matmul) must run in the compute dtype, not
    # the SSD state math's fp32 (§Perf hypothesis A5)
    return y.astype(p["out_proj"].dtype) @ p["out_proj"]


def mamba_forward(cfg: ModelConfig, ssm: SSMConfig, p: dict, x: jax.Array,
                  state: Optional[dict], chunk: int = 64
                  ) -> Tuple[jax.Array, dict]:
    B, S, d = x.shape
    padn = (-S) % chunk
    if padn:
        x = jnp.pad(x, ((0, 0), (0, padn), (0, 0)))
    Sp = S + padn
    z, xs_pre, bc_pre, dt, di, H, N = _mamba_proj(cfg, ssm, p, x)
    cx = state["conv"] if state is not None else None
    cbc = state["conv_bc"] if state is not None else None
    xs = _causal_depthwise_conv(xs_pre, p["conv_w"], p["conv_b"], cx)
    bc = _causal_depthwise_conv(bc_pre, p["conv_w_bc"], p["conv_b_bc"],
                                cbc)
    B_, C_ = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,Sp,H)
    if padn:  # padded tail: zero dt kills both decay and state writes
        dt = dt * (jnp.arange(Sp) < S)[None, :, None]
    log_a = -jnp.exp(p["a_log"])[None, None, :] * dt             # <= 0
    x_heads = xs.reshape(B, Sp, H, P_HEAD).astype(jnp.float32)
    xh = x_heads * dt[..., None]
    h0 = state["h"] if state is not None else jnp.zeros((B, H, P_HEAD, N),
                                                        jnp.float32)
    y, h = mamba_ssd_chunked(xh, B_.astype(jnp.float32),
                             C_.astype(jnp.float32), log_a, h0, chunk)
    if padn:
        y, z, x_heads = y[:, :S], z[:, :S], x_heads[:, :S]
        xs_pre, bc_pre = xs_pre[:, :S], bc_pre[:, :S]
    out = _mamba_post(cfg, ssm, p, y, z, x_heads, B, S, di, H)
    W = ssm.d_conv

    def hist(carry, pre, ch):
        zpad = jnp.zeros((B, W - 1, ch), x.dtype)
        full = jnp.concatenate(
            [(carry.astype(x.dtype) if carry is not None else zpad), pre],
            axis=1)
        return full[:, -(W - 1):, :]

    new_state = {"h": h, "conv": hist(cx, xs_pre, di),
                 "conv_bc": hist(cbc, bc_pre, 2 * N)}
    return out, new_state


def mamba_step(cfg: ModelConfig, ssm: SSMConfig, p: dict, x: jax.Array,
               state: dict) -> Tuple[jax.Array, dict]:
    """Exact single-token recurrence. x: (B,1,d)."""
    B, _, d = x.shape
    z, xs_pre, bc_pre, dt, di, H, N = _mamba_proj(cfg, ssm, p, x)
    W = ssm.d_conv
    conv_in = jnp.concatenate([state["conv"].astype(x.dtype), xs_pre],
                              axis=1)                     # (B, W, di)
    conv_in_bc = jnp.concatenate([state["conv_bc"].astype(x.dtype),
                                  bc_pre], axis=1)        # (B, W, 2N)
    xs = jax.nn.silu(jnp.einsum("bwc,wc->bc", conv_in, p["conv_w"])
                     + p["conv_b"])[:, None, :]
    bc = jax.nn.silu(jnp.einsum("bwc,wc->bc", conv_in_bc, p["conv_w_bc"])
                     + p["conv_b_bc"])[:, None, :]
    B_, C_ = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]  # (B,H)
    a = jnp.exp(-jnp.exp(p["a_log"])[None, :] * dt)       # (B,H)
    x_heads = xs.reshape(B, 1, H, P_HEAD).astype(jnp.float32)
    xdt = x_heads[:, 0] * dt[..., None]                   # (B,H,P)
    h = state["h"] * a[:, :, None, None] + \
        jnp.einsum("bhp,bn->bhpn", xdt, B_[:, 0].astype(jnp.float32))
    y = jnp.einsum("bn,bhpn->bhp", C_[:, 0].astype(jnp.float32), h)[:, None]
    out = _mamba_post(cfg, ssm, p, y, z, x_heads, B, 1, di, H)
    return out, {"h": h, "conv": conv_in[:, -(W - 1):, :],
                 "conv_bc": conv_in_bc[:, -(W - 1):, :]}


def init_mamba_state(cfg: ModelConfig, ssm: SSMConfig, batch: int) -> dict:
    di, H, N = mamba_dims(cfg, ssm)
    return {"h": jnp.zeros((batch, H, P_HEAD, N), jnp.float32),
            "conv": jnp.zeros((batch, ssm.d_conv - 1, di), jnp.float32),
            "conv_bc": jnp.zeros((batch, ssm.d_conv - 1, 2 * N),
                                 jnp.float32)}
