"""Optimizers: AdamW, Adafactor (factored second moment — what makes the
1T-param kimi-k2 fit), SGD; global-norm clipping; warmup+cosine schedule;
DP gradient compression with error feedback.

Compression note (DESIGN.md): with compute_dtype=bfloat16 the
data-parallel gradient reduction already moves bf16 on the wire (AD's
psum runs in operand dtype — verified in the dry-run HLO). 'bf16'/'int8'
modes additionally quantize the gradient *estimate* with an
error-feedback residual so the numerics of compressed training are
faithful; int8's 1-byte wire format needs int8 collectives, which XLA
emulates at int32 width — noted in EXPERIMENTS.md.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig

Params = Any


def lr_schedule(cfg: TrainConfig, total_steps: int = 10_000
                ) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        # (step+1): step 0 must not have lr == 0 (a dead first step)
        warm = jnp.minimum((step + 1.0) / jnp.maximum(cfg.warmup_steps, 1),
                           1.0)
        prog = jnp.clip((step - cfg.warmup_steps)
                        / max(total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return cfg.learning_rate * warm * (0.1 + 0.9 * cos)
    return lr


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


# ---------------------------------------------------------------------------
# Gradient compression with error feedback
# ---------------------------------------------------------------------------

def compress_grads(grads, residual, mode: Optional[str]):
    """Quantize grads (+ carry error feedback). Returns (grads', residual')."""
    if mode is None:
        return grads, residual

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        if mode == "bf16":
            q = gf.astype(jnp.bfloat16).astype(jnp.float32)
        elif mode == "int8":
            scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
            q = jnp.round(gf / scale).astype(jnp.int8).astype(jnp.float32) \
                * scale
        else:
            raise ValueError(mode)
        return q.astype(g.dtype), gf - q

    out = jax.tree.map(one, grads, residual)
    return (jax.tree.map(lambda t: t[0], out, is_leaf=lambda x:
                         isinstance(x, tuple)),
            jax.tree.map(lambda t: t[1], out, is_leaf=lambda x:
                         isinstance(x, tuple)))


def init_residual(params, mode: Optional[str]):
    if mode is None:
        return ()
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(params) -> Dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params)}


def adamw_update(cfg: TrainConfig, params, grads, state, step, lr):
    b1, b2, eps = cfg.beta1, cfg.beta2, cfg.eps
    t = step + 1
    c1 = 1 - b1 ** t
    c2 = 1 - b2 ** t

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * gf
        v = b2 * v + (1 - b2) * gf * gf
        mh, vh = m / c1, v / c2
        step_ = mh / (jnp.sqrt(vh) + eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return (p - lr * step_.astype(p.dtype)).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
    return pick(0), {"m": pick(1), "v": pick(2)}


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern 2018), factored for >=2-D tensors
# ---------------------------------------------------------------------------

def _factored(p) -> bool:
    return p.ndim >= 2 and p.shape[-1] >= 2 and p.shape[-2] >= 2


def adafactor_init(params) -> Dict:
    def one(p):
        if _factored(p):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                    jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"v": jax.tree.map(one, params,
                              is_leaf=lambda x: isinstance(x, jax.Array)
                              or hasattr(x, "shape"))}


def adafactor_update(cfg: TrainConfig, params, grads, state, step, lr):
    t = step + 1
    beta2 = 1.0 - t ** -0.8   # Adafactor's schedule
    eps = 1e-30

    def upd(p, g, v):
        gf = g.astype(jnp.float32)
        g2 = gf * gf + eps
        if _factored(p):
            vr = beta2 * v["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * v["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
            rmean = jnp.mean(vr, axis=-1, keepdims=True)
            prec = (vr / jnp.maximum(rmean, eps))[..., None] * \
                jnp.expand_dims(vc, -2)
            u = gf / jnp.sqrt(jnp.maximum(prec, eps))
            nv = {"vr": vr, "vc": vc}
        else:
            nv = {"v": beta2 * v["v"] + (1 - beta2) * g2}
            u = gf / jnp.sqrt(jnp.maximum(nv["v"], eps))
        # update clipping (RMS <= 1)
        rms = jnp.sqrt(jnp.mean(u * u) + eps)
        u = u / jnp.maximum(1.0, rms)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p - lr * u.astype(p.dtype)).astype(p.dtype), nv

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_v = tdef.flatten_up_to(state["v"])
    new = [upd(p, g, v) for p, g, v in zip(flat_p, flat_g, flat_v)]
    return (jax.tree.unflatten(tdef, [n[0] for n in new]),
            {"v": jax.tree.unflatten(tdef, [n[1] for n in new])})


# ---------------------------------------------------------------------------
# SGD (momentum)
# ---------------------------------------------------------------------------

def sgd_init(params) -> Dict:
    return {"m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)}


def sgd_update(cfg: TrainConfig, params, grads, state, step, lr):
    def upd(p, g, m):
        m = cfg.beta1 * m + g.astype(jnp.float32)
        return (p - lr * m.astype(p.dtype)).astype(p.dtype), m
    out = jax.tree.map(upd, params, grads, state["m"])
    pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
    return pick(0), {"m": pick(1)}


# ---------------------------------------------------------------------------

OPTIMIZERS = {
    "adamw": (adamw_init, adamw_update),
    "adafactor": (adafactor_init, adafactor_update),
    "sgd": (sgd_init, sgd_update),
}


def init_opt_state(cfg: TrainConfig, params) -> Dict:
    init, _ = OPTIMIZERS[cfg.optimizer]
    state = init(params)
    state["step"] = jnp.zeros((), jnp.int32)
    state["residual"] = init_residual(params, cfg.grad_compression)
    return state


def apply_updates(cfg: TrainConfig, params, grads, state,
                  total_steps: int = 10_000):
    step = state["step"]
    lr = lr_schedule(cfg, total_steps)(step)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    grads, residual = compress_grads(grads, state["residual"],
                                     cfg.grad_compression)
    _, update = OPTIMIZERS[cfg.optimizer]
    opt_only = {k: v for k, v in state.items()
                if k not in ("step", "residual")}
    new_params, new_opt = update(cfg, params, grads, opt_only, step, lr)
    new_opt["step"] = step + 1
    new_opt["residual"] = residual
    return new_params, new_opt, {"grad_norm": gnorm, "lr": lr}
