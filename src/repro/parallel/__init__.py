from repro.parallel.sharding import (NO_MESH, ParallelCtx,
                                     logical_to_physical, make_ctx,
                                     tree_shardings)

__all__ = ["NO_MESH", "ParallelCtx", "logical_to_physical", "make_ctx",
           "tree_shardings"]
