"""Logical-axis sharding: one place that decides how every tensor maps
onto the physical mesh (MaxText-style rules, but as a small explicit
context object passed through the model).

Physical axes: ("pod",) "data", "model". Logical axes used by the model:

  batch      -> (pod, data)          activations' batch dim
  seq        -> None | data          long-context activation / KV seq dim
  heads      -> model                q-head dim (uneven heads pad via GSPMD)
  kv_heads   -> model | None         KV cache head dim
  d_ff       -> model                FFN hidden (tensor parallel)
  vocab      -> model                embedding / logits vocab dim
  expert     -> model (ep) | None    MoE expert dim
  fsdp       -> (pod, data) if fsdp  weight shard dim (ZeRO-3 / "PS shard")
  layers     -> None                 stacked-scan leading dim
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig


@dataclass(frozen=True)
class ParallelCtx:
    mesh: Optional[Mesh]
    batch_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    fsdp: bool = False
    ps_mode: bool = False
    expert_sharding: str = "tp"      # 'tp' | 'ep'
    seq_shard_prefill: bool = True
    seq_shard_kv_decode: bool = True
    # hillclimb knobs (see EXPERIMENTS.md §Perf)
    shard_kv_heads: bool = True      # shard KV cache heads over model axis

    # ------------------------------------------------------------------
    def axis(self, logical: Optional[str]):
        if logical is None:
            return None
        if logical == "batch":
            return self.batch_axes if len(self.batch_axes) > 1 \
                else self.batch_axes[0]
        if logical in ("heads", "d_ff", "vocab"):
            return self.model_axis
        if logical == "kv_heads":
            return self.model_axis if self.shard_kv_heads else None
        if logical == "expert":
            return self.model_axis if self.expert_sharding == "ep" else None
        if logical == "fsdp":
            return (self.batch_axes if len(self.batch_axes) > 1
                    else self.batch_axes[0]) if self.fsdp else None
        if logical == "seq":
            return "data"
        if logical == "kv_seq":
            # decode KV caches: sequence over the model axis
            # (flash-decoding-style split; KV heads stay replicated since
            # n_kv < mesh axis for most archs and jit shardings must
            # divide evenly)
            return self.model_axis
        if logical == "kv_seq_all":
            # single-stream long-context decode: sequence over the whole
            # mesh
            return tuple(self.batch_axes) + (self.model_axis,)
        if logical == "layers":
            return None
        raise KeyError(logical)

    def spec(self, *logical: Optional[str]) -> P:
        return P(*[self.axis(a) for a in logical])

    def sharding(self, *logical: Optional[str]) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(*logical))

    def constrain(self, x: jax.Array, *logical: Optional[str]) -> jax.Array:
        """with_sharding_constraint when a mesh is active, else identity."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(*logical)))

    @property
    def n_batch_shards(self) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for a in self.batch_axes:
            n *= self.mesh.shape[a]
        return n

    @property
    def n_model_shards(self) -> int:
        if self.mesh is None:
            return 1
        return self.mesh.shape[self.model_axis]


def make_ctx(cfg: ArchConfig, mesh: Optional[Mesh]) -> ParallelCtx:
    batch_axes: Tuple[str, ...] = ("data",)
    if mesh is not None and "pod" in mesh.axis_names:
        batch_axes = ("pod", "data")
    es = cfg.parallel.expert_sharding or (
        cfg.model.moe.expert_sharding if cfg.model.moe else "tp")
    # EP requires the expert count to divide evenly over the model axis.
    if mesh is not None and es == "ep" and cfg.model.moe is not None:
        if cfg.model.moe.num_experts % mesh.shape["model"] != 0:
            es = "tp"
    return ParallelCtx(
        mesh=mesh,
        batch_axes=batch_axes,
        fsdp=cfg.parallel.fsdp,
        ps_mode=cfg.parallel.ps_mode,
        expert_sharding=es,
        seq_shard_prefill=cfg.parallel.seq_shard_prefill,
        seq_shard_kv_decode=cfg.parallel.seq_shard_kv_decode,
    )


NO_MESH = ParallelCtx(mesh=None)


def logical_to_physical(ctx: ParallelCtx, logical_tree):
    """Map a pytree of PartitionSpec-of-*logical*-names to physical specs."""
    return jax.tree.map(lambda lp: ctx.spec(*lp), logical_tree)


def tree_shardings(ctx: ParallelCtx, logical_tree):
    """NamedShardings for a logical tree (requires a mesh)."""
    assert ctx.mesh is not None
    return jax.tree.map(
        lambda lp: NamedSharding(ctx.mesh, ctx.spec(*lp)), logical_tree)
