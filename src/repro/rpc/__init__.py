"""repro.rpc — in-process RPC fabric (gRPC analogue).

Layers, bottom-up:

  framing     wire format; serialized mode coalesces iovecs through the
              payload_pack Pallas kernel
  flow        credit-based flow control (per-channel windows)
  completion  completion-queue event loop primitive
  transport   pluggable Transports: loopback (shared-buffer memcpy),
              simulated (netmodel-priced, hundreds of endpoints)
  collective  transport lowering flights onto core.channels ppermute
              schedules (measured on real devices)
  fabric      Channel/Server API, unary + streaming calls, flush loop

See docs/RPC.md for the architecture and transport matrix.
"""
from repro.rpc.completion import CompletionQueue, Event
from repro.rpc.fabric import (Call, Channel, FlightReport, RpcError,
                              RpcFabric, Server, fully_connected_exchange)
from repro.rpc.flow import CreditWindow, FlowStats
from repro.rpc.framing import (FLAG_ERROR, FLAG_ONE_WAY, FLAG_REPLY,
                               FLAG_SERIALIZED, FLAG_STREAM,
                               FLAG_STREAM_END, Frame, decode, encode,
                               make_frame, method_id)
from repro.rpc.transport import (Delivery, LoopbackTransport, Message,
                                 SimulatedTransport, Transport,
                                 schedule_rounds, spec_of)

__all__ = [
    "Call", "Channel", "CompletionQueue", "CreditWindow", "Delivery",
    "Event", "FlightReport", "FlowStats", "Frame", "LoopbackTransport",
    "Message", "RpcError", "RpcFabric", "Server", "SimulatedTransport",
    "Transport", "decode", "encode", "fully_connected_exchange",
    "make_frame", "method_id", "schedule_rounds", "spec_of",
    "FLAG_ERROR", "FLAG_ONE_WAY", "FLAG_REPLY", "FLAG_SERIALIZED",
    "FLAG_STREAM", "FLAG_STREAM_END",
]


def CollectiveTransport(*args, **kwargs):
    """Lazy import: the collective transport pulls in jax/channels."""
    from repro.rpc.collective import CollectiveTransport as _CT
    return _CT(*args, **kwargs)
