"""repro.rpc — in-process RPC fabric (gRPC analogue).

Layers, bottom-up:

  framing     wire format (unary + stream-chunk frames, incl. the
              budget_us deadline-propagation header word); serialized
              mode coalesces iovecs through the payload_pack kernel
  flow        credit-based flow control (per-channel, per-direction
              windows; ChunkGate FIFO for stream chunks)
  completion  completion-queue event loop primitive
  transport   pluggable Transports (built via make_transport): loopback
              (shared-buffer memcpy), simulated (netmodel-priced
              ingress+egress, hundreds of endpoints), fault (seeded
              fault-injection wrapper around any of them)
  cluster     ClusterSpec (named endpoints/jobs/links) + the
              multi-endpoint ClusterTransport: per-link routing and
              pricing, endpoint-addressed channels, per-endpoint
              windows — the PS-style multi-host topology layer
  collective  transport lowering flights onto core.channels ppermute
              schedules (measured on real devices)
  fabric      Channel/Server API, unary + client/server/bidi streaming
              calls, flush loop (deadline enforcement + propagation:
              budgets stamped at flight departure, servers shed
              expired work before handlers run);
              fully_connected/ring/incast exchanges
  interceptors client/server interceptor chains: metrics (incl.
              queue-depth/shed/rejection tracking), deadline defaults,
              budget-aware retry (unary + zero-chunk server-stream),
              admission control (ResourceExhausted rejections)
  service     declarative ServiceDef/MethodSpec + generated Stubs —
              the gRPC-style API surface over the fabric
  tracing     distributed tracing: per-call span trees (phases, wire
              spans, server spans) on the fabric clock, trace ids
              propagated in a frame-header word, Chrome trace-event
              export (Perfetto) + per-phase latency breakdown
  telemetry   bounded latency histograms (exact up to a cap, then
              log-bucketed) behind a shared HistogramRegistry — what
              MetricsInterceptor records percentiles into

See docs/RPC.md for the architecture and transport matrix.
"""
from repro.rpc.completion import CompletionQueue, Event
from repro.rpc.fabric import (BIDI, CLIENT_STREAM, DEADLINE_EXCEEDED,
                              HANDLER_FAULTS, LINK_FAULT, SERVER_STREAM,
                              UNARY, BidiStream, Call, Channel,
                              FlightReport, RpcError, RpcFabric, Server,
                              ServerStream, StreamHandle, StreamPump,
                              fully_connected_exchange, incast_exchange,
                              ring_exchange)
from repro.rpc.cluster import (ClusterSpec, ClusterTransport,
                               EndpointSpec, LinkSpec, as_cluster_spec,
                               cluster_allreduce_time,
                               cluster_fc_round_time,
                               cluster_incast_round_time,
                               cluster_ring_allreduce_time,
                               cluster_ring_round_time,
                               cluster_rsag_allreduce_time,
                               cluster_tree_allreduce_time, homogeneous,
                               ps_worker_cluster)
from repro.rpc.collectives import (ALLREDUCE_ALGOS, CollectiveReport,
                                   allreduce, ring_allreduce,
                                   rsag_allreduce, tree_allreduce)
from repro.rpc.flow import ChunkGate, CreditWindow, FlowStats, WindowConfig
from repro.rpc.interceptors import (AdmissionInterceptor, CallContext,
                                    ClientInterceptor,
                                    DeadlineInterceptor,
                                    MetricsInterceptor, ResourceExhausted,
                                    RetryInterceptor, ServerContext,
                                    ServerInterceptor, TransientError,
                                    is_resource_exhausted, is_transient)
from repro.rpc.service import (ALLREDUCE_SERVICE, CONFORMANCE_SERVICE,
                               EXCHANGE_SERVICE,
                               INCAST_SERVICE, RING_SERVICE, Codec,
                               MethodSpec, ServiceDef, Stub, StubMethod,
                               UnaryCall, conformance_handlers)
from repro.rpc.bufpool import (BufferPool, PoolExhausted, get_pool,
                               release_call, reset_pools)
from repro.rpc.framing import (FLAG_ERROR, FLAG_FAULT, FLAG_ONE_WAY,
                               FLAG_REPLY, FLAG_SERIALIZED, FLAG_STREAM,
                               FLAG_STREAM_END, FLAG_ZERO_COPY,
                               WIRE_MODES, Frame, FramingError, decode,
                               encode, make_frame, method_id,
                               resolve_wire_mode, stream_chunk)
from repro.rpc.telemetry import BoundedHistogram, HistogramRegistry
from repro.rpc.tracing import PHASES, Span, Tracer
from repro.rpc.transport import (Delivery, FaultInjectionTransport,
                                 LoopbackTransport, Message,
                                 SimulatedTransport, Transport,
                                 make_transport, schedule_rounds,
                                 spec_of)

__all__ = [
    "ALLREDUCE_ALGOS", "ALLREDUCE_SERVICE",
    "AdmissionInterceptor", "BIDI", "BidiStream", "BoundedHistogram",
    "BufferPool", "Call", "CallContext", "CollectiveReport",
    "Channel", "ChunkGate", "CLIENT_STREAM", "CONFORMANCE_SERVICE",
    "ClientInterceptor", "ClusterSpec", "ClusterTransport", "Codec",
    "CompletionQueue", "CreditWindow", "DEADLINE_EXCEEDED",
    "DeadlineInterceptor", "Delivery", "EXCHANGE_SERVICE",
    "EndpointSpec", "Event", "FaultInjectionTransport", "FlightReport",
    "FlowStats", "Frame", "FramingError", "HANDLER_FAULTS",
    "HistogramRegistry",
    "INCAST_SERVICE", "LINK_FAULT", "LinkSpec", "LoopbackTransport",
    "Message", "MethodSpec", "MetricsInterceptor", "PHASES",
    "PoolExhausted",
    "RING_SERVICE", "ResourceExhausted", "RetryInterceptor", "RpcError",
    "RpcFabric", "SERVER_STREAM", "Server", "ServerContext",
    "ServerInterceptor", "ServerStream", "ServiceDef",
    "SimulatedTransport", "Span", "StreamHandle", "StreamPump", "Stub",
    "StubMethod",
    "Tracer", "Transport", "TransientError", "UNARY",
    "UnaryCall", "WIRE_MODES", "WindowConfig", "allreduce",
    "as_cluster_spec", "cluster_allreduce_time",
    "cluster_fc_round_time", "cluster_incast_round_time",
    "cluster_ring_allreduce_time", "cluster_ring_round_time",
    "cluster_rsag_allreduce_time", "cluster_tree_allreduce_time",
    "conformance_handlers", "decode",
    "encode", "fully_connected_exchange", "get_pool", "homogeneous",
    "incast_exchange", "is_resource_exhausted", "is_transient",
    "ring_allreduce", "rsag_allreduce", "tree_allreduce",
    "make_frame", "make_transport", "method_id", "ps_worker_cluster",
    "release_call", "reset_pools", "resolve_wire_mode", "ring_exchange",
    "schedule_rounds", "spec_of", "stream_chunk",
    "FLAG_ERROR", "FLAG_FAULT", "FLAG_ONE_WAY", "FLAG_REPLY",
    "FLAG_SERIALIZED", "FLAG_STREAM", "FLAG_STREAM_END",
    "FLAG_ZERO_COPY",
]


def CollectiveTransport(*args, **kwargs):
    """Lazy import: the collective transport pulls in jax/channels."""
    from repro.rpc.collective import CollectiveTransport as _CT
    return _CT(*args, **kwargs)
