"""repro.rpc — in-process RPC fabric (gRPC analogue).

Layers, bottom-up:

  framing     wire format (unary + stream-chunk frames); serialized mode
              coalesces iovecs through the payload_pack Pallas kernel
  flow        credit-based flow control (per-channel, per-direction
              windows; ChunkGate FIFO for stream chunks)
  completion  completion-queue event loop primitive
  transport   pluggable Transports: loopback (shared-buffer memcpy),
              simulated (netmodel-priced ingress+egress, hundreds of
              endpoints)
  collective  transport lowering flights onto core.channels ppermute
              schedules (measured on real devices)
  fabric      Channel/Server API, unary + client/server/bidi streaming
              calls, flush loop; fully_connected/ring/incast exchanges

See docs/RPC.md for the architecture and transport matrix.
"""
from repro.rpc.completion import CompletionQueue, Event
from repro.rpc.fabric import (BidiStream, Call, Channel, FlightReport,
                              RpcError, RpcFabric, Server, ServerStream,
                              StreamHandle, fully_connected_exchange,
                              incast_exchange, ring_exchange)
from repro.rpc.flow import ChunkGate, CreditWindow, FlowStats
from repro.rpc.framing import (FLAG_ERROR, FLAG_ONE_WAY, FLAG_REPLY,
                               FLAG_SERIALIZED, FLAG_STREAM,
                               FLAG_STREAM_END, Frame, decode, encode,
                               make_frame, method_id, stream_chunk)
from repro.rpc.transport import (Delivery, LoopbackTransport, Message,
                                 SimulatedTransport, Transport,
                                 schedule_rounds, spec_of)

__all__ = [
    "BidiStream", "Call", "Channel", "ChunkGate", "CompletionQueue",
    "CreditWindow", "Delivery", "Event", "FlightReport", "FlowStats",
    "Frame", "LoopbackTransport", "Message", "RpcError", "RpcFabric",
    "Server", "ServerStream", "SimulatedTransport", "StreamHandle",
    "Transport", "decode", "encode", "fully_connected_exchange",
    "incast_exchange", "make_frame", "method_id", "ring_exchange",
    "schedule_rounds", "spec_of", "stream_chunk",
    "FLAG_ERROR", "FLAG_ONE_WAY", "FLAG_REPLY", "FLAG_SERIALIZED",
    "FLAG_STREAM", "FLAG_STREAM_END",
]


def CollectiveTransport(*args, **kwargs):
    """Lazy import: the collective transport pulls in jax/channels."""
    from repro.rpc.collective import CollectiveTransport as _CT
    return _CT(*args, **kwargs)
