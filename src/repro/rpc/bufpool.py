"""Pre-registered shared buffer pools for the ``zero_copy`` wire mode.

The one-sided-RDMA-write analogue ("RPC Considered Harmful", PAPERS.md):
both ends of a channel share a pinned, pre-registered memory region. The
*sender* manages placement — it copies each payload buffer into the next
free slot of the region and puts only a ``(pool_id, offset, size)``
descriptor on the wire; the receiver reads the bytes straight out of the
shared region. Steady-state tensor transfer therefore skips the
pack/unpack copies entirely — the only residual cost is the one-time
registration (pinning) of the region, amortized over its reuse, which is
exactly the ``zero_copy`` branch of
:meth:`repro.core.netmodel.NetworkModel.copy_cost`.

Placement is a lane-aligned bump allocator that wraps at capacity
(steady-state reuse). Receivers get *views* into the region — true
zero-copy semantics — so a slot must not be recycled while its call is
still in flight. Placements made on behalf of a call (``owner=`` the
call id, which is how the framing layer places every descriptor) are
*live spans*: the allocator skips over them when it wraps, and the
fabric releases them when the call completes (reply landed, stream
ended, error, or retry of the old attempt) — free-on-complete, the
same invalidation point a real one-sided write protocol acks at. A
flight whose live placements exceed the region raises
:class:`PoolExhausted` loudly instead of silently overwriting bytes a
receiver still holds views into (the old wrap-and-overwrite behavior
produced torn reads). Ownerless placements (direct pool use) keep the
plain wrapping-bump behavior.

Pools are process-global, keyed by ``pool_id``, and resolved through
:func:`get_pool` — the registration step. Constructing ``BufferPool``
directly outside ``src/repro/rpc/`` is forbidden (CI grep gate mirrored
in ``tests/test_service_api.py``): everything goes through the registry
so decode can resolve any descriptor it sees on the wire.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np


class PoolExhausted(RuntimeError):
    """Raised when a placement cannot fit without overwriting a live
    (in-flight) span — the zero-copy analogue of running out of
    registered memory. Complete the in-flight calls or register a
    larger region; silently recycling a live slot would hand the
    receiver torn bytes."""

# Placement alignment in bytes. Must equal repro.rpc.framing.LANE
# (pinned by tests) — not imported from there to keep this module
# dependency-free so framing can import it without a cycle.
LANE = 128

DEFAULT_POOL_ID = 0

#: default region capacity (16 MiB) — large enough that the benchmark
#: families' steady-state flights reuse slots long after the receiver
#: consumed them
DEFAULT_CAPACITY = 16 << 20


class BufferPool:
    """One pre-registered shared region with a wrapping bump allocator."""

    def __init__(self, pool_id: int, capacity: int = DEFAULT_CAPACITY):
        capacity = int(capacity)
        assert capacity >= LANE and capacity % LANE == 0, capacity
        self.pool_id = int(pool_id)
        self.capacity = capacity
        self.region = np.zeros(capacity, dtype=np.uint8)
        self._cursor = 0
        # live spans: owner (call id) -> [(offset, reserved_bytes), ...]
        # — slots the allocator must not recycle until released
        self._live: Dict[int, List[Tuple[int, int]]] = {}
        # telemetry: how much reuse the registration cost amortizes over
        self.placements = 0
        self.placed_bytes = 0
        self.wraps = 0
        self.releases = 0

    def live_bytes(self) -> int:
        """Reserved bytes currently pinned by in-flight calls."""
        return sum(n for spans in self._live.values() for _, n in spans)

    def _find_slot(self, need: int) -> int:
        """The first lane-aligned offset with ``need`` free bytes,
        scanning from the cursor and wrapping once past any live span
        that blocks the tail. Raises :class:`PoolExhausted` when no gap
        between live spans is wide enough."""
        spans = sorted((off, off + n)
                       for s in self._live.values() for off, n in s)

        def blocked_until(off: int) -> Optional[int]:
            end = off + need
            for s_off, s_end in spans:
                if off < s_end and s_off < end:
                    return s_end
            return None

        wrapped = False
        for start in (self._cursor, 0):
            off = start
            while off + need <= self.capacity:
                hit = blocked_until(off)
                if hit is None:
                    if wrapped or off < self._cursor:
                        self.wraps += 1
                    return off
            # skip past the live span, re-aligned to the lane
                off = -(-hit // LANE) * LANE
            wrapped = True
        raise PoolExhausted(
            f"pool {self.pool_id} exhausted: need {need} bytes but "
            f"{self.live_bytes()} of {self.capacity} are pinned by "
            f"{len(self._live)} in-flight call(s) — complete (or "
            f"release) them, or register a larger region")

    def place(self, buf: np.ndarray, *,
              owner: Optional[int] = None) -> Tuple[int, int]:
        """Copy ``buf`` into the next free lane-aligned slot
        (sender-managed placement) and return its ``(offset, size)``
        descriptor half. ``owner`` pins the slot as a live span until
        :meth:`release`; the allocator never recycles a live span —
        :class:`PoolExhausted` fires instead. Ownerless placements wrap
        to offset 0 when the tail can't fit the buffer."""
        b = np.ascontiguousarray(buf, dtype=np.uint8).reshape(-1)
        size = int(b.size)
        need = max(LANE, -(-size // LANE) * LANE)
        if need > self.capacity:
            raise ValueError(
                f"buffer of {size} bytes exceeds pool {self.pool_id} "
                f"capacity {self.capacity}")
        offset = self._find_slot(need)
        if size:
            self.region[offset:offset + size] = b
        self._cursor = offset + need
        if owner is not None:
            self._live.setdefault(int(owner), []).append((offset, need))
        self.placements += 1
        self.placed_bytes += size
        return offset, size

    def release(self, owner: int) -> int:
        """Free every span placed under ``owner`` (call completed — the
        receiver's views are dead). Returns the number of bytes
        unpinned; unknown owners are a no-op (zero-copy never rode this
        call, or it was already released)."""
        spans = self._live.pop(int(owner), None)
        if spans is None:
            return 0
        self.releases += 1
        return sum(n for _, n in spans)

    def read(self, offset: int, size: int) -> np.ndarray:
        """A zero-copy *view* of ``size`` bytes at ``offset`` — valid
        until the write cursor laps the slot."""
        if not (0 <= offset and offset + size <= self.capacity):
            raise ValueError(
                f"descriptor ({offset}, {size}) outside pool "
                f"{self.pool_id} capacity {self.capacity}")
        return self.region[offset:offset + size]

    def reset(self) -> None:
        """Rewind the allocator and drop every live span (telemetry
        counters are kept)."""
        self._cursor = 0
        self._live.clear()


_POOLS: Dict[int, BufferPool] = {}


def get_pool(pool_id: int = DEFAULT_POOL_ID, *,
             capacity: int = DEFAULT_CAPACITY) -> BufferPool:
    """Resolve (registering on first use) the shared pool ``pool_id``.
    This is the registration step every zero-copy endpoint goes
    through; ``capacity`` only applies when the pool is first created."""
    pool = _POOLS.get(pool_id)
    if pool is None:
        pool = _POOLS[pool_id] = BufferPool(pool_id, capacity)
    return pool


def release_call(call_id: int) -> int:
    """Free-on-complete hook: unpin every span any registered pool
    holds for ``call_id``. The fabric calls this at each call's
    terminal edge (reply landed, stream ended, error, retry of the old
    attempt); returns the total bytes unpinned."""
    return sum(pool.release(call_id) for pool in _POOLS.values())


def reset_pools() -> None:
    """Drop every registered pool (tests)."""
    _POOLS.clear()
