"""Pre-registered shared buffer pools for the ``zero_copy`` wire mode.

The one-sided-RDMA-write analogue ("RPC Considered Harmful", PAPERS.md):
both ends of a channel share a pinned, pre-registered memory region. The
*sender* manages placement — it copies each payload buffer into the next
free slot of the region and puts only a ``(pool_id, offset, size)``
descriptor on the wire; the receiver reads the bytes straight out of the
shared region. Steady-state tensor transfer therefore skips the
pack/unpack copies entirely — the only residual cost is the one-time
registration (pinning) of the region, amortized over its reuse, which is
exactly the ``zero_copy`` branch of
:meth:`repro.core.netmodel.NetworkModel.copy_cost`.

Placement is a lane-aligned bump allocator that wraps at capacity
(steady-state reuse): a region stays valid until the write cursor laps
it, so the pool capacity sets the reuse distance. Receivers get *views*
into the region — true zero-copy semantics — and must consume a
descriptor before the sender recycles its slot, the same contract a
real one-sided write protocol imposes.

Pools are process-global, keyed by ``pool_id``, and resolved through
:func:`get_pool` — the registration step. Constructing ``BufferPool``
directly outside ``src/repro/rpc/`` is forbidden (CI grep gate mirrored
in ``tests/test_service_api.py``): everything goes through the registry
so decode can resolve any descriptor it sees on the wire.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

# Placement alignment in bytes. Must equal repro.rpc.framing.LANE
# (pinned by tests) — not imported from there to keep this module
# dependency-free so framing can import it without a cycle.
LANE = 128

DEFAULT_POOL_ID = 0

#: default region capacity (16 MiB) — large enough that the benchmark
#: families' steady-state flights reuse slots long after the receiver
#: consumed them
DEFAULT_CAPACITY = 16 << 20


class BufferPool:
    """One pre-registered shared region with a wrapping bump allocator."""

    def __init__(self, pool_id: int, capacity: int = DEFAULT_CAPACITY):
        capacity = int(capacity)
        assert capacity >= LANE and capacity % LANE == 0, capacity
        self.pool_id = int(pool_id)
        self.capacity = capacity
        self.region = np.zeros(capacity, dtype=np.uint8)
        self._cursor = 0
        # telemetry: how much reuse the registration cost amortizes over
        self.placements = 0
        self.placed_bytes = 0
        self.wraps = 0

    def place(self, buf: np.ndarray) -> Tuple[int, int]:
        """Copy ``buf`` into the next lane-aligned slot (sender-managed
        placement) and return its ``(offset, size)`` descriptor half.
        Wraps to offset 0 when the tail can't fit the buffer."""
        b = np.ascontiguousarray(buf, dtype=np.uint8).reshape(-1)
        size = int(b.size)
        need = max(LANE, -(-size // LANE) * LANE)
        if need > self.capacity:
            raise ValueError(
                f"buffer of {size} bytes exceeds pool {self.pool_id} "
                f"capacity {self.capacity}")
        if self._cursor + need > self.capacity:
            self._cursor = 0
            self.wraps += 1
        offset = self._cursor
        if size:
            self.region[offset:offset + size] = b
        self._cursor += need
        self.placements += 1
        self.placed_bytes += size
        return offset, size

    def read(self, offset: int, size: int) -> np.ndarray:
        """A zero-copy *view* of ``size`` bytes at ``offset`` — valid
        until the write cursor laps the slot."""
        if not (0 <= offset and offset + size <= self.capacity):
            raise ValueError(
                f"descriptor ({offset}, {size}) outside pool "
                f"{self.pool_id} capacity {self.capacity}")
        return self.region[offset:offset + size]

    def reset(self) -> None:
        """Rewind the allocator (telemetry counters are kept)."""
        self._cursor = 0


_POOLS: Dict[int, BufferPool] = {}


def get_pool(pool_id: int = DEFAULT_POOL_ID, *,
             capacity: int = DEFAULT_CAPACITY) -> BufferPool:
    """Resolve (registering on first use) the shared pool ``pool_id``.
    This is the registration step every zero-copy endpoint goes
    through; ``capacity`` only applies when the pool is first created."""
    pool = _POOLS.get(pool_id)
    if pool is None:
        pool = _POOLS[pool_id] = BufferPool(pool_id, capacity)
    return pool


def reset_pools() -> None:
    """Drop every registered pool (tests)."""
    _POOLS.clear()
