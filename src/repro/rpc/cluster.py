"""Multi-endpoint cluster transport: N named endpoints on one fabric.

The paper's workload characterization (§3) is built around
parameter-server deployments — many worker processes talking to PS
processes over gRPC. :class:`ClusterSpec` declares such a deployment in
one object: named endpoints grouped into jobs (``ps`` / ``worker``),
each with its own base network model and advertised credit windows,
plus per-directed-link bandwidth/latency overrides.
:class:`ClusterTransport` binds the spec onto one fabric:

* **endpoint-addressed channels** — ``fabric.channel("worker0", "ps1")``
  and ``fabric.add_server("ps1")`` resolve names through the spec;
* **per-link routing** — a flight's messages are grouped per directed
  link and priced on that link's resolved model (dst endpoint base
  network + overrides), with per-link AND cross-link host-copy
  contention, matching ``core.netmodel.cluster_flight_time`` exactly;
* **loopback-fast local calls** — same-endpoint messages cost one host
  memcpy, never link alpha / rpc overhead / egress;
* **per-endpoint credit windows** — an endpoint that advertises a
  window sizes every channel touching it (forward direction by the
  receiver's window, reverse by the client's).

Frames pass through un-copied (like ``SimulatedTransport``), so
dispatching handlers — including a real serving engine — run on the
delivered payloads while elapsed time stays fully modeled: a cluster
serving experiment is deterministic and runs at memcpy speed.

The pattern-level closed forms (``cluster_fc_round_time`` /
``cluster_ring_round_time`` / ``cluster_incast_round_time``) price one
round of each fabric benchmark family on a spec; the transport driving
``rpc.fully_connected_exchange`` / ``ring_exchange`` /
``incast_exchange`` must land on them exactly
(tests/test_cluster_transport.py, incl. by-mutation checks).
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.core.netmodel import (ALLREDUCE_TAG_BYTES, NETWORKS, LinkLoad,
                                 NetworkModel, allreduce_chunk_sizes,
                                 cluster_flight_time,
                                 ring_allreduce_send_chunk,
                                 tree_reduce_rounds)
from repro.core.payload import PayloadSpec, classify, scale_sizes
from repro.rpc.flow import WindowConfig
from repro.rpc.transport import (Delivery, Message, Transport,
                                 schedule_rounds, spec_of)


@dataclass(frozen=True)
class EndpointSpec:
    """One named endpoint: its job, base network, advertised window,
    and (optional) advertised admission limit — the outstanding-call
    cap an ``AdmissionInterceptor`` enforces for this endpoint (calls
    beyond it are rejected with a transient ``resource exhausted``
    error clients retry or fail over)."""
    name: str
    job: str = "worker"
    network: str = "eth40g"           # key into core.netmodel.NETWORKS
    window: Optional[WindowConfig] = None
    admission_limit: Optional[int] = None

    def model(self) -> NetworkModel:
        return NETWORKS[self.network]


@dataclass(frozen=True)
class LinkSpec:
    """Overrides for one *directed* link (src -> dst by endpoint name).
    Unset fields inherit from the dst endpoint's base network."""
    src: str
    dst: str
    bandwidth_Bps: Optional[float] = None
    latency_s: Optional[float] = None


@dataclass(frozen=True)
class ClusterSpec:
    """A PS-style deployment: named endpoints + per-link overrides."""
    endpoints: Tuple[EndpointSpec, ...]
    links: Tuple[LinkSpec, ...] = ()

    def __post_init__(self):
        if not self.endpoints:
            raise ValueError("ClusterSpec needs at least one endpoint")
        seen = set()
        for ep in self.endpoints:
            if ep.name in seen:
                raise ValueError(f"duplicate endpoint name {ep.name!r}")
            seen.add(ep.name)
            if ep.network not in NETWORKS:
                raise ValueError(
                    f"endpoint {ep.name!r}: unknown network "
                    f"{ep.network!r}; choose from {sorted(NETWORKS)}")
            if ep.admission_limit is not None and ep.admission_limit < 1:
                raise ValueError(
                    f"endpoint {ep.name!r}: admission_limit must be "
                    f">= 1, got {ep.admission_limit}")
        pairs = set()
        for ln in self.links:
            for end in (ln.src, ln.dst):
                if end not in seen:
                    raise ValueError(
                        f"link {ln.src!r}->{ln.dst!r}: unknown endpoint "
                        f"{end!r}")
            if ln.src == ln.dst:
                # same-endpoint traffic is a host memcpy — a self-link
                # override would be silently dead config
                raise ValueError(
                    f"self-link {ln.src!r}->{ln.dst!r}: same-endpoint "
                    f"calls are loopback memcpys, link parameters "
                    f"never apply to them")
            if (ln.src, ln.dst) in pairs:
                raise ValueError(
                    f"duplicate link {ln.src!r}->{ln.dst!r}")
            pairs.add((ln.src, ln.dst))

    # addressing -------------------------------------------------------
    @property
    def n_endpoints(self) -> int:
        return len(self.endpoints)

    def index(self, name: str) -> int:
        for i, ep in enumerate(self.endpoints):
            if ep.name == name:
                return i
        raise ValueError(
            f"unknown endpoint {name!r}; endpoints: "
            f"{[ep.name for ep in self.endpoints]}")

    def name_of(self, endpoint: int) -> str:
        return self.endpoints[endpoint].name

    def job_endpoints(self, job: str) -> Tuple[str, ...]:
        """Endpoint names of one job, in spec order (the PS/worker
        job -> endpoint mapping)."""
        return tuple(ep.name for ep in self.endpoints if ep.job == job)

    @property
    def jobs(self) -> Dict[str, Tuple[str, ...]]:
        out: Dict[str, Tuple[str, ...]] = {}
        for ep in self.endpoints:
            out[ep.job] = out.get(ep.job, ()) + (ep.name,)
        return out

    def admission_limits(self) -> Dict[int, int]:
        """endpoint index -> advertised admission limit, for every
        endpoint that declares one — the ``limits`` mapping an
        ``AdmissionInterceptor`` takes (``serve_cluster`` wires this
        automatically)."""
        return {i: ep.admission_limit
                for i, ep in enumerate(self.endpoints)
                if ep.admission_limit is not None}

    # link resolution --------------------------------------------------
    def base_model(self, endpoint: int) -> NetworkModel:
        return self.endpoints[endpoint].model()

    def link_model(self, src: int, dst: int) -> NetworkModel:
        """The resolved model of one directed link: the dst endpoint's
        base network with this link's bandwidth/latency overrides."""
        base = self.base_model(dst)
        sname, dname = self.name_of(src), self.name_of(dst)
        for ln in self.links:
            if ln.src == sname and ln.dst == dname:
                return base.with_link(bandwidth_Bps=ln.bandwidth_Bps,
                                      latency_s=ln.latency_s)
        return base

    # serialization ----------------------------------------------------
    def to_dict(self) -> dict:
        return {
            "endpoints": [
                {"name": ep.name, "job": ep.job, "network": ep.network,
                 **({"window": {"bytes": ep.window.bytes,
                                "msgs": ep.window.msgs}}
                    if ep.window is not None else {}),
                 **({"admission_limit": ep.admission_limit}
                    if ep.admission_limit is not None else {})}
                for ep in self.endpoints],
            "links": [
                {"src": ln.src, "dst": ln.dst,
                 **({"bandwidth_Bps": ln.bandwidth_Bps}
                    if ln.bandwidth_Bps is not None else {}),
                 **({"latency_s": ln.latency_s}
                    if ln.latency_s is not None else {})}
                for ln in self.links],
        }

    @classmethod
    def from_dict(cls, d: dict) -> "ClusterSpec":
        eps = []
        for e in d.get("endpoints", ()):
            w = e.get("window")
            eps.append(EndpointSpec(
                name=e["name"], job=e.get("job", "worker"),
                network=e.get("network", "eth40g"),
                window=(WindowConfig(int(w["bytes"]), int(w["msgs"]))
                        if w is not None else None),
                admission_limit=(int(e["admission_limit"])
                                 if e.get("admission_limit") is not None
                                 else None)))
        links = tuple(LinkSpec(
            src=ln["src"], dst=ln["dst"],
            bandwidth_Bps=(float(ln["bandwidth_Bps"])
                           if ln.get("bandwidth_Bps") is not None
                           else None),
            latency_s=(float(ln["latency_s"])
                       if ln.get("latency_s") is not None else None))
            for ln in d.get("links", ()))
        return cls(endpoints=tuple(eps), links=links)

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_json(cls, text: str) -> "ClusterSpec":
        return cls.from_dict(json.loads(text))


def homogeneous(n: int, network: str = "eth40g", *, job: str = "worker",
                prefix: str = "ep",
                window: Optional[WindowConfig] = None) -> ClusterSpec:
    """n identical endpoints on one network — the degenerate cluster a
    plain ``--transport cluster`` run (no ``--cluster-spec``) gets; it
    reproduces ``SimulatedTransport`` pricing exactly."""
    return ClusterSpec(endpoints=tuple(
        EndpointSpec(f"{prefix}{i}", job=job, network=network,
                     window=window) for i in range(n)))


def ps_worker_cluster(n_ps: int, n_workers: int, *,
                      ps_network: str = "eth40g",
                      worker_network: str = "eth40g",
                      links: Sequence[LinkSpec] = ()) -> ClusterSpec:
    """The paper's deployment shape: ``ps0..`` endpoints first (so the
    incast server, endpoint 0, is a PS), then ``worker0..``."""
    eps = tuple(EndpointSpec(f"ps{i}", job="ps", network=ps_network)
                for i in range(n_ps))
    eps += tuple(EndpointSpec(f"worker{i}", job="worker",
                              network=worker_network)
                 for i in range(n_workers))
    return ClusterSpec(endpoints=eps, links=tuple(links))


def as_cluster_spec(obj: Union[ClusterSpec, dict, str]) -> ClusterSpec:
    """Coerce a ClusterSpec | dict | JSON string into a ClusterSpec."""
    if isinstance(obj, ClusterSpec):
        return obj
    if isinstance(obj, dict):
        return ClusterSpec.from_dict(obj)
    if isinstance(obj, str):
        return ClusterSpec.from_json(obj)
    raise TypeError(f"cannot build a ClusterSpec from {type(obj)!r}")


def load_cluster_spec(text: str) -> ClusterSpec:
    """The CLIs' ``--cluster-spec`` value: inline JSON (starts with
    ``{``) or a path to a JSON file."""
    if text.lstrip().startswith("{"):
        return ClusterSpec.from_json(text)
    with open(text) as f:
        return ClusterSpec.from_json(f.read())


# ---------------------------------------------------------------------------
# the transport
# ---------------------------------------------------------------------------

class ClusterTransport(Transport):
    """Analytic multi-endpoint transport over a :class:`ClusterSpec`.

    Per flight, messages are routed onto their directed links; each
    link's messages serialize on the link's resolved model and pay the
    per-link quadratic host-copy term; messages from *different* links
    landing on one endpoint additionally pay the cross-link host-copy
    term; each sender pays egress per link. Same-endpoint messages are
    loopback memcpys. Matches ``netmodel.cluster_flight_time`` exactly.

    Frames pass through with their buffers intact, so handlers (and a
    real serving engine) run on a fully modeled clock.
    """

    modeled = True

    def __init__(self, cluster: ClusterSpec):
        self.cluster = cluster
        self.n_endpoints = cluster.n_endpoints
        self.clock_s = 0.0
        self._models: Dict[Tuple[int, int], NetworkModel] = {}

    # endpoint addressing (the fabric resolves names through these) ----
    def resolve(self, name: str) -> int:
        return self.cluster.index(name)

    def endpoint_name(self, endpoint: int) -> str:
        return self.cluster.name_of(endpoint)

    def channel_windows(self, src: int, dst: int
                        ) -> Tuple[Optional[WindowConfig],
                                   Optional[WindowConfig]]:
        """(forward, reverse) window overrides for a (src -> dst)
        channel: gRPC-style receiver-advertised flow control — the
        forward direction is sized by the dst endpoint's window, the
        reverse by the src's. None keeps the fabric default."""
        return (self.cluster.endpoints[dst].window,
                self.cluster.endpoints[src].window)

    def link_model(self, src: int, dst: int) -> NetworkModel:
        key = (src, dst)
        m = self._models.get(key)
        if m is None:
            m = self.cluster.link_model(src, dst)
            self._models[key] = m
        return m

    # pricing ----------------------------------------------------------
    @staticmethod
    def price(model: NetworkModel, frame) -> float:
        """One message at the link's receiver: payload + 64B ack."""
        return (model.payload_time(spec_of(frame),
                                   mode=frame.wire_mode)
                + model.msg_time(64))

    @staticmethod
    def _link_contention(model: NetworkModel, n_msgs: int,
                         total_bytes: int) -> float:
        """The per-link quadratic copy term (the mutation target of the
        conformance cross-checks: zeroing it must break the match)."""
        if n_msgs < 2:
            return 0.0
        return (n_msgs * (n_msgs - 1) * (total_bytes / n_msgs)
                / model.cpu_copy_Bps)

    def deliver(self, messages: Sequence[Message]) -> Delivery:
        # route the flight onto its directed links
        per_link: Dict[Tuple[int, int], List] = {}
        for m in messages:
            assert 0 <= m.src < self.n_endpoints, m.src
            assert 0 <= m.dst < self.n_endpoints, m.dst
            per_link.setdefault((m.src, m.dst), []).append(m.frame)
        ingress: Dict[int, float] = {}
        egress: Dict[int, float] = {}
        cross: Dict[int, List[Tuple[NetworkModel, int, int]]] = {}
        for (src, dst), frames in per_link.items():
            model = self.link_model(src, dst)
            nbytes = sum(f.total_bytes for f in frames)
            if src == dst:
                # loopback-fast: one host memcpy per message
                ingress[dst] = (ingress.get(dst, 0.0)
                                + nbytes / model.cpu_copy_Bps)
                continue
            t = sum(self.price(model, f) for f in frames)
            t += self._link_contention(model, len(frames), nbytes)
            ingress[dst] = ingress.get(dst, 0.0) + t
            egress[src] = (egress.get(src, 0.0)
                           + nbytes / model.beta_Bps)
            cross.setdefault(dst, []).append((model, len(frames),
                                              nbytes))
        # cross-link host-copy contention at each receiving endpoint
        for dst, lds in cross.items():
            k_tot = sum(k for _, k, _ in lds)
            if k_tot < 2:
                continue
            pairs = k_tot * (k_tot - 1) - sum(k * (k - 1)
                                              for _, k, _ in lds)
            if pairs <= 0:
                continue
            bytes_tot = sum(b for _, _, b in lds)
            ingress[dst] += (pairs * (bytes_tot / k_tot)
                             / lds[0][0].cpu_copy_Bps)
        elapsed = max((ingress.get(e, 0.0) + egress.get(e, 0.0)
                       for e in set(ingress) | set(egress)),
                      default=0.0)
        self.clock_s += elapsed
        rounds = schedule_rounds(messages)
        return Delivery(list(messages), elapsed, len(rounds),
                        modeled=True)


# ---------------------------------------------------------------------------
# pattern-level closed forms (one round of each fabric benchmark family
# on a ClusterSpec; built on netmodel.cluster_flight_time)
# ---------------------------------------------------------------------------

def _payload_spec(sizes: Sequence[int]) -> PayloadSpec:
    return PayloadSpec(sizes=tuple(int(s) for s in sizes), scheme="wire",
                       categories=tuple(classify(int(s)) for s in sizes))


def _load(cluster: ClusterSpec, src: int, dst: int, spec: PayloadSpec,
          n_msgs: int, serialized: bool,
          mode: Optional[str] = None) -> LinkLoad:
    return LinkLoad(src, dst, cluster.link_model(src, dst),
                    (spec,) * n_msgs, serialized=serialized, mode=mode)


def cluster_fc_round_time(cluster: ClusterSpec, sizes: Sequence[int], *,
                          serialized: bool = False,
                          mode: Optional[str] = None) -> float:
    """One fully-connected exchange on the cluster: every endpoint one
    payload to every other, all in one flight."""
    n = cluster.n_endpoints
    assert n >= 2, n
    spec = _payload_spec(sizes)
    loads = [_load(cluster, i, j, spec, 1, serialized, mode)
             for i in range(n) for j in range(n) if i != j]
    return cluster_flight_time(loads)


def cluster_ring_round_time(cluster: ClusterSpec, sizes: Sequence[int],
                            *, n_chunks: int = 1,
                            serialized: bool = False,
                            mode: Optional[str] = None) -> float:
    """One chunked ring pass: every endpoint streams n_chunks to its
    successor (i -> (i+1) % n), one flight."""
    n = cluster.n_endpoints
    assert n >= 2, n
    spec = _payload_spec(sizes)
    loads = [_load(cluster, i, (i + 1) % n, spec, n_chunks, serialized,
                   mode)
             for i in range(n)]
    return cluster_flight_time(loads)


def cluster_incast_round_time(cluster: ClusterSpec,
                              sizes: Sequence[int], *,
                              n_chunks: int = 1,
                              serialized: bool = False,
                              mode: Optional[str] = None,
                              fetch_ratio: float = 1.0,
                              server: int = 0) -> float:
    """One incast round: every non-server endpoint streams n_chunks
    into the server (the push flight), which streams the fetch back
    sized ``fetch_ratio`` times the push (the fetch flight)."""
    n = cluster.n_endpoints
    assert n >= 2, n
    spec = _payload_spec(sizes)
    fspec = _payload_spec(scale_sizes(sizes, fetch_ratio))
    workers = [w for w in range(n) if w != server]
    push = [_load(cluster, w, server, spec, n_chunks, serialized, mode)
            for w in workers]
    fetch = [_load(cluster, server, w, fspec, n_chunks, serialized,
                   mode)
             for w in workers]
    return cluster_flight_time(push) + cluster_flight_time(fetch)


def cluster_ring_allreduce_time(cluster: ClusterSpec, total_bytes: int,
                                *, itemsize: int = 1,
                                serialized: bool = False,
                                mode: Optional[str] = None) -> float:
    """Ring allreduce on the cluster: 2(n-1) rotation flights, each
    worker sending one balanced chunk to its successor — per-step link
    loads summed through ``cluster_flight_time``, matching
    ``rpc.collectives.ring_allreduce`` over a ClusterTransport."""
    n = cluster.n_endpoints
    if n < 2:
        return 0.0
    chunks = allreduce_chunk_sizes(total_bytes, n, itemsize=itemsize)
    total = 0.0
    for step in range(2 * (n - 1)):
        loads = [_load(cluster, i, (i + 1) % n,
                       _payload_spec(
                           (chunks[ring_allreduce_send_chunk(i, step,
                                                             n)],)),
                       1, serialized, mode)
                 for i in range(n)]
        total += cluster_flight_time(loads)
    return total


def cluster_tree_allreduce_time(cluster: ClusterSpec, total_bytes: int,
                                *, serialized: bool = False,
                                mode: Optional[str] = None) -> float:
    """Binomial-tree allreduce on the cluster: ceil(log2 n) reduce
    flights toward endpoint 0, mirrored broadcast flights back out,
    full payload per pair send."""
    n = cluster.n_endpoints
    if n < 2:
        return 0.0
    spec = _payload_spec((int(total_bytes),))
    rounds = tree_reduce_rounds(n)
    total = 0.0
    for pairs in rounds:
        total += cluster_flight_time(
            [_load(cluster, s, d, spec, 1, serialized, mode)
             for s, d in pairs])
    for pairs in reversed(rounds):
        total += cluster_flight_time(
            [_load(cluster, d, s, spec, 1, serialized, mode)
             for s, d in pairs])
    return total


def cluster_rsag_allreduce_time(cluster: ClusterSpec, total_bytes: int,
                                *, itemsize: int = 1,
                                serialized: bool = False,
                                mode: Optional[str] = None) -> float:
    """Reduce-scatter + allgather on the cluster: two all-to-all
    flights of source-tagged chunks (every endpoint ingests n-1
    messages per flight — the cross-link contention case)."""
    n = cluster.n_endpoints
    if n < 2:
        return 0.0
    chunks = allreduce_chunk_sizes(total_bytes, n, itemsize=itemsize)
    tag = ALLREDUCE_TAG_BYTES
    scatter = [_load(cluster, i, j, _payload_spec((tag, chunks[j])), 1,
                     serialized, mode)
               for i in range(n) for j in range(n) if j != i]
    gather = [_load(cluster, j, i, _payload_spec((tag, chunks[j])), 1,
                    serialized, mode)
              for j in range(n) for i in range(n) if i != j]
    return cluster_flight_time(scatter) + cluster_flight_time(gather)


def cluster_allreduce_time(cluster: ClusterSpec, algo: str,
                           total_bytes: int, *, itemsize: int = 1,
                           serialized: bool = False,
                           mode: Optional[str] = None) -> float:
    """Dispatch on the ``netmodel.ALLREDUCE_ALGOS`` name."""
    forms = {"ring": cluster_ring_allreduce_time,
             "tree": cluster_tree_allreduce_time,
             "rsag": cluster_rsag_allreduce_time}
    if algo not in forms:
        raise ValueError(f"unknown allreduce algo {algo!r}; "
                         f"expected one of {tuple(forms)}")
    kw = {} if algo == "tree" else {"itemsize": itemsize}
    return forms[algo](cluster, total_bytes, serialized=serialized,
                       mode=mode, **kw)


__all__ = [
    "ClusterSpec", "ClusterTransport", "EndpointSpec", "LinkSpec",
    "as_cluster_spec", "cluster_allreduce_time",
    "cluster_fc_round_time", "cluster_incast_round_time",
    "cluster_ring_allreduce_time", "cluster_ring_round_time",
    "cluster_rsag_allreduce_time", "cluster_tree_allreduce_time",
    "homogeneous", "load_cluster_spec", "ps_worker_cluster",
]
