"""Collective transport: lowers RPC flights onto the ``ppermute``
schedules of ``repro.core.channels`` and measures them on real devices.

Endpoint *i* is device *i* on the 1-D ``net`` mesh. A flight is edge-
colored into rounds (unique src/dst — precisely ppermute's contract) and
compiled to one jitted program per distinct round pattern: serialized
frames move as one packed collective per round, non-serialized frames as
one collective per iovec buffer. Frames must be homogeneous across the
flight (one PayloadSpec), which is what the benchmark families generate
— the datapath is SPMD, so per-endpoint python handlers don't run here
(service semantics are exchange/echo, as in the paper's benchmarks).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Sequence, Tuple

import jax

from repro.core import channels as ch
from repro.core.payload import PayloadSpec
from repro.rpc.transport import (Delivery, Message, Transport,
                                 schedule_rounds)


class CollectiveTransport(Transport):

    dispatches = False

    def __init__(self, mesh, spec: PayloadSpec, *, serialized: bool = False,
                 n_endpoints: int = 0, seed: int = 0):
        self.mesh = mesh
        n_dev = mesh.shape[ch.AXIS]
        self.n_endpoints = n_endpoints or n_dev
        assert self.n_endpoints <= n_dev, (self.n_endpoints, n_dev)
        self.spec = spec
        self.serialized = serialized
        self.bufs = ch.device_payload(mesh, spec, seed=seed)
        self._fns: Dict[Tuple[Tuple[Tuple[int, int], ...], ...],
                        Callable] = {}

    def _fn(self, perms: Tuple[Tuple[Tuple[int, int], ...], ...]):
        if perms not in self._fns:
            self._fns[perms] = ch.permute_rounds_fn(
                self.mesh, self.spec.n_buffers,
                [list(p) for p in perms], serialized=self.serialized)
        return self._fns[perms]

    def deliver(self, messages: Sequence[Message]) -> Delivery:
        for m in messages:
            assert m.frame.sizes == self.spec.sizes, \
                "collective transport needs homogeneous frames (one spec)"
            assert m.src < self.n_endpoints and m.dst < self.n_endpoints
        rounds = schedule_rounds(messages)
        perms = tuple(tuple((m.src, m.dst) for m in rnd) for rnd in rounds)
        fn = self._fn(perms)
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn(*self.bufs))
        elapsed = time.perf_counter() - t0
        del out
        return Delivery(list(messages), elapsed, len(rounds),
                        modeled=False)
