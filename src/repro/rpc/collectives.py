"""Allreduce collectives as first-class fabric schedules.

Three algorithms reduce one flat float32 gradient across every fabric
endpoint, each expressed as per-step flights of store-only unary RPCs
through :data:`repro.rpc.service.ALLREDUCE_SERVICE` stubs:

  ring    2(n-1) rotation steps over balanced chunks — the
          bandwidth-optimal schedule (each endpoint moves ~2·T/n bytes
          per step, no receiver contention);
  tree    binomial reduce toward endpoint 0 plus the mirrored
          broadcast — 2·ceil(log2 n) full-payload hops, latency-optimal
          at small payloads;
  rsag    reduce-scatter + allgather in two all-to-all flights — the
          fewest flights, but every endpoint ingests n-1 messages per
          flight and pays the quadratic host-copy contention the
          paper's incast measurements isolate.

Every step is one ``fabric.flush()``: all of a step's sends form one
transport flight, so the modeled elapsed time is the closed forms in
``core.netmodel`` (``ring_allreduce_time`` / ``tree_allreduce_time`` /
``rsag_allreduce_time``) and ``rpc.cluster``
(``cluster_*_allreduce_time``) *exactly* — driver and closed form share
the chunk partition (``netmodel.allreduce_chunk_sizes``) and schedule
helpers, so they cannot drift apart.

Handlers are store-only and the reduction arithmetic runs in the
driver between flushes, summing in a fixed worker order: a seeded link
fault that forces a retry never changes the summation order, so a
retried allreduce produces bit-identical gradients
(tests/test_collectives.py holds this). Reduce-scatter messages carry
an int64 source tag (``netmodel.ALLREDUCE_TAG_BYTES``) because their
inbox order is not topology-determined; ring and tree infer the source
from the schedule.

Real data rides any dispatching transport — loopback moves real bytes,
simulated/cluster pass buffers through unencoded while pricing the
spec — so one test can check numerics and modeled time in a single
run.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np

from repro.core.netmodel import (ALLREDUCE_ALGOS, ALLREDUCE_TAG_BYTES,
                                 allreduce_chunk_sizes,
                                 ring_allreduce_send_chunk,
                                 tree_reduce_rounds)
from repro.rpc.fabric import FlightReport, RpcFabric
from repro.rpc.service import ALLREDUCE_SERVICE

_DTYPE = np.float32
_ITEMSIZE = np.dtype(_DTYPE).itemsize


@dataclass
class CollectiveReport:
    """Aggregate of the per-step :class:`FlightReport`\\ s of one
    collective, plus the reduced per-endpoint vectors when real data
    was supplied (``None`` for spec-only runs)."""
    algo: str = ""
    steps: int = 0
    flights: int = 0
    rounds: int = 0
    messages: int = 0
    replies: int = 0
    elapsed_s: float = 0.0
    wall_s: float = 0.0
    modeled: bool = False
    result: Optional[List[np.ndarray]] = field(default=None, repr=False)

    def merge(self, rep: FlightReport) -> None:
        self.steps += 1
        self.flights += rep.flights
        self.rounds += rep.rounds
        self.messages += rep.messages
        self.replies += rep.replies
        self.elapsed_s += rep.elapsed_s
        self.wall_s += rep.wall_s


def _inboxes(fabric: RpcFabric) -> Optional[dict]:
    """Per-endpoint inboxes behind store-only ``Allreduce/chunk``
    handlers, registered once per fabric (state rides the fabric like
    ``_incast_setup`` does). Non-dispatching transports get ``None`` —
    delivery is completion there and only spec-only runs make sense."""
    if not fabric.transport.dispatches:
        return None
    boxes = getattr(fabric, "_allreduce_inboxes", None)
    if boxes is None:
        boxes = {e: [] for e in range(fabric.n_endpoints)}
        for e in range(fabric.n_endpoints):
            srv = fabric.servers.get(e)
            if srv is None:
                srv = fabric.add_server(e)

            def chunk(req, _box=boxes[e]):
                # copy immediately: zero-copy views point into pool
                # slots that are reclaimed once the call completes
                _box.append([np.asarray(b, dtype=np.uint8).copy()
                             for b in req] if req else None)
                return None

            srv.add_service(ALLREDUCE_SERVICE, {"chunk": chunk})
        fabric._allreduce_inboxes = boxes
    return boxes


def _clear(boxes: Optional[dict]) -> None:
    if boxes:
        for box in boxes.values():
            box.clear()


def _take_one(boxes: dict, endpoint: int) -> List[np.ndarray]:
    box = boxes[endpoint]
    assert len(box) == 1, \
        f"endpoint {endpoint}: expected 1 inbox entry, got {len(box)}"
    entry = box.pop()
    assert entry is not None, "real-data step delivered a spec-only frame"
    return entry


def _prepare(fabric: RpcFabric, total_bytes: Optional[int],
             data: Optional[Sequence[np.ndarray]], itemsize: int):
    """Validate the (spec-only | real-data) call shape; return
    ``(n, work, total_bytes, itemsize)`` with ``work`` the per-endpoint
    float32 working vectors (None for spec-only)."""
    n = fabric.n_endpoints
    if (total_bytes is None) == (data is None):
        raise ValueError("pass exactly one of total_bytes (spec-only) "
                         "or data (real buffers)")
    if data is None:
        total_bytes = int(total_bytes)
        if total_bytes < itemsize:
            raise ValueError(f"total_bytes must be >= itemsize, got "
                             f"{total_bytes}")
        return n, None, total_bytes, itemsize
    if not fabric.transport.dispatches:
        raise ValueError("real-data allreduce needs a dispatching "
                         "transport (loopback/simulated/cluster); "
                         "spec-only runs work everywhere")
    if len(data) != n:
        raise ValueError(f"data must have one vector per endpoint: "
                         f"got {len(data)} for {n} endpoints")
    work = [np.ascontiguousarray(np.asarray(d).ravel(), dtype=_DTYPE)
            .copy() for d in data]
    elems = work[0].size
    if elems == 0 or any(w.size != elems for w in work):
        raise ValueError("data vectors must share one non-empty shape")
    return n, work, elems * _ITEMSIZE, _ITEMSIZE


def _elem_offsets(chunks: Sequence[int], itemsize: int) -> List[int]:
    offs = [0]
    for c in chunks:
        offs.append(offs[-1] + c // itemsize)
    return offs


def _tag(src: int) -> np.ndarray:
    return np.array([src], dtype="<i8").view(np.uint8)


def _read_tagged(entry: List[np.ndarray]):
    src = int(np.frombuffer(entry[0], dtype="<i8")[0])
    return src, np.frombuffer(entry[1], dtype=_DTYPE)


def _stub(fabric, src, dst, serialized, wire_mode):
    return fabric.stub(ALLREDUCE_SERVICE, src, dst,
                       serialized=serialized, wire_mode=wire_mode)


# ---------------------------------------------------------------------------
# the three schedules
# ---------------------------------------------------------------------------

def ring_allreduce(fabric: RpcFabric, total_bytes: Optional[int] = None,
                   *, data: Optional[Sequence[np.ndarray]] = None,
                   itemsize: int = 1, serialized: bool = False,
                   wire_mode: Optional[str] = None) -> CollectiveReport:
    """Ring allreduce: 2(n-1) flights; at step ``s`` worker ``i`` sends
    chunk ``ring_allreduce_send_chunk(i, s, n)`` to ``(i+1) % n`` —
    reduce-scatter rotation, then allgather of the reduced chunks."""
    n, work, total_bytes, itemsize = _prepare(fabric, total_bytes, data,
                                              itemsize)
    rep = CollectiveReport(algo="ring", modeled=fabric.transport.modeled)
    if n < 2:
        rep.result = work
        return rep
    if total_bytes // itemsize < n:
        raise ValueError(f"ring allreduce needs >= 1 element per worker"
                         f": {total_bytes // itemsize} elements for "
                         f"{n} workers")
    boxes = _inboxes(fabric)
    chunks = allreduce_chunk_sizes(total_bytes, n, itemsize=itemsize)
    offs = _elem_offsets(chunks, itemsize)
    for step in range(2 * (n - 1)):
        for i in range(n):
            c = ring_allreduce_send_chunk(i, step, n)
            stub = _stub(fabric, i, (i + 1) % n, serialized, wire_mode)
            if work is None:
                stub.chunk(None, sizes=(chunks[c],), one_way=True)
            else:
                seg = np.ascontiguousarray(work[i][offs[c]:offs[c + 1]])
                stub.chunk([seg.view(np.uint8)], one_way=True)
        rep.merge(fabric.flush())
        if work is None:
            _clear(boxes)
            continue
        for i in range(n):
            rc = ring_allreduce_send_chunk((i - 1) % n, step, n)
            incoming = np.frombuffer(_take_one(boxes, i)[0],
                                     dtype=_DTYPE)
            seg = slice(offs[rc], offs[rc + 1])
            if step < n - 1:
                # predecessor's partial sum + own contribution: the
                # ring's fixed accumulation order
                work[i][seg] = incoming + work[i][seg]
            else:
                work[i][seg] = incoming
    rep.result = work
    return rep


def tree_allreduce(fabric: RpcFabric, total_bytes: Optional[int] = None,
                   *, data: Optional[Sequence[np.ndarray]] = None,
                   serialized: bool = False,
                   wire_mode: Optional[str] = None) -> CollectiveReport:
    """Binomial-tree allreduce: ceil(log2 n) full-payload reduce
    flights toward endpoint 0, then the mirrored broadcast flights."""
    n, work, total_bytes, _ = _prepare(fabric, total_bytes, data, 1)
    rep = CollectiveReport(algo="tree", modeled=fabric.transport.modeled)
    if n < 2:
        rep.result = work
        return rep
    boxes = _inboxes(fabric)
    rounds = tree_reduce_rounds(n)
    sizes = (total_bytes,)
    for pairs in rounds:
        for s, d in pairs:
            stub = _stub(fabric, s, d, serialized, wire_mode)
            if work is None:
                stub.chunk(None, sizes=sizes, one_way=True)
            else:
                stub.chunk([work[s].view(np.uint8)], one_way=True)
        rep.merge(fabric.flush())
        if work is None:
            _clear(boxes)
            continue
        for s, d in pairs:
            incoming = np.frombuffer(_take_one(boxes, d)[0],
                                     dtype=_DTYPE)
            work[d] = incoming + work[d]
    for pairs in reversed(rounds):
        for s, d in pairs:
            stub = _stub(fabric, d, s, serialized, wire_mode)
            if work is None:
                stub.chunk(None, sizes=sizes, one_way=True)
            else:
                stub.chunk([work[d].view(np.uint8)], one_way=True)
        rep.merge(fabric.flush())
        if work is None:
            _clear(boxes)
            continue
        for s, d in pairs:
            work[s] = np.frombuffer(_take_one(boxes, s)[0],
                                    dtype=_DTYPE).copy()
    rep.result = work
    return rep


def rsag_allreduce(fabric: RpcFabric, total_bytes: Optional[int] = None,
                   *, data: Optional[Sequence[np.ndarray]] = None,
                   itemsize: int = 1, serialized: bool = False,
                   wire_mode: Optional[str] = None) -> CollectiveReport:
    """Reduce-scatter + allgather: flight 1 sends chunk ``j`` (with the
    int64 source tag) from every worker to worker ``j``, which reduces
    its chunk in ascending-source order; flight 2 broadcasts every
    reduced chunk."""
    n, work, total_bytes, itemsize = _prepare(fabric, total_bytes, data,
                                              itemsize)
    rep = CollectiveReport(algo="rsag", modeled=fabric.transport.modeled)
    if n < 2:
        rep.result = work
        return rep
    if total_bytes // itemsize < n:
        raise ValueError(f"rsag allreduce needs >= 1 element per worker"
                         f": {total_bytes // itemsize} elements for "
                         f"{n} workers")
    boxes = _inboxes(fabric)
    chunks = allreduce_chunk_sizes(total_bytes, n, itemsize=itemsize)
    offs = _elem_offsets(chunks, itemsize)
    tag = ALLREDUCE_TAG_BYTES
    # flight 1: reduce-scatter (src-major submission order — the closed
    # forms replay the same order)
    for i in range(n):
        for j in range(n):
            if j == i:
                continue
            stub = _stub(fabric, i, j, serialized, wire_mode)
            if work is None:
                stub.chunk(None, sizes=(tag, chunks[j]), one_way=True)
            else:
                seg = np.ascontiguousarray(work[i][offs[j]:offs[j + 1]])
                stub.chunk([_tag(i), seg.view(np.uint8)], one_way=True)
    rep.merge(fabric.flush())
    reduced: List[Optional[np.ndarray]] = [None] * n
    if work is None:
        _clear(boxes)
    else:
        for j in range(n):
            got = {}
            for entry in boxes[j]:
                src, vals = _read_tagged(entry)
                got[src] = vals
            boxes[j].clear()
            assert len(got) == n - 1, \
                f"endpoint {j}: got chunks from {sorted(got)}"
            # own contribution first, then ascending source order —
            # fixed regardless of delivery (and retry) order
            acc = work[j][offs[j]:offs[j + 1]].copy()
            for src in sorted(got):
                acc = acc + got[src]
            work[j][offs[j]:offs[j + 1]] = acc
            reduced[j] = acc
    # flight 2: allgather of the reduced chunks (sender-major order)
    for j in range(n):
        for i in range(n):
            if i == j:
                continue
            stub = _stub(fabric, j, i, serialized, wire_mode)
            if work is None:
                stub.chunk(None, sizes=(tag, chunks[j]), one_way=True)
            else:
                stub.chunk([_tag(j),
                            np.ascontiguousarray(reduced[j])
                            .view(np.uint8)], one_way=True)
    rep.merge(fabric.flush())
    if work is None:
        _clear(boxes)
    else:
        for i in range(n):
            for entry in boxes[i]:
                src, vals = _read_tagged(entry)
                work[i][offs[src]:offs[src + 1]] = vals
            boxes[i].clear()
    rep.result = work
    return rep


_DRIVERS = {"ring": ring_allreduce, "tree": tree_allreduce,
            "rsag": rsag_allreduce}


def allreduce(fabric: RpcFabric, algo: str,
              total_bytes: Optional[int] = None, *,
              data: Optional[Sequence[np.ndarray]] = None,
              itemsize: int = 1, serialized: bool = False,
              wire_mode: Optional[str] = None) -> CollectiveReport:
    """Dispatch on the :data:`ALLREDUCE_ALGOS` name."""
    if algo not in _DRIVERS:
        raise ValueError(f"unknown allreduce algo {algo!r}; "
                         f"expected one of {ALLREDUCE_ALGOS}")
    kw = {} if algo == "tree" else {"itemsize": itemsize}
    return _DRIVERS[algo](fabric, total_bytes, data=data,
                          serialized=serialized, wire_mode=wire_mode,
                          **kw)


__all__ = [
    "ALLREDUCE_ALGOS", "CollectiveReport", "allreduce",
    "ring_allreduce", "rsag_allreduce", "tree_allreduce",
]
