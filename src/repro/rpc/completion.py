"""Completion queue — the fabric's event loop primitive (gRPC CQ
analogue). Transports and the fabric push typed events; drivers poll or
drain. Thread-safe so a loopback server thread may complete calls while
the client polls.
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass
from typing import Any, Deque, List, Optional


@dataclass(frozen=True)
class Event:
    tag: int                # call_id (or flight id for transport events)
    kind: str               # "sent" | "received" | "replied" | "error"
                            # | "stream_chunk" | "stream_end"
                            # | "deadline_exceeded" | "retry"
    ok: bool = True
    payload: Any = None     # usually a framing.Frame
    elapsed_s: float = 0.0


class CompletionQueue:
    """Bounded: when nobody drains (benchmark loops), the oldest events
    fall off instead of retaining every delivered payload forever;
    ``dropped`` counts them."""

    def __init__(self, maxlen: int = 4096):
        self._q: Deque[Event] = deque(maxlen=maxlen)
        self._cv = threading.Condition()
        self.dropped = 0

    def push(self, ev: Event) -> None:
        with self._cv:
            if self._q.maxlen is not None \
                    and len(self._q) == self._q.maxlen:
                self.dropped += 1
            self._q.append(ev)
            self._cv.notify_all()

    def poll(self, timeout_s: float = 0.0) -> Optional[Event]:
        with self._cv:
            if not self._q and timeout_s > 0:
                self._cv.wait(timeout_s)
            return self._q.popleft() if self._q else None

    def drain(self) -> List[Event]:
        with self._cv:
            out = list(self._q)
            self._q.clear()
            return out

    def __len__(self) -> int:
        with self._cv:
            return len(self._q)
