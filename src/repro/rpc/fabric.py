"""The RPC fabric: Channel/Server API over a pluggable Transport.

Client side                         Server side
-----------                         -----------
fabric.channel(src, dst)            fabric.add_server(endpoint)
  .call(method, bufs)    ->flight->   server.register(method, handler)
  .stream(method, [bufs...])          handler(bufs) -> reply bufs

Calls are buffered and moved in *flights* by ``flush()`` — the event
loop. One flush: admit calls the credit window allows, deliver them
through the transport (edge-colored into rounds), dispatch delivered
frames to endpoint servers, send replies back (a second flight), grant
credits, resolve futures, and push an :class:`completion.Event` per
completion. ``flush`` loops until the backlog drains, so a burst larger
than the flow-control window simply takes several flights — the stall
count in ``Channel.window.stats`` records the back-pressure.

Transports with ``dispatches=False`` (the collective transport) are pure
exchange datapaths: delivery itself completes the call and the reply
flight is skipped (the 64B ack is priced inside the transport).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, replace
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.rpc import framing
from repro.rpc.completion import CompletionQueue, Event
from repro.rpc.flow import CreditWindow
from repro.rpc.transport import Message, Transport


class RpcError(Exception):
    pass


def _spec_only(frame: Optional[framing.Frame]) -> Optional[framing.Frame]:
    """Events carry frame *metadata* only — retaining payload buffers in
    an undrained completion queue would pin gigabytes in benchmark
    loops. Callers get the data from their Call future."""
    if frame is None or frame.bufs is None:
        return frame
    return replace(frame, bufs=None)


@dataclass
class Call:
    """Client-side future for one RPC."""
    call_id: int
    method: str
    dst: int
    done: bool = False
    result: Optional[framing.Frame] = None
    error: Optional[str] = None

    def reply_bufs(self) -> List[np.ndarray]:
        assert self.done, "call not complete — fabric.flush() first"
        if self.error is not None:
            raise RpcError(self.error)
        assert self.result is not None and self.result.bufs is not None
        return self.result.bufs


Handler = Callable[[List[np.ndarray]], Optional[List[np.ndarray]]]


class Server:
    """Per-endpoint method table. Streaming methods receive the
    concatenated buffer lists of every frame in the stream."""

    def __init__(self, endpoint: int):
        self.endpoint = endpoint
        self._methods: Dict[int, Tuple[str, Handler, bool]] = {}
        self._streams: Dict[int, List[List[np.ndarray]]] = {}
        self.calls_served = 0

    def register(self, name: str, handler: Handler, *,
                 streaming: bool = False) -> None:
        self._methods[framing.method_id(name)] = (name, handler, streaming)

    def dispatch(self, frame: framing.Frame) -> Optional[framing.Frame]:
        """Handle one delivered frame; return the reply frame (None for
        one-way calls and non-final stream chunks)."""
        entry = self._methods.get(frame.method)
        if entry is None:
            return frame.reply(
                [np.frombuffer(b"unimplemented", dtype=np.uint8).copy()],
                error=True)
        name, handler, streaming = entry
        is_stream = bool(frame.flags & framing.FLAG_STREAM)
        if is_stream != streaming:
            want = "streaming" if streaming else "unary"
            got = "streaming" if is_stream else "unary"
            msg = f"{name}: cardinality mismatch ({got} call to {want} " \
                  f"method)".encode()
            self._streams.pop(frame.call_id, None)
            return frame.reply(
                [np.frombuffer(msg, dtype=np.uint8).copy()], error=True)
        if is_stream:
            chunks = self._streams.setdefault(frame.call_id, [])
            chunks.append(frame.bufs or [])
            if not frame.flags & framing.FLAG_STREAM_END:
                return None
            del self._streams[frame.call_id]
            request = [b for bufs in chunks for b in bufs]
        else:
            request = frame.bufs or []
        try:
            reply = handler(request)
        except Exception as e:  # noqa: BLE001 — handler fault -> RPC error
            msg = f"{name}: {e}".encode()
            return frame.reply(
                [np.frombuffer(msg, dtype=np.uint8).copy()], error=True)
        self.calls_served += 1
        if frame.one_way:
            return None
        if reply is None:
            reply = [np.zeros(1, dtype=np.uint8)]
        return frame.reply([np.ascontiguousarray(r, dtype=np.uint8)
                            .reshape(-1) for r in reply])


class Channel:
    """A (src -> dst) flow with its own credit window."""

    def __init__(self, fabric: "RpcFabric", src: int, dst: int, *,
                 serialized: bool = False,
                 window: Optional[CreditWindow] = None):
        self.fabric = fabric
        self.src, self.dst = src, dst
        self.serialized = serialized
        self.window = window or CreditWindow()
        self.backlogged = 0      # messages queued behind the window

    def call(self, method: str, bufs: Optional[List[np.ndarray]], *,
             sizes: Optional[Sequence[int]] = None,
             one_way: bool = False) -> Call:
        frame = framing.make_frame(
            self.fabric.next_call_id(), method, bufs, sizes=sizes,
            serialized=self.serialized, one_way=one_way)
        return self.fabric.submit(self, frame, method)

    def stream(self, method: str,
               chunks: Sequence[List[np.ndarray]]) -> Call:
        """Client-streaming call: N data frames, one reply after END."""
        assert len(chunks) >= 1
        cid = self.fabric.next_call_id()
        last = len(chunks) - 1
        call: Optional[Call] = None
        for i, bufs in enumerate(chunks):
            frame = framing.make_frame(
                cid, method, bufs, serialized=self.serialized,
                stream=True, stream_end=(i == last))
            c = self.fabric.submit(self, frame, method)
            call = c if i == last else call
        assert call is not None
        return call


@dataclass
class FlightReport:
    elapsed_s: float = 0.0      # transport time (measured or modeled)
    wall_s: float = 0.0         # host wall clock of the whole flush
    flights: int = 0
    rounds: int = 0
    messages: int = 0
    replies: int = 0
    modeled: bool = False


class RpcFabric:
    def __init__(self, transport: Transport, *,
                 window_bytes: int = 4 * 1024 * 1024,
                 window_msgs: int = 32):
        self.transport = transport
        self.window_bytes = window_bytes
        self.window_msgs = window_msgs
        self.cq = CompletionQueue()
        self.servers: Dict[int, Server] = {}
        self._calls: Dict[int, Call] = {}
        self._channels: Dict[Tuple[int, int, bool], Channel] = {}
        self._pending: List[Tuple[Channel, Message]] = []
        self._backlog: List[Tuple[Channel, Message]] = []
        # request messages whose credits are granted when their reply
        # lands; a list because stream chunks share one call_id and can
        # each draw a (error) reply
        self._awaiting_grant: Dict[int, List[Message]] = {}
        self._next_id = 1

    # ------------------------------------------------------------------
    @property
    def n_endpoints(self) -> int:
        return self.transport.n_endpoints

    def next_call_id(self) -> int:
        cid = self._next_id
        self._next_id += 1
        return cid

    def channel(self, src: int, dst: int, *,
                serialized: bool = False) -> Channel:
        key = (src, dst, serialized)
        if key not in self._channels:
            self._channels[key] = Channel(
                self, src, dst, serialized=serialized,
                window=CreditWindow(self.window_bytes, self.window_msgs))
        return self._channels[key]

    def add_server(self, endpoint: int) -> Server:
        assert endpoint not in self.servers, endpoint
        srv = Server(endpoint)
        self.servers[endpoint] = srv
        return srv

    # ------------------------------------------------------------------
    def submit(self, channel: Channel, frame: framing.Frame,
               method: str) -> Call:
        call = Call(frame.call_id, method, channel.dst)
        self._calls[frame.call_id] = call
        msg = Message(channel.src, channel.dst, frame)
        # FIFO per channel: once anything is backlogged, later messages
        # queue behind it even if they would fit — a stream's END chunk
        # must never overtake a stalled middle chunk
        if channel.backlogged == 0 \
                and channel.window.try_acquire(frame.total_bytes):
            self._pending.append((channel, msg))
        else:
            if channel.backlogged == 0:
                pass        # try_acquire already counted the stall
            else:
                channel.window.stats.stalled += 1
            channel.backlogged += 1
            self._backlog.append((channel, msg))
        return call

    def _complete(self, call: Call, frame: Optional[framing.Frame],
                  kind: str, error: Optional[str] = None) -> None:
        call.done, call.result, call.error = True, frame, error
        self.cq.push(Event(call.call_id, kind, ok=error is None,
                           payload=_spec_only(frame)))
        # the caller holds the Call object; the fabric is done with it
        self._calls.pop(call.call_id, None)

    def _grant(self, msg: Message) -> None:
        ch = self._channels.get((msg.src, msg.dst, msg.frame.serialized))
        if ch is not None:
            ch.window.grant(msg.frame.total_bytes)

    def flush(self) -> FlightReport:
        """Drive the event loop until every submitted call completes."""
        rep = FlightReport(modeled=self.transport.modeled)
        t0 = time.perf_counter()
        while self._pending or self._backlog:
            if not self._pending:
                # admit backlog as credits allow; at least one must fit
                # or the window is simply too small for the message
                admitted = self._admit_backlog(force_one=True)
                assert admitted, "flow-control deadlock"
            flight = self._pending
            self._pending = []
            delivery = self.transport.deliver([m for _, m in flight])
            rep.flights += 1
            rep.rounds += delivery.rounds
            rep.messages += len(delivery.messages)
            rep.elapsed_s += delivery.elapsed_s
            replies: List[Message] = []
            for m in delivery.messages:
                call = self._calls.get(m.frame.call_id)
                if not self.transport.dispatches:
                    # exchange datapath: delivery IS completion
                    self._grant(m)
                    if call is not None and not call.done:
                        self._complete(call, m.frame, "sent")
                    continue
                srv = self.servers.get(m.dst)
                if srv is None:
                    self._grant(m)
                    if call is not None and not call.done:
                        self._complete(call, None, "error",
                                       error=f"no server at endpoint "
                                             f"{m.dst}")
                    continue
                reply = srv.dispatch(m.frame)
                self.cq.push(Event(m.frame.call_id, "received",
                                   payload=_spec_only(m.frame)))
                if reply is None:
                    self._grant(m)
                    if call is not None and m.frame.one_way \
                            and not call.done:
                        self._complete(call, None, "sent")
                    continue
                self._awaiting_grant.setdefault(m.frame.call_id,
                                                []).append(m)
                replies.append(Message(m.dst, m.src, reply))
            if replies:
                rdel = self.transport.deliver(replies)
                rep.flights += 1
                rep.rounds += rdel.rounds
                rep.replies += len(rdel.messages)
                rep.elapsed_s += rdel.elapsed_s
                for m in rdel.messages:
                    # grant the REQUEST's credits (reply size differs)
                    reqs = self._awaiting_grant.get(m.frame.call_id)
                    if reqs:
                        self._grant(reqs.pop(0))
                        if not reqs:
                            del self._awaiting_grant[m.frame.call_id]
                    call = self._calls.get(m.frame.call_id)
                    if call is None or call.done:
                        continue
                    if m.frame.flags & framing.FLAG_ERROR:
                        err = bytes(m.frame.bufs[0]).decode(
                            errors="replace") if m.frame.bufs else "error"
                        self._complete(call, m.frame, "error", error=err)
                    else:
                        self._complete(call, m.frame, "replied")
            self._admit_backlog()
        rep.wall_s = time.perf_counter() - t0
        return rep

    def _admit_backlog(self, force_one: bool = False) -> int:
        admitted, rest = 0, []
        blocked: set = set()
        for ch_, msg in self._backlog:
            # head-of-line per channel: once one of a channel's messages
            # stays blocked, its later ones stay queued too (ordering)
            if id(ch_) in blocked:
                rest.append((ch_, msg))
                continue
            # can_acquire first: a retry is not a new stall, so the
            # stall count stays one-per-call (recorded at submit time)
            if ch_.window.can_acquire(msg.frame.total_bytes):
                ch_.window.try_acquire(msg.frame.total_bytes)
                self._pending.append((ch_, msg))
                ch_.backlogged -= 1
                admitted += 1
            elif force_one and admitted == 0:
                self._pending.append((ch_, msg))
                ch_.backlogged -= 1
                admitted += 1
            else:
                blocked.add(id(ch_))
                rest.append((ch_, msg))
        self._backlog = rest
        return admitted


# ---------------------------------------------------------------------------
# benchmark driver: the fully-connected exchange (paper §2's
# every-worker-to-every-worker process architecture)
# ---------------------------------------------------------------------------

def fully_connected_exchange(fabric: RpcFabric, sizes: Sequence[int], *,
                             bufs: Optional[List[np.ndarray]] = None,
                             serialized: bool = False) -> FlightReport:
    """Every endpoint sends one payload to every other endpoint
    (n * (n-1) one-way RPCs), generated in the shift order of
    ``channels.all_to_all_schedule`` so the transport's edge coloring
    recovers exactly n-1 rounds."""
    n = fabric.n_endpoints
    assert n >= 2, n
    if fabric.transport.dispatches:
        for e in range(n):
            if e not in fabric.servers:
                fabric.add_server(e).register("exchange", lambda req: None)
    for r in range(1, n):
        for i in range(n):
            fabric.channel(i, (i + r) % n, serialized=serialized).call(
                "exchange", bufs,
                sizes=sizes if bufs is None else None, one_way=True)
    return fabric.flush()
