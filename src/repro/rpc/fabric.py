"""The RPC fabric: Channel/Server API over a pluggable Transport.

Client side                          Server side
-----------                          -----------
fabric.stub(service, src, dst)       fabric.add_server(endpoint)
  .method(request)      ->flight->     server.add_service(service, handlers)

Services are declared once (:mod:`repro.rpc.service`): a ``ServiceDef``
of ``MethodSpec``\\ s with four cardinalities — unary (1 request ->
1 reply), client-streaming (N chunks -> 1 reply), server-streaming
(1 request -> N chunks), bidi (N <-> M chunks). ``add_service`` binds
every method of a service at once; the generated ``Stub``'s methods
return call handles uniformly: :class:`service.UnaryCall` for the
reply-bearing kinds, :class:`ServerStream` / :class:`BidiStream` for
the response-streaming kinds. The per-kind ``Server.register*`` /
``Channel.call``/``stream``/... entry points below remain as the
mechanism under the stubs (and as deprecated direct API for one
release).

Calls are buffered and moved in *flights* by ``flush()`` — the event
loop. One flush: admit frames the per-direction credit windows allow,
deliver them through the transport (edge-colored into rounds), dispatch
delivered frames to endpoint servers, send plain replies back (a second
flight), queue server->client stream chunks behind the *reverse* window
(``Channel.rwindow`` via its :class:`flow.ChunkGate`), grant credits,
resolve futures, and push an :class:`completion.Event` per completion.
``flush`` loops until the backlog and every chunk gate drain, so a
burst larger than a flow-control window simply takes several flights —
the stall counts in ``Channel.window.stats`` / ``rwindow.stats`` record
the back-pressure per direction.

Interceptors (:mod:`repro.rpc.interceptors`) thread through this loop:
every call gets a :class:`interceptors.CallContext`; the client chain
sees submit (``on_start``), every completion-queue event
(``on_event``), and the terminal event (``on_complete``, which may
answer ``"retry"`` to resubmit a failed unary call — or a server-stream
call that has delivered zero chunks); the server chain brackets handler
dispatch (``on_admit``/``on_receive``/``on_done``/``on_shed``). Calls
carry an optional **deadline** (relative seconds at submit, absolute on
the context): the flush loop cancels expired calls — failing the
future/handle with a ``deadline_exceeded`` event and dropping their
window-stalled chunks — and when everything is stalled on credits it
advances the clock to the earliest stalled deadline (the transport's
modeled clock, or a real sleep) instead of force-admitting, so
back-pressure with a deadline resolves by cancellation, exactly gRPC's
contract. The deadline also **propagates**: the remaining budget is
stamped into each request frame's header word at flight departure
(gRPC's ``grpc-timeout``), and the receiving server sheds
already-expired work before invoking any handler. Messages a
``FaultInjectionTransport`` loses to a link fault come back flagged
``FLAG_FAULT``: their credits are refunded and the call fails with a
retryable transient error.

A :class:`tracing.Tracer` attached at construction
(``RpcFabric(..., tracer=t)``) records a span tree per call on the
fabric clock — queue/credit_stall/wire/server/reply/backoff phases on
the client track, admit/shed/handler spans on the server tracks — with
the trace id stamped into the frame header at flight departure
alongside the budget, so spans stay attributed across cluster
endpoints, retries, and failover re-routes.

Transports with ``dispatches=False`` (the collective transport) are pure
exchange datapaths: delivery itself completes the call and the reply
flight is skipped (the 64B ack is priced inside the transport).
"""
from __future__ import annotations

import time
from collections.abc import Mapping
from dataclasses import dataclass, replace
from typing import (Any, Callable, Dict, List, Optional, Sequence, Set,
                    Tuple)

import numpy as np

from repro.rpc import bufpool, framing
from repro.rpc.completion import CompletionQueue, Event
from repro.rpc.flow import ChunkGate, CreditWindow, WindowConfig
from repro.rpc.interceptors import (RESOURCE_EXHAUSTED, TRANSIENT_PREFIX,
                                    CallContext, ClientInterceptor,
                                    ResourceExhausted, ServerContext,
                                    ServerInterceptor, TransientError)
from repro.rpc.tracing import Tracer
from repro.rpc.transport import Message, Transport


class RpcError(Exception):
    pass


DEADLINE_EXCEEDED = "deadline exceeded"

#: the client-visible text of an injected link fault (transport-level
#: fault injection surfaces as a retryable transient error)
LINK_FAULT = f"{TRANSIENT_PREFIX} link fault injected by transport"

#: The server fault boundary: anything a handler raises becomes an RPC
#: error reply instead of crashing the flush loop. This is the ONE
#: deliberate broad catch in the fabric — the CI deprecation gate (and
#: tests/test_service_api.py) reject inline blanket Exception handlers
#: inside src/repro/rpc/, so every broad catch must go through this
#: named, documented boundary.
HANDLER_FAULTS = (Exception,)


def _spec_only(frame: Optional[framing.Frame]) -> Optional[framing.Frame]:
    """Events carry frame *metadata* only — retaining payload buffers in
    an undrained completion queue would pin gigabytes in benchmark
    loops. Callers get the data from their Call future."""
    if frame is None or frame.bufs is None:
        return frame
    return replace(frame, bufs=None)


@dataclass
class Call:
    """Client-side future for one RPC."""
    call_id: int
    method: str
    dst: int
    done: bool = False
    result: Optional[framing.Frame] = None
    error: Optional[str] = None

    def reply_bufs(self) -> List[np.ndarray]:
        assert self.done, "call not complete — fabric.flush() first"
        if self.error is not None:
            raise RpcError(self.error)
        assert self.result is not None and self.result.bufs is not None
        return self.result.bufs


Handler = Callable[[List[np.ndarray]], Optional[List[np.ndarray]]]

# method cardinalities
UNARY = "unary"                    # 1 request frame  -> 1 reply frame
CLIENT_STREAM = "client_stream"    # N chunks -> 1 reply after END
SERVER_STREAM = "server_stream"    # 1 request -> N reply chunks
BIDI = "bidi"                      # N chunks <-> M reply chunks

# a stream-chunk payload a handler may return: real buffers, or a bare
# tuple of sizes for a spec-only chunk (modeled transports)
ChunkPayload = object


def _error_reply(frame: framing.Frame, msg: str) -> framing.Frame:
    return frame.reply([np.frombuffer(msg.encode(), dtype=np.uint8)
                        .copy()], error=True)


class StreamPump:
    """Opt-in incremental server streaming: a SERVER_STREAM handler that
    returns ``StreamPump(chunks)`` (instead of a list/generator that the
    server materializes at dispatch) has its chunks pulled **one per
    flush-loop iteration** — so several pumped calls on one endpoint
    interleave chunk-by-chunk instead of each monopolizing the wire
    until done. The serving engine's continuous-batching scheduler
    rides this: every flush iteration is one shared decode step across
    all in-flight generation requests.

    ``frame`` and ``server`` are bound by the server at dispatch, so
    the producer (via a closure over the pump) can attribute
    server-track tracer spans to the originating call."""

    def __init__(self, chunks):
        self.chunks = iter(chunks)
        self.frame: Optional[framing.Frame] = None
        self.server: Optional["Server"] = None
        self.name = ""                 # wire method name, set at dispatch
        self.seq = 0                   # next server->client chunk seq
        # (src, dst, serialized) of the owning channel — bound by the
        # flush loop at dispatch so pumped chunks ride the right gate
        self.channel_key: Optional[Tuple[int, int, str]] = None

    def close(self) -> None:
        close = getattr(self.chunks, "close", None)
        if close is not None:
            close()


def _chunk_frames(frame: framing.Frame, chunks: Sequence[ChunkPayload],
                  *, seq0: int = 0, close: bool = False
                  ) -> List[framing.Frame]:
    """Server->client chunk frames for handler output. An empty output
    with ``close`` becomes one bare END trailer so the client still sees
    the stream finish."""
    out: List[framing.Frame] = []
    for i, c in enumerate(chunks):
        end = close and i == len(chunks) - 1
        if isinstance(c, tuple):     # spec-only: sizes, no bytes
            out.append(frame.reply_chunk(None, seq=seq0 + i, end=end,
                                         sizes=c))
        else:
            out.append(frame.reply_chunk(list(c), seq=seq0 + i, end=end))
    if close and not out:
        out.append(frame.reply_chunk(None, seq=seq0, end=True))
    return out


class Server:
    """Per-endpoint method table. The primary registration surface is
    :meth:`add_service` — bind a whole ``ServiceDef`` at once under its
    ``Service/method`` wire names. The per-kind ``register*`` methods
    remain as the mechanism underneath (and as deprecated direct API
    for one release); duplicate method or service registration raises
    ``ValueError`` instead of silently last-write-winning.

    Handler shapes per kind: client-streaming methods receive the
    concatenated buffer lists of every frame in the stream;
    server-streaming handlers return an iterable of chunk buffer lists;
    bidi handlers are called once per incoming chunk (with an ``end``
    flag) and return 0..M reply chunks each."""

    def __init__(self, endpoint: int, *,
                 interceptors=None,
                 clock: Callable[[], float] = time.perf_counter,
                 tracer=None):
        self.endpoint = endpoint
        # a list, or a zero-arg callable returning one (the fabric
        # passes a getter so reassigning fabric.server_interceptors
        # after add_server still takes effect)
        self._interceptors = interceptors
        self._clock = clock
        # a Tracer, or a zero-arg getter (the fabric passes one so
        # attaching a tracer later reaches existing servers); server
        # spans — admit/shed/handler — land on this endpoint's track
        self._tracer_src = tracer
        self._methods: Dict[int, Tuple[str, Callable, str]] = {}
        self._services: Set[str] = set()
        self._streams: Dict[int, List[List[np.ndarray]]] = {}
        self._bidi_seq: Dict[int, int] = {}
        # open incremental server streams (handlers that returned a
        # StreamPump); the flush loop pulls one chunk per pump per
        # iteration
        self._pumps: Dict[int, StreamPump] = {}
        # streams shed/rejected at their opening chunk: later chunks of
        # the same call are dropped instead of re-creating state (they
        # may ride the same flight as the rejected opener)
        self._dead_streams: Set[int] = set()
        self.calls_served = 0
        #: calls dropped before their handler ran because the deadline
        #: budget propagated in the frame header was already spent
        self.calls_shed = 0

    @property
    def interceptors(self) -> List[ServerInterceptor]:
        it = self._interceptors
        if callable(it):
            return it()
        return it if it is not None else []

    @property
    def tracer(self) -> Optional[Tracer]:
        t = self._tracer_src
        return t() if callable(t) else t

    @property
    def clock(self) -> Callable[[], float]:
        """The clock this endpoint timestamps on (the fabric clock when
        fabric-created) — services that record their own spans read it."""
        return self._clock

    def add_service(self, service, handlers) -> "Server":
        """Bind every method of ``service`` (a ``ServiceDef``) at once.
        ``handlers`` is an object with an attribute per method name, or
        a mapping ``{method_name: callable}``. Validates the full
        binding before registering anything, so a bad service is
        atomic; re-adding a service name raises ``ValueError``."""
        if service.name in self._services:
            raise ValueError(f"endpoint {self.endpoint}: service "
                             f"{service.name!r} already added")
        resolved = []
        for spec in service.methods:
            h = (handlers.get(spec.name) if isinstance(handlers, Mapping)
                 else getattr(handlers, spec.name, None))
            if h is None:
                raise ValueError(
                    f"handlers for service {service.name!r} missing "
                    f"method {spec.name!r}")
            full = service.full_name(spec.name)
            if framing.method_id(full) in self._methods:
                raise ValueError(f"endpoint {self.endpoint}: method "
                                 f"{full!r} already registered")
            resolved.append((spec, h))
        for spec, h in resolved:
            self.register(service.full_name(spec.name), h,
                          kind=spec.kind)
        self._services.add(service.name)
        return self

    def register(self, name: str, handler: Callable, *,
                 streaming: bool = False, kind: Optional[str] = None
                 ) -> None:
        kind = kind or (CLIENT_STREAM if streaming else UNARY)
        assert kind in (UNARY, CLIENT_STREAM, SERVER_STREAM, BIDI), kind
        mid = framing.method_id(name)
        if mid in self._methods:
            raise ValueError(f"endpoint {self.endpoint}: method "
                             f"{self._methods[mid][0]!r} already "
                             f"registered")
        self._methods[mid] = (name, handler, kind)

    def register_server_stream(self, name: str, handler: Callable) -> None:
        """Deprecated — use :meth:`add_service` with a SERVER_STREAM
        ``MethodSpec``. handler(request_bufs) -> iterable of chunks."""
        self.register(name, handler, kind=SERVER_STREAM)

    def register_bidi(self, name: str, handler: Callable) -> None:
        """Deprecated — use :meth:`add_service` with a BIDI
        ``MethodSpec``. handler(chunk_bufs, end: bool) -> iterable of
        reply chunks (or None). Called once per incoming chunk; the
        reply chunks produced for the END chunk close the server's
        direction."""
        self.register(name, handler, kind=BIDI)

    def abort_call(self, call_id: int) -> None:
        """Drop per-call stream state (a cancelled stream's END frame
        will never arrive to clean it up)."""
        self._streams.pop(call_id, None)
        self._bidi_seq.pop(call_id, None)
        self._dead_streams.discard(call_id)
        pump = self._pumps.pop(call_id, None)
        if pump is not None:
            pump.close()        # producer's finally-cleanup runs now

    def pump_one(self, call_id: int) -> List[framing.Frame]:
        """Pull the next chunk of one pumped stream: one chunk frame,
        a bare END trailer when the producer is exhausted, or an error
        reply when it raised (through the HANDLER_FAULTS boundary, like
        a dispatch-time handler fault)."""
        pump = self._pumps[call_id]
        frame = pump.frame
        try:
            chunk = next(pump.chunks)
        except StopIteration:
            del self._pumps[call_id]
            return _chunk_frames(frame, [], seq0=pump.seq, close=True)
        except HANDLER_FAULTS as e:   # producer fault -> RPC error
            return self._fault(frame, pump.name, e)
        out = _chunk_frames(frame, [chunk], seq0=pump.seq)
        pump.seq += len(out)
        return out

    def _sctx(self, frame: framing.Frame, name: str, kind: str,
              deadline_s: Optional[float], queue_depth: int
              ) -> ServerContext:
        return ServerContext(self.endpoint, frame.call_id, name, kind,
                             self._clock(), deadline_s=deadline_s,
                             queue_depth=queue_depth, clock=self._clock)

    def _invoke(self, frame: framing.Frame, name: str, kind: str,
                handler: Callable, args: tuple, *,
                deadline_s: Optional[float] = None,
                queue_depth: int = 0):
        """Run one handler invocation through the server interceptor
        chain: on_receive outer->inner, on_done inner->outer (with the
        fault when the handler raised). An attached tracer gets one
        ``handler`` span per invocation on this endpoint's track."""
        chain = self.interceptors
        tracer = self.tracer
        if not chain and tracer is None:
            return handler(*args)
        sctx = (self._sctx(frame, name, kind, deadline_s, queue_depth)
                if chain else None)
        for si in chain:
            si.on_receive(sctx)
        t0 = self._clock() if tracer is not None else 0.0
        try:
            out = handler(*args)
        except HANDLER_FAULTS as e:
            if tracer is not None:
                tracer.server_span(frame, self.endpoint,
                                   f"handler {name}", t0, self._clock(),
                                   ok=False, error=str(e))
            for si in reversed(chain):
                si.on_done(sctx, False, str(e))
            raise
        if tracer is not None:
            tracer.server_span(frame, self.endpoint, f"handler {name}",
                               t0, self._clock(), ok=True)
        for si in reversed(chain):
            si.on_done(sctx, True)
        return out

    def _fault(self, frame: framing.Frame, name: str, e: Exception
               ) -> List[framing.Frame]:
        self.abort_call(frame.call_id)
        msg = f"{name}: {e}"
        if isinstance(e, ResourceExhausted) and RESOURCE_EXHAUSTED not in msg:
            msg = f"{RESOURCE_EXHAUSTED}: {msg}"
        if isinstance(e, TransientError):
            msg = f"{TRANSIENT_PREFIX} {msg}"
        return [_error_reply(frame, msg)]

    def _shed(self, frame: framing.Frame, name: str, kind: str,
              deadline_s: float, queue_depth: int
              ) -> List[framing.Frame]:
        """Deadline propagation, server half: the budget the frame
        carried in its header is already spent — drop the work before
        the handler runs (gRPC servers cancel already-expired calls on
        arrival) and tell the client it was a deadline outcome."""
        self.calls_shed += 1
        self.abort_call(frame.call_id)
        if frame.is_stream and not frame.stream_end:
            self._dead_streams.add(frame.call_id)
        tracer = self.tracer
        if tracer is not None:
            t = self._clock()
            tracer.server_span(frame, self.endpoint, "shed", t, t,
                               reason=DEADLINE_EXCEEDED)
        chain = self.interceptors
        if chain:
            sctx = self._sctx(frame, name, kind, deadline_s, queue_depth)
            for si in chain:
                si.on_shed(sctx)
        if frame.one_way:
            return []
        return [_error_reply(
            frame, f"{name}: {DEADLINE_EXCEEDED} (shed at endpoint "
                   f"{self.endpoint})")]

    def _admit(self, frame: framing.Frame, name: str, kind: str,
               deadline_s: Optional[float], queue_depth: int
               ) -> Optional[List[framing.Frame]]:
        """Run the chain's admission hooks for a call-opening frame;
        the first rejection becomes a transient ``resource exhausted``
        error reply (None = admitted)."""
        chain = self.interceptors
        if not chain:
            return None
        sctx = self._sctx(frame, name, kind, deadline_s, queue_depth)
        for si in chain:
            reason = si.on_admit(sctx)
            if reason:
                self.abort_call(frame.call_id)
                if frame.is_stream and not frame.stream_end:
                    self._dead_streams.add(frame.call_id)
                tracer = self.tracer
                if tracer is not None:
                    t = self._clock()
                    tracer.server_span(frame, self.endpoint,
                                       "admission_reject", t, t,
                                       reason=reason,
                                       queue_depth=queue_depth)
                if frame.one_way:
                    return []
                return [_error_reply(
                    frame, f"{TRANSIENT_PREFIX} {name}: {reason}")]
        return None

    def dispatch(self, frame: framing.Frame, *,
                 deadline_s: Optional[float] = None,
                 queue_depth: int = 0) -> List[framing.Frame]:
        """Handle one delivered frame; return the outgoing frames: plain
        replies (no FLAG_STREAM) and/or server->client stream chunks.
        Empty for one-way calls and non-final client-stream chunks.
        ``deadline_s`` is the absolute fabric-clock deadline recovered
        from the frame's propagated budget; already-expired frames are
        shed before the handler. ``queue_depth`` is the fabric's load
        signal for this endpoint (admission control's input)."""
        entry = self._methods.get(frame.method)
        if entry is None:
            return [_error_reply(frame, "unimplemented")]
        name, handler, kind = entry
        if frame.is_stream and frame.call_id in self._dead_streams:
            # later chunk of a stream shed/rejected at its opener:
            # consume it silently (the client already has the error)
            if frame.stream_end:
                self._dead_streams.discard(frame.call_id)
            return []
        if deadline_s is not None and self._clock() >= deadline_s:
            return self._shed(frame, name, kind, deadline_s, queue_depth)
        if not frame.is_stream or frame.seq == 0:
            rejected = self._admit(frame, name, kind, deadline_s,
                                   queue_depth)
            if rejected is not None:
                return rejected
            tracer = self.tracer
            if tracer is not None:
                # the admission decision itself, on the server track
                t = self._clock()
                tracer.server_span(frame, self.endpoint, "admit", t, t,
                                   queue_depth=queue_depth)
        is_stream = frame.is_stream
        if is_stream != (kind in (CLIENT_STREAM, BIDI)):
            got = "streaming" if is_stream else "unary"
            self._streams.pop(frame.call_id, None)
            return [_error_reply(
                frame, f"{name}: cardinality mismatch ({got} call to "
                       f"{kind} method)")]

        if kind == BIDI:
            end = frame.stream_end
            try:
                outs = self._invoke(frame, name, kind, handler,
                                    (frame.bufs or [], end),
                                    deadline_s=deadline_s,
                                    queue_depth=queue_depth) or []
            except HANDLER_FAULTS as e:   # handler fault -> RPC error
                return self._fault(frame, name, e)
            seq0 = self._bidi_seq.get(frame.call_id, 0)
            frames = _chunk_frames(frame, list(outs), seq0=seq0,
                                   close=end)
            self._bidi_seq[frame.call_id] = seq0 + len(frames)
            if end:
                del self._bidi_seq[frame.call_id]
                self.calls_served += 1
            return frames

        if kind == CLIENT_STREAM:
            chunks = self._streams.setdefault(frame.call_id, [])
            chunks.append(frame.bufs or [])
            if not frame.stream_end:
                return []
            del self._streams[frame.call_id]
            request = [b for bufs in chunks for b in bufs]
        else:
            request = frame.bufs or []

        if kind == SERVER_STREAM:
            # materialize inside the fault boundary: handlers may
            # return lazy generators whose errors surface mid-iteration
            # — unless the handler opted into incremental delivery by
            # returning a StreamPump (pulled by the flush loop instead)
            handler = (lambda req, _h=handler:
                       (lambda out: out if isinstance(out, StreamPump)
                        else list(out or []))(_h(req)))
        try:
            reply = self._invoke(frame, name, kind, handler, (request,),
                                 deadline_s=deadline_s,
                                 queue_depth=queue_depth)
        except HANDLER_FAULTS as e:       # handler fault -> RPC error
            return self._fault(frame, name, e)
        self.calls_served += 1

        if kind == SERVER_STREAM:
            if isinstance(reply, StreamPump):
                reply.frame, reply.server, reply.name = frame, self, name
                self._pumps[frame.call_id] = reply
                return []
            return _chunk_frames(frame, reply, close=True)
        if frame.one_way:
            return []
        if reply is None:
            reply = [np.zeros(1, dtype=np.uint8)]
        return [frame.reply([np.ascontiguousarray(r, dtype=np.uint8)
                             .reshape(-1) for r in reply])]


class StreamHandle:
    """Client-side handle for a call whose response is a chunk stream
    (server-streaming or bidi). Driven by the completion queue: every
    delivered chunk pushes a ``stream_chunk`` event and lands in
    ``chunks``; END pushes ``stream_end`` and sets ``done``."""

    def __init__(self, channel: "Channel", call_id: int, method: str):
        self.channel = channel
        self.call_id = call_id
        self.method = method
        self.chunks: List[List[np.ndarray]] = []
        self.done = False
        self.error: Optional[str] = None

    @property
    def dst(self) -> int:
        return self.channel.dst

    def chunk_bufs(self) -> List[List[np.ndarray]]:
        assert self.done, "stream not complete — fabric.flush() first"
        if self.error is not None:
            raise RpcError(self.error)
        return self.chunks

    def result(self) -> List[List[np.ndarray]]:
        """Flush the fabric if needed, then return the chunks (uniform
        with ``service.UnaryCall.result``)."""
        if not self.done:
            self.channel.fabric.flush()
        return self.chunk_bufs()


class ServerStream(StreamHandle):
    """One request out, N chunks back."""


class BidiStream(StreamHandle):
    """Chunks both ways. ``send`` queues an outgoing chunk behind the
    channel's forward window; ``close`` (or ``send(..., end=True)``)
    ends the client's direction. The server's chunks accumulate in
    ``chunks`` and its END completes the handle."""

    def __init__(self, channel: "Channel", call_id: int, method: str):
        super().__init__(channel, call_id, method)
        self._seq = 0
        self.closed = False

    def send(self, bufs: Optional[List[np.ndarray]], *,
             sizes: Optional[Sequence[int]] = None,
             end: bool = False) -> None:
        assert not self.closed, "bidi stream already closed"
        frame = framing.stream_chunk(
            self.call_id, self.method, bufs, seq=self._seq, end=end,
            wire_mode=self.channel.wire_mode, sizes=sizes)
        self._seq += 1
        self.closed = end
        fabric = self.channel.fabric
        ctx = fabric.context(self.call_id)
        if ctx is not None:
            fabric._buffer_request_chunk(ctx, frame)
        fabric.submit_raw(self.channel, frame)

    def close(self) -> None:
        """End the client direction with a bare END trailer."""
        self.send(None, end=True)


class Channel:
    """A (src -> dst) flow with one credit window per direction:
    ``window`` gates client->server frames, ``rwindow`` (behind
    ``rx_gate``) gates server->client stream chunks. ``deadline_s`` on
    any call kind is relative seconds on the fabric clock; the flush
    loop enforces it (see :class:`RpcFabric`)."""

    def __init__(self, fabric: "RpcFabric", src: int, dst: int, *,
                 serialized: bool = False,
                 wire_mode: Optional[str] = None,
                 window: Optional[CreditWindow] = None,
                 rwindow: Optional[CreditWindow] = None):
        self.fabric = fabric
        self.src, self.dst = src, dst
        # explicit wire_mode wins over the legacy serialized bool; the
        # bool is kept as a derived attribute for existing readers
        self.wire_mode = framing.resolve_wire_mode(serialized, wire_mode)
        self.serialized = self.wire_mode == "serialized"
        self.window = window or CreditWindow()
        self.rwindow = rwindow or CreditWindow()
        self.rx_gate = ChunkGate(self.rwindow)
        self.backlogged = 0      # messages queued behind the window

    def call(self, method: str, bufs: Optional[List[np.ndarray]], *,
             sizes: Optional[Sequence[int]] = None,
             one_way: bool = False,
             deadline_s: Optional[float] = None) -> Call:
        frame = framing.make_frame(
            self.fabric.next_call_id(), method, bufs, sizes=sizes,
            wire_mode=self.wire_mode, one_way=one_way)
        return self.fabric.submit(self, frame, method, kind=UNARY,
                                  deadline_s=deadline_s, retryable=True)

    def stream(self, method: str,
               chunks: Sequence[List[np.ndarray]], *,
               one_way: bool = False,
               sizes: Optional[Sequence[int]] = None,
               n_chunks: Optional[int] = None,
               deadline_s: Optional[float] = None) -> Call:
        """Client-streaming call: N data frames, one reply after END
        (none when one-way). ``sizes`` sends spec-only chunks of that
        size list instead of real buffers — ``n_chunks`` of them."""
        assert (chunks is not None and len(chunks) >= 1) \
            or sizes is not None
        cid = self.fabric.next_call_id()
        n = len(chunks) if chunks else max(1, n_chunks or 1)
        call: Optional[Call] = None
        for i in range(n):
            bufs = chunks[i] if chunks else None
            frame = framing.stream_chunk(
                cid, method, bufs, seq=i, end=(i == n - 1),
                wire_mode=self.wire_mode, one_way=one_way,
                sizes=sizes if bufs is None else None)
            c = self.fabric.submit(self, frame, method,
                                   kind=CLIENT_STREAM,
                                   deadline_s=deadline_s)
            call = c if i == n - 1 else call
        assert call is not None
        return call

    def server_stream(self, method: str,
                      bufs: Optional[List[np.ndarray]], *,
                      sizes: Optional[Sequence[int]] = None,
                      deadline_s: Optional[float] = None
                      ) -> ServerStream:
        """Server-streaming call: one request frame, chunked response.
        The request frame is retained on the call context, so a
        RetryInterceptor can transparently re-issue it while zero
        response chunks have been delivered."""
        cid = self.fabric.next_call_id()
        frame = framing.make_frame(cid, method, bufs, sizes=sizes,
                                   wire_mode=self.wire_mode)
        handle = ServerStream(self, cid, method)
        self.fabric.register_handle(handle, kind=SERVER_STREAM,
                                    deadline_s=deadline_s,
                                    request=frame)
        self.fabric.submit_raw(self, frame)
        return handle

    def bidi_stream(self, method: str,
                    chunks: Optional[Sequence[List[np.ndarray]]] = None,
                    *, deadline_s: Optional[float] = None) -> BidiStream:
        """Bidirectional stream. With ``chunks`` everything is sent and
        the client direction closed; without, use ``send``/``close``."""
        handle = BidiStream(self, self.fabric.next_call_id(), method)
        self.fabric.register_handle(handle, kind=BIDI,
                                    deadline_s=deadline_s)
        if chunks is not None:
            assert len(chunks) >= 1
            for i, bufs in enumerate(chunks):
                handle.send(bufs, end=(i == len(chunks) - 1))
        return handle


@dataclass
class FlightReport:
    elapsed_s: float = 0.0      # transport time (measured or modeled)
    wall_s: float = 0.0         # host wall clock of the whole flush
    flights: int = 0
    rounds: int = 0
    messages: int = 0
    replies: int = 0
    modeled: bool = False


class RpcFabric:
    def __init__(self, transport: Transport, *,
                 window_bytes: int = 4 * 1024 * 1024,
                 window_msgs: int = 32,
                 retry_buffer_chunks: int = 16,
                 client_interceptors: Optional[
                     List[ClientInterceptor]] = None,
                 server_interceptors: Optional[
                     List[ServerInterceptor]] = None,
                 tracer: Optional[Tracer] = None):
        self.transport = transport
        self.window_bytes = window_bytes
        self.window_msgs = window_msgs
        #: how many sent chunks of a client-stream/bidi call the client
        #: retains for transparent retry (gRPC's bounded retry buffer);
        #: past the bound the call stops being retryable (sticky), 0
        #: disables stream-retry buffering entirely
        self.retry_buffer_chunks = retry_buffer_chunks
        #: optional distributed tracing (repro.rpc.tracing): every call
        #: gets a span tree — phases on the client track, admit/shed/
        #: handler spans on the server tracks — with its trace id
        #: propagated in the frame header across endpoints
        self.tracer = tracer
        if tracer is not None:
            tracer.bind(self)
        self.cq = CompletionQueue()
        self.client_interceptors: List[ClientInterceptor] = \
            list(client_interceptors or [])
        self.server_interceptors: List[ServerInterceptor] = \
            list(server_interceptors or [])
        self.servers: Dict[int, Server] = {}
        self._calls: Dict[int, Call] = {}
        self._handles: Dict[int, StreamHandle] = {}
        self._ctx: Dict[int, CallContext] = {}
        self._channels: Dict[Tuple[int, int, bool], Channel] = {}
        self._stubs: Dict[Tuple[str, int, int, bool], Any] = {}
        self._pending: List[Tuple[Channel, Message]] = []
        self._backlog: List[Tuple[Channel, Message]] = []
        # request messages whose credits are granted when their reply
        # lands; a list because stream chunks share one call_id and can
        # each draw a (error) reply
        self._awaiting_grant: Dict[int, List[Message]] = {}
        # (sizes, fetch_ratio) the incast server was bound with — the
        # fetch payload lives in its handler closure, so a later
        # incast_exchange with a different shape must be rejected
        self._incast_setup: Optional[Tuple] = None
        self._next_id = 1

    # ------------------------------------------------------------------
    @property
    def n_endpoints(self) -> int:
        return self.transport.n_endpoints

    def now(self) -> float:
        """The fabric clock: the transport's modeled clock when one
        exists, host wall time otherwise. Deadlines and interceptor
        latencies are measured on this clock, so simulated runs get
        deterministic modeled latencies."""
        if self.transport.modeled and hasattr(self.transport, "clock_s"):
            return float(self.transport.clock_s)
        return time.perf_counter()

    def next_call_id(self) -> int:
        cid = self._next_id
        self._next_id += 1
        return cid

    def resolve_endpoint(self, endpoint) -> int:
        """Endpoint address -> index. Integers pass through; names
        resolve through the transport (cluster transports name their
        endpoints — ``fabric.channel("worker0", "ps1")``)."""
        if isinstance(endpoint, str):
            resolve = getattr(self.transport, "resolve", None)
            if resolve is None:
                raise ValueError(
                    f"endpoint {endpoint!r}: named endpoint addressing "
                    f"needs a transport with named endpoints (cluster)")
            return resolve(endpoint)
        return int(endpoint)

    def channel(self, src, dst, *, serialized: bool = False,
                wire_mode: Optional[str] = None) -> Channel:
        src, dst = self.resolve_endpoint(src), self.resolve_endpoint(dst)
        mode = framing.resolve_wire_mode(serialized, wire_mode)
        key = (src, dst, mode)
        if key not in self._channels:
            # window sizing: fabric default unless the transport's
            # endpoints advertise their own (gRPC's receiver-set
            # windows — cluster endpoints size the channels that
            # touch them)
            fwd = rev = WindowConfig(self.window_bytes, self.window_msgs)
            hook = getattr(self.transport, "channel_windows", None)
            if hook is not None:
                f, r = hook(src, dst)
                fwd, rev = f or fwd, r or rev
            self._channels[key] = Channel(
                self, src, dst, wire_mode=mode,
                window=fwd.make(), rwindow=rev.make())
        return self._channels[key]

    def stub(self, service, src, dst, *, serialized: bool = False,
             wire_mode: Optional[str] = None):
        """The generated client for ``service`` over the (src -> dst)
        channel; cached per (service, channel). Keyed by service
        *identity* — the cached Stub keeps its ServiceDef alive, so two
        live definitions sharing a name never alias."""
        from repro.rpc.service import Stub
        src, dst = self.resolve_endpoint(src), self.resolve_endpoint(dst)
        mode = framing.resolve_wire_mode(serialized, wire_mode)
        key = (id(service), src, dst, mode)
        st = self._stubs.get(key)
        if st is None:
            st = Stub(self.channel(src, dst, wire_mode=mode),
                      service)
            self._stubs[key] = st
        return st

    def add_server(self, endpoint) -> Server:
        endpoint = self.resolve_endpoint(endpoint)
        assert endpoint not in self.servers, endpoint
        # a getter, not the list: reassigning fabric.server_interceptors
        # later still reaches existing servers
        srv = Server(endpoint,
                     interceptors=lambda: self.server_interceptors,
                     clock=self.now,
                     tracer=lambda: self.tracer)
        self.servers[endpoint] = srv
        return srv

    # ------------------------------------------------------------------
    def submit(self, channel: Channel, frame: framing.Frame,
               method: str, *, kind: str = UNARY,
               deadline_s: Optional[float] = None,
               retryable: bool = False) -> Call:
        call = Call(frame.call_id, method, channel.dst)
        self._calls[frame.call_id] = call
        ctx = self._start_ctx(frame.call_id, method, kind, channel,
                              deadline_s=deadline_s,
                              request=frame if retryable else None)
        if kind == CLIENT_STREAM:
            self._buffer_request_chunk(ctx, frame)
        self.submit_raw(channel, frame)
        return call

    def submit_raw(self, channel: Channel, frame: framing.Frame) -> None:
        """Queue a client->server frame behind the forward window
        without creating a Call future (stream chunks are tracked
        through their StreamHandle instead)."""
        msg = Message(channel.src, channel.dst, frame)
        # FIFO per channel: once anything is backlogged, later messages
        # queue behind it even if they would fit — a stream's END chunk
        # must never overtake a stalled middle chunk
        if channel.backlogged == 0 \
                and channel.window.try_acquire(frame.total_bytes):
            self._pending.append((channel, msg))
        else:
            if channel.backlogged == 0:
                pass        # try_acquire already counted the stall
            else:
                channel.window.stats.stalled += 1
            channel.backlogged += 1
            self._backlog.append((channel, msg))
            if self.tracer is not None:
                self.tracer.on_stall(frame.call_id)

    def _buffer_request_chunk(self, ctx: CallContext,
                              frame: framing.Frame) -> None:
        """Client-side chunk retention for transparent stream retry:
        keep up to ``retry_buffer_chunks`` sent frames of a
        client-stream/bidi call on its context so a RetryInterceptor
        can replay the whole stream under a fresh call id. Past the
        bound the buffer is dropped for good — the sticky
        ``meta["buffer_overflow"]`` makes the interceptor give up
        (``gave_up_buffer``) instead of replaying a hole."""
        if ctx.kind not in (CLIENT_STREAM, BIDI) \
                or ctx.meta.get("buffer_overflow"):
            return
        if ctx.request_chunks is None:
            ctx.request_chunks = []
        ctx.request_chunks.append(frame)
        if ctx.request is None:
            ctx.request = frame
        if len(ctx.request_chunks) > self.retry_buffer_chunks:
            ctx.request = None
            ctx.request_chunks = None
            ctx.meta["buffer_overflow"] = True

    def register_handle(self, handle: StreamHandle, *,
                        kind: str = SERVER_STREAM,
                        deadline_s: Optional[float] = None,
                        request: Optional[framing.Frame] = None) -> None:
        self._handles[handle.call_id] = handle
        self._start_ctx(handle.call_id, handle.method, kind,
                        handle.channel, deadline_s=deadline_s,
                        request=request)

    def context(self, call_id: int) -> Optional[CallContext]:
        """The live CallContext of an in-flight call (None once it
        completes). Dispatch layers above the fabric (ShardedServeStub)
        use it to attach routing metadata their interceptors read."""
        return self._ctx.get(call_id)

    # interceptor plumbing ---------------------------------------------
    def _start_ctx(self, call_id: int, method: str, kind: str,
                   channel: Channel, *,
                   deadline_s: Optional[float] = None,
                   request: Optional[framing.Frame] = None
                   ) -> CallContext:
        existing = self._ctx.get(call_id)
        if existing is not None:     # later chunks of one client stream
            return existing
        now = self.now()
        ctx = CallContext(
            call_id, method, kind, channel.dst, now, channel=channel,
            deadline_s=(now + deadline_s) if deadline_s is not None
            else None,
            request=request)
        self._ctx[call_id] = ctx
        if self.tracer is not None:
            self.tracer.on_call_start(ctx, channel.src)
        for ic in self.client_interceptors:
            ic.on_start(ctx)
        return ctx

    def _emit(self, ev: Event) -> None:
        """Push one event through the completion queue and the client
        chain's ``on_event`` hooks."""
        self.cq.push(ev)
        if self.client_interceptors:
            ctx = self._ctx.get(ev.tag)
            if ctx is not None:
                for ic in self.client_interceptors:
                    ic.on_event(ctx, ev)

    def _client_complete(self, ctx: CallContext, ev: Event) -> bool:
        """Unwind the client chain inner->outer for a terminal event.
        The first interceptor to answer ``"retry"`` (on a retryable
        call) consumes the failure — interceptors outer to it never see
        this attempt; returns True when a retry was scheduled."""
        for ic in reversed(self.client_interceptors):
            if ic.on_complete(ctx, ev) == "retry" \
                    and ctx.request is not None:
                self._resubmit(ctx)
                return True
        return False

    def _resubmit(self, ctx: CallContext) -> None:
        """Re-issue a failed call under a fresh call_id; the caller's
        Call future / stream handle stays open across attempts. Unary
        and server-stream calls replay their single retained request
        frame; client-stream/bidi calls replay every buffered sent
        chunk in order (``retry_buffer_chunks``). An
        interceptor-requested backoff (``ctx.meta["retry_backoff_s"]``)
        is paid on the fabric clock first — the call's original
        deadline keeps running through it, so a retry can still be
        cancelled by the budget it inherited."""
        old_id = ctx.call_id
        call = self._calls.pop(old_id, None)
        handle = self._handles.pop(old_id, None)
        self._ctx.pop(old_id, None)
        # the dead attempt's zero-copy placements will never be read;
        # unpin them before the retry places the frames again
        bufpool.release_call(old_id)
        backoff = float(ctx.meta.pop("retry_backoff_s", 0.0) or 0.0)
        if backoff > 0.0:
            if self.transport.modeled \
                    and hasattr(self.transport, "clock_s"):
                self.transport.clock_s += backoff
            else:
                time.sleep(backoff)
        new_id = self.next_call_id()
        if ctx.request_chunks:
            frames = [replace(f, call_id=new_id)
                      for f in ctx.request_chunks]
            ctx.request_chunks = frames
            ctx.request = frames[0]
        else:
            frames = [replace(ctx.request, call_id=new_id)]
            ctx.request = frames[0]
        ctx.call_id, ctx.attempts = new_id, ctx.attempts + 1
        ctx.dst = ctx.channel.dst     # failover may have rerouted
        self._ctx[new_id] = ctx
        if call is not None:
            call.call_id, call.dst = new_id, ctx.channel.dst
            self._calls[new_id] = call
        if handle is not None:
            handle.call_id = new_id
            handle.channel = ctx.channel
            self._handles[new_id] = handle
        if self.tracer is not None:
            # attempt N closed at the failure, backoff paid on the
            # clock, attempt N+1 (possibly re-routed) opens now
            t_fail = ctx.end_s if ctx.end_s is not None else self.now()
            self.tracer.on_retry(ctx, old_id, t_fail, self.now())
        self._emit(Event(new_id, "retry"))
        for frame in frames:
            self.submit_raw(ctx.channel, frame)

    # completion --------------------------------------------------------
    def _complete(self, call: Call, frame: Optional[framing.Frame],
                  kind: str, error: Optional[str] = None) -> None:
        ctx = self._ctx.get(call.call_id)
        ev = Event(call.call_id, kind, ok=error is None,
                   payload=_spec_only(frame))
        if ctx is not None:
            ctx.end_s = self.now()
            ctx.meta["error"] = error
            # uniform terminal order, every outcome: on_complete unwinds
            # the chain first (it may consume an error as a retry), then
            # the terminal event hits the cq and on_event
            if self._client_complete(ctx, ev):
                return                       # retried; future stays open
        call.done, call.result, call.error = True, frame, error
        if self.tracer is not None and ctx is not None:
            self.tracer.on_terminal(ctx, kind, error)
        self._emit(ev)
        # the caller holds the Call object; the fabric is done with it
        self._calls.pop(call.call_id, None)
        self._ctx.pop(call.call_id, None)
        # free-on-complete: unpin this call's zero-copy placements
        bufpool.release_call(call.call_id)

    def _finish_handle(self, handle: StreamHandle,
                       error: Optional[str] = None,
                       kind: Optional[str] = None) -> None:
        ev = Event(handle.call_id,
                   kind or ("error" if error else "stream_end"),
                   ok=error is None)
        ctx = self._ctx.get(handle.call_id)
        if ctx is not None:
            ctx.end_s = self.now()
            ctx.meta["error"] = error
            # a server-stream that failed before any chunk arrived may
            # be transparently re-issued by a RetryInterceptor
            if self._client_complete(ctx, ev):
                return                  # retried; the handle stays open
        handle.done, handle.error = True, error
        if self.tracer is not None and ctx is not None:
            self.tracer.on_terminal(ctx, ev.kind, error)
        self._emit(ev)
        self._handles.pop(handle.call_id, None)
        self._ctx.pop(handle.call_id, None)
        # free-on-complete: unpin this stream's zero-copy placements
        bufpool.release_call(handle.call_id)

    def _grant(self, msg: Message) -> None:
        ch = self._channels.get((msg.src, msg.dst, msg.frame.wire_mode))
        if ch is not None:
            ch.window.grant(msg.frame.total_bytes)

    def _offer_chunk(self, channel: Channel, frame: framing.Frame
                     ) -> None:
        """Queue one server->client stream chunk behind the channel's
        reverse window; admitted chunks join the next flight."""
        msg = Message(channel.dst, channel.src, frame)
        admitted = channel.rx_gate.offer(msg, frame.total_bytes)
        self._pending.extend((channel, m) for m in admitted)
        if self.tracer is not None and not admitted:
            self.tracer.on_stall(frame.call_id)

    def _on_client_chunk(self, m: Message) -> None:
        """A server->client stream chunk was delivered: hand it to the
        handle, return the reverse-window credits (the client consumed
        it), and complete the handle on END."""
        ch = self._channels.get((m.dst, m.src, m.frame.wire_mode))
        if ch is not None:
            ch.rx_gate.grant(m.frame.total_bytes)
        handle = self._handles.get(m.frame.call_id)
        if handle is None or handle.done:
            return
        if (m.frame.flags & framing.FLAG_ERROR) \
                and not m.frame.is_stream:
            # a pumped stream's producer faulted mid-stream: the error
            # reply rides the chunk path (reverse window) back to the
            # client and fails the handle like a dispatch-time fault
            err = (bytes(m.frame.bufs[0]).decode(errors="replace")
                   if m.frame.bufs else "error")
            self._purge_call(m.frame.call_id)
            self._finish_handle(
                handle, error=err,
                kind=("deadline_exceeded" if DEADLINE_EXCEEDED in err
                      else "error"))
            return
        if m.frame.n_buffers or not m.frame.stream_end:
            # bare END trailers carry no payload chunk
            handle.chunks.append(m.frame.bufs
                                 if m.frame.bufs is not None
                                 else list(m.frame.sizes))
            ctx = self._ctx.get(m.frame.call_id)
            if ctx is not None:
                ctx.chunks += 1     # delivered: a retry would duplicate
            self._emit(Event(m.frame.call_id, "stream_chunk",
                             payload=_spec_only(m.frame)))
        if m.frame.stream_end:
            self._finish_handle(handle)

    # deadlines ---------------------------------------------------------
    def _have_deadlines(self) -> bool:
        return any(c.deadline_s is not None for c in self._ctx.values())

    def _stamp_budget(self, msg: Message, now: float) -> Message:
        """Context propagation at flight departure: stamp the remaining
        deadline budget (gRPC's ``grpc-timeout``) and the call's trace
        id (the census-metadata analogue) into a request frame's header
        words, so the receiving server can shed work whose budget the
        wire consumed and attribute its spans to the originating
        call."""
        f = msg.frame
        if f.is_reply:
            return msg
        ctx = self._ctx.get(f.call_id)
        if ctx is None:
            return msg
        budget = f.budget_us
        if ctx.deadline_s is not None:
            budget = max(1, min(framing.MAX_BUDGET_US,
                                int((ctx.deadline_s - now) * 1e6)))
        if budget == f.budget_us and ctx.trace_id == f.trace_id:
            return msg
        return replace(msg, frame=replace(f, budget_us=budget,
                                          trace_id=ctx.trace_id))

    def _cancel_expired(self) -> int:
        now = self.now()
        expired = [c for c in self._ctx.values()
                   if c.deadline_s is not None and now >= c.deadline_s]
        for ctx in expired:
            self._cancel(ctx, DEADLINE_EXCEEDED)
        return len(expired)

    def _purge_call(self, cid: int) -> None:
        """Drop every in-flight frame of one call — backlogged, gated,
        AND already admitted to the next flight (refunding the admitted
        frames' window credits) — and the servers' partial-stream
        state. Dropping pending frames matters: a chunk delivered
        after a cancel would silently re-create the server-side stream
        state that no END will ever clean up."""
        kept: List[Tuple[Channel, Message]] = []
        for ch_, msg in self._backlog:
            if msg.frame.call_id == cid:
                ch_.backlogged -= 1     # queued frames held no credits
            else:
                kept.append((ch_, msg))
        self._backlog = kept
        kept = []
        for ch_, msg in self._pending:
            if msg.frame.call_id != cid:
                kept.append((ch_, msg))
            elif msg.frame.is_reply:    # admitted server->client chunk
                ch_.rx_gate.grant(msg.frame.total_bytes)
            else:                       # admitted client->server frame
                ch_.window.grant(msg.frame.total_bytes)
        self._pending = kept
        for ch_ in self._channels.values():
            ch_.rx_gate.drop(lambda m: m.frame.call_id == cid)
        for srv in self.servers.values():
            srv.abort_call(cid)     # partial streams never get their END

    def _cancel(self, ctx: CallContext, reason: str,
                kind: str = "deadline_exceeded") -> None:
        """Cancel one call: purge its frames and server state, then
        fail the future/handle with a ``kind`` event (deadline expiry,
        or ``"error"`` for an injected link fault — in which case the
        completion may be consumed as a retry and the call lives on
        under a fresh call_id)."""
        cid = ctx.call_id
        self._purge_call(cid)
        call = self._calls.get(cid)
        if call is not None and not call.done:
            self._complete(call, None, kind, error=reason)
        handle = self._handles.get(cid)
        if handle is not None and not handle.done:
            self._finish_handle(handle, error=reason, kind=kind)
        self._ctx.pop(cid, None)

    def _refund_message(self, m: Message) -> None:
        """Return the credits one undeliverable main-flight message
        held: reverse-window credits for a server->client stream
        chunk, forward-window credits for a client->server frame. The
        ONE refund path for faulted messages and their same-flight
        stragglers — the credit invariant the fault tier asserts."""
        if m.frame.is_reply:
            ch = self._channels.get((m.dst, m.src, m.frame.wire_mode))
            if ch is not None:
                ch.rx_gate.grant(m.frame.total_bytes)
        else:
            self._grant(m)

    def _on_link_fault(self, m: Message) -> List[int]:
        """A FaultInjectionTransport flagged this main-flight message
        lost to a transient link fault: refund the credits it held,
        purge the call's other in-flight frames, and fail it with a
        retryable error. Returns the dead call_id so same-flight
        stragglers of the call can be consumed without dispatching."""
        cid = m.frame.call_id
        self._refund_message(m)
        if self.tracer is not None:
            self.tracer.on_fault(m, self.now())
        ctx = self._ctx.get(cid)
        if ctx is not None:
            self._cancel(ctx, LINK_FAULT, kind="error")
        return [cid]

    def _deadline_wait(self) -> bool:
        """Everything is stalled on credits and nothing is in flight.
        If any *stalled* frame's call carries a deadline, advance the
        fabric clock to the earliest one (the modeled transport clock,
        or a real sleep) and cancel — back-pressure with a deadline
        resolves by cancellation, not by forcing uncredited admission.
        Returns True when a cancellation freed the loop."""
        stalled = {m.frame.call_id for _, m in self._backlog}
        for ch in self._channels.values():
            stalled.update(m.frame.call_id for m, _ in ch.rx_gate.items())
        deadlines = [self._ctx[c].deadline_s for c in stalled
                     if c in self._ctx
                     and self._ctx[c].deadline_s is not None]
        if not deadlines:
            return False
        target = min(deadlines)
        if self.transport.modeled and hasattr(self.transport, "clock_s"):
            self.transport.clock_s = max(self.transport.clock_s, target)
        else:
            time.sleep(max(0.0, target - time.perf_counter()))
        return self._cancel_expired() > 0

    # event loop --------------------------------------------------------
    def flush(self, *, until_s: Optional[float] = None) -> FlightReport:
        """Drive the event loop until every submitted call completes,
        every open response stream drains, and every expired deadline
        has cancelled its call.

        ``until_s`` bounds the drive by *fabric-clock time* instead:
        the loop stops as soon as ``now()`` reaches it, leaving
        unfinished calls pending for a later ``flush`` to continue —
        the open-loop workload driver (``repro.workload.driver``)
        rides this to interleave new arrivals with in-flight traffic
        on the modeled clock. Flights are atomic, so the clock may
        overshoot ``until_s`` by one flight."""
        rep = FlightReport(modeled=self.transport.modeled)
        t0 = time.perf_counter()
        while True:
            if until_s is not None and self.now() >= until_s:
                break
            if self._ctx and self._have_deadlines():
                self._cancel_expired()
            if self._open_pumps():
                # one chunk per pumped stream per iteration: concurrent
                # pumped calls interleave chunk-by-chunk (the serving
                # scheduler's decode steps ride this cadence)
                self._pump_server_streams()
            if not (self._pending or self._backlog
                    or self._gated_chunks() or self._open_pumps()):
                break
            if not self._pending:
                # admit as credits allow; otherwise wait out a stalled
                # deadline; as a last resort one message must move or
                # the window is simply too small for the message
                admitted = self._admit_backlog() or self._pump_gates()
                if not admitted:
                    if self._deadline_wait():
                        continue
                    admitted = (self._admit_backlog(force_one=True)
                                or self._pump_gates(force_one=True))
                    assert admitted, "flow-control deadlock"
            flight = self._pending
            self._pending = []
            t_send = self.now()     # flight departure: budgets stamped
            stamped = [self._stamp_budget(m, t_send) for _, m in flight]
            if self.tracer is not None:
                for m in stamped:
                    if not m.frame.is_reply:
                        self.tracer.on_depart(m.frame.call_id, t_send)
            delivery = self.transport.deliver(stamped)
            rep.flights += 1
            rep.rounds += delivery.rounds
            rep.messages += len(delivery.messages)
            rep.elapsed_s += delivery.elapsed_s
            if self.tracer is not None:
                t_arrive = t_send + delivery.elapsed_s
                for m in delivery.messages:
                    if not (m.frame.flags & framing.FLAG_FAULT):
                        self.tracer.on_wire(m, t_send, t_arrive)
            replies: List[Message] = []
            dead: Set[int] = set()      # calls killed by a link fault
            # per-dst call_ids landed this flight: the queue-depth unit
            # is CALLS (a stream's chunks are one call's arrivals)
            arrivals: Dict[int, Set[int]] = {}
            for m in delivery.messages:
                if m.frame.flags & framing.FLAG_FAULT:
                    dead.update(self._on_link_fault(m))
                    continue
                if m.frame.call_id in dead:
                    # a straggler of a call a link fault already killed
                    # this flight: consume it, refund its credits, and
                    # never let it re-create server-side stream state
                    self._refund_message(m)
                    continue
                if m.frame.is_reply:
                    # server->client stream chunk riding a main flight
                    self._on_client_chunk(m)
                    continue
                call = self._calls.get(m.frame.call_id)
                handle = self._handles.get(m.frame.call_id)
                if not self.transport.dispatches:
                    # exchange datapath: delivery IS completion — a
                    # stream's call completes when its END lands, so
                    # deadlines/metrics cover the whole stream
                    self._grant(m)
                    if call is not None and not call.done \
                            and (not m.frame.is_stream
                                 or m.frame.stream_end):
                        self._complete(call, m.frame, "sent")
                    if handle is not None and m.frame.stream_end:
                        self._finish_handle(handle)
                    continue
                srv = self.servers.get(m.dst)
                if srv is None:
                    self._grant(m)
                    err = f"no server at endpoint {m.dst}"
                    if call is not None and not call.done:
                        self._complete(call, None, "error", error=err)
                    if handle is not None and not handle.done:
                        self._finish_handle(handle, error=err)
                    continue
                # the server's view of the propagated deadline: the
                # budget the frame left with, minus what the wire ate
                deadline = (t_send + m.frame.budget_us / 1e6
                            if m.frame.budget_us else None)
                cid = m.frame.call_id
                landed = arrivals.setdefault(m.dst, set())
                landed.add(cid)
                # queue depth = calls landed on this endpoint so far
                # this flight (including this one) + partial input
                # streams still open from EARLIER flights. Open pumps
                # are NOT counted: a pump is a call that was already
                # admitted and is now delivering results, so counting
                # it would starve unary traffic behind every long
                # decode (pump load reaches dispatch policies via the
                # scheduler gauges instead).
                depth = len(landed) \
                    + sum(1 for k in srv._streams if k not in landed) \
                    + sum(1 for k in srv._bidi_seq if k not in landed)
                if self.tracer is not None:
                    self.tracer.on_server(cid, self.now())
                outs = srv.dispatch(m.frame, deadline_s=deadline,
                                    queue_depth=depth)
                self._emit(Event(m.frame.call_id, "received",
                                 payload=_spec_only(m.frame)))
                plain = [o for o in outs if not o.is_stream]
                chunks = [o for o in outs if o.is_stream]
                pump = srv._pumps.get(cid)
                if pump is not None and pump.channel_key is None:
                    pump.channel_key = (m.src, m.dst,
                                        m.frame.wire_mode)
                if self.tracer is not None:
                    self.tracer.on_dispatched(
                        cid, self.now(),
                        replying=bool(plain or chunks)
                        or pump is not None)
                if plain:
                    # request credits return when the reply lands
                    self._awaiting_grant.setdefault(m.frame.call_id,
                                                    []).append(m)
                    replies.extend(Message(m.dst, m.src, o)
                                   for o in plain)
                else:
                    # stream-kind input (or one-way): receipt is
                    # consumption — forward credits return now. A
                    # one-way STREAM call completes only when its END
                    # chunk is consumed, keeping the call context (and
                    # its deadline) live for the whole stream
                    self._grant(m)
                    if call is not None and m.frame.one_way \
                            and not call.done \
                            and (not m.frame.is_stream
                                 or m.frame.stream_end):
                        self._complete(call, None, "sent")
                for o in chunks:
                    ch = self._channels.get((m.src, m.dst,
                                             m.frame.wire_mode))
                    assert ch is not None
                    self._offer_chunk(ch, o)
            if replies:
                t_rsend = self.now()
                rdel = self.transport.deliver(replies)
                rep.flights += 1
                rep.rounds += rdel.rounds
                rep.replies += len(rdel.messages)
                rep.elapsed_s += rdel.elapsed_s
                if self.tracer is not None:
                    t_rarr = t_rsend + rdel.elapsed_s
                    for m in rdel.messages:
                        if not (m.frame.flags & framing.FLAG_FAULT):
                            self.tracer.on_wire(m, t_rsend, t_rarr)
                for m in rdel.messages:
                    # grant the REQUEST's credits (reply size differs);
                    # even for a LOST reply — the server consumed the
                    # request regardless
                    reqs = self._awaiting_grant.get(m.frame.call_id)
                    if reqs:
                        self._grant(reqs.pop(0))
                        if not reqs:
                            del self._awaiting_grant[m.frame.call_id]
                    if m.frame.flags & framing.FLAG_FAULT:
                        # the reply was lost to an injected link fault:
                        # the call fails transiently (a retry re-runs
                        # the handler — at-least-once, like gRPC)
                        if self.tracer is not None:
                            self.tracer.on_fault(m, self.now())
                        ctx = self._ctx.get(m.frame.call_id)
                        if ctx is not None:
                            self._cancel(ctx, LINK_FAULT, kind="error")
                        continue
                    is_err = bool(m.frame.flags & framing.FLAG_ERROR)
                    err = None
                    if is_err:
                        err = bytes(m.frame.bufs[0]).decode(
                            errors="replace") if m.frame.bufs else "error"
                        # a rejected/shed stream call's remaining chunks
                        # are already doomed: purge them so they cannot
                        # re-create server-side state no END cleans up
                        self._purge_call(m.frame.call_id)
                    # server-shed work is a deadline outcome, not a
                    # generic error — metrics must count it as such
                    err_kind = ("deadline_exceeded"
                                if err and DEADLINE_EXCEEDED in err
                                else "error")
                    handle = self._handles.get(m.frame.call_id)
                    if handle is not None and not handle.done:
                        # stream request answered with a plain (error)
                        # reply — fail the handle
                        self._finish_handle(
                            handle, error=err or "protocol error",
                            kind=err_kind if is_err else None)
                    call = self._calls.get(m.frame.call_id)
                    if call is None or call.done:
                        continue
                    if is_err:
                        self._complete(call, m.frame, err_kind,
                                       error=err)
                    else:
                        self._complete(call, m.frame, "replied")
            self._admit_backlog()
            self._pump_gates()
        rep.wall_s = time.perf_counter() - t0
        return rep

    def _gated_chunks(self) -> int:
        return sum(len(ch.rx_gate) for ch in self._channels.values())

    def _open_pumps(self) -> int:
        return sum(len(srv._pumps) for srv in self.servers.values())

    def _pump_server_streams(self) -> None:
        """Pull one chunk from every open pumped server stream and
        offer it behind the owning channel's reverse window. A pump
        whose previous chunk is still window-gated is skipped this
        iteration — the producer is paced by the consumer's credits
        instead of piling chunks into the gate."""
        for srv in self.servers.values():
            for cid in list(srv._pumps):
                pump = srv._pumps[cid]
                ch = (self._channels.get(pump.channel_key)
                      if pump.channel_key is not None else None)
                if ch is None:      # registered this iteration; next one
                    continue
                if any(m.frame.call_id == cid
                       for m, _ in ch.rx_gate.items()):
                    continue
                for o in srv.pump_one(cid):
                    self._offer_chunk(ch, o)

    def _pump_gates(self, force_one: bool = False) -> int:
        """Re-admit reverse-window-stalled chunks after credit grants."""
        admitted = 0
        for ch in self._channels.values():
            if not len(ch.rx_gate):
                continue
            msgs = ch.rx_gate.pump(force_one=force_one and not admitted)
            self._pending.extend((ch, m) for m in msgs)
            if self.tracer is not None:
                for m in msgs:
                    self.tracer.on_admit(m.frame.call_id, reply=True)
            admitted += len(msgs)
        return admitted

    def _admit_backlog(self, force_one: bool = False) -> int:
        admitted, rest = 0, []
        blocked: set = set()
        for ch_, msg in self._backlog:
            # head-of-line per channel: once one of a channel's messages
            # stays blocked, its later ones stay queued too (ordering)
            if id(ch_) in blocked:
                rest.append((ch_, msg))
                continue
            # can_acquire first: a retry is not a new stall, so the
            # stall count stays one-per-call (recorded at submit time)
            if ch_.window.can_acquire(msg.frame.total_bytes):
                ch_.window.try_acquire(msg.frame.total_bytes)
                self._pending.append((ch_, msg))
                ch_.backlogged -= 1
                admitted += 1
                if self.tracer is not None:
                    self.tracer.on_admit(msg.frame.call_id)
            elif force_one and admitted == 0:
                self._pending.append((ch_, msg))
                ch_.backlogged -= 1
                admitted += 1
                if self.tracer is not None:
                    self.tracer.on_admit(msg.frame.call_id)
            else:
                blocked.add(id(ch_))
                rest.append((ch_, msg))
        self._backlog = rest
        return admitted


# ---------------------------------------------------------------------------
# benchmark drivers: the fully-connected / ring / incast exchanges over
# one fabric (paper §2's process architecture beyond the 3 fixed
# benchmarks), each expressed as stub calls against its declared
# service (service.EXCHANGE_SERVICE / RING_SERVICE / INCAST_SERVICE)
# ---------------------------------------------------------------------------

def fully_connected_exchange(fabric: RpcFabric, sizes: Sequence[int], *,
                             bufs: Optional[List[np.ndarray]] = None,
                             serialized: bool = False,
                             wire_mode: Optional[str] = None
                             ) -> FlightReport:
    """Every endpoint sends one payload to every other endpoint
    (n * (n-1) one-way unary RPCs through ``Exchange/exchange`` stubs),
    generated in the shift order of ``channels.all_to_all_schedule`` so
    the transport's edge coloring recovers exactly n-1 rounds."""
    from repro.rpc.service import EXCHANGE_SERVICE
    n = fabric.n_endpoints
    assert n >= 2, n
    if fabric.transport.dispatches:
        handlers = {"exchange": lambda req: None}
        for e in range(n):
            if e not in fabric.servers:
                fabric.add_server(e).add_service(EXCHANGE_SERVICE,
                                                 handlers)
    for r in range(1, n):
        for i in range(n):
            stub = fabric.stub(EXCHANGE_SERVICE, i, (i + r) % n,
                               serialized=serialized,
                               wire_mode=wire_mode)
            stub.exchange(bufs, sizes=sizes if bufs is None else None,
                          one_way=True)
    return fabric.flush()


def ring_exchange(fabric: RpcFabric, sizes: Sequence[int], *,
                  n_chunks: int = 1,
                  bufs: Optional[List[np.ndarray]] = None,
                  serialized: bool = False,
                  wire_mode: Optional[str] = None) -> FlightReport:
    """Every worker client-streams ``n_chunks`` payload chunks to its
    successor (i -> (i+1) % n) through ``Ring/ring`` stubs: n one-way
    streams whose chunks the transport edge-colors back into exactly
    ``channels.ring_schedule(n, n_chunks)`` — n_chunks rotation
    rounds."""
    from repro.rpc.service import RING_SERVICE
    n = fabric.n_endpoints
    assert n >= 2, n
    assert n_chunks >= 1, n_chunks
    if fabric.transport.dispatches:
        handlers = {"ring": lambda req: None}
        for e in range(n):
            if e not in fabric.servers:
                fabric.add_server(e).add_service(RING_SERVICE, handlers)
    for i in range(n):
        stub = fabric.stub(RING_SERVICE, i, (i + 1) % n,
                           serialized=serialized, wire_mode=wire_mode)
        stub.ring([bufs] * n_chunks if bufs is not None else None,
                  sizes=sizes if bufs is None else None,
                  n_chunks=n_chunks, one_way=True)
    return fabric.flush()


def incast_exchange(fabric: RpcFabric, sizes: Sequence[int], *,
                    n_chunks: int = 1,
                    bufs: Optional[List[np.ndarray]] = None,
                    serialized: bool = False,
                    wire_mode: Optional[str] = None,
                    fetch_ratio: float = 1.0) -> FlightReport:
    """The Cori-style parameter-server hotspot: every worker
    (endpoints 1..n-1) bidi-streams ``n_chunks`` payload chunks into
    one server (endpoint 0) through ``Incast/push_fetch`` stubs; on
    each stream's END the server streams the fetch back — sized
    ``fetch_ratio`` times the push payload (1.0 = symmetric; <1 models
    a small variable pull, >1 a fetch-heavy read) — so the server pays
    both the N-way ingress of the push AND the N-way egress of the
    fetch. On non-dispatching transports (collective) only the push
    half runs."""
    from repro.core.payload import scale_sizes
    from repro.rpc.service import INCAST_SERVICE
    n = fabric.n_endpoints
    assert n >= 2, "incast needs >= 1 worker + the server endpoint"
    assert n_chunks >= 1, n_chunks
    assert fetch_ratio > 0, fetch_ratio
    fetch_sizes = scale_sizes(sizes, fetch_ratio)
    # the fetch payload is baked into the server's handler closure on
    # first registration; a later call with a different shape would be
    # silently served the old fetch — reject it instead
    setup = (tuple(int(s) for s in sizes), float(fetch_ratio))
    prev = fabric._incast_setup
    if prev is not None and prev != setup:
        raise ValueError(
            f"incast server on this fabric already bound with "
            f"sizes/fetch_ratio {prev}; got {setup} — use a fresh "
            f"fabric to change the fetch shape")
    fabric._incast_setup = setup
    if fabric.transport.dispatches and 0 not in fabric.servers:
        if bufs is not None:
            fetch_bufs = [np.resize(b, s).astype(np.uint8)
                          for b, s in zip(bufs, fetch_sizes)]
            fetch = [list(fetch_bufs)] * n_chunks
        else:
            fetch = [tuple(fetch_sizes)] * n_chunks

        def push_fetch(chunk, end, _fetch=fetch):
            return _fetch if end else None

        fabric.add_server(0).add_service(INCAST_SERVICE,
                                         {"push_fetch": push_fetch})
    handles = [fabric.stub(INCAST_SERVICE, w, 0,
                           serialized=serialized, wire_mode=wire_mode)
               .push_fetch() for w in range(1, n)]
    for c in range(n_chunks):
        for h in handles:
            h.send(bufs, sizes=sizes if bufs is None else None,
                   end=(c == n_chunks - 1))
    rep = fabric.flush()
    assert all(h.done for h in handles)
    return rep
