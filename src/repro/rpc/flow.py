"""Credit-based flow control (the HTTP/2 / gRPC window analogue).

Each channel holds one :class:`CreditWindow` **per direction**: the
forward window gates client->server frames, the reverse window gates
server->client stream chunks. Issuing a frame consumes byte + message
credits of its direction; completions (replies, chunk delivery, or
transport delivery for one-way calls) grant them back. When credits run
dry the frame queues locally instead of being dropped — the stall is
counted, which is exactly the back-pressure signal the paper's
flow-control discussion (§2.2) says a benchmark suite should expose —
and the stream resumes as soon as grants return credits. Because the
two directions hold independent windows, a bidi stream that is
window-limited both ways still makes progress: each direction drains on
its own credits.

:class:`ChunkGate` is the enforcement mechanism for a chunk stream: a
FIFO of pending chunks in front of one CreditWindow, so a later chunk
can never overtake an earlier stalled one.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Any, Deque, List, Tuple


@dataclass
class FlowStats:
    acquired: int = 0           # calls admitted
    stalled: int = 0            # calls that had to wait for credits
    bytes_in_flight_peak: int = 0


@dataclass(frozen=True)
class WindowConfig:
    """Declarative size of one direction's CreditWindow — the shared
    vocabulary between the fabric's defaults and a cluster endpoint's
    advertised window (``rpc.cluster.EndpointSpec.window``)."""
    bytes: int = 4 * 1024 * 1024
    msgs: int = 32

    def make(self) -> "CreditWindow":
        return CreditWindow(self.bytes, self.msgs)


class CreditWindow:
    def __init__(self, window_bytes: int = 4 * 1024 * 1024,
                 window_msgs: int = 32):
        assert window_bytes > 0 and window_msgs > 0
        self.window_bytes = window_bytes
        self.window_msgs = window_msgs
        self.bytes_avail = window_bytes
        self.msgs_avail = window_msgs
        self.stats = FlowStats()

    @property
    def bytes_in_flight(self) -> int:
        return self.window_bytes - self.bytes_avail

    def can_acquire(self, nbytes: int) -> bool:
        # an over-window message is admitted alone (gRPC: a message may
        # exceed the window; it just occupies the whole window)
        fits = (self.bytes_avail >= min(nbytes, self.window_bytes)
                and self.msgs_avail >= 1)
        return fits

    def try_acquire(self, nbytes: int) -> bool:
        if not self.can_acquire(nbytes):
            self.stats.stalled += 1
            return False
        self.bytes_avail -= min(nbytes, self.window_bytes)
        self.msgs_avail -= 1
        self.stats.acquired += 1
        self.stats.bytes_in_flight_peak = max(
            self.stats.bytes_in_flight_peak, self.bytes_in_flight)
        return True

    def grant(self, nbytes: int) -> None:
        self.bytes_avail = min(self.window_bytes,
                               self.bytes_avail + min(nbytes,
                                                      self.window_bytes))
        self.msgs_avail = min(self.window_msgs, self.msgs_avail + 1)


class ChunkGate:
    """FIFO of stream chunks gated by one direction's CreditWindow.

    ``offer`` admits a chunk immediately when the window has credits and
    nothing is already queued (FIFO: a stalled chunk blocks all later
    ones); otherwise the chunk queues and the stall is counted once.
    ``pump`` re-admits queued chunks after ``grant`` returns credits.
    Chunks are never dropped: exhaustion only stalls the stream.
    """

    def __init__(self, window: CreditWindow):
        self.window = window
        self._q: Deque[Tuple[Any, int]] = deque()

    def __len__(self) -> int:
        return len(self._q)

    def offer(self, item: Any, nbytes: int) -> List[Any]:
        """Submit one chunk; returns the (0- or 1-element) admitted list."""
        if not self._q and self.window.try_acquire(nbytes):
            return [item]
        if self._q:     # try_acquire above already counted a fresh stall
            self.window.stats.stalled += 1
        self._q.append((item, nbytes))
        return []

    def pump(self, force_one: bool = False) -> List[Any]:
        """Admit queued chunks in FIFO order while credits last. With
        ``force_one`` and an empty window, admit the head anyway — an
        over-window chunk must occupy the window alone, not deadlock."""
        out: List[Any] = []
        while self._q:
            item, nbytes = self._q[0]
            # can_acquire first: a retry is not a new stall
            if self.window.can_acquire(nbytes):
                self.window.try_acquire(nbytes)
            elif force_one and not out:
                pass                    # admit uncredited, head-of-line
            else:
                break
            self._q.popleft()
            out.append(item)
        return out

    def grant(self, nbytes: int) -> None:
        self.window.grant(nbytes)

    def items(self) -> List[Tuple[Any, int]]:
        """The queued (item, nbytes) pairs, FIFO order (inspection —
        e.g. the fabric's deadline scan over stalled chunks)."""
        return list(self._q)

    def drop(self, pred) -> List[Tuple[Any, int]]:
        """Remove queued chunks whose item matches ``pred`` (call
        cancellation). Queued chunks hold no credits, so nothing is
        granted back; returns the dropped (item, nbytes) pairs."""
        dropped = [(it, nb) for it, nb in self._q if pred(it)]
        if dropped:
            self._q = deque((it, nb) for it, nb in self._q
                            if not pred(it))
        return dropped
