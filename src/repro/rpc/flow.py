"""Credit-based flow control (the HTTP/2 / gRPC window analogue).

Each channel holds a :class:`CreditWindow`. Issuing a call consumes
byte + message credits; completions (replies, or transport delivery for
one-way calls) grant them back. When credits run dry the fabric queues
the call locally instead of submitting it — the stall is counted, which
is exactly the back-pressure signal the paper's flow-control discussion
(§2.2) says a benchmark suite should expose.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass
class FlowStats:
    acquired: int = 0           # calls admitted
    stalled: int = 0            # calls that had to wait for credits
    bytes_in_flight_peak: int = 0


class CreditWindow:
    def __init__(self, window_bytes: int = 4 * 1024 * 1024,
                 window_msgs: int = 32):
        assert window_bytes > 0 and window_msgs > 0
        self.window_bytes = window_bytes
        self.window_msgs = window_msgs
        self.bytes_avail = window_bytes
        self.msgs_avail = window_msgs
        self.stats = FlowStats()

    @property
    def bytes_in_flight(self) -> int:
        return self.window_bytes - self.bytes_avail

    def can_acquire(self, nbytes: int) -> bool:
        # an over-window message is admitted alone (gRPC: a message may
        # exceed the window; it just occupies the whole window)
        fits = (self.bytes_avail >= min(nbytes, self.window_bytes)
                and self.msgs_avail >= 1)
        return fits

    def try_acquire(self, nbytes: int) -> bool:
        if not self.can_acquire(nbytes):
            self.stats.stalled += 1
            return False
        self.bytes_avail -= min(nbytes, self.window_bytes)
        self.msgs_avail -= 1
        self.stats.acquired += 1
        self.stats.bytes_in_flight_peak = max(
            self.stats.bytes_in_flight_peak, self.bytes_in_flight)
        return True

    def grant(self, nbytes: int) -> None:
        self.bytes_avail = min(self.window_bytes,
                               self.bytes_avail + min(nbytes,
                                                      self.window_bytes))
        self.msgs_avail = min(self.window_msgs, self.msgs_avail + 1)
