"""Message framing for the in-process RPC fabric (gRPC wire analogue).

A call is one :class:`Frame`: a fixed-layout little-endian header plus a
list of iovec payload buffers (uint8). Three wire encodings mirror the
paper's payload modes plus the one-sided-RDMA tier of "RPC Considered
Harmful" (PAPERS.md):

  serialized     — header + every buffer coalesced into ONE contiguous
                   uint8 wire buffer via the ``payload_pack`` Pallas
                   kernel (``backend="kernel"``, the TPU path) or a
                   byte-identical numpy copy (``backend="numpy"``, the
                   fast host path). One wire message per call.
  scatter_gather — header buffer + each payload buffer as a separate
                   wire message (iovec scatter-gather): no coalescing
                   copy, N+1 messages per call. (The config-level name
                   of this mode is ``non_serialized``.)
  zero_copy      — header buffer + ONE descriptor block of
                   ``(pool_id, offset, size)`` ``<u8`` triples; payload
                   bytes never ride the wire. The sender places each
                   buffer into a pre-registered shared
                   :class:`repro.rpc.bufpool.BufferPool` region
                   (sender-managed placement — the one-sided-RDMA-write
                   analogue) and the receiver reads the bytes back out
                   of the pool, byte-identically, as zero-copy views.

Header layout (uint32 words, little-endian), zero-padded to a multiple
of the 128-byte TPU lane so it can itself be a pack-kernel buffer:

  [MAGIC, call_id, method_id, flags, seq, budget_us, trace_id,
   n_buffers, size_0 .. size_{n-1}]

``budget_us`` is the call's remaining deadline budget in microseconds
at the moment the frame left the sender — the wire form of gRPC's
``grpc-timeout`` header (0 = no deadline). The fabric stamps it at
flight departure and the receiving server sheds frames whose budget the
wire already consumed, before invoking any handler.

``trace_id`` is the call's distributed-tracing context (the gRPC
census-metadata analogue, see :mod:`repro.rpc.tracing`): stamped at
flight departure alongside the budget, propagated unchanged into
replies and reply chunks, and stable across retries and failover
re-routes — the receiving endpoint attributes its spans to the
originating call through it (0 = untraced).

``seq`` orders the chunks of one stream (0 for unary frames). Stream
*chunks* (``stream_chunk``) carry FLAG_STREAM and a running seq; the
last chunk of a direction adds FLAG_STREAM_END; server->client chunks
add FLAG_REPLY. Chunks use the same two wire encodings as unary frames
— serialized chunks still coalesce through the payload_pack kernel.

Frames may be *spec-only* (``bufs is None``): the sizes are real but no
bytes are materialized — the simulated transport prices such frames
analytically without ever allocating hundreds of endpoints' payloads.
Zero-length iovec buffers are legal (a stream END trailer is a frame
with no buffers at all); they occupy one zero-filled lane on the
serialized wire and a zero-size message on the non-serialized wire.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, replace
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.rpc.bufpool import get_pool

# TPU lane width in bytes. Must equal repro.kernels.payload_pack.LANE
# (pinned by tests/test_rpc.py) — not imported from there so that
# importing repro.rpc does not drag in jax/pallas; only the optional
# backend="kernel" paths do.
LANE = 128

#: the three wire modes, in paper order (Ethernet/IPoIB/RDMA analogue).
#: Must equal repro.core.netmodel.WIRE_MODES (pinned by tests) — not
#: imported from there to keep framing free of core dependencies.
WIRE_MODES = ("serialized", "scatter_gather", "zero_copy")


class FramingError(ValueError):
    """A wire buffer that cannot be a frame: truncated header block,
    corrupt ``n_buffers`` word, or descriptors inconsistent with the
    header sizes."""

MAGIC = 0x52504331  # "RPC1"

FLAG_SERIALIZED = 1
FLAG_STREAM = 2
FLAG_STREAM_END = 4
FLAG_REPLY = 8
FLAG_ERROR = 16
FLAG_ONE_WAY = 32
#: set by a FaultInjectionTransport on a message it "lost" to a
#: transient link fault: the fabric refunds the frame's credits and
#: fails the call with a retryable error instead of dispatching it
FLAG_FAULT = 64
#: the frame's payload travels as shared-pool descriptors, not bytes
#: (mutually exclusive with FLAG_SERIALIZED; neither = scatter-gather)
FLAG_ZERO_COPY = 128

#: budget_us is a uint32 header word; longer deadlines saturate (them
#: expiring mid-flight is indistinguishable from no deadline anyway)
MAX_BUDGET_US = 0xFFFFFFFF

#: trace_id is a uint32 header word (0 = untraced)
MAX_TRACE_ID = 0xFFFFFFFF

_WORD = 4


def method_id(name: str) -> int:
    """Stable 32-bit id for a method name (both ends compute it)."""
    return zlib.crc32(name.encode("utf-8")) & 0xFFFFFFFF


def _pad128(n: int) -> int:
    return max(LANE, -(-n // LANE) * LANE)


def _as_u8(b: np.ndarray) -> np.ndarray:
    """Coerce to a flat contiguous uint8 view. Fast path: an array that
    already is one passes through untouched (no copy, no np call) — the
    common case on the flush-loop hot path."""
    if (isinstance(b, np.ndarray) and b.dtype == np.uint8 and b.ndim == 1
            and b.flags.c_contiguous):
        return b
    return np.ascontiguousarray(b, dtype=np.uint8).reshape(-1)


def _mode_flags(serialized: bool, wire_mode: Optional[str]) -> int:
    """Resolve the (legacy bool, explicit mode) pair to header flags."""
    if wire_mode is None:
        return FLAG_SERIALIZED if serialized else 0
    if wire_mode not in WIRE_MODES:
        raise ValueError(f"unknown wire mode {wire_mode!r}; "
                         f"expected one of {WIRE_MODES}")
    if serialized and wire_mode != "serialized":
        raise ValueError(f"serialized=True conflicts with "
                         f"wire_mode={wire_mode!r}")
    if wire_mode == "serialized":
        return FLAG_SERIALIZED
    if wire_mode == "zero_copy":
        return FLAG_ZERO_COPY
    return 0


def resolve_wire_mode(serialized: bool = False,
                      wire_mode: Optional[str] = None) -> str:
    """Resolve the (legacy ``serialized`` bool, explicit ``wire_mode``)
    pair every fabric entry point accepts to a :data:`WIRE_MODES` name,
    rejecting unknown modes and conflicting combinations."""
    flags = _mode_flags(serialized, wire_mode)
    if flags & FLAG_SERIALIZED:
        return "serialized"
    if flags & FLAG_ZERO_COPY:
        return "zero_copy"
    return "scatter_gather"


@dataclass(frozen=True)
class Frame:
    call_id: int
    method: int                      # method_id(name)
    flags: int
    sizes: Tuple[int, ...]           # true (unpadded) iovec byte counts
    bufs: Optional[List[np.ndarray]] = None   # uint8, len == len(sizes)
    seq: int = 0                     # chunk index within a stream
    budget_us: int = 0               # remaining deadline budget (0=none)
    trace_id: int = 0                # tracing context (0=untraced)

    def __post_init__(self):
        assert 0 <= self.budget_us <= MAX_BUDGET_US, self.budget_us
        assert 0 <= self.trace_id <= MAX_TRACE_ID, self.trace_id
        assert not (self.flags & FLAG_SERIALIZED
                    and self.flags & FLAG_ZERO_COPY), \
            "FLAG_SERIALIZED and FLAG_ZERO_COPY are mutually exclusive"
        if self.bufs is not None:
            assert len(self.bufs) == len(self.sizes)
            for b, s in zip(self.bufs, self.sizes):
                assert b.dtype == np.uint8 and b.size == s, (b.shape, s)

    @property
    def total_bytes(self) -> int:
        return int(sum(self.sizes))

    @property
    def n_buffers(self) -> int:
        return len(self.sizes)

    @property
    def serialized(self) -> bool:
        return bool(self.flags & FLAG_SERIALIZED)

    @property
    def zero_copy(self) -> bool:
        return bool(self.flags & FLAG_ZERO_COPY)

    @property
    def wire_mode(self) -> str:
        if self.flags & FLAG_SERIALIZED:
            return "serialized"
        if self.flags & FLAG_ZERO_COPY:
            return "zero_copy"
        return "scatter_gather"

    @property
    def one_way(self) -> bool:
        return bool(self.flags & FLAG_ONE_WAY)

    @property
    def is_stream(self) -> bool:
        return bool(self.flags & FLAG_STREAM)

    @property
    def is_reply(self) -> bool:
        return bool(self.flags & FLAG_REPLY)

    @property
    def stream_end(self) -> bool:
        return bool(self.flags & FLAG_STREAM_END)

    def reply(self, bufs: Optional[List[np.ndarray]],
              sizes: Optional[Sequence[int]] = None, *,
              error: bool = False) -> "Frame":
        if bufs is not None:
            bufs = [_as_u8(b) for b in bufs]
        if sizes is None:
            assert bufs is not None
            sizes = [int(b.size) for b in bufs]
        flags = (self.flags & (FLAG_SERIALIZED | FLAG_ZERO_COPY)) | FLAG_REPLY
        if error:
            flags |= FLAG_ERROR
        return Frame(self.call_id, self.method, flags,
                     tuple(int(s) for s in sizes), bufs,
                     trace_id=self.trace_id)

    def reply_chunk(self, bufs: Optional[List[np.ndarray]], *, seq: int,
                    end: bool = False,
                    sizes: Optional[Sequence[int]] = None) -> "Frame":
        """A server->client stream chunk answering this frame's call.
        ``bufs=None`` with explicit ``sizes`` builds a spec-only chunk
        (modeled transports); ``bufs=None, sizes=None`` a bare END
        trailer (no payload, still encodable)."""
        if bufs is None and sizes is None:
            bufs = []
        if bufs is not None:
            bufs = [_as_u8(b) for b in bufs]
        if sizes is None:
            sizes = [int(b.size) for b in bufs] if bufs is not None else []
        flags = ((self.flags & (FLAG_SERIALIZED | FLAG_ZERO_COPY))
                 | FLAG_REPLY | FLAG_STREAM
                 | (FLAG_STREAM_END if end else 0))
        return Frame(self.call_id, self.method, flags,
                     tuple(int(s) for s in sizes), bufs, seq=seq,
                     trace_id=self.trace_id)


def make_frame(call_id: int, method: str, bufs: Optional[List[np.ndarray]],
               *, sizes: Optional[Sequence[int]] = None,
               serialized: bool = False, wire_mode: Optional[str] = None,
               one_way: bool = False,
               stream: bool = False, stream_end: bool = False,
               reply: bool = False, seq: int = 0,
               budget_us: int = 0) -> Frame:
    if sizes is None:
        assert bufs is not None, "spec-only frames need explicit sizes"
        sizes = [int(b.size) for b in bufs]
    assert all(s >= 0 for s in sizes), sizes
    bufs = [_as_u8(b) for b in bufs] if bufs is not None else None
    flags = (_mode_flags(serialized, wire_mode)
             | (FLAG_ONE_WAY if one_way else 0)
             | (FLAG_STREAM if stream else 0)
             | (FLAG_STREAM_END if stream_end else 0)
             | (FLAG_REPLY if reply else 0))
    return Frame(call_id, method_id(method), flags, tuple(int(s)
                                                          for s in sizes),
                 bufs, seq=seq, budget_us=budget_us)


def stream_chunk(call_id: int, method: str,
                 bufs: Optional[List[np.ndarray]], *, seq: int,
                 end: bool = False, serialized: bool = False,
                 wire_mode: Optional[str] = None,
                 one_way: bool = False, reply: bool = False,
                 sizes: Optional[Sequence[int]] = None) -> Frame:
    """One chunk of a stream: FLAG_STREAM + running seq; the last chunk
    of a direction carries FLAG_STREAM_END. ``bufs=None`` with no sizes
    is the bare END trailer (a header-only frame)."""
    if bufs is None and sizes is None:
        bufs = []
    return make_frame(call_id, method, bufs, sizes=sizes,
                      serialized=serialized, wire_mode=wire_mode,
                      one_way=one_way, stream=True,
                      stream_end=end, reply=reply, seq=seq)


# ---------------------------------------------------------------------------
# header
# ---------------------------------------------------------------------------

# MAGIC, call_id, method, flags, seq, budget_us, trace_id, n_buffers
_FIXED_WORDS = 8


def header_bytes(frame: Frame) -> np.ndarray:
    """Little-endian uint32 header, zero-padded to a LANE multiple."""
    words = [MAGIC, frame.call_id, frame.method, frame.flags, frame.seq,
             frame.budget_us, frame.trace_id, frame.n_buffers,
             *frame.sizes]
    raw = np.asarray(words, dtype="<u4").view(np.uint8)
    out = np.zeros(_pad128(raw.size), dtype=np.uint8)
    out[:raw.size] = raw
    return out


def parse_header(data: np.ndarray) -> Tuple[Frame, int]:
    """Parse a header prefix -> (spec-only Frame, header length in bytes).

    Raises :class:`FramingError` on a truncated header block or a
    corrupt ``n_buffers`` word that claims more size words than the
    wire buffer holds (previously this silently yielded a short
    ``sizes`` tuple)."""
    if data.size < LANE:
        raise FramingError(
            f"truncated wire buffer: {data.size} bytes, header needs "
            f"at least {LANE}")
    head = np.ascontiguousarray(data[:LANE]).view("<u4")
    assert int(head[0]) == MAGIC, f"bad frame magic {int(head[0]):#x}"
    call_id, method, flags, seq, budget_us, trace_id, n = (
        int(head[1]), int(head[2]), int(head[3]), int(head[4]),
        int(head[5]), int(head[6]), int(head[7]))
    hdr_len = _pad128((_FIXED_WORDS + n) * _WORD)
    if hdr_len > data.size:
        raise FramingError(
            f"corrupt n_buffers={n}: header claims {hdr_len} bytes but "
            f"wire buffer holds only {data.size}")
    if hdr_len <= LANE:        # common case: sizes fit the first lane
        words = head
    else:
        words = np.ascontiguousarray(data[:hdr_len]).view("<u4")
    sizes = tuple(int(s) for s in words[_FIXED_WORDS:_FIXED_WORDS + n])
    return Frame(call_id, method, flags, sizes, None, seq=seq,
                 budget_us=budget_us, trace_id=trace_id), hdr_len


# ---------------------------------------------------------------------------
# wire encode / decode
# ---------------------------------------------------------------------------

def _pack_numpy(bufs: List[np.ndarray]) -> np.ndarray:
    """Byte-identical host-side layout of the pack kernel: each buffer
    zero-padded to the 128-byte lane (a zero-size buffer becomes one
    zero lane), then concatenated. One preallocated output with slice
    copies — no per-buffer ``np.pad``/``np.concatenate`` temporaries."""
    total = 0
    offsets = []
    for b in bufs:
        offsets.append(total)
        total += _pad128(b.size)
    out = np.zeros(total, dtype=np.uint8)
    for b, off in zip(bufs, offsets):
        if b.size:
            out[off:off + b.size] = b
    return out


def _unpack_numpy(wire: np.ndarray, sizes: Sequence[int]
                  ) -> List[np.ndarray]:
    out, off = [], 0
    for s in sizes:
        out.append(np.asarray(wire[off:off + s]))
        off += _pad128(s)
    return out


def _check_backend(backend: str) -> None:
    if backend not in ("numpy", "kernel"):
        raise ValueError(f"unknown framing backend {backend!r}; "
                         f"expected 'numpy' or 'kernel'")


def _encode_descriptors(frame: Frame) -> np.ndarray:
    """Place every payload buffer into the shared pool and return the
    descriptor block: one ``(pool_id, offset, size)`` ``<u8`` triple per
    buffer, viewed as uint8 wire bytes."""
    pool = get_pool()
    desc = np.zeros(3 * len(frame.bufs), dtype="<u8")
    for i, b in enumerate(frame.bufs):
        # pin the slot to the call: the fabric releases it when the
        # call completes (free-on-complete), so a wrap can never tear
        # bytes an in-flight receiver still views
        offset, size = pool.place(b, owner=frame.call_id)
        desc[3 * i] = pool.pool_id
        desc[3 * i + 1] = offset
        desc[3 * i + 2] = size
    return desc.view(np.uint8)


def _decode_descriptors(head: Frame, desc_msg: np.ndarray
                        ) -> List[np.ndarray]:
    """Resolve a descriptor block back to payload views (pool
    read-back). Sizes must match the header's size words."""
    desc = np.ascontiguousarray(desc_msg).view("<u8")
    if desc.size != 3 * head.n_buffers:
        raise FramingError(
            f"descriptor block has {desc.size // 3} triples for "
            f"{head.n_buffers} buffers")
    bufs = []
    for i, want in enumerate(head.sizes):
        pid = int(desc[3 * i])
        offset = int(desc[3 * i + 1])
        size = int(desc[3 * i + 2])
        if size != want:
            raise FramingError(
                f"descriptor {i} size {size} != header size {want}")
        bufs.append(get_pool(pid).read(offset, size))
    return bufs


def encode(frame: Frame, *, backend: str = "numpy") -> List[np.ndarray]:
    """Frame -> wire messages (list of uint8 arrays).

    serialized: one message [header | packed payload]; the coalescing
    copy runs through the payload_pack kernel (backend="kernel") or the
    equivalent numpy path (backend="numpy") — identical bytes either way.
    scatter_gather: [header, buf_0, .., buf_{n-1}] untouched.
    zero_copy: [header, descriptor block]; the payload bytes go into
    the shared pool (sender-managed placement), never onto the wire.
    """
    _check_backend(backend)
    assert frame.bufs is not None, "cannot encode a spec-only frame"
    hdr = header_bytes(frame)
    if frame.zero_copy:
        return [hdr, _encode_descriptors(frame)]
    if not frame.serialized:
        return [hdr] + list(frame.bufs)
    parts = [hdr] + list(frame.bufs)
    # the pack kernel wants non-empty operands; zero-size buffers (legal
    # in stream chunks) take the byte-identical numpy layout instead
    if backend == "kernel" and all(p.size > 0 for p in parts):
        from repro.kernels.payload_pack import pack as kpack
        import jax.numpy as jnp
        packed, _ = kpack([jnp.asarray(b) for b in parts])
        # kernel output is already the lane-padded concatenation
        return [np.asarray(packed)]
    return [_pack_numpy(parts)]


def decode(messages: List[np.ndarray], *, backend: str = "numpy") -> Frame:
    """Wire messages -> Frame (byte-identical round trip of encode).
    Zero-copy frames resolve their descriptors to views into the shared
    pool — valid until the sender's placement cursor laps the slot."""
    _check_backend(backend)
    head, hdr_len = parse_header(messages[0])
    if head.zero_copy:
        assert len(messages) == 2, \
            "zero-copy frame is header + descriptor block"
        return replace(head, bufs=_decode_descriptors(head, messages[1]))
    if not head.serialized:
        bufs = [_as_u8(m[:s]) for m, s in zip(messages[1:], head.sizes)]
        return replace(head, bufs=bufs)
    assert len(messages) == 1, "serialized frame is one wire message"
    wire = messages[0]
    sizes = [hdr_len] + list(head.sizes)
    if backend == "kernel" and all(s > 0 for s in sizes):
        from repro.kernels.payload_pack import unpack as kunpack
        import jax.numpy as jnp
        parts = [np.asarray(p) for p in kunpack(jnp.asarray(wire), sizes)]
    else:
        parts = _unpack_numpy(wire, sizes)
    return replace(head, bufs=parts[1:])
