"""Client/server interceptor chains for the RPC fabric (the gRPC
interceptor analogue), threaded through the completion queue.

A *client* interceptor observes every call made through a fabric:
``on_start`` when the first frame is submitted, ``on_event`` for every
completion-queue event the call produces, ``on_complete`` once when the
call reaches a terminal state (the chain's ``on_complete`` unwinds
first, then the terminal event itself reaches the cq and ``on_event`` —
uniformly for success, error, and deadline outcomes). The chain nests like gRPC's: for
``fabric.client_interceptors = [outer, inner]`` the start hooks run
outer->inner and the completion hooks unwind inner->outer; an
interceptor that answers ``"retry"`` from ``on_complete`` consumes the
failure — interceptors outer to it never see the failed attempt, only
the final outcome.

A *server* interceptor brackets handler dispatch on every endpoint the
fabric creates after it is installed: ``on_admit`` when a call opens
(outer->inner; the first hook to answer with an error string rejects
the call with a transient ``resource exhausted`` reply before the
handler ever runs), ``on_receive`` before the handler runs
(outer->inner), ``on_done`` after (inner->outer, with the fault carried
when the handler raised), and ``on_shed`` when the server drops a call
whose propagated deadline budget the wire already consumed.

The stock interceptors cover the bookkeeping the paper's §2.2 calls
out as part of the RPC interface layer itself:

  MetricsInterceptor    per-method call counts + latency percentiles
                        (and stream chunk counts), measured on the
                        fabric clock — wall time for measured
                        transports, the transport's modeled clock for
                        simulated ones. Server-side it additionally
                        tracks the per-endpoint queue depth the fabric
                        computed for each flight — the load signal
                        admission control feeds on — plus shed and
                        admission-rejection counts.
  DeadlineInterceptor   applies a default deadline to calls that set
                        none and counts ``deadline_exceeded`` events;
                        the fabric enforces deadlines (cancelling
                        stalled calls and dropping their gated chunks)
                        and propagates the remaining budget to servers
                        in the frame header.
  RetryInterceptor      resubmits calls that failed with a transient
                        error (``TransientError`` on the server, "no
                        server at endpoint", an injected link fault, or
                        an admission rejection): unary calls, and —
                        transparently — server-stream calls iff zero
                        response chunks were delivered. Retries are
                        budget-aware: the original deadline keeps
                        running and a retry that cannot fit in the
                        remaining budget is never attempted.
  AdmissionInterceptor  server-side admission control: rejects a call
                        with ``ResourceExhausted`` when its endpoint is
                        over the configured outstanding-call limit.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.rpc import framing
from repro.rpc.completion import Event
from repro.rpc.telemetry import HistogramRegistry


class TransientError(Exception):
    """Raise from a handler to mark the failure retryable: the error
    reply is prefixed ``TRANSIENT:`` and a RetryInterceptor resubmits
    the call."""


class ResourceExhausted(TransientError):
    """The server refused the call because the endpoint is over its
    admission limit (gRPC's RESOURCE_EXHAUSTED). Transient by
    construction: retrying — ideally on another shard, which is what
    ``ShardedServeStub``'s failover does — is the correct response."""


TRANSIENT_PREFIX = "TRANSIENT:"
RESOURCE_EXHAUSTED = "resource exhausted"


@dataclass
class CallContext:
    """Per-call state shared by the fabric and the client chain."""
    call_id: int
    method: str
    kind: str                      # fabric.UNARY/.CLIENT_STREAM/...
    dst: int
    start_s: float                 # fabric clock at submit
    channel: Any = None
    deadline_s: Optional[float] = None   # absolute fabric-clock time
    end_s: Optional[float] = None
    attempts: int = 1
    chunks: int = 0                # response stream chunks delivered
    #: distributed-tracing context (0 = untraced): assigned by the
    #: fabric's Tracer at call start, stamped into the frame header at
    #: flight departure, stable across retries and failover re-routes
    trace_id: int = 0
    # retained for retries (unary + server-stream; bufs caller-owned)
    request: Optional[framing.Frame] = None
    #: sent chunk frames of a client-stream/bidi call, retained (up to
    #: the fabric's ``retry_buffer_chunks``) so a retry can replay the
    #: whole stream; None once the bound is exceeded (sticky
    #: ``meta["buffer_overflow"]`` marks that) or for unary calls
    request_chunks: Optional[List[framing.Frame]] = None
    meta: Dict[str, Any] = field(default_factory=dict)


@dataclass
class ServerContext:
    """Per-dispatch state shared by the server chain."""
    endpoint: int
    call_id: int
    method: str
    kind: str
    start_s: float
    #: absolute fabric-clock deadline recovered from the frame's
    #: propagated budget (None when the call carried no deadline)
    deadline_s: Optional[float] = None
    #: the fabric's load signal for this dispatch: request frames that
    #: landed on this endpoint so far in the current flight (including
    #: this one) plus the server's open partial streams
    queue_depth: int = 0
    clock: Optional[Callable[[], float]] = None
    meta: Dict[str, Any] = field(default_factory=dict)

    def time_remaining(self) -> Optional[float]:
        """Remaining propagated deadline budget in seconds on the
        fabric clock (None without a deadline, 0.0 once expired) — the
        gRPC ``context.time_remaining()`` analogue handlers and server
        interceptors shed doomed work against."""
        if self.deadline_s is None:
            return None
        now = self.clock() if self.clock is not None else self.start_s
        return max(0.0, self.deadline_s - now)


class ClientInterceptor:
    def on_start(self, ctx: CallContext) -> None:
        pass

    def on_event(self, ctx: CallContext, event: Event) -> None:
        pass

    def on_complete(self, ctx: CallContext, event: Event
                    ) -> Optional[str]:
        """Terminal hook; return ``"retry"`` to consume the failure and
        resubmit (unary calls only)."""
        return None


class ServerInterceptor:
    def on_admit(self, ctx: ServerContext) -> Optional[str]:
        """Admission hook, run outer->inner when a call OPENS at the
        server (unary frames and the first chunk of a stream), before
        the handler. Return an error string to reject the call with a
        transient ``resource exhausted`` reply; None admits."""
        return None

    def on_receive(self, ctx: ServerContext) -> None:
        pass

    def on_done(self, ctx: ServerContext, ok: bool,
                error: Optional[str] = None) -> None:
        pass

    def on_shed(self, ctx: ServerContext) -> None:
        """The server dropped this call before the handler ran: its
        propagated deadline budget was already spent on the wire."""


def is_transient(error: Optional[str]) -> bool:
    """Transient = a server fault raised as TransientError (the reply
    text is prefixed ``TRANSIENT:`` by the fabric's fault path), a
    not-yet-registered endpoint, or an injected link fault. Matched at
    the start only, so a permanent error that merely *quotes* a
    transient one is not retried."""
    return bool(error) and (error.startswith(TRANSIENT_PREFIX)
                            or error.startswith("no server at endpoint"))


def is_resource_exhausted(error: Optional[str]) -> bool:
    """An admission-control rejection (or a handler-raised
    ``ResourceExhausted``): transient, but retrying the SAME endpoint
    is pointless until load drains — the signal ``ShardedServeStub``
    fails over to another PS shard on."""
    return bool(error) and error.startswith(TRANSIENT_PREFIX) \
        and RESOURCE_EXHAUSTED in error


# ---------------------------------------------------------------------------
# stock interceptors
# ---------------------------------------------------------------------------

class MetricsInterceptor(ClientInterceptor, ServerInterceptor):
    """Per-method call counts and latency percentiles, for free on every
    stub call. Client side: one record per call attempt's terminal
    event, latency on the fabric clock. Server side (install in
    ``fabric.server_interceptors``): handler invocation counts under a
    ``server:`` key prefix.

    With ``per_endpoint=True`` every client-side record is additionally
    kept under ``method@src->dst`` (and server dispatches under
    ``server:method@endpoint``), so interleaved calls from several
    client endpoints get separate counts and percentiles — the
    per-endpoint breakdown a cluster run reports. ``endpoint_name``
    labels the endpoints (a cluster transport's ``endpoint_name``
    renders names instead of indices).

    Latency distributions live in a :class:`telemetry.HistogramRegistry`
    (one bounded histogram per method key — exact percentiles for small
    runs, log-bucketed constant memory past
    ``telemetry.EXACT_CAP`` samples, instead of the unbounded per-call
    list this class used to keep). Pass ``registry=`` to share one sink
    across several interceptors; ``histogram(method)`` exposes the full
    distribution (p999 etc.) beyond the 4 percentiles ``snapshot()``
    reports."""

    def __init__(self, *, per_endpoint: bool = False,
                 endpoint_name: Optional[Callable[[int], str]] = None,
                 registry: Optional[HistogramRegistry] = None):
        self.per_endpoint = per_endpoint
        self._ep_name = endpoint_name or str
        self.registry = registry if registry is not None \
            else HistogramRegistry()
        self._recs: Dict[str, Dict[str, Any]] = {}
        # per-endpoint queue depth, refreshed by on_admit each dispatch
        # — the load signal an AdmissionInterceptor installed INNER to
        # this one feeds on
        self._depth: Dict[int, int] = {}
        # live gauge providers merged into snapshot() under their own
        # keys (e.g. a serve scheduler publishing admission counters)
        self._gauges: Dict[str, Callable[[], Dict[str, Any]]] = {}

    def _rec(self, method: str) -> Dict[str, Any]:
        return self._recs.setdefault(method, {
            "calls": 0, "ok": 0, "errors": 0, "deadline_exceeded": 0,
            "retries": 0, "chunks": 0, "shed": 0, "rejected": 0})

    def histogram(self, method: str):
        """The method's full latency distribution (a
        :class:`telemetry.BoundedHistogram`, seconds; None before the
        first completion)."""
        return self.registry.get("latency:" + method)

    def _client_keys(self, ctx: CallContext) -> List[str]:
        keys = [ctx.method]
        if self.per_endpoint and ctx.channel is not None:
            keys.append(f"{ctx.method}@{self._ep_name(ctx.channel.src)}"
                        f"->{self._ep_name(ctx.channel.dst)}")
        return keys

    def reset(self) -> None:
        """Discard everything recorded so far (benchmarks call this
        after warmup so compile/warmup calls don't pollute the
        published percentiles)."""
        for k in self._recs:
            self.registry.remove("latency:" + k)
        self._recs.clear()
        self._depth.clear()

    # client side --------------------------------------------------------
    def on_start(self, ctx: CallContext) -> None:
        for k in self._client_keys(ctx):
            self._rec(k)["calls"] += 1

    def on_event(self, ctx: CallContext, event: Event) -> None:
        for k in self._client_keys(ctx):
            if event.kind == "stream_chunk":
                self._rec(k)["chunks"] += 1
            elif event.kind == "retry":
                self._rec(k)["retries"] += 1
                self._rec(k)["calls"] += 1     # the new attempt

    def on_complete(self, ctx: CallContext, event: Event
                    ) -> Optional[str]:
        for k in self._client_keys(ctx):
            rec = self._rec(k)
            if event.kind == "deadline_exceeded":
                rec["deadline_exceeded"] += 1
            if event.ok:
                rec["ok"] += 1
            else:
                rec["errors"] += 1
            if ctx.end_s is not None:
                self.registry.hist("latency:" + k).record(
                    ctx.end_s - ctx.start_s)
        return None

    # server side --------------------------------------------------------
    def _server_keys(self, ctx: ServerContext) -> List[str]:
        keys = ["server:" + ctx.method]
        if self.per_endpoint:
            keys.append(f"server:{ctx.method}"
                        f"@{self._ep_name(ctx.endpoint)}")
        return keys

    def on_admit(self, ctx: ServerContext) -> Optional[str]:
        self._depth[ctx.endpoint] = ctx.queue_depth
        for k in self._server_keys(ctx):
            rec = self._rec(k)
            rec["queue_peak"] = max(rec.get("queue_peak", 0),
                                    ctx.queue_depth)
        return None

    def server_queue_depth(self, endpoint: int) -> int:
        """The endpoint's load at its most recent dispatch (request
        frames landed this flight + open partial streams) — what an
        AdmissionInterceptor installed inner to this one reads."""
        return self._depth.get(endpoint, 0)

    def record_rejection(self, ctx: ServerContext) -> None:
        for k in self._server_keys(ctx):
            self._rec(k)["rejected"] += 1

    def on_shed(self, ctx: ServerContext) -> None:
        for k in self._server_keys(ctx):
            self._rec(k)["shed"] += 1

    def on_receive(self, ctx: ServerContext) -> None:
        for k in self._server_keys(ctx):
            self._rec(k)["calls"] += 1

    def on_done(self, ctx: ServerContext, ok: bool,
                error: Optional[str] = None) -> None:
        for k in self._server_keys(ctx):
            self._rec(k)["ok" if ok else "errors"] += 1

    def attach_gauges(self, key: str,
                      fn: Callable[[], Dict[str, Any]]) -> None:
        """Register a live gauge provider: ``snapshot(gauges=True)``
        calls ``fn`` and reports its dict under ``key`` alongside the
        per-method records. A serve scheduler attaches its
        admission/preemption counters here so they surface in
        ``rpc_metrics`` output."""
        self._gauges[key] = fn

    def gauges(self) -> Dict[str, Dict[str, Any]]:
        """Live gauge readings alone, keyed by provider."""
        return {key: dict(fn()) for key, fn in self._gauges.items()}

    # reporting ----------------------------------------------------------
    def snapshot(self, *, gauges: bool = False
                 ) -> Dict[str, Dict[str, Any]]:
        """JSON-ready per-method summary with latency percentiles.
        ``gauges=True`` folds in attached gauge providers (whose
        records have their own shapes, not the per-method schema)."""
        out: Dict[str, Dict[str, Any]] = {}
        if gauges:
            out.update(self.gauges())
        for method, rec in self._recs.items():
            row = dict(rec)
            h = self.registry.get("latency:" + method)
            if h is not None and h.count:
                row["latency_us"] = {
                    "mean": h.mean * 1e6,
                    "p50": h.percentile(50) * 1e6,
                    "p95": h.percentile(95) * 1e6,
                    "p99": h.percentile(99) * 1e6,
                }
            out[method] = row
        return out


class DeadlineInterceptor(ClientInterceptor):
    """Applies ``default_deadline_s`` (relative) to calls that set no
    deadline and counts deadline-exceeded completions. Enforcement —
    cancelling the call, failing its handle, dropping its window-stalled
    chunks — lives in the fabric's flush loop, which honors
    ``ctx.deadline_s`` wherever it was set from."""

    def __init__(self, default_deadline_s: Optional[float] = None):
        self.default_deadline_s = default_deadline_s
        self.exceeded = 0

    def on_start(self, ctx: CallContext) -> None:
        if ctx.deadline_s is None and self.default_deadline_s is not None:
            ctx.deadline_s = ctx.start_s + self.default_deadline_s

    def on_complete(self, ctx: CallContext, event: Event
                    ) -> Optional[str]:
        if event.kind == "deadline_exceeded":
            self.exceeded += 1
        return None


class RetryInterceptor(ClientInterceptor):
    """Retries calls that failed transiently, up to ``max_attempts``
    total attempts — the full call-kind matrix:

      unary          always (the request frame is retained)
      server_stream  iff ZERO response chunks have been delivered
                     (re-issuing then cannot duplicate anything the
                     caller observed)
      client_stream  iff the fabric's bounded client-side chunk buffer
                     (``RpcFabric(retry_buffer_chunks=...)``) still
                     holds every sent chunk — the whole stream is
                     replayed under a fresh call id
      bidi           same buffer condition, and additionally zero
                     response chunks delivered (like server_stream)

    A transient failure whose sent-chunk buffer overflowed is NOT
    retried; ``gave_up_buffer`` counts those. The retry consumes the
    failure: interceptors outer to this one see only the final outcome.

    Retries respect the call's ORIGINAL deadline — the budget keeps
    running across attempts, never resets — and back off
    ``backoff_s * backoff_multiplier**(attempt-1)`` seconds on the
    fabric clock between attempts. A retry whose backoff alone would
    outlive the remaining budget is not attempted at all
    (``gave_up_budget`` counts those)."""

    def __init__(self, max_attempts: int = 3,
                 retry_on: Callable[[Optional[str]], bool] = is_transient,
                 *, backoff_s: float = 0.0,
                 backoff_multiplier: float = 2.0):
        assert max_attempts >= 1
        assert backoff_s >= 0.0 and backoff_multiplier >= 1.0
        self.max_attempts = max_attempts
        self.retry_on = retry_on
        self.backoff_s = backoff_s
        self.backoff_multiplier = backoff_multiplier
        self.retries = 0
        self.gave_up_budget = 0
        self.gave_up_buffer = 0

    def on_complete(self, ctx: CallContext, event: Event
                    ) -> Optional[str]:
        if event.kind != "error":
            return None
        if ctx.meta.get("buffer_overflow"):
            # client-stream/bidi whose sent chunks outgrew the bounded
            # retry buffer: a replay is impossible, give up loudly
            if self.retry_on(ctx.meta.get("error")):
                self.gave_up_buffer += 1
            return None
        if ctx.request is None:
            return None
        if ctx.kind in ("server_stream", "bidi") and ctx.chunks > 0:
            return None        # mid-stream: a re-issue would duplicate
        if ctx.attempts >= self.max_attempts \
                or not self.retry_on(ctx.meta.get("error")):
            return None
        delay = self.backoff_s \
            * self.backoff_multiplier ** (ctx.attempts - 1)
        if ctx.deadline_s is not None:
            now = ctx.end_s if ctx.end_s is not None else ctx.start_s
            if now + delay >= ctx.deadline_s:
                self.gave_up_budget += 1
                return None    # doomed: cannot finish inside the budget
        if delay > 0.0:
            ctx.meta["retry_backoff_s"] = delay
        self.retries += 1
        return "retry"


class AdmissionInterceptor(ServerInterceptor):
    """Server-side admission control: reject a call when its endpoint
    is over its outstanding-call limit, with a transient
    ``resource exhausted`` error — clients retry it (later flights see
    a drained queue) or, through ``ShardedServeStub``'s failover, move
    it to another PS shard.

    The load signal is fed by a server-side :class:`MetricsInterceptor`
    installed OUTER to this one (its ``on_admit`` records the queue
    depth the fabric computed before this hook runs); without one the
    interceptor reads the context's own ``queue_depth`` directly.
    ``limit`` is the default per-endpoint cap; ``limits`` overrides it
    per endpoint index (e.g. a ClusterSpec endpoint's advertised
    ``admission_limit``). ``None`` means unlimited."""

    def __init__(self, limit: Optional[int] = None, *,
                 metrics: Optional[MetricsInterceptor] = None,
                 limits: Optional[Dict[int, int]] = None):
        assert limit is None or limit >= 1, limit
        assert all(v >= 1 for v in (limits or {}).values()), limits
        self.limit = limit
        self.metrics = metrics
        self.limits = dict(limits or {})
        self.rejected = 0

    def limit_for(self, endpoint: int) -> Optional[int]:
        return self.limits.get(endpoint, self.limit)

    def on_admit(self, ctx: ServerContext) -> Optional[str]:
        limit = self.limit_for(ctx.endpoint)
        if limit is None:
            return None
        depth = (self.metrics.server_queue_depth(ctx.endpoint)
                 if self.metrics is not None else ctx.queue_depth)
        if depth <= limit:
            return None
        self.rejected += 1
        if self.metrics is not None:
            self.metrics.record_rejection(ctx)
        return (f"{RESOURCE_EXHAUSTED}: endpoint {ctx.endpoint} over "
                f"admission limit ({depth} > {limit})")


__all__ = [
    "AdmissionInterceptor", "CallContext", "ClientInterceptor",
    "DeadlineInterceptor", "MetricsInterceptor", "ResourceExhausted",
    "RetryInterceptor", "RESOURCE_EXHAUSTED", "ServerContext",
    "ServerInterceptor", "TransientError", "TRANSIENT_PREFIX",
    "is_resource_exhausted", "is_transient",
]
