"""Client/server interceptor chains for the RPC fabric (the gRPC
interceptor analogue), threaded through the completion queue.

A *client* interceptor observes every call made through a fabric:
``on_start`` when the first frame is submitted, ``on_event`` for every
completion-queue event the call produces, ``on_complete`` once when the
call reaches a terminal state (the chain's ``on_complete`` unwinds
first, then the terminal event itself reaches the cq and ``on_event`` —
uniformly for success, error, and deadline outcomes). The chain nests like gRPC's: for
``fabric.client_interceptors = [outer, inner]`` the start hooks run
outer->inner and the completion hooks unwind inner->outer; an
interceptor that answers ``"retry"`` from ``on_complete`` consumes the
failure — interceptors outer to it never see the failed attempt, only
the final outcome.

A *server* interceptor brackets handler dispatch on every endpoint the
fabric creates after it is installed: ``on_receive`` before the handler
runs (outer->inner), ``on_done`` after (inner->outer), with the fault
carried when the handler raised.

Three stock interceptors cover the bookkeeping the paper's §2.2 calls
out as part of the RPC interface layer itself:

  MetricsInterceptor   per-method call counts + latency percentiles
                       (and stream chunk counts), measured on the
                       fabric clock — wall time for measured
                       transports, the transport's modeled clock for
                       simulated ones.
  DeadlineInterceptor  applies a default deadline to calls that set
                       none and counts ``deadline_exceeded`` events;
                       the fabric enforces deadlines (cancelling
                       stalled calls and dropping their gated chunks).
  RetryInterceptor     resubmits unary calls that failed with a
                       transient error (``TransientError`` on the
                       server, or "no server at endpoint").
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.rpc import framing
from repro.rpc.completion import Event


class TransientError(Exception):
    """Raise from a handler to mark the failure retryable: the error
    reply is prefixed ``TRANSIENT:`` and a RetryInterceptor resubmits
    the call."""


TRANSIENT_PREFIX = "TRANSIENT:"


@dataclass
class CallContext:
    """Per-call state shared by the fabric and the client chain."""
    call_id: int
    method: str
    kind: str                      # fabric.UNARY/.CLIENT_STREAM/...
    dst: int
    start_s: float                 # fabric clock at submit
    channel: Any = None
    deadline_s: Optional[float] = None   # absolute fabric-clock time
    end_s: Optional[float] = None
    attempts: int = 1
    # retained for retries (unary only; the bufs are caller-owned)
    request: Optional[framing.Frame] = None
    meta: Dict[str, Any] = field(default_factory=dict)


@dataclass
class ServerContext:
    """Per-dispatch state shared by the server chain."""
    endpoint: int
    call_id: int
    method: str
    kind: str
    start_s: float
    meta: Dict[str, Any] = field(default_factory=dict)


class ClientInterceptor:
    def on_start(self, ctx: CallContext) -> None:
        pass

    def on_event(self, ctx: CallContext, event: Event) -> None:
        pass

    def on_complete(self, ctx: CallContext, event: Event
                    ) -> Optional[str]:
        """Terminal hook; return ``"retry"`` to consume the failure and
        resubmit (unary calls only)."""
        return None


class ServerInterceptor:
    def on_receive(self, ctx: ServerContext) -> None:
        pass

    def on_done(self, ctx: ServerContext, ok: bool,
                error: Optional[str] = None) -> None:
        pass


def is_transient(error: Optional[str]) -> bool:
    """Transient = a server fault raised as TransientError (the reply
    text is prefixed ``TRANSIENT:`` by the fabric's fault path) or a
    not-yet-registered endpoint. Matched at the start only, so a
    permanent error that merely *quotes* a transient one is not
    retried."""
    return bool(error) and (error.startswith(TRANSIENT_PREFIX)
                            or error.startswith("no server at endpoint"))


# ---------------------------------------------------------------------------
# stock interceptors
# ---------------------------------------------------------------------------

class MetricsInterceptor(ClientInterceptor, ServerInterceptor):
    """Per-method call counts and latency percentiles, for free on every
    stub call. Client side: one record per call attempt's terminal
    event, latency on the fabric clock. Server side (install in
    ``fabric.server_interceptors``): handler invocation counts under a
    ``server:`` key prefix.

    With ``per_endpoint=True`` every client-side record is additionally
    kept under ``method@src->dst`` (and server dispatches under
    ``server:method@endpoint``), so interleaved calls from several
    client endpoints get separate counts and percentiles — the
    per-endpoint breakdown a cluster run reports. ``endpoint_name``
    labels the endpoints (a cluster transport's ``endpoint_name``
    renders names instead of indices)."""

    def __init__(self, *, per_endpoint: bool = False,
                 endpoint_name: Optional[Callable[[int], str]] = None):
        self.per_endpoint = per_endpoint
        self._ep_name = endpoint_name or str
        self._recs: Dict[str, Dict[str, Any]] = {}

    def _rec(self, method: str) -> Dict[str, Any]:
        return self._recs.setdefault(method, {
            "calls": 0, "ok": 0, "errors": 0, "deadline_exceeded": 0,
            "retries": 0, "chunks": 0, "latencies_s": []})

    def _client_keys(self, ctx: CallContext) -> List[str]:
        keys = [ctx.method]
        if self.per_endpoint and ctx.channel is not None:
            keys.append(f"{ctx.method}@{self._ep_name(ctx.channel.src)}"
                        f"->{self._ep_name(ctx.channel.dst)}")
        return keys

    def reset(self) -> None:
        """Discard everything recorded so far (benchmarks call this
        after warmup so compile/warmup calls don't pollute the
        published percentiles)."""
        self._recs.clear()

    # client side --------------------------------------------------------
    def on_start(self, ctx: CallContext) -> None:
        for k in self._client_keys(ctx):
            self._rec(k)["calls"] += 1

    def on_event(self, ctx: CallContext, event: Event) -> None:
        for k in self._client_keys(ctx):
            if event.kind == "stream_chunk":
                self._rec(k)["chunks"] += 1
            elif event.kind == "retry":
                self._rec(k)["retries"] += 1
                self._rec(k)["calls"] += 1     # the new attempt

    def on_complete(self, ctx: CallContext, event: Event
                    ) -> Optional[str]:
        for k in self._client_keys(ctx):
            rec = self._rec(k)
            if event.kind == "deadline_exceeded":
                rec["deadline_exceeded"] += 1
            if event.ok:
                rec["ok"] += 1
            else:
                rec["errors"] += 1
            if ctx.end_s is not None:
                rec["latencies_s"].append(ctx.end_s - ctx.start_s)
        return None

    # server side --------------------------------------------------------
    def _server_keys(self, ctx: ServerContext) -> List[str]:
        keys = ["server:" + ctx.method]
        if self.per_endpoint:
            keys.append(f"server:{ctx.method}"
                        f"@{self._ep_name(ctx.endpoint)}")
        return keys

    def on_receive(self, ctx: ServerContext) -> None:
        for k in self._server_keys(ctx):
            self._rec(k)["calls"] += 1

    def on_done(self, ctx: ServerContext, ok: bool,
                error: Optional[str] = None) -> None:
        for k in self._server_keys(ctx):
            self._rec(k)["ok" if ok else "errors"] += 1

    # reporting ----------------------------------------------------------
    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        """JSON-ready per-method summary with latency percentiles."""
        out: Dict[str, Dict[str, Any]] = {}
        for method, rec in self._recs.items():
            row = {k: v for k, v in rec.items() if k != "latencies_s"}
            lat = rec["latencies_s"]
            if lat:
                a = np.asarray(lat) * 1e6
                row["latency_us"] = {
                    "mean": float(a.mean()),
                    "p50": float(np.percentile(a, 50)),
                    "p95": float(np.percentile(a, 95)),
                    "p99": float(np.percentile(a, 99)),
                }
            out[method] = row
        return out


class DeadlineInterceptor(ClientInterceptor):
    """Applies ``default_deadline_s`` (relative) to calls that set no
    deadline and counts deadline-exceeded completions. Enforcement —
    cancelling the call, failing its handle, dropping its window-stalled
    chunks — lives in the fabric's flush loop, which honors
    ``ctx.deadline_s`` wherever it was set from."""

    def __init__(self, default_deadline_s: Optional[float] = None):
        self.default_deadline_s = default_deadline_s
        self.exceeded = 0

    def on_start(self, ctx: CallContext) -> None:
        if ctx.deadline_s is None and self.default_deadline_s is not None:
            ctx.deadline_s = ctx.start_s + self.default_deadline_s

    def on_complete(self, ctx: CallContext, event: Event
                    ) -> Optional[str]:
        if event.kind == "deadline_exceeded":
            self.exceeded += 1
        return None


class RetryInterceptor(ClientInterceptor):
    """Retries unary calls that failed transiently, up to
    ``max_attempts`` total attempts. The retry consumes the failure:
    interceptors outer to this one see only the final outcome."""

    def __init__(self, max_attempts: int = 3,
                 retry_on: Callable[[Optional[str]], bool] = is_transient):
        assert max_attempts >= 1
        self.max_attempts = max_attempts
        self.retry_on = retry_on
        self.retries = 0

    def on_complete(self, ctx: CallContext, event: Event
                    ) -> Optional[str]:
        if (event.kind == "error" and ctx.request is not None
                and ctx.attempts < self.max_attempts
                and self.retry_on(ctx.meta.get("error"))):
            self.retries += 1
            return "retry"
        return None


__all__ = [
    "CallContext", "ClientInterceptor", "DeadlineInterceptor",
    "MetricsInterceptor", "RetryInterceptor", "ServerContext",
    "ServerInterceptor", "TransientError", "TRANSIENT_PREFIX",
    "is_transient",
]
