"""Declarative service API for the RPC fabric (the gRPC service/stub
analogue).

A :class:`ServiceDef` names a set of :class:`MethodSpec`\\ s — method
name, cardinality kind, optional payload codecs. The server side binds
a whole service at once (``Server.add_service(service, handlers)``);
the client side gets a generated :class:`Stub` whose attributes are the
service's methods and whose invocations return call handles uniformly:

    GREETER = ServiceDef("Greeter", (
        MethodSpec("hello", UNARY),
        MethodSpec("stream_hello", SERVER_STREAM),
    ))

    fabric.add_server(1).add_service(GREETER, handlers)
    stub = fabric.stub(GREETER, src=0, dst=1)
    call = stub.hello([buf])                 # -> UnaryCall
    h = stub.stream_hello([buf])             # -> fabric.ServerStream
    fabric.flush(); call.result(); h.chunk_bufs()

Wire method names are ``"Service/method"`` (hashed through
``framing.method_id`` like every method). Each stub method accepts
``deadline_s`` (relative seconds, enforced by the fabric's flush loop)
and validates the invocation against the method's kind — invoking a
unary method as a stream raises a ``method-kind mismatch`` ValueError
on the client, before anything hits the wire.

Codecs are optional ``encode(obj) -> iovec list`` /
``decode(iovecs) -> obj`` pairs; with a request codec the stub method
takes the object, with a response codec ``UnaryCall.result()`` returns
the object. Without codecs everything is raw iovec buffer lists, the
benchmark-friendly path.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.rpc.fabric import (BIDI, CLIENT_STREAM, SERVER_STREAM, UNARY,
                              BidiStream, Call, Channel, RpcError,
                              ServerStream)

KINDS = (UNARY, CLIENT_STREAM, SERVER_STREAM, BIDI)


@dataclass(frozen=True)
class Codec:
    """Payload codec: python object <-> iovec buffer list."""
    encode: Callable[[Any], List[np.ndarray]]
    decode: Callable[[List[np.ndarray]], Any]


@dataclass(frozen=True)
class MethodSpec:
    """One method of a service: name, cardinality kind, codecs, and an
    optional default deadline (relative seconds) the stub applies to
    invocations that pass none — the declarative twin of a
    ``DeadlineInterceptor`` default, scoped to one method. The budget
    is propagated to the server in the frame header like any
    deadline."""
    name: str
    kind: str = UNARY
    request_codec: Optional[Codec] = None
    response_codec: Optional[Codec] = None
    deadline_s: Optional[float] = None

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(
                f"method {self.name!r}: unknown kind {self.kind!r}; "
                f"choose from {KINDS}")
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError(
                f"method {self.name!r}: deadline_s must be > 0, got "
                f"{self.deadline_s}")


@dataclass(frozen=True)
class ServiceDef:
    """A named set of methods; the unit of registration and stubbing."""
    name: str
    methods: Tuple[MethodSpec, ...]

    def __post_init__(self):
        seen = set()
        for m in self.methods:
            if m.name in seen:
                raise ValueError(f"service {self.name!r}: duplicate "
                                 f"method {m.name!r}")
            seen.add(m.name)

    def full_name(self, method: str) -> str:
        """The wire method name, gRPC-style ``Service/method``."""
        return f"{self.name}/{method}"

    def spec(self, method: str) -> MethodSpec:
        for m in self.methods:
            if m.name == method:
                return m
        raise ValueError(f"service {self.name!r} has no method "
                         f"{method!r}; methods: "
                         f"{[m.name for m in self.methods]}")


class UnaryCall:
    """Uniform client handle for unary and client-streaming calls:
    wraps the fabric's :class:`Call` future, decodes through the
    method's response codec, and can drive itself to completion."""

    def __init__(self, call: Call, channel: Channel, spec: MethodSpec):
        self._call = call
        self._channel = channel
        self._spec = spec

    @property
    def call_id(self) -> int:
        return self._call.call_id

    @property
    def done(self) -> bool:
        return self._call.done

    @property
    def error(self) -> Optional[str]:
        return self._call.error

    def result(self) -> Any:
        """Flush the fabric if needed, then return the decoded response
        (or the raw reply iovecs without a response codec). Raises
        :class:`RpcError` on error / deadline-exceeded."""
        if not self._call.done:
            self._channel.fabric.flush()
        bufs = self._call.reply_bufs()
        if self._spec.response_codec is not None:
            return self._spec.response_codec.decode(bufs)
        return bufs

    def reply_bufs(self) -> List[np.ndarray]:
        return self._call.reply_bufs()


class StubMethod:
    """One callable method of a stub. ``__call__`` dispatches on the
    spec's kind; the explicit per-kind invokers raise a
    ``method-kind mismatch`` ValueError when used against a method of
    another kind (the client-side twin of the server's cardinality
    check)."""

    def __init__(self, channel: Channel, service: ServiceDef,
                 spec: MethodSpec):
        self._channel = channel
        self._service = service
        self.spec = spec
        self.full_name = service.full_name(spec.name)

    def __call__(self, request: Any = None, **kw):
        return {UNARY: self.unary, CLIENT_STREAM: self.client_stream,
                SERVER_STREAM: self.server_stream,
                BIDI: self.bidi}[self.spec.kind](request, **kw)

    def _require(self, kind: str) -> None:
        if self.spec.kind != kind:
            raise ValueError(
                f"method-kind mismatch: {self.full_name} is "
                f"{self.spec.kind}, invoked as {kind}")

    def _encode(self, request: Any) -> Optional[List[np.ndarray]]:
        if request is None:
            return None
        if self.spec.request_codec is not None:
            return self.spec.request_codec.encode(request)
        return list(request)

    def _deadline(self, deadline_s: Optional[float]) -> Optional[float]:
        """A per-call deadline wins; otherwise the method's declared
        default (None = no deadline)."""
        return deadline_s if deadline_s is not None \
            else self.spec.deadline_s

    # per-kind invokers --------------------------------------------------
    def unary(self, request: Any = None, *,
              sizes: Optional[Sequence[int]] = None,
              one_way: bool = False,
              deadline_s: Optional[float] = None) -> UnaryCall:
        self._require(UNARY)
        call = self._channel.call(self.full_name, self._encode(request),
                                  sizes=sizes, one_way=one_way,
                                  deadline_s=self._deadline(deadline_s))
        return UnaryCall(call, self._channel, self.spec)

    def client_stream(self, chunks: Any = None, *,
                      sizes: Optional[Sequence[int]] = None,
                      n_chunks: Optional[int] = None,
                      one_way: bool = False,
                      deadline_s: Optional[float] = None) -> UnaryCall:
        """``chunks`` is a sequence of per-chunk requests (each run
        through the request codec); spec-only streams pass
        ``sizes`` + ``n_chunks`` instead."""
        self._require(CLIENT_STREAM)
        enc = ([self._encode(c) for c in chunks]
               if chunks is not None else [])
        call = self._channel.stream(self.full_name, enc, sizes=sizes,
                                    n_chunks=n_chunks, one_way=one_way,
                                    deadline_s=self._deadline(deadline_s))
        return UnaryCall(call, self._channel, self.spec)

    def server_stream(self, request: Any = None, *,
                      sizes: Optional[Sequence[int]] = None,
                      deadline_s: Optional[float] = None) -> ServerStream:
        self._require(SERVER_STREAM)
        return self._channel.server_stream(
            self.full_name, self._encode(request), sizes=sizes,
            deadline_s=self._deadline(deadline_s))

    def bidi(self, chunks: Any = None, *,
             deadline_s: Optional[float] = None) -> BidiStream:
        self._require(BIDI)
        enc = ([self._encode(c) for c in chunks]
               if chunks is not None else None)
        return self._channel.bidi_stream(
            self.full_name, enc, deadline_s=self._deadline(deadline_s))


class Stub:
    """Generated client for one service over one channel: an attribute
    per method, each a :class:`StubMethod`."""

    def __init__(self, channel: Channel, service: ServiceDef):
        self._channel = channel
        self.service = service
        self._methods = {m.name: StubMethod(channel, service, m)
                         for m in service.methods}

    def __getattr__(self, name: str) -> StubMethod:
        # everything below reads via __dict__: this hook must degrade
        # to a plain AttributeError (not recurse) when the instance is
        # unpopulated, e.g. during copy/pickle protocol probes
        methods = self.__dict__.get("_methods")
        if methods is not None and name in methods:
            return methods[name]
        svc = self.__dict__.get("service")
        raise AttributeError(
            f"service {svc.name if svc else '?'!r} has no method "
            f"{name!r}; methods: {sorted(methods or ())}")

    def method(self, name: str) -> StubMethod:
        """Explicit lookup (for computed method names)."""
        return self.__getattr__(name)

    @property
    def channel(self) -> Channel:
        return self._channel


# ---------------------------------------------------------------------------
# benchmark services — the fabric exchange families, declared gRPC-style
# ---------------------------------------------------------------------------

#: fully-connected family: one one-way unary per (src, dst) pair
EXCHANGE_SERVICE = ServiceDef("Exchange", (
    MethodSpec("exchange", UNARY),))

#: ring family: each worker client-streams chunks to its successor
RING_SERVICE = ServiceDef("Ring", (
    MethodSpec("ring", CLIENT_STREAM),))

#: incast family: workers bidi-stream into one server that streams the
#: (possibly asymmetric) fetch back
INCAST_SERVICE = ServiceDef("Incast", (
    MethodSpec("push_fetch", BIDI),))

#: allreduce family: one store-only unary method every collective
#: schedule (ring / tree / reduce-scatter+allgather) sends its per-step
#: chunks through — rpc.collectives drives the flights
ALLREDUCE_SERVICE = ServiceDef("Allreduce", (
    MethodSpec("chunk", UNARY),))

#: transport-conformance service: one method per cardinality kind, so a
#: dispatching transport can be exercised uniformly across endpoints
#: (the fabric conformance test tier drives it against every transport)
CONFORMANCE_SERVICE = ServiceDef("Conformance", (
    MethodSpec("echo", UNARY),              # request back verbatim
    MethodSpec("gather", CLIENT_STREAM),    # total byte count of stream
    MethodSpec("split", SERVER_STREAM),     # request rechunked
    MethodSpec("relay", BIDI),              # each chunk echoed
))


def conformance_handlers(*, chunk_bytes: int = 128):
    """Reference handlers for :data:`CONFORMANCE_SERVICE`: ``echo``
    returns the request buffers, ``gather`` replies with the byte count
    of the concatenated stream (little-endian uint32), ``split``
    streams the concatenated request back in ``chunk_bytes`` pieces,
    ``relay`` echoes every chunk as it arrives."""

    def echo(req):
        return [np.array(b, copy=True) for b in req]

    def gather(req):
        total = int(sum(b.size for b in req))
        return [np.asarray([total], dtype="<u4").view(np.uint8)]

    def split(req):
        data = (np.concatenate([b.reshape(-1) for b in req])
                if req else np.zeros(0, np.uint8))
        if data.size == 0:
            return []
        return [[np.array(data[i:i + chunk_bytes], copy=True)]
                for i in range(0, data.size, chunk_bytes)]

    def relay(chunk, end):
        return [[np.array(b, copy=True) for b in chunk]] if chunk else []

    return {"echo": echo, "gather": gather, "split": split,
            "relay": relay}


__all__ = [
    "ALLREDUCE_SERVICE", "BIDI", "CLIENT_STREAM", "CONFORMANCE_SERVICE",
    "Codec",
    "EXCHANGE_SERVICE", "INCAST_SERVICE", "KINDS", "MethodSpec",
    "RING_SERVICE", "RpcError", "SERVER_STREAM", "ServiceDef", "Stub",
    "StubMethod", "UNARY", "UnaryCall", "conformance_handlers",
]
