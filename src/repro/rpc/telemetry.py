"""Bounded latency telemetry for the RPC fabric.

:class:`BoundedHistogram` replaces the unbounded per-call latency lists
the ``MetricsInterceptor`` used to keep: it stores exact samples up to
``exact_cap`` (so small runs report percentiles byte-identical to
``np.percentile`` over the raw values — the behavior tests pin), then
folds into fixed log-spaced buckets, after which memory stays constant
no matter how many samples a long-running serve loop records.
Percentiles from the bucketed state are bucket upper bounds: monotone
in q and within one bucket's relative resolution (~15% at the default
16 buckets/decade) of the true value.

:class:`HistogramRegistry` is the shared sink: every interceptor (and
the serve engine) records into one registry keyed by metric name, so a
process has ONE bounded copy of each distribution instead of one list
per interceptor instance.

Everything here is measured on the *fabric clock* (see
``RpcFabric.now``) — this module never reads wall time itself, which is
what the CI telemetry-clock gate enforces for all of ``repro.rpc``.
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, List, Optional

import numpy as np

#: default exact-sample capacity before folding into buckets
EXACT_CAP = 4096


class BoundedHistogram:
    """Latency histogram with two regimes:

    exact    — up to ``exact_cap`` raw samples; percentiles via
               ``np.percentile`` (identical to the unbounded-list
               behavior this class replaces)
    bucketed — past the cap, samples fold into log-spaced buckets
               covering [lo, hi) at ``buckets_per_decade`` resolution
               (plus one underflow and one overflow bucket); memory is
               O(n_buckets) forever after

    ``count``/``total``/``min``/``max`` stay exact in both regimes.
    """

    def __init__(self, *, exact_cap: int = EXACT_CAP,
                 lo: float = 1e-9, hi: float = 1e4,
                 buckets_per_decade: int = 16):
        assert exact_cap >= 1 and lo > 0 and hi > lo
        assert buckets_per_decade >= 1
        self.exact_cap = exact_cap
        self.lo, self.hi = float(lo), float(hi)
        self.buckets_per_decade = buckets_per_decade
        self._n_buckets = (int(math.ceil(
            math.log10(hi / lo) * buckets_per_decade)) + 2)
        self._exact: Optional[List[float]] = []
        self._counts: Optional[np.ndarray] = None
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf

    @property
    def bucketed(self) -> bool:
        return self._exact is None

    def _bucket_index(self, v: float) -> int:
        if v < self.lo:
            return 0
        if v >= self.hi:
            return self._n_buckets - 1
        return 1 + int(math.log10(v / self.lo) * self.buckets_per_decade)

    def _bucket_upper(self, i: int) -> float:
        """Upper edge of bucket i (the percentile estimate returned in
        the bucketed regime; conservative — never under-reports)."""
        if i == 0:
            return self.lo
        if i >= self._n_buckets - 1:
            return self.max if self.max > 0 else self.hi
        return self.lo * 10.0 ** (i / self.buckets_per_decade)

    def _fold(self) -> None:
        self._counts = np.zeros(self._n_buckets, dtype=np.int64)
        for v in self._exact:
            self._counts[self._bucket_index(v)] += 1
        self._exact = None

    def record(self, value: float) -> None:
        value = float(value)
        self.count += 1
        self.total += value
        self.min = min(self.min, value)
        self.max = max(self.max, value)
        if self._exact is not None:
            self._exact.append(value)
            if len(self._exact) > self.exact_cap:
                self._fold()
        else:
            self._counts[self._bucket_index(value)] += 1

    def extend(self, values: Iterable[float]) -> None:
        for v in values:
            self.record(v)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """q in [0, 100]. Exact regime: ``np.percentile`` over the raw
        samples. Bucketed regime: the upper edge of the bucket holding
        the q-th sample (monotone in q; min/max stay exact)."""
        assert 0.0 <= q <= 100.0, q
        if self.count == 0:
            return 0.0
        if self._exact is not None:
            return float(np.percentile(np.asarray(self._exact), q))
        if q == 0.0:
            return self.min
        if q == 100.0:
            return self.max
        rank = q / 100.0 * self.count
        cum = np.cumsum(self._counts)
        i = int(np.searchsorted(cum, rank, side="left"))
        return min(self._bucket_upper(i), self.max)

    def percentiles(self, qs: Iterable[float]) -> List[float]:
        return [self.percentile(q) for q in qs]

    def snapshot(self) -> Dict[str, float]:
        """JSON-ready summary (seconds, like the recorded samples)."""
        if self.count == 0:
            return {"count": 0}
        return {
            "count": self.count,
            "mean": self.mean,
            "min": self.min,
            "max": self.max,
            "p50": self.percentile(50),
            "p95": self.percentile(95),
            "p99": self.percentile(99),
            "p999": self.percentile(99.9),
        }


class HistogramRegistry:
    """Named :class:`BoundedHistogram` sink shared across interceptors.

    ``hist(name)`` creates on first use; every histogram in one
    registry shares the construction parameters, so the whole
    registry's memory is bounded by ``n_names * O(n_buckets +
    exact_cap)``.
    """

    def __init__(self, *, exact_cap: int = EXACT_CAP,
                 lo: float = 1e-9, hi: float = 1e4,
                 buckets_per_decade: int = 16):
        self._kw = dict(exact_cap=exact_cap, lo=lo, hi=hi,
                        buckets_per_decade=buckets_per_decade)
        self._hists: Dict[str, BoundedHistogram] = {}

    def hist(self, name: str) -> BoundedHistogram:
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = BoundedHistogram(**self._kw)
        return h

    def get(self, name: str) -> Optional[BoundedHistogram]:
        return self._hists.get(name)

    def names(self) -> List[str]:
        return list(self._hists)

    def remove(self, name: str) -> None:
        self._hists.pop(name, None)

    def clear(self) -> None:
        self._hists.clear()

    def snapshot(self) -> Dict[str, Dict[str, float]]:
        return {name: h.snapshot() for name, h in self._hists.items()}


__all__ = ["BoundedHistogram", "HistogramRegistry", "EXACT_CAP"]
