"""Distributed tracing for the RPC fabric (the gRPC census/OpenCensus
analogue).

Every call gets a **trace id**, carried across endpoints in its own
frame-header word (stamped at flight departure next to ``budget_us`` —
see :mod:`repro.rpc.framing`), so a server can attribute its spans to
the originating call without any in-process state sharing. A
:class:`Tracer` attached to a fabric (``RpcFabric(..., tracer=t)``)
records a span tree per call, every timestamp on the **fabric clock**
(``RpcFabric.now``): modeled transports yield deterministic traces,
measured ones wall-clock traces.

Span tree of one call::

    call <method>                      (client endpoint track)
      attempt 1          dst=ps0
        queue | credit_stall | wire | server | reply    <- phases
        wire src->dst                  (per delivered frame)
        server: admit / handler / shed (server endpoint track)
      backoff                          (between attempts, on the root)
      attempt 2          dst=ps1      <- retry after re-route
        ...

*Phases* are special: within one call they are a contiguous,
non-overlapping partition of [start, end] — at every lifecycle event
the fabric closes the open phase and opens the next at the same clock
reading, so per-call phase durations sum exactly to the end-to-end
latency. That is the invariant the hypothesis tier asserts and the
per-phase breakdown ``bench_comm --json`` reports.

Export: :meth:`Tracer.export_chrome` writes Chrome trace-event JSON
(one track per endpoint, loadable at https://ui.perfetto.dev);
:meth:`Tracer.phase_breakdown` aggregates phase totals per method.
This module never reads wall time itself (CI telemetry-clock gate).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional

#: the client-side phase names, in lifecycle order
PHASES = ("queue", "credit_stall", "wire", "server", "reply", "backoff")

#: trace_id is a uint32 header word (0 = untraced)
MAX_TRACE_ID = 0xFFFFFFFF


@dataclass
class Span:
    """One node of a call's span tree. ``end_s is None`` while open;
    ``category`` is one of call/attempt/phase/wire/server/fault."""
    span_id: int
    trace_id: int
    name: str
    category: str
    start_s: float
    end_s: Optional[float] = None
    parent_id: Optional[int] = None
    endpoint: Optional[int] = None
    attrs: Dict[str, Any] = field(default_factory=dict)
    children: List["Span"] = field(default_factory=list)

    @property
    def duration_s(self) -> float:
        return (self.end_s - self.start_s) if self.end_s is not None \
            else 0.0

    @property
    def closed(self) -> bool:
        return self.end_s is not None

    def walk(self) -> Iterator["Span"]:
        """This span and every descendant, depth-first."""
        yield self
        for c in self.children:
            yield from c.walk()

    def phase_spans(self) -> List["Span"]:
        return [s for s in self.walk() if s.category == "phase"]

    def attempt_spans(self) -> List["Span"]:
        return [s for s in self.walk() if s.category == "attempt"]


class _CallState:
    """Live bookkeeping for one in-flight call."""
    __slots__ = ("root", "attempt", "phase")

    def __init__(self, root: Span, attempt: Span, phase: Span):
        self.root = root
        self.attempt = attempt
        self.phase = phase      # the OPEN phase span


class Tracer:
    """Fabric-attached span recorder. Construct, pass to
    ``RpcFabric(..., tracer=tracer)`` (which calls :meth:`bind`), run
    calls, then query ``calls()`` / ``phase_breakdown()`` or
    ``export_chrome(path)``. All hooks are cheap no-ops for calls the
    tracer is not tracking, and tracking stops (``dropped`` counts)
    once ``max_spans`` is reached, so a tracer left attached to a
    long benchmark loop cannot grow without bound."""

    def __init__(self, *, max_spans: int = 200_000):
        assert max_spans >= 1
        self.max_spans = max_spans
        self.dropped = 0
        self._clock = None
        self._ep_name = str
        self._spans: List[Span] = []
        self._by_call: Dict[int, _CallState] = {}
        self._by_trace: Dict[int, _CallState] = {}
        self._next_trace = 1
        self._next_span = 1

    # binding ----------------------------------------------------------
    def bind(self, fabric) -> "Tracer":
        """Adopt the fabric's clock and endpoint naming. Called by
        ``RpcFabric.__init__``; idempotent."""
        self._clock = fabric.now
        namer = getattr(fabric.transport, "endpoint_name", None)
        if callable(namer):
            self._ep_name = namer
        return self

    def now(self) -> float:
        return self._clock() if self._clock is not None else 0.0

    def new_trace_id(self) -> int:
        tid = self._next_trace
        self._next_trace = (self._next_trace % MAX_TRACE_ID) + 1
        return tid

    # span plumbing ----------------------------------------------------
    def _span(self, name: str, category: str, trace_id: int,
              start_s: float, *, parent: Optional[Span] = None,
              endpoint: Optional[int] = None,
              attrs: Optional[Dict[str, Any]] = None) -> Span:
        s = Span(self._next_span, trace_id, name, category, start_s,
                 parent_id=parent.span_id if parent is not None else None,
                 endpoint=endpoint, attrs=attrs or {})
        self._next_span += 1
        self._spans.append(s)
        if parent is not None:
            parent.children.append(s)
        return s

    def _set_phase(self, st: _CallState, name: str, t: float,
                   *, parent: Optional[Span] = None) -> None:
        if st.phase is not None and st.phase.name == name \
                and st.phase.end_s is None:
            return
        if st.phase is not None and st.phase.end_s is None:
            st.phase.end_s = t
        st.phase = self._span(name, "phase", st.root.trace_id, t,
                              parent=parent or st.attempt,
                              endpoint=st.root.endpoint)

    def _state_for_frame(self, frame) -> Optional[_CallState]:
        """Server-side lookup: the propagated trace-id header word
        first (cross-endpoint context), the in-process call id as the
        fallback for frames that never crossed a stamped flight."""
        st = None
        if getattr(frame, "trace_id", 0):
            st = self._by_trace.get(frame.trace_id)
        return st if st is not None else self._by_call.get(frame.call_id)

    # fabric hooks: call lifecycle ------------------------------------
    def on_call_start(self, ctx, src: int) -> None:
        """A new CallContext opened: assign its trace id and open the
        root/attempt/queue spans on the client endpoint's track."""
        ctx.trace_id = self.new_trace_id()
        if len(self._spans) >= self.max_spans:
            self.dropped += 1
            return
        root = self._span(ctx.method, "call", ctx.trace_id, ctx.start_s,
                          endpoint=src,
                          attrs={"call_id": ctx.call_id,
                                 "kind": ctx.kind,
                                 "dst": self._ep_name(ctx.dst)})
        attempt = self._span("attempt 1", "attempt", ctx.trace_id,
                             ctx.start_s, parent=root, endpoint=src,
                             attrs={"dst": self._ep_name(ctx.dst)})
        st = _CallState(root, attempt, None)
        self._set_phase(st, "queue", ctx.start_s)
        self._by_call[ctx.call_id] = st
        self._by_trace[ctx.trace_id] = st

    def on_stall(self, call_id: int) -> None:
        """A frame of this call queued behind a credit window."""
        st = self._by_call.get(call_id)
        if st is not None:
            self._set_phase(st, "credit_stall", self.now())

    def on_admit(self, call_id: int, *, reply: bool = False) -> None:
        """A window-stalled frame was re-admitted to the next flight."""
        st = self._by_call.get(call_id)
        if st is not None and st.phase is not None \
                and st.phase.name == "credit_stall":
            self._set_phase(st, "reply" if reply else "queue", self.now())

    def on_depart(self, call_id: int, t: float) -> None:
        """A request frame of this call left in a flight."""
        st = self._by_call.get(call_id)
        if st is not None:
            self._set_phase(st, "wire", t)

    def on_wire(self, msg, t0: float, t1: float) -> None:
        """One delivered frame: a wire span on the source track."""
        st = self._state_for_frame(msg.frame)
        if st is None:
            return
        # wire spans are records, not phase transitions
        s = self._span(f"wire {self._ep_name(msg.src)}->"
                       f"{self._ep_name(msg.dst)}", "wire",
                       st.root.trace_id, t0, parent=st.attempt,
                       endpoint=msg.src,
                       attrs={"bytes": msg.frame.total_bytes,
                              "seq": msg.frame.seq,
                              "reply": msg.frame.is_reply})
        s.end_s = t1

    def on_fault(self, msg, t: float) -> None:
        """A FaultInjectionTransport lost this frame: instant span."""
        st = self._state_for_frame(msg.frame)
        if st is None:
            return
        s = self._span(f"link_fault {self._ep_name(msg.src)}->"
                       f"{self._ep_name(msg.dst)}", "fault",
                       st.root.trace_id, t, parent=st.attempt,
                       endpoint=msg.dst,
                       attrs={"bytes": msg.frame.total_bytes})
        s.end_s = t

    def on_server(self, call_id: int, t: float) -> None:
        """The call's frame reached its server; dispatch is starting."""
        st = self._by_call.get(call_id)
        if st is not None:
            self._set_phase(st, "server", t)

    def on_dispatched(self, call_id: int, t: float, *,
                      replying: bool) -> None:
        """Dispatch returned: a reply/chunks are in flight (``reply``
        phase) or the client still owes stream chunks (``queue``)."""
        st = self._by_call.get(call_id)
        if st is not None:
            self._set_phase(st, "reply" if replying else "queue", t)

    def server_span(self, frame, endpoint: int, name: str, t0: float,
                    t1: float, **attrs) -> None:
        """A server-side event (admit/shed/handler) on the server
        endpoint's track, attributed via the frame's propagated trace
        id."""
        st = self._state_for_frame(frame)
        if st is None:
            return
        s = self._span(name, "server", st.root.trace_id, t0,
                       parent=st.attempt, endpoint=endpoint,
                       attrs=attrs)
        s.end_s = t1

    def on_retry(self, ctx, old_call_id: int, t_fail: float,
                 t_resume: float) -> None:
        """The failed attempt is over; after ``backoff`` (possibly
        zero-length) a new attempt opens — ``ctx`` already carries the
        new call id and (possibly re-routed) channel."""
        st = self._by_call.pop(old_call_id, None)
        if st is None:
            return
        if st.phase is not None and st.phase.end_s is None:
            st.phase.end_s = t_fail
        st.phase = None
        if st.attempt.end_s is None:
            st.attempt.end_s = t_fail
        if t_resume > t_fail:
            b = self._span("backoff", "phase", st.root.trace_id, t_fail,
                           parent=st.root, endpoint=st.root.endpoint)
            b.end_s = t_resume
        st.attempt = self._span(
            f"attempt {ctx.attempts}", "attempt", st.root.trace_id,
            t_resume, parent=st.root, endpoint=st.root.endpoint,
            attrs={"dst": self._ep_name(ctx.channel.dst)})
        self._set_phase(st, "queue", t_resume)
        self._by_call[ctx.call_id] = st

    def on_terminal(self, ctx, kind: str,
                    error: Optional[str] = None) -> None:
        """The call reached a terminal event: close phase, attempt and
        root at ``ctx.end_s``."""
        st = self._by_call.pop(ctx.call_id, None)
        if st is None:
            return
        self._by_trace.pop(ctx.trace_id, None)
        t = ctx.end_s if ctx.end_s is not None else self.now()
        if st.phase is not None and st.phase.end_s is None:
            st.phase.end_s = t
        if st.attempt.end_s is None:
            st.attempt.end_s = t
        st.root.end_s = t
        st.root.attrs["outcome"] = kind
        st.root.attrs["attempts"] = ctx.attempts
        if error:
            st.root.attrs["error"] = error

    # queries ----------------------------------------------------------
    def spans(self) -> List[Span]:
        return list(self._spans)

    def calls(self) -> List[Span]:
        """Root call spans, in start order."""
        return [s for s in self._spans if s.category == "call"]

    def trace(self, trace_id: int) -> Optional[Span]:
        for s in self._spans:
            if s.category == "call" and s.trace_id == trace_id:
                return s
        return None

    def clear(self) -> None:
        self._spans.clear()
        self._by_call.clear()
        self._by_trace.clear()
        self.dropped = 0

    def phase_breakdown(self) -> Dict[str, Dict[str, Any]]:
        """Per-method phase totals over CLOSED calls. Each entry's
        ``phases`` sum exactly to ``end_to_end_s`` (the partition
        invariant), so a breakdown row attributes every second of
        latency to queue/credit_stall/wire/server/reply/backoff."""
        out: Dict[str, Dict[str, Any]] = {}
        for root in self.calls():
            if not root.closed:
                continue
            row = out.setdefault(root.name, {
                "calls": 0, "end_to_end_s": 0.0,
                "phases": {p: 0.0 for p in PHASES}})
            row["calls"] += 1
            row["end_to_end_s"] += root.duration_s
            for ph in root.phase_spans():
                if ph.closed:
                    row["phases"][ph.name] = \
                        row["phases"].get(ph.name, 0.0) + ph.duration_s
        return out

    # export -----------------------------------------------------------
    def chrome_events(self) -> List[Dict[str, Any]]:
        """Chrome trace-event list: one pid, one tid (track) per
        endpoint, complete ("X") events in microseconds. Open spans are
        skipped."""
        events: List[Dict[str, Any]] = [{
            "ph": "M", "name": "process_name", "pid": 0, "tid": 0,
            "args": {"name": "rpc-fabric"}}]
        endpoints = sorted({s.endpoint for s in self._spans
                            if s.endpoint is not None})
        for ep in endpoints:
            events.append({"ph": "M", "name": "thread_name", "pid": 0,
                           "tid": ep,
                           "args": {"name": f"endpoint "
                                            f"{self._ep_name(ep)}"}})
        for s in self._spans:
            if not s.closed:
                continue
            args = dict(s.attrs)
            args["trace_id"] = s.trace_id
            events.append({
                "ph": "X", "name": s.name, "cat": s.category,
                "ts": s.start_s * 1e6, "dur": s.duration_s * 1e6,
                "pid": 0, "tid": s.endpoint if s.endpoint is not None
                else 0,
                "args": args})
        return events

    def export_chrome(self, path) -> None:
        """Write Perfetto-loadable Chrome trace-event JSON to
        ``path`` (str or file-like)."""
        doc = {"traceEvents": self.chrome_events(),
               "displayTimeUnit": "ms"}
        if hasattr(path, "write"):
            json.dump(doc, path)
        else:
            with open(path, "w") as f:
                json.dump(doc, f)


__all__ = ["MAX_TRACE_ID", "PHASES", "Span", "Tracer"]
