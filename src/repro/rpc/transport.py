"""Pluggable transports for the RPC fabric.

A transport moves one *flight* of point-to-point messages and reports
how long the flight took:

  LoopbackTransport        — single-host shared-buffer memcpy;
                             wall-clock measured. The
                             serving-experiment path.
  SimulatedTransport       — no data moves; elapsed is priced by a
                             ``core.netmodel.NetworkModel``
                             (receiver-side NIC serialization +
                             CPU-copy contention, plus sender-side
                             egress), so topologies of hundreds of
                             endpoints run in milliseconds.
  CollectiveTransport      — (repro.rpc.collective) lowers the flight
                             onto the ``ppermute`` schedules of
                             ``core.channels``; measured on real
                             devices.
  FaultInjectionTransport  — seeded fault-injection wrapper around any
                             of the above: per-link transient message
                             faults, extra latency, and stalled
                             streams — the instrument the fault test
                             tier drives everything with.

Physical fabrics move at most one message per (src, dst) port pair at a
time, so a flight is internally decomposed into edge-colored *rounds*
(unique sources and destinations per round) — the same constraint
``channels.bipartite_schedule`` encodes for ppermute.
"""
from __future__ import annotations

import abc
import time
from collections import deque
from dataclasses import dataclass, replace
from typing import (Deque, Dict, Iterable, List, Optional, Sequence,
                    Set, Tuple)

import numpy as np

from repro.core.netmodel import NetworkModel
from repro.core.payload import PayloadSpec, classify
from repro.rpc import framing


@dataclass(frozen=True)
class Message:
    src: int
    dst: int
    frame: framing.Frame


@dataclass
class Delivery:
    messages: List[Message]     # as delivered (frames re-decoded off wire)
    elapsed_s: float
    rounds: int
    modeled: bool


def schedule_rounds(messages: Sequence[Message]) -> List[List[Message]]:
    """Greedy edge coloring: split a flight into rounds with unique
    sources AND destinations (the ppermute / single-port constraint)."""
    pending = list(messages)
    rounds: List[List[Message]] = []
    while pending:
        used_src, used_dst = set(), set()
        this_round, rest = [], []
        for m in pending:
            if m.src not in used_src and m.dst not in used_dst:
                used_src.add(m.src)
                used_dst.add(m.dst)
                this_round.append(m)
            else:
                rest.append(m)
        rounds.append(this_round)
        pending = rest
    return rounds


def spec_of(frame: framing.Frame) -> PayloadSpec:
    """A netmodel-priceable spec for a frame's payload."""
    return PayloadSpec(sizes=frame.sizes, scheme="wire",
                       categories=tuple(classify(s) for s in frame.sizes))


class Transport(abc.ABC):
    """One flight in, delivery + timing out."""

    n_endpoints: int
    modeled: bool = False
    #: True when endpoint servers run python handlers on delivered frames
    #: (loopback/simulated); the collective transport is a pure SPMD
    #: datapath whose service semantics are echo/exchange.
    dispatches: bool = True

    @abc.abstractmethod
    def deliver(self, messages: Sequence[Message]) -> Delivery:
        ...

    def close(self) -> None:
        pass


def make_transport(kind: str, n_endpoints: int = None, *,
                   network=None, cluster=None, mesh=None, spec=None,
                   inner: "Transport" = None, **kw) -> Transport:
    """The one transport constructor call sites outside ``repro.rpc``
    use (the CI deprecation gate rejects direct class construction
    elsewhere). Kinds:

      loopback    — make_transport("loopback", n)
      simulated   — make_transport("simulated", n, network=model|name)
      cluster     — make_transport("cluster",
                                   cluster=ClusterSpec|dict|json)
      collective  — make_transport("collective", n, mesh=mesh,
                                   spec=payload_spec, ...)
      fault       — make_transport("fault", inner=<any of the above>,
                                   seed=0, fault_rate=..., ...)
    """
    if kind == "fault":
        if not isinstance(inner, Transport):
            raise ValueError(
                "fault transport needs inner= (a Transport built by "
                f"make_transport); got {inner!r}")
        return FaultInjectionTransport(inner, **kw)
    if kind in ("loopback", "simulated") and n_endpoints is None:
        raise ValueError(f"{kind} transport needs n_endpoints")
    if kind == "loopback":
        return LoopbackTransport(n_endpoints, **kw)
    if kind == "simulated":
        if isinstance(network, str):
            from repro.core.netmodel import NETWORKS
            if network not in NETWORKS:
                raise ValueError(f"unknown network {network!r}; choose "
                                 f"from {sorted(NETWORKS)}")
            network = NETWORKS[network]
        if not isinstance(network, NetworkModel):
            raise ValueError(
                "simulated transport needs network= (a NetworkModel or "
                "a name in core.netmodel.NETWORKS); got "
                f"{network!r}")
        return SimulatedTransport(n_endpoints, network, **kw)
    if kind == "cluster":
        from repro.rpc.cluster import ClusterTransport, as_cluster_spec
        if cluster is None:
            raise ValueError("cluster transport needs cluster= (a "
                             "ClusterSpec, dict, or JSON string)")
        return ClusterTransport(as_cluster_spec(cluster), **kw)
    if kind == "collective":
        if mesh is None or spec is None:
            raise ValueError("collective transport needs mesh= and "
                             "spec= (a device mesh + PayloadSpec)")
        from repro.rpc.collective import CollectiveTransport
        return CollectiveTransport(mesh, spec,
                                   n_endpoints=n_endpoints or 0, **kw)
    raise ValueError(f"unknown transport kind {kind!r}; choose from "
                     f"('loopback', 'simulated', 'cluster', "
                     f"'collective', 'fault')")


class FaultInjectionTransport(Transport):
    """Seeded fault-injection wrapper around any transport — the
    instrument the fault test tier drives the fabric with. Three fault
    families, each optionally restricted to a set of directed
    ``(src, dst)`` links and drawn from ONE seeded RNG, so a schedule
    is reproducible and independent of wall clock:

      fault_rate   per-message probability the message is lost to a
                   transient link fault: it is NOT delivered to the
                   inner transport; the fabric sees it flagged
                   ``FLAG_FAULT``, refunds its credits, and fails the
                   call with a retryable transient error.
      stall_rate   per-message probability of a *stalled stream*: the
                   message is delivered but the flight is charged an
                   extra ``stall_s`` (the modeled clock advances, or —
                   on measured transports — the wall clock actually
                   passes) — with deadline propagation the budget is
                   consumed on the wire, so the server sheds the call
                   on arrival and the client's deadline machinery
                   fires.
      latency_rate per-message probability of ``latency_s`` extra
                   flight latency (a degraded link, milder than a
                   stall).

    ``max_faults`` bounds faults + stalls injected over the transport's
    lifetime so every schedule eventually drains; the counters
    (``faults_injected``, ``stalls_injected``, ``extra_latency_s``)
    let tests assert the schedule actually fired. Unknown attributes
    (``clock_s``, ``resolve``, ``channel_windows``, ``cluster``, ...)
    delegate to the wrapped transport, so the wrapper is drop-in for
    loopback, simulated, and cluster fabrics alike.

    ``burst_windows`` adds *correlated* burst loss on top of the
    i.i.d. schedule: a list of ``(t_start, t_end)`` or
    ``(t_start, t_end, link)`` windows on the modeled clock during
    which every eligible message is lost (``link`` is a directed
    ``(src, dst)`` pair — names resolve through a cluster inner — or
    None for all links). Real outages cluster in time; the workload
    tier derives these windows from a trace's arrival bursts so fault
    schedules correlate with load instead of sampling independently
    per message. Windows need a modeled inner transport (the loss
    condition is a clock read) and do not draw from the RNG or count
    against ``max_faults`` — they are already time-bounded;
    ``burst_faults_injected`` counts them separately (they also bump
    ``faults_injected``, the total every lost message shares)."""

    def __init__(self, inner: Transport, *, seed: int = 0,
                 fault_rate: float = 0.0, stall_rate: float = 0.0,
                 latency_rate: float = 0.0, stall_s: float = 0.0,
                 latency_s: float = 0.0,
                 links: Optional[Iterable[Tuple[int, int]]] = None,
                 max_faults: Optional[int] = None,
                 burst_windows: Optional[Iterable[tuple]] = None):
        for rate in (fault_rate, stall_rate, latency_rate):
            assert 0.0 <= rate <= 1.0, rate
        assert fault_rate + stall_rate + latency_rate <= 1.0, \
            "fault families draw from one RNG sample; rates must sum <= 1"
        assert stall_s >= 0.0 and latency_s >= 0.0
        self.inner = inner
        self.seed = seed
        self.fault_rate = fault_rate
        self.stall_rate = stall_rate
        self.latency_rate = latency_rate
        self.stall_s = stall_s
        self.latency_s = latency_s
        self.links: Optional[Set[Tuple[int, int]]] = \
            set((int(s), int(d)) for s, d in links) \
            if links is not None else None
        self.max_faults = max_faults
        self.faults_injected = 0
        self.stalls_injected = 0
        self.burst_faults_injected = 0
        self.extra_latency_s = 0.0
        self._rng = np.random.default_rng(seed)
        self.burst_windows: List[Tuple[float, float,
                                       Optional[Tuple[int, int]]]] = []
        if burst_windows:
            assert inner.modeled and hasattr(inner, "clock_s"), \
                "burst_windows are defined on the modeled clock; the " \
                "inner transport must be modeled (simulated/cluster)"
            for w in burst_windows:
                t0, t1 = float(w[0]), float(w[1])
                assert t1 > t0, (t0, t1)
                link = w[2] if len(w) > 2 else None
                if link is not None:
                    s, d = link
                    if isinstance(s, str):
                        s = inner.resolve(s)
                    if isinstance(d, str):
                        d = inner.resolve(d)
                    link = (int(s), int(d))
                self.burst_windows.append((t0, t1, link))

    def _in_burst(self, m: Message) -> bool:
        if not self.burst_windows:
            return False
        t = self.inner.clock_s
        return any(t0 <= t < t1
                   and (link is None or (m.src, m.dst) == link)
                   for t0, t1, link in self.burst_windows)

    # the wrapped transport's identity -----------------------------------
    @property
    def n_endpoints(self) -> int:
        return self.inner.n_endpoints

    @property
    def modeled(self) -> bool:
        return self.inner.modeled

    @property
    def dispatches(self) -> bool:
        return self.inner.dispatches

    @property
    def clock_s(self) -> float:
        return self.inner.clock_s     # AttributeError when inner has none

    @clock_s.setter
    def clock_s(self, value: float) -> None:
        self.inner.clock_s = value

    def __getattr__(self, name: str):
        # optional transport hooks (resolve, endpoint_name,
        # channel_windows, cluster, network, ...) pass through
        if name == "inner":       # pre-__init__ probes must not recurse
            raise AttributeError(name)
        return getattr(self.inner, name)

    # the schedule -------------------------------------------------------
    def _eligible(self, m: Message) -> bool:
        return self.links is None or (m.src, m.dst) in self.links

    def _budget_left(self) -> bool:
        return self.max_faults is None or \
            (self.faults_injected + self.stalls_injected) < self.max_faults

    def deliver(self, messages: Sequence[Message]) -> Delivery:
        faulted: List[Message] = []
        through: List[Message] = []
        extra = 0.0
        for m in messages:
            if self._in_burst(m):
                self.burst_faults_injected += 1
                self.faults_injected += 1
                faulted.append(replace(
                    m, frame=replace(m.frame,
                                     flags=m.frame.flags
                                     | framing.FLAG_FAULT)))
                continue
            draw = (self._rng.random()
                    if self._eligible(m) and self._budget_left()
                    else 1.0)
            if draw < self.fault_rate:
                self.faults_injected += 1
                faulted.append(replace(
                    m, frame=replace(m.frame,
                                     flags=m.frame.flags
                                     | framing.FLAG_FAULT)))
                continue
            if draw < self.fault_rate + self.stall_rate:
                self.stalls_injected += 1
                extra += self.stall_s
            elif draw < (self.fault_rate + self.stall_rate
                         + self.latency_rate):
                extra += self.latency_s
            through.append(m)
        d = self.inner.deliver(through)
        if extra > 0.0:
            self.extra_latency_s += extra
            if self.inner.modeled and hasattr(self.inner, "clock_s"):
                self.inner.clock_s += extra
            else:
                # measured transports live on the wall clock: the stall
                # must actually pass for deadline propagation (server
                # shedding) and client-side expiry to see it
                time.sleep(extra)
        # faulted messages FIRST: the fabric must see a call's fault
        # before any same-flight stragglers of that call — a stream's
        # END outrunning its faulted middle chunk would complete the
        # stream with a silently missing chunk
        return Delivery(faulted + list(d.messages),
                        d.elapsed_s + extra, d.rounds, d.modeled)

    def close(self) -> None:
        self.inner.close()


class LoopbackTransport(Transport):
    """Shared-buffer transport: every endpoint lives in this process and
    owns an inbox list; delivery encodes each frame to wire bytes and
    copies them into the destination inbox. The encode+memcpy is exactly
    the serialized/non-serialized trade measured on one host."""

    def __init__(self, n_endpoints: int, *, backend: str = "numpy",
                 inbox_depth: int = 8):
        assert n_endpoints >= 1
        self.n_endpoints = n_endpoints
        self.backend = backend
        # bounded: retains only the last few wire messages per endpoint
        # for inspection — benchmark loops would otherwise accumulate
        # every payload copy ever delivered
        self.inboxes: List[Deque[List[np.ndarray]]] = [
            deque(maxlen=inbox_depth) for _ in range(n_endpoints)]

    def deliver(self, messages: Sequence[Message]) -> Delivery:
        rounds = schedule_rounds(messages)
        out: List[Message] = []
        t0 = time.perf_counter()
        for rnd in rounds:
            for m in rnd:
                assert 0 <= m.dst < self.n_endpoints, m.dst
                wire = framing.encode(m.frame, backend=self.backend)
                copied = [np.array(w, copy=True) for w in wire]
                self.inboxes[m.dst].append(copied)
                out.append(Message(m.src, m.dst,
                                   framing.decode(copied,
                                                  backend=self.backend)))
        elapsed = time.perf_counter() - t0
        return Delivery(out, elapsed, len(rounds), modeled=False)


class SimulatedTransport(Transport):
    """Analytic transport over a ``NetworkModel``.

    Per flight, each receiver serializes its incoming messages on its
    NIC/stack (sum of per-message times) and pays the quadratic host
    CPU-copy contention term when several messages land on one endpoint
    — the same receiver-bound model ``netmodel.ps_round_time`` uses, so
    a simulated PS pattern reproduces the paper's throughput ratios.
    Each *sender* additionally serializes its outgoing bytes on its own
    NIC (the egress term): a flight's elapsed time is the max over
    endpoints of ingress + copy contention + egress. Egress is what
    makes the fan-OUT half of an incast contend — one server streaming
    fetch responses to N workers is limited by its own egress pump,
    not by any single receiver. Matches ``netmodel.fc_round_time`` /
    ``ring_round_time`` / ``incast_round_time`` exactly.
    Frames may be spec-only; nothing is allocated or copied.
    """

    modeled = True

    def __init__(self, n_endpoints: int, network: NetworkModel):
        assert n_endpoints >= 1
        self.n_endpoints = n_endpoints
        self.network = network
        self.clock_s = 0.0

    def price(self, frame: framing.Frame) -> float:
        """One message's cost at the receiver: payload + 64B ack."""
        return (self.network.payload_time(spec_of(frame),
                                          mode=frame.wire_mode)
                + self.network.msg_time(64))

    def egress_price(self, frame: framing.Frame) -> float:
        """One message's cost at the sender: pumping the bytes onto the
        wire (alpha and the RPC software overhead are receiver-side)."""
        return frame.total_bytes / self.network.beta_Bps

    def deliver(self, messages: Sequence[Message]) -> Delivery:
        # one accumulator dict per endpoint ([ingress, count, bytes,
        # egress] rows) instead of four — flush-loop hot path, the
        # four-dict version paid 4 hash probes + .get churn per message
        acc: Dict[int, list] = {}
        n_end = self.n_endpoints
        net = self.network
        beta = net.beta_Bps
        ack = net.msg_time(64)
        ptime = net._payload_time_raw
        for m in messages:
            assert 0 <= m.dst < n_end, m.dst
            assert 0 <= m.src < n_end, m.src
            frame = m.frame
            row = acc.get(m.dst)
            if row is None:
                row = acc[m.dst] = [0.0, 0, 0, 0.0]
            sizes = frame.sizes
            nbytes = int(sum(sizes))
            # == self.price(frame), with the spec_of construction and
            # the constant 64B-ack term hoisted out of the hot loop
            row[0] += ptime(nbytes, len(sizes), frame.wire_mode) + ack
            row[1] += 1
            row[2] += nbytes
            row = acc.get(m.src)
            if row is None:
                row = acc[m.src] = [0.0, 0, 0, 0.0]
            row[3] += nbytes / beta
        elapsed = 0.0
        cpu_copy = self.network.cpu_copy_Bps
        for ingress, k, nbytes, egress in acc.values():
            t = ingress
            if k > 1:
                # == k * (k - 1) * avg_bytes / cpu_copy, avg = nbytes/k,
                # but as one exact integer product before the division
                t += (k - 1) * nbytes / cpu_copy
            t += egress
            if t > elapsed:
                elapsed = t
        self.clock_s += elapsed
        rounds = schedule_rounds(messages)
        return Delivery(list(messages), elapsed, len(rounds), modeled=True)
