"""Pluggable transports for the RPC fabric.

A transport moves one *flight* of point-to-point messages and reports
how long the flight took:

  LoopbackTransport   — single-host shared-buffer memcpy; wall-clock
                        measured. The serving-experiment path.
  SimulatedTransport  — no data moves; elapsed is priced by a
                        ``core.netmodel.NetworkModel`` (receiver-side
                        NIC serialization + CPU-copy contention, plus
                        sender-side egress), so topologies of hundreds
                        of endpoints run in milliseconds.
  CollectiveTransport — (repro.rpc.collective) lowers the flight onto
                        the ``ppermute`` schedules of
                        ``core.channels``; measured on real devices.

Physical fabrics move at most one message per (src, dst) port pair at a
time, so a flight is internally decomposed into edge-colored *rounds*
(unique sources and destinations per round) — the same constraint
``channels.bipartite_schedule`` encodes for ppermute.
"""
from __future__ import annotations

import abc
import time
from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, List, Sequence

import numpy as np

from repro.core.netmodel import NetworkModel
from repro.core.payload import PayloadSpec, classify
from repro.rpc import framing


@dataclass(frozen=True)
class Message:
    src: int
    dst: int
    frame: framing.Frame


@dataclass
class Delivery:
    messages: List[Message]     # as delivered (frames re-decoded off wire)
    elapsed_s: float
    rounds: int
    modeled: bool


def schedule_rounds(messages: Sequence[Message]) -> List[List[Message]]:
    """Greedy edge coloring: split a flight into rounds with unique
    sources AND destinations (the ppermute / single-port constraint)."""
    pending = list(messages)
    rounds: List[List[Message]] = []
    while pending:
        used_src, used_dst = set(), set()
        this_round, rest = [], []
        for m in pending:
            if m.src not in used_src and m.dst not in used_dst:
                used_src.add(m.src)
                used_dst.add(m.dst)
                this_round.append(m)
            else:
                rest.append(m)
        rounds.append(this_round)
        pending = rest
    return rounds


def spec_of(frame: framing.Frame) -> PayloadSpec:
    """A netmodel-priceable spec for a frame's payload."""
    return PayloadSpec(sizes=frame.sizes, scheme="wire",
                       categories=tuple(classify(s) for s in frame.sizes))


class Transport(abc.ABC):
    """One flight in, delivery + timing out."""

    n_endpoints: int
    modeled: bool = False
    #: True when endpoint servers run python handlers on delivered frames
    #: (loopback/simulated); the collective transport is a pure SPMD
    #: datapath whose service semantics are echo/exchange.
    dispatches: bool = True

    @abc.abstractmethod
    def deliver(self, messages: Sequence[Message]) -> Delivery:
        ...

    def close(self) -> None:
        pass


def make_transport(kind: str, n_endpoints: int = None, *,
                   network=None, cluster=None, mesh=None, spec=None,
                   **kw) -> Transport:
    """The one transport constructor call sites outside ``repro.rpc``
    use (the CI deprecation gate rejects direct class construction
    elsewhere). Kinds:

      loopback    — make_transport("loopback", n)
      simulated   — make_transport("simulated", n, network=model|name)
      cluster     — make_transport("cluster",
                                   cluster=ClusterSpec|dict|json)
      collective  — make_transport("collective", n, mesh=mesh,
                                   spec=payload_spec, ...)
    """
    if kind in ("loopback", "simulated") and n_endpoints is None:
        raise ValueError(f"{kind} transport needs n_endpoints")
    if kind == "loopback":
        return LoopbackTransport(n_endpoints, **kw)
    if kind == "simulated":
        if isinstance(network, str):
            from repro.core.netmodel import NETWORKS
            if network not in NETWORKS:
                raise ValueError(f"unknown network {network!r}; choose "
                                 f"from {sorted(NETWORKS)}")
            network = NETWORKS[network]
        if not isinstance(network, NetworkModel):
            raise ValueError(
                "simulated transport needs network= (a NetworkModel or "
                "a name in core.netmodel.NETWORKS); got "
                f"{network!r}")
        return SimulatedTransport(n_endpoints, network, **kw)
    if kind == "cluster":
        from repro.rpc.cluster import ClusterTransport, as_cluster_spec
        if cluster is None:
            raise ValueError("cluster transport needs cluster= (a "
                             "ClusterSpec, dict, or JSON string)")
        return ClusterTransport(as_cluster_spec(cluster), **kw)
    if kind == "collective":
        if mesh is None or spec is None:
            raise ValueError("collective transport needs mesh= and "
                             "spec= (a device mesh + PayloadSpec)")
        from repro.rpc.collective import CollectiveTransport
        return CollectiveTransport(mesh, spec,
                                   n_endpoints=n_endpoints or 0, **kw)
    raise ValueError(f"unknown transport kind {kind!r}; choose from "
                     f"('loopback', 'simulated', 'cluster', "
                     f"'collective')")


class LoopbackTransport(Transport):
    """Shared-buffer transport: every endpoint lives in this process and
    owns an inbox list; delivery encodes each frame to wire bytes and
    copies them into the destination inbox. The encode+memcpy is exactly
    the serialized/non-serialized trade measured on one host."""

    def __init__(self, n_endpoints: int, *, backend: str = "numpy",
                 inbox_depth: int = 8):
        assert n_endpoints >= 1
        self.n_endpoints = n_endpoints
        self.backend = backend
        # bounded: retains only the last few wire messages per endpoint
        # for inspection — benchmark loops would otherwise accumulate
        # every payload copy ever delivered
        self.inboxes: List[Deque[List[np.ndarray]]] = [
            deque(maxlen=inbox_depth) for _ in range(n_endpoints)]

    def deliver(self, messages: Sequence[Message]) -> Delivery:
        rounds = schedule_rounds(messages)
        out: List[Message] = []
        t0 = time.perf_counter()
        for rnd in rounds:
            for m in rnd:
                assert 0 <= m.dst < self.n_endpoints, m.dst
                wire = framing.encode(m.frame, backend=self.backend)
                copied = [np.array(w, copy=True) for w in wire]
                self.inboxes[m.dst].append(copied)
                out.append(Message(m.src, m.dst,
                                   framing.decode(copied,
                                                  backend=self.backend)))
        elapsed = time.perf_counter() - t0
        return Delivery(out, elapsed, len(rounds), modeled=False)


class SimulatedTransport(Transport):
    """Analytic transport over a ``NetworkModel``.

    Per flight, each receiver serializes its incoming messages on its
    NIC/stack (sum of per-message times) and pays the quadratic host
    CPU-copy contention term when several messages land on one endpoint
    — the same receiver-bound model ``netmodel.ps_round_time`` uses, so
    a simulated PS pattern reproduces the paper's throughput ratios.
    Each *sender* additionally serializes its outgoing bytes on its own
    NIC (the egress term): a flight's elapsed time is the max over
    endpoints of ingress + copy contention + egress. Egress is what
    makes the fan-OUT half of an incast contend — one server streaming
    fetch responses to N workers is limited by its own egress pump,
    not by any single receiver. Matches ``netmodel.fc_round_time`` /
    ``ring_round_time`` / ``incast_round_time`` exactly.
    Frames may be spec-only; nothing is allocated or copied.
    """

    modeled = True

    def __init__(self, n_endpoints: int, network: NetworkModel):
        assert n_endpoints >= 1
        self.n_endpoints = n_endpoints
        self.network = network
        self.clock_s = 0.0

    def price(self, frame: framing.Frame) -> float:
        """One message's cost at the receiver: payload + 64B ack."""
        serialized = frame.serialized
        return (self.network.payload_time(spec_of(frame),
                                          serialized=serialized)
                + self.network.msg_time(64))

    def egress_price(self, frame: framing.Frame) -> float:
        """One message's cost at the sender: pumping the bytes onto the
        wire (alpha and the RPC software overhead are receiver-side)."""
        return frame.total_bytes / self.network.beta_Bps

    def deliver(self, messages: Sequence[Message]) -> Delivery:
        per_dst: Dict[int, float] = {}
        per_dst_count: Dict[int, int] = {}
        per_dst_bytes: Dict[int, int] = {}
        per_src: Dict[int, float] = {}
        for m in messages:
            assert 0 <= m.dst < self.n_endpoints, m.dst
            assert 0 <= m.src < self.n_endpoints, m.src
            per_dst[m.dst] = per_dst.get(m.dst, 0.0) + self.price(m.frame)
            per_dst_count[m.dst] = per_dst_count.get(m.dst, 0) + 1
            per_dst_bytes[m.dst] = (per_dst_bytes.get(m.dst, 0)
                                    + m.frame.total_bytes)
            per_src[m.src] = (per_src.get(m.src, 0.0)
                              + self.egress_price(m.frame))
        elapsed = 0.0
        for e in set(per_dst) | set(per_src):
            t = per_dst.get(e, 0.0)
            k = per_dst_count.get(e, 0)
            if k:
                avg_bytes = per_dst_bytes[e] / k
                t += (k * (k - 1) * avg_bytes
                      / self.network.cpu_copy_Bps)
            elapsed = max(elapsed, t + per_src.get(e, 0.0))
        self.clock_s += elapsed
        rounds = schedule_rounds(messages)
        return Delivery(list(messages), elapsed, len(rounds), modeled=True)
