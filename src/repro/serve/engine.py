"""Batched serving engine: prefill + decode with per-layer KV/SSM state,
greedy/temperature sampling, continuous batching through a per-endpoint
request scheduler.

All generation — local ``generate`` calls and rpc-served traffic —
runs through a :class:`repro.serve.scheduler.ServeScheduler`: a queue
of in-flight requests advanced one token per shared decode step, with
admission gated by ``max_batch`` and a modeled KV-cache block budget,
and preemption-by-recompute when decode growth exhausts the budget
(see ``docs/SERVE.md``). ``attach`` builds one scheduler per served
endpoint, so requests that arrive while others are mid-decode join the
running step instead of queueing behind a whole batch.

Generation requests arrive through the rpc fabric: the engine binds
the ``Serve`` service (:data:`SERVE_SERVICE`) on an ``rpc.Server``
endpoint via ``attach``/``serve_loopback``, so serving traffic
exercises the same framing / flow-control / transport stack the
communication benchmarks measure. The service has two methods:

  ``generate``         unary — the whole (B, new) token block in one
                       reply (the original wire shape).
  ``generate_stream``  server-streaming — one chunk per decode step,
                       each a (B,) int32 token vector, emitted
                       incrementally from the shared step (an
                       ``rpc.StreamPump``), so concurrent streams
                       interleave chunk-by-chunk over the fabric.

``serve_stub(channel)`` builds the generated client stub;
``rpc_generate`` / ``rpc_generate_stream`` are convenience wrappers
over it (``rpc_generate`` is the deprecated shim for the pre-stub API).

Multi-host (PS-style) serving: ``serve_cluster`` binds the service on
every ``ps`` endpoint of a ``rpc.ClusterSpec`` and hands each
``worker`` endpoint a :class:`ShardedServeStub` — a dispatch client
that shards generation requests across the PS endpoints under a
``round_robin`` or ``least_loaded`` policy, so several client
endpoints generate concurrently over per-link-priced cluster routes.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.launch import steps as steps_lib
from repro.models import model as M
from repro.parallel.sharding import ParallelCtx
from repro.rpc.interceptors import (ClientInterceptor,
                                    MetricsInterceptor,
                                    is_resource_exhausted)
from repro.serve.scheduler import Request, ServeScheduler


@dataclass
class ServeConfig:
    max_seq: int = 512
    max_new_tokens: int = 32
    temperature: float = 0.0     # 0 = greedy
    eos_id: Optional[int] = None
    seed: int = 0


class ServeEngine:
    def __init__(self, ctx: ParallelCtx, acfg: ArchConfig, params,
                 cfg: ServeConfig = ServeConfig()):
        assert not acfg.model.is_encoder, "encoder models do not decode"
        self.ctx, self.acfg, self.cfg = ctx, acfg, cfg
        self.params = params
        self._prefill = steps_lib.make_prefill_step(ctx, acfg,
                                                    max_seq=cfg.max_seq)
        self._decode = {}
        #: per-endpoint ServeScheduler, populated by :meth:`attach`
        self.schedulers: Dict = {}

    def _decode_fn(self, batch: int):
        if batch not in self._decode:
            self._decode[batch] = steps_lib.make_decode_step(
                self.ctx, self.acfg, batch)
        return self._decode[batch]

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits[:, -1], axis=-1)
        return jax.random.categorical(key,
                                      logits[:, -1] / self.cfg.temperature)

    # ------------------------------------------------------------------
    # scheduler model ops: one request's prefill / decode-step /
    # state rebuild, each at the request's own batch size — the compute
    # half of the continuous-batching loop (ServeScheduler owns the
    # queueing half). Key-stream discipline is identical across all
    # three, so a preempted request resumes byte-identically.
    # ------------------------------------------------------------------

    def scheduler_prefill(self, req: Request) -> np.ndarray:
        """Prefill ``req`` and sample its first token; leaves the
        request's decode runtime (states, last token, key) on it."""
        states, logits = self._prefill(
            self.params, {"tokens": jnp.asarray(req.prompts)})
        key = jax.random.PRNGKey(self.cfg.seed)
        key, k0 = jax.random.split(key)
        tok = self._sample(logits, k0)
        req.runtime = (states, tok, key)
        return np.asarray(tok)

    def scheduler_decode(self, req: Request) -> np.ndarray:
        """Advance ``req`` one decode step; returns the (B,) token."""
        states, tok, key = req.runtime
        key, k = jax.random.split(key)
        states, logits = self._decode_fn(req.rows)(
            self.params, states, tok[:, None], None)
        tok = self._sample(logits, k)
        req.runtime = (states, tok, key)
        return np.asarray(tok)

    def scheduler_rebuild(self, req: Request) -> None:
        """Recompute a preempted request's runtime from its prompt and
        recorded tokens (teacher-forced replay of the exact prefill +
        decode + key-split sequence, so the rebuilt states are
        bit-identical to the ones dropped at preemption)."""
        states, logits = self._prefill(
            self.params, {"tokens": jnp.asarray(req.prompts)})
        key = jax.random.PRNGKey(self.cfg.seed)
        key, k0 = jax.random.split(key)
        tok = self._sample(logits, k0)
        decode = self._decode_fn(req.rows)
        for _ in range(len(req.tokens) - 1):
            key, k = jax.random.split(key)
            states, logits = decode(self.params, states, tok[:, None],
                                    None)
            tok = self._sample(logits, k)
        req.runtime = (states, tok, key)

    def make_scheduler(self, *, max_batch: int = 8,
                       kv_blocks: Optional[int] = None,
                       block_size: int = 16,
                       sched_policy: str = "fifo",
                       starvation_age_s: Optional[float] = None
                       ) -> ServeScheduler:
        """A continuous-batching scheduler over this engine's model
        ops (``attach`` builds one per served endpoint).
        ``sched_policy`` picks the admission order (``fifo`` or
        ``sjf``; see :class:`repro.serve.scheduler.ServeScheduler`)."""
        return ServeScheduler(self, max_batch=max_batch,
                              kv_blocks=kv_blocks,
                              block_size=block_size,
                              policy=sched_policy,
                              starvation_age_s=starvation_age_s)

    def generate_tokens(self, prompts: np.ndarray,
                        max_new_tokens: Optional[int] = None
                        ) -> Iterator[np.ndarray]:
        """Token-by-token generation: yields one (B,) token vector per
        decode step — the unit the server-streaming ``generate_stream``
        method ships as a chunk. Runs the request through a private
        unconstrained scheduler, so the op/key sequence (and therefore
        every token) is identical to a request sharing a served
        endpoint's continuous batch."""
        sched = self.make_scheduler(max_batch=1)
        req = sched.submit(np.asarray(prompts), max_new_tokens)
        return sched.stream_tokens(req)

    def generate(self, prompts: np.ndarray,
                 max_new_tokens: Optional[int] = None) -> np.ndarray:
        """prompts: (B, S) int32 (right-aligned, no padding support needed
        for fixed-length prompt batches). Returns (B, new) int32."""
        toks = list(self.generate_tokens(prompts, max_new_tokens))
        return np.stack(toks, axis=1)

    # ------------------------------------------------------------------
    # rpc endpoint
    # ------------------------------------------------------------------

    def rpc_handler(self, bufs: List[np.ndarray],
                    scheduler: Optional[ServeScheduler] = None
                    ) -> List[np.ndarray]:
        """``Serve/generate`` method body: iovec request -> iovec reply.
        With a ``scheduler`` the request joins the endpoint's shared
        continuous batch and is driven to completion (concurrently
        advancing whatever else is in flight there)."""
        prompts, mnt = decode_generate_request(bufs)
        if scheduler is None:
            out = self.generate(prompts, mnt or None)
        else:
            out = scheduler.run(scheduler.submit(prompts, mnt or None))
        return encode_generate_reply(out)

    def rpc_stream_handler(self, bufs: List[np.ndarray],
                           scheduler: Optional[ServeScheduler] = None):
        """``Serve/generate_stream`` method body: iovec request -> one
        chunk per decode step, each a (B,) int32 token vector. With a
        ``scheduler`` the chunks come from the endpoint's shared decode
        step wrapped in an ``rpc.StreamPump``, so the flush loop pulls
        one chunk per iteration and concurrent streams interleave."""
        prompts, mnt = decode_generate_request(bufs)
        if scheduler is None:
            return ([_i32_buf(tok)]
                    for tok in self.generate_tokens(prompts, mnt or None))
        from repro import rpc as rpclib
        req = scheduler.submit(prompts, mnt or None)
        pump = rpclib.StreamPump(
            [_i32_buf(tok)] for tok in scheduler.stream_tokens(req))
        req.pump = pump          # phase spans attribute to this call
        return pump

    def attach(self, server, *, max_batch: int = 8,
               kv_blocks: Optional[int] = None,
               block_size: int = 16, sched_policy: str = "fifo",
               starvation_age_s: Optional[float] = None
               ) -> ServeScheduler:
        """Bind this engine's Serve service on an ``rpc.Server``, with
        a dedicated continuous-batching scheduler for the endpoint
        (``self.schedulers[endpoint]``; also returned). The scheduler
        adopts the server's clock/tracer for phase spans, and publishes
        its counters through a ``MetricsInterceptor`` when the server's
        chain has one (under ``serve:scheduler@<endpoint>``)."""
        sched = self.make_scheduler(max_batch=max_batch,
                                    kv_blocks=kv_blocks,
                                    block_size=block_size,
                                    sched_policy=sched_policy,
                                    starvation_age_s=starvation_age_s)
        self.schedulers[server.endpoint] = sched
        return bind_scheduler(server, sched)

    def serve_loopback(self, *, endpoint: int = 0, client: int = 1,
                       serialized: bool = True, tracer=None,
                       max_batch: int = 8,
                       kv_blocks: Optional[int] = None,
                       block_size: int = 16,
                       sched_policy: str = "fifo",
                       starvation_age_s: Optional[float] = None):
        """One-call wiring for single-host serving experiments: a
        loopback-transport fabric with this engine at ``endpoint``.
        ``tracer`` (a ``rpc.Tracer``) records per-call span trees —
        including the scheduler's waiting/prefill/decode/preempted
        phases. ``max_batch`` / ``kv_blocks`` / ``block_size``
        configure the endpoint's scheduler. Returns (fabric, client
        channel)."""
        from repro import rpc as rpclib
        fabric = rpclib.RpcFabric(
            rpclib.make_transport("loopback",
                                  max(endpoint, client) + 1),
            tracer=tracer)
        self.attach(fabric.add_server(endpoint), max_batch=max_batch,
                    kv_blocks=kv_blocks, block_size=block_size,
                    sched_policy=sched_policy,
                    starvation_age_s=starvation_age_s)
        return fabric, fabric.channel(client, endpoint,
                                      serialized=serialized)

    def serve_cluster(self, cluster, *, serialized: bool = True,
                      policy: str = "round_robin", ps_job: str = "ps",
                      worker_job: str = "worker",
                      client_interceptors=None,
                      server_interceptors=None, fault=None,
                      tracer=None, max_batch: int = 8,
                      kv_blocks: Optional[int] = None,
                      block_size: int = 16,
                      sched_policy: str = "fifo",
                      starvation_age_s: Optional[float] = None):
        """Multi-endpoint serving over a cluster transport: this
        engine's ``Serve`` service bound on every ``ps_job`` endpoint
        of ``cluster`` (a ``rpc.ClusterSpec`` / dict / JSON), one
        :class:`ShardedServeStub` per ``worker_job`` endpoint. Returns
        ``(fabric, {worker_name: ShardedServeStub})`` — submit from
        several workers, then ``fabric.flush()`` drives all of them
        concurrently through per-link-priced routes.

        Failure hardening: ``client_interceptors`` /
        ``server_interceptors`` seed the fabric's chains (metrics,
        deadline, retry); ``fault`` (a dict of
        ``FaultInjectionTransport`` kwargs) wraps the cluster transport
        in a seeded fault schedule; and endpoints that advertise an
        ``admission_limit`` in the spec get an ``AdmissionInterceptor``
        installed automatically, fed by a server-side
        ``MetricsInterceptor`` when one is present in the chain.
        ``tracer`` (a ``rpc.Tracer``) records per-call span trees —
        spans follow calls across endpoints and through shard
        failover re-routes.

        ``max_batch`` / ``kv_blocks`` / ``block_size`` configure each
        PS endpoint's continuous-batching scheduler; each scheduler
        reports its load as a metrics gauge that the
        ``scheduler_least_loaded`` dispatch policy reads (admission
        control sheds on the per-flight dispatch queue depth)."""
        from repro import rpc as rpclib
        from repro.rpc.cluster import as_cluster_spec
        cluster = as_cluster_spec(cluster)
        ps = cluster.job_endpoints(ps_job)
        workers = cluster.job_endpoints(worker_job)
        if not ps or not workers:
            raise ValueError(
                f"serve_cluster needs >= 1 {ps_job!r} and >= 1 "
                f"{worker_job!r} endpoint; cluster jobs: "
                f"{ {j: len(e) for j, e in cluster.jobs.items()} }")
        transport = rpclib.make_transport("cluster", cluster=cluster)
        if fault:
            transport = rpclib.make_transport("fault", inner=transport,
                                              **fault)
        fabric = rpclib.RpcFabric(
            transport, client_interceptors=client_interceptors,
            server_interceptors=server_interceptors, tracer=tracer)
        limits = cluster.admission_limits()
        if limits and not any(isinstance(si, rpclib.AdmissionInterceptor)
                              for si in fabric.server_interceptors):
            metrics = next(
                (si for si in fabric.server_interceptors
                 if isinstance(si, rpclib.MetricsInterceptor)), None)
            fabric.server_interceptors.append(
                rpclib.AdmissionInterceptor(limits=limits,
                                            metrics=metrics))
        for name in ps:
            self.attach(fabric.add_server(name), max_batch=max_batch,
                        kv_blocks=kv_blocks, block_size=block_size,
                        sched_policy=sched_policy,
                        starvation_age_s=starvation_age_s)
        stubs = {w: ShardedServeStub(fabric, w, ps, policy=policy,
                                     serialized=serialized)
                 for w in workers}
        return fabric, stubs


# ---------------------------------------------------------------------------
# generate-over-rpc wire codec + generated stub
# ---------------------------------------------------------------------------

def _i32_buf(values) -> np.ndarray:
    return np.ascontiguousarray(np.asarray(values, dtype="<i4")) \
        .view(np.uint8).reshape(-1)


def encode_generate_request(prompts: np.ndarray,
                            max_new_tokens: int = 0) -> List[np.ndarray]:
    """[header(B, S, max_new_tokens) | row-major int32 tokens]."""
    B, S = prompts.shape
    return [_i32_buf([B, S, max_new_tokens]),
            _i32_buf(prompts)]


def decode_generate_request(bufs: List[np.ndarray]
                            ) -> Tuple[np.ndarray, int]:
    B, S, mnt = np.ascontiguousarray(bufs[0]).view("<i4")[:3]
    prompts = np.ascontiguousarray(bufs[1]).view("<i4") \
        .reshape(int(B), int(S))
    return prompts, int(mnt)


def encode_generate_reply(tokens: np.ndarray) -> List[np.ndarray]:
    B, N = tokens.shape
    return [_i32_buf([B, N]), _i32_buf(tokens)]


def decode_generate_reply(bufs: List[np.ndarray]) -> np.ndarray:
    B, N = np.ascontiguousarray(bufs[0]).view("<i4")[:2]
    return np.ascontiguousarray(bufs[1]).view("<i4") \
        .reshape(int(B), int(N))


def decode_token_chunk(bufs: List[np.ndarray]) -> np.ndarray:
    """One ``generate_stream`` chunk -> (B,) int32 token vector."""
    return np.ascontiguousarray(bufs[0]).view("<i4").copy()


def _build_serve_service():
    from repro.rpc.service import (SERVER_STREAM, UNARY, Codec,
                                   MethodSpec, ServiceDef)
    request_codec = Codec(
        encode=lambda r: encode_generate_request(*r),
        decode=lambda bufs: decode_generate_request(bufs))
    reply_codec = Codec(encode=lambda t: encode_generate_reply(t),
                        decode=decode_generate_reply)
    return ServiceDef("Serve", (
        MethodSpec("generate", UNARY, request_codec=request_codec,
                   response_codec=reply_codec),
        MethodSpec("generate_stream", SERVER_STREAM,
                   request_codec=request_codec),
    ))


#: the serving service: unary ``generate`` + streaming ``generate_stream``
SERVE_SERVICE = _build_serve_service()


def serve_handlers(scheduler: ServeScheduler):
    """The ``Serve`` service handler table over a scheduler: unary
    ``generate`` runs the request to completion in the endpoint's
    shared continuous batch; ``generate_stream`` wraps the request's
    token stream in an ``rpc.StreamPump`` (one chunk per flush
    iteration). Engine-agnostic — anything implementing the scheduler
    model ops serves through it, which is how the workload tier serves
    a model-free synthetic engine over the same wire surface."""
    def generate(bufs: List[np.ndarray]) -> List[np.ndarray]:
        prompts, mnt = decode_generate_request(bufs)
        out = scheduler.run(scheduler.submit(prompts, mnt or None))
        return encode_generate_reply(out)

    def generate_stream(bufs: List[np.ndarray]):
        from repro import rpc as rpclib
        prompts, mnt = decode_generate_request(bufs)
        req = scheduler.submit(prompts, mnt or None)
        pump = rpclib.StreamPump(
            [_i32_buf(tok)] for tok in scheduler.stream_tokens(req))
        req.pump = pump          # phase spans attribute to this call
        return pump

    return {"generate": generate, "generate_stream": generate_stream}


def bind_scheduler(server, scheduler: ServeScheduler) -> ServeScheduler:
    """Wire one scheduler onto one ``rpc.Server`` endpoint: adopt the
    server's clock/tracer, register the ``Serve`` service, and publish
    the scheduler's counters through a server-side
    ``MetricsInterceptor`` when the chain has one (under
    ``serve:scheduler@<endpoint>`` — the gauge the
    ``scheduler_least_loaded`` dispatch policy reads)."""
    scheduler.bind(server)
    server.add_service(SERVE_SERVICE, serve_handlers(scheduler))
    metrics = next((si for si in server.interceptors
                    if isinstance(si, MetricsInterceptor)), None)
    if metrics is not None:
        metrics.attach_gauges(f"serve:scheduler@{server.endpoint}",
                              scheduler.stats)
    return scheduler

#: wire name of the unary method (kept for callers that log/match on it)
GENERATE_METHOD = SERVE_SERVICE.full_name("generate")


def serve_stub(channel):
    """The generated ``Serve`` client stub over an existing channel
    (served from the fabric's stub cache)."""
    return channel.fabric.stub(SERVE_SERVICE, channel.src, channel.dst,
                               serialized=channel.serialized)


#: dispatch policies ShardedServeStub understands
DISPATCH_POLICIES = ("round_robin", "least_loaded",
                     "scheduler_least_loaded")


class ShardFailoverInterceptor(ClientInterceptor):
    """Client-side failover for :class:`ShardedServeStub`: a dispatch a
    PS shard rejected with a transient ``resource exhausted`` error
    (its admission control) is transparently re-issued on the NEXT
    shard instead of being retried against the overloaded one. One
    instance is shared per fabric by every ShardedServeStub, installed
    innermost in the client chain so it consumes the rejection before
    an outer ``RetryInterceptor`` burns an attempt on the same shard.
    Each shard is tried at most once per call; when every shard has
    rejected it, the failure surfaces (an outer retry may still re-try
    the whole cycle on a later, less loaded flight)."""

    def __init__(self):
        self.failovers = 0

    def on_complete(self, ctx, event):
        route = ctx.meta.get("shard_route")
        if route is None or event.kind != "error" \
                or ctx.request is None:
            return None
        if not is_resource_exhausted(ctx.meta.get("error")):
            return None
        if ctx.kind == "server_stream" and ctx.chunks > 0:
            return None         # chunks observed: re-issue would dupe
        stub, shard = route
        tried = ctx.meta.setdefault("shards_tried", set())
        tried.add(shard)
        if len(tried) >= len(stub.servers):
            ctx.meta["shards_tried"] = set()    # a later cycle may pass
            return None
        nxt = (shard + 1) % len(stub.servers)
        while nxt in tried:
            nxt = (nxt + 1) % len(stub.servers)
        ctx.meta["shard_route"] = (stub, nxt)
        ctx.channel = stub.shard_channel(nxt)
        # keep the stub's outstanding-call books consistent with the
        # re-route: the call now loads the NEW shard, not the rejected
        # one — least_loaded dispatch reads these counts
        stub._move_inflight(ctx.call_id, shard, nxt)
        self.failovers += 1
        return "retry"


class ShardedServeStub:
    """PS-style sharded dispatch client: one client endpoint fanning
    generation requests across several server endpoints of one fabric.

    ``round_robin`` cycles the servers; ``least_loaded`` picks the
    server with the fewest outstanding (submitted, not yet completed)
    calls from this client, ties broken by server order. Outstanding
    counts are tracked per handle, so interleaved ``generate`` /
    ``generate_stream`` submissions from several stubs before one
    ``fabric.flush()`` shard the way a real PS front-end would.

    With ``failover=True`` (the default) a shared
    :class:`ShardFailoverInterceptor` is installed on the fabric: a
    dispatch rejected by a shard's admission control fails over to the
    next shard transparently during ``flush``."""

    def __init__(self, fabric, client, servers, *,
                 policy: str = "round_robin", serialized: bool = True,
                 failover: bool = True):
        if policy not in DISPATCH_POLICIES:
            raise ValueError(f"unknown dispatch policy {policy!r}; "
                             f"choose from {DISPATCH_POLICIES}")
        assert servers, "sharded dispatch needs >= 1 server endpoint"
        self.fabric = fabric
        self.client = client
        self.servers = list(servers)
        self.policy = policy
        self._stubs = [serve_stub(fabric.channel(client, s,
                                                 serialized=serialized))
                       for s in self.servers]
        self._rr = 0
        self._inflight: List[list] = [[] for _ in self.servers]
        self._failover = None
        if failover:
            self._failover = next(
                (ic for ic in fabric.client_interceptors
                 if isinstance(ic, ShardFailoverInterceptor)), None)
            if self._failover is None:
                self._failover = ShardFailoverInterceptor()
                fabric.client_interceptors.append(self._failover)

    def shard_channel(self, shard: int):
        """The underlying channel of one shard's stub (failover reroutes
        a call's context onto it)."""
        return self._stubs[shard].channel

    def outstanding(self, shard: int) -> int:
        """Submitted-but-incomplete calls this client has on one
        server (completed handles are pruned lazily)."""
        self._inflight[shard] = [h for h in self._inflight[shard]
                                 if not h.done]
        return len(self._inflight[shard])

    def _move_inflight(self, call_id: int, old: int, new: int) -> None:
        """Re-book a call failover moved between shards, so
        ``outstanding`` charges it to the shard actually serving it."""
        for h in self._inflight[old]:
            if h.call_id == call_id:
                self._inflight[old].remove(h)
                self._inflight[new].append(h)
                return

    def _shard_queue_depth(self, shard: int) -> int:
        metrics = next((si for si in self.fabric.server_interceptors
                        if isinstance(si, MetricsInterceptor)), None)
        if metrics is None:
            return 0
        ep = self.fabric.resolve_endpoint(self.servers[shard])
        gauge = metrics.gauges().get(f"serve:scheduler@{ep}")
        if gauge is not None:
            # the endpoint scheduler's live load report: requests
            # decoding + requests queued behind the batch/KV budget
            return gauge["running"] + gauge["waiting"]
        return metrics.server_queue_depth(ep)

    def _pick(self) -> int:
        if self.policy == "round_robin":
            shard = self._rr % len(self._stubs)
            self._rr += 1
            return shard
        if self.policy == "scheduler_least_loaded":
            # server-reported load first (the endpoint scheduler's
            # running + waiting gauge), own outstanding calls as the
            # tiebreak — so dispatch steers around shards other
            # clients have loaded up, not just ours
            return min(range(len(self._stubs)),
                       key=lambda i: (self._shard_queue_depth(i),
                                      self.outstanding(i), i))
        return min(range(len(self._stubs)),
                   key=lambda i: (self.outstanding(i), i))

    def _dispatch(self, method: str, prompts: np.ndarray,
                  max_new_tokens: int, **kw):
        shard = self._pick()
        handle = getattr(self._stubs[shard], method)(
            (prompts, max_new_tokens), **kw)
        self._inflight[shard].append(handle)
        if self._failover is not None:
            ctx = self.fabric.context(handle.call_id)
            if ctx is not None:
                ctx.meta["shard_route"] = (self, shard)
        return handle

    def generate(self, prompts: np.ndarray, max_new_tokens: int = 0,
                 **kw):
        """Unary generate on the picked shard -> ``UnaryCall`` (its
        ``result()`` is the decoded (B, new) token block)."""
        return self._dispatch("generate", prompts, max_new_tokens, **kw)

    def generate_stream(self, prompts: np.ndarray,
                        max_new_tokens: int = 0, **kw):
        """Streaming generate on the picked shard -> ``ServerStream``
        (one (B,) token chunk per decode step)."""
        return self._dispatch("generate_stream", prompts,
                              max_new_tokens, **kw)


def rpc_generate(channel, prompts: np.ndarray,
                 max_new_tokens: int = 0) -> np.ndarray:
    """Deprecated shim (one release): delegates to the generated stub's
    unary ``generate`` method. Use ``serve_stub(channel).generate``."""
    warnings.warn(
        "rpc_generate is deprecated; use "
        "serve_stub(channel).generate((prompts, max_new_tokens))"
        ".result() instead",
        DeprecationWarning, stacklevel=2)
    return serve_stub(channel).generate((prompts, max_new_tokens)) \
        .result()


def rpc_generate_stream(channel, prompts: np.ndarray,
                        max_new_tokens: int = 0) -> np.ndarray:
    """Client for the streaming method: drives the ``ServerStream``
    handle to completion and reassembles the per-step token chunks into
    the same (B, new) block ``generate`` returns."""
    handle = serve_stub(channel).generate_stream(
        (prompts, max_new_tokens))
    chunks = handle.result()
    return np.stack([decode_token_chunk(c) for c in chunks], axis=1)
