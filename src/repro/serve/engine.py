"""Batched serving engine: prefill + decode with per-layer KV/SSM state,
greedy/temperature sampling, static batch with slot reuse.

Generation requests can also arrive through the rpc fabric: the engine
exposes a ``generate`` method on an ``rpc.Server`` endpoint
(``attach``/``serve_loopback``), so serving traffic exercises the same
framing / flow-control / transport stack the communication benchmarks
measure. ``rpc_generate`` is the matching client stub.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.launch import steps as steps_lib
from repro.models import model as M
from repro.parallel.sharding import ParallelCtx


@dataclass
class ServeConfig:
    max_seq: int = 512
    max_new_tokens: int = 32
    temperature: float = 0.0     # 0 = greedy
    eos_id: Optional[int] = None
    seed: int = 0


class ServeEngine:
    def __init__(self, ctx: ParallelCtx, acfg: ArchConfig, params,
                 cfg: ServeConfig = ServeConfig()):
        assert not acfg.model.is_encoder, "encoder models do not decode"
        self.ctx, self.acfg, self.cfg = ctx, acfg, cfg
        self.params = params
        self._prefill = steps_lib.make_prefill_step(ctx, acfg,
                                                    max_seq=cfg.max_seq)
        self._decode = {}

    def _decode_fn(self, batch: int):
        if batch not in self._decode:
            self._decode[batch] = steps_lib.make_decode_step(
                self.ctx, self.acfg, batch)
        return self._decode[batch]

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits[:, -1], axis=-1)
        return jax.random.categorical(key,
                                      logits[:, -1] / self.cfg.temperature)

    def generate(self, prompts: np.ndarray,
                 max_new_tokens: Optional[int] = None) -> np.ndarray:
        """prompts: (B, S) int32 (right-aligned, no padding support needed
        for fixed-length prompt batches). Returns (B, new) int32."""
        B, S = prompts.shape
        mnt = max_new_tokens or self.cfg.max_new_tokens
        assert S + mnt <= self.cfg.max_seq, (S, mnt, self.cfg.max_seq)
        key = jax.random.PRNGKey(self.cfg.seed)

        states, logits = self._prefill(self.params,
                                       {"tokens": jnp.asarray(prompts)})
        decode = self._decode_fn(B)
        out = []
        key, k0 = jax.random.split(key)
        tok = self._sample(logits, k0)
        out.append(tok)
        for _ in range(mnt - 1):
            key, k = jax.random.split(key)
            states, logits = decode(self.params, states, tok[:, None],
                                    None)
            tok = self._sample(logits, k)
            out.append(tok)
        return np.asarray(jnp.stack(out, axis=1))

    # ------------------------------------------------------------------
    # rpc endpoint
    # ------------------------------------------------------------------

    def rpc_handler(self, bufs: List[np.ndarray]) -> List[np.ndarray]:
        """``generate`` method body: iovec request -> iovec reply."""
        prompts, mnt = decode_generate_request(bufs)
        out = self.generate(prompts, mnt or None)
        return encode_generate_reply(out)

    def attach(self, server) -> None:
        """Register this engine's methods on an ``rpc.Server``."""
        server.register(GENERATE_METHOD, self.rpc_handler)

    def serve_loopback(self, *, endpoint: int = 0, client: int = 1,
                       serialized: bool = True):
        """One-call wiring for single-host serving experiments: a
        loopback-transport fabric with this engine at ``endpoint``.
        Returns (fabric, client channel)."""
        from repro import rpc as rpclib
        fabric = rpclib.RpcFabric(
            rpclib.LoopbackTransport(max(endpoint, client) + 1))
        self.attach(fabric.add_server(endpoint))
        return fabric, fabric.channel(client, endpoint,
                                      serialized=serialized)


# ---------------------------------------------------------------------------
# generate-over-rpc wire codec + client stub
# ---------------------------------------------------------------------------

GENERATE_METHOD = "generate"


def _i32_buf(values) -> np.ndarray:
    return np.ascontiguousarray(np.asarray(values, dtype="<i4")) \
        .view(np.uint8).reshape(-1)


def encode_generate_request(prompts: np.ndarray,
                            max_new_tokens: int = 0) -> List[np.ndarray]:
    """[header(B, S, max_new_tokens) | row-major int32 tokens]."""
    B, S = prompts.shape
    return [_i32_buf([B, S, max_new_tokens]),
            _i32_buf(prompts)]


def decode_generate_request(bufs: List[np.ndarray]
                            ) -> Tuple[np.ndarray, int]:
    B, S, mnt = np.ascontiguousarray(bufs[0]).view("<i4")[:3]
    prompts = np.ascontiguousarray(bufs[1]).view("<i4") \
        .reshape(int(B), int(S))
    return prompts, int(mnt)


def encode_generate_reply(tokens: np.ndarray) -> List[np.ndarray]:
    B, N = tokens.shape
    return [_i32_buf([B, N]), _i32_buf(tokens)]


def decode_generate_reply(bufs: List[np.ndarray]) -> np.ndarray:
    B, N = np.ascontiguousarray(bufs[0]).view("<i4")[:2]
    return np.ascontiguousarray(bufs[1]).view("<i4") \
        .reshape(int(B), int(N))


def rpc_generate(channel, prompts: np.ndarray,
                 max_new_tokens: int = 0) -> np.ndarray:
    """Client stub: one unary ``generate`` call, driven to completion."""
    call = channel.call(GENERATE_METHOD,
                        encode_generate_request(prompts, max_new_tokens))
    channel.fabric.flush()
    return decode_generate_reply(call.reply_bufs())
