"""Batched serving engine: prefill + decode with per-layer KV/SSM state,
greedy/temperature sampling, static batch with slot reuse.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.launch import steps as steps_lib
from repro.models import model as M
from repro.parallel.sharding import ParallelCtx


@dataclass
class ServeConfig:
    max_seq: int = 512
    max_new_tokens: int = 32
    temperature: float = 0.0     # 0 = greedy
    eos_id: Optional[int] = None
    seed: int = 0


class ServeEngine:
    def __init__(self, ctx: ParallelCtx, acfg: ArchConfig, params,
                 cfg: ServeConfig = ServeConfig()):
        assert not acfg.model.is_encoder, "encoder models do not decode"
        self.ctx, self.acfg, self.cfg = ctx, acfg, cfg
        self.params = params
        self._prefill = steps_lib.make_prefill_step(ctx, acfg,
                                                    max_seq=cfg.max_seq)
        self._decode = {}

    def _decode_fn(self, batch: int):
        if batch not in self._decode:
            self._decode[batch] = steps_lib.make_decode_step(
                self.ctx, self.acfg, batch)
        return self._decode[batch]

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits[:, -1], axis=-1)
        return jax.random.categorical(key,
                                      logits[:, -1] / self.cfg.temperature)

    def generate(self, prompts: np.ndarray,
                 max_new_tokens: Optional[int] = None) -> np.ndarray:
        """prompts: (B, S) int32 (right-aligned, no padding support needed
        for fixed-length prompt batches). Returns (B, new) int32."""
        B, S = prompts.shape
        mnt = max_new_tokens or self.cfg.max_new_tokens
        assert S + mnt <= self.cfg.max_seq, (S, mnt, self.cfg.max_seq)
        key = jax.random.PRNGKey(self.cfg.seed)

        states, logits = self._prefill(self.params,
                                       {"tokens": jnp.asarray(prompts)})
        decode = self._decode_fn(B)
        out = []
        key, k0 = jax.random.split(key)
        tok = self._sample(logits, k0)
        out.append(tok)
        for _ in range(mnt - 1):
            key, k = jax.random.split(key)
            states, logits = decode(self.params, states, tok[:, None],
                                    None)
            tok = self._sample(logits, k)
            out.append(tok)
        return np.asarray(jnp.stack(out, axis=1))
