"""Continuous-batching request scheduler for the serving engine
(vLLM/aphrodite-style).

``ServeScheduler`` holds a queue of in-flight generation requests and
advances ALL of them one token per :meth:`step` — requests join and
leave the shared decode loop mid-flight instead of one fixed batch
running to completion:

    waiting -> prefill -> decode -> { finished,
                                      preempted -> waiting -> ... }

Admission is gated twice: ``max_batch`` caps how many requests decode
concurrently, and a modeled KV-cache block budget (``kv_blocks`` blocks
of ``block_size`` token slots each, :func:`blocks_per_seq` per
sequence) caps how much cache the running set may occupy. When decode
growth exhausts the budget the most recently admitted request is
**preempted by recompute**: its device state is dropped, the request is
requeued at the head of the wait queue, and on re-admission its state
is rebuilt deterministically from the prompt and the tokens it already
produced — byte-identical continuation, never a duplicated or skipped
token (already-streamed chunks are tracked by ``Request.emitted``).

Every request's decode states live at the request's own batch size, so
the token stream of a request is bit-exact with a solo
``ServeEngine.generate`` run regardless of what else shares the loop
(per-row determinism of prefill/decode; the arrival-order hypothesis
suite asserts this).

Scheduler phases are recorded as tracer spans on the serving
endpoint's track (``waiting`` / ``prefill`` / ``decode`` /
``preempted``) when the scheduler is bound to an ``rpc.Server`` with a
tracer attached — ``serve --trace`` shows per-request timelines.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Any, Deque, Dict, Iterator, List, Optional

import numpy as np

#: admission-ordering policies a ServeScheduler understands
SCHED_POLICIES = ("fifo", "sjf")

#: request lifecycle states
WAITING = "waiting"
RUNNING = "running"
PREEMPTED = "preempted"
FINISHED = "finished"
CANCELLED = "cancelled"


def blocks_per_seq(prompt_len: int, generated: int, *,
                   block_size: int = 16) -> int:
    """KV-cache blocks one sequence occupies: the prompt plus every
    generated token, in ``block_size``-token blocks (the paged-KV
    accounting unit — a partially filled block still occupies a whole
    block)."""
    assert prompt_len >= 1 and generated >= 0 and block_size >= 1
    return -(-(prompt_len + generated) // block_size)


class Request:
    """One generation request in the scheduler: a (B, S) prompt block
    decoding ``max_new_tokens`` steps. ``tokens`` holds every produced
    (B,) step vector; ``emitted`` counts how many of them the consumer
    (the rpc stream pump, or ``run``) has taken — preemption never
    rewinds it, so re-derived tokens are not re-delivered."""

    __slots__ = ("id", "prompts", "max_new_tokens", "rows",
                 "prompt_len", "tokens", "emitted", "state", "runtime",
                 "pump", "_phase_t0")

    def __init__(self, rid: int, prompts: np.ndarray,
                 max_new_tokens: int):
        B, S = prompts.shape
        self.id = rid
        self.prompts = prompts
        self.max_new_tokens = int(max_new_tokens)
        self.rows, self.prompt_len = int(B), int(S)
        self.tokens: List[np.ndarray] = []
        self.emitted = 0
        self.state = WAITING
        self.runtime: Any = None      # engine-owned device state
        self.pump: Any = None         # rpc.StreamPump when rpc-routed
        self._phase_t0 = 0.0

    @property
    def generated(self) -> int:
        return len(self.tokens)

    @property
    def finished(self) -> bool:
        return self.state == FINISHED

    def blocks(self, *, block_size: int, extra: int = 0) -> int:
        """Blocks this request's ``rows`` sequences occupy with
        ``extra`` more generated tokens per row."""
        return self.rows * blocks_per_seq(self.prompt_len,
                                          self.generated + extra,
                                          block_size=block_size)


class ServeScheduler:
    """The per-endpoint continuous-batching loop. ``engine`` provides
    the model ops (``scheduler_prefill`` / ``scheduler_decode`` /
    ``scheduler_rebuild``); the scheduler owns admission, preemption,
    and per-request token delivery.

    ``kv_blocks=None`` disables the cache budget (admission is then
    capped by ``max_batch`` alone). The budget must fit at least one
    sequence: a lone over-budget request still runs — a scheduler that
    preempted its only request would livelock.

    ``policy`` orders admission from the wait queue: ``"fifo"``
    (arrival order, the default) or ``"sjf"`` — shortest-prompt-first
    with FIFO tiebreak, which cuts mean queueing delay under
    heavy-tailed prompt lengths at the cost of delaying long prompts.
    Two guards keep SJF safe: preempted requests always resume before
    fresh admissions (their recompute debt only grows while they
    wait), and a request whose wait exceeds ``starvation_age_s``
    regains strict FIFO priority (the starvation escape hatch — a
    stream of short prompts can otherwise park a long one forever)."""

    def __init__(self, engine, *, max_batch: int = 8,
                 kv_blocks: Optional[int] = None, block_size: int = 16,
                 policy: str = "fifo",
                 starvation_age_s: Optional[float] = None):
        assert max_batch >= 1, max_batch
        assert kv_blocks is None or kv_blocks >= 1, kv_blocks
        assert block_size >= 1, block_size
        if policy not in SCHED_POLICIES:
            raise ValueError(f"unknown scheduler policy {policy!r}; "
                             f"choose from {SCHED_POLICIES}")
        assert starvation_age_s is None or starvation_age_s >= 0.0
        self.engine = engine
        self.max_batch = max_batch
        self.kv_blocks = kv_blocks
        self.block_size = block_size
        self.policy = policy
        self.starvation_age_s = starvation_age_s
        self.waiting: Deque[Request] = deque()
        self.running: List[Request] = []
        self.counters: Dict[str, int] = {
            "submitted": 0, "admitted": 0, "finished": 0,
            "preempted": 0, "requeued": 0, "cancelled": 0, "steps": 0,
            "peak_running": 0, "peak_waiting": 0,
        }
        self._server = None          # rpc.Server this endpoint serves on
        self._next_id = 1

    # wiring -----------------------------------------------------------
    def bind(self, server) -> "ServeScheduler":
        """Adopt an ``rpc.Server``'s clock and tracer: phase spans land
        on its endpoint track, timestamps on the fabric clock."""
        self._server = server
        return self

    def _now(self) -> float:
        if self._server is not None:
            return self._server.clock()
        return time.perf_counter()

    def _span(self, req: Request, name: str, t0: float, t1: float,
              **attrs) -> None:
        srv = self._server
        if srv is None or req.pump is None or req.pump.frame is None:
            return
        tracer = srv.tracer
        if tracer is not None:
            tracer.server_span(req.pump.frame, srv.endpoint, name,
                               t0, t1, request=req.id, **attrs)

    def _enter_phase(self, req: Request, state: str) -> None:
        req.state = state
        req._phase_t0 = self._now()

    def _close_phase(self, req: Request, name: str, **attrs) -> None:
        self._span(req, name, req._phase_t0, self._now(), **attrs)

    # intake -----------------------------------------------------------
    def submit(self, prompts: np.ndarray,
               max_new_tokens: Optional[int] = None) -> Request:
        """Queue one (B, S) prompt block; it joins the decode loop at a
        later :meth:`step` when ``max_batch`` and the block budget
        admit it."""
        prompts = np.asarray(prompts)
        assert prompts.ndim == 2, prompts.shape
        mnt = max_new_tokens or self.engine.cfg.max_new_tokens
        S = prompts.shape[1]
        assert S + mnt <= self.engine.cfg.max_seq, \
            (S, mnt, self.engine.cfg.max_seq)
        req = Request(self._next_id, prompts, mnt)
        self._next_id += 1
        self._enter_phase(req, WAITING)
        self.waiting.append(req)
        self.counters["submitted"] += 1
        self.counters["peak_waiting"] = max(
            self.counters["peak_waiting"], len(self.waiting))
        return req

    def cancel(self, req: Request) -> None:
        """Evict a request whose consumer is gone (cancelled rpc call,
        expired deadline): drop device state, leave the loop."""
        if req.state in (FINISHED, CANCELLED):
            return
        self._close_phase(req,
                          "decode" if req.state == RUNNING else req.state)
        if req in self.running:
            self.running.remove(req)
        if req in self.waiting:
            self.waiting.remove(req)
        req.runtime = None
        req.state = CANCELLED
        self.counters["cancelled"] += 1

    # accounting -------------------------------------------------------
    def load(self) -> int:
        """Requests in the loop (running + waiting) — the load signal
        the ``scheduler_least_loaded`` dispatch policy reads via the
        metrics gauge."""
        return len(self.running) + len(self.waiting)

    def used_blocks(self, *, extra: int = 0) -> int:
        return sum(r.blocks(block_size=self.block_size, extra=extra)
                   for r in self.running)

    def _fits(self, req: Request) -> bool:
        if self.kv_blocks is None:
            return True
        if not self.running:
            return True          # a lone request always runs
        need = req.blocks(block_size=self.block_size, extra=1)
        return self.used_blocks(extra=1) + need <= self.kv_blocks

    def _next_index(self) -> int:
        """Index into ``waiting`` of the next request to admit.
        FIFO: the head. SJF: preempted requests first (resume debt),
        then any request past the starvation age (FIFO among those),
        then shortest prompt with FIFO (submit-id) tiebreak."""
        if self.policy == "fifo" or len(self.waiting) <= 1:
            return 0
        preempted = [i for i, r in enumerate(self.waiting)
                     if r.state == PREEMPTED]
        if preempted:
            return min(preempted,
                       key=lambda i: self.waiting[i].id)
        if self.starvation_age_s is not None:
            now = self._now()
            starved = [i for i, r in enumerate(self.waiting)
                       if now - r._phase_t0 >= self.starvation_age_s]
            if starved:
                return min(starved, key=lambda i: self.waiting[i].id)
        return min(range(len(self.waiting)),
                   key=lambda i: (self.waiting[i].prompt_len,
                                  self.waiting[i].id))

    # the shared decode step -------------------------------------------
    def step(self) -> int:
        """One tick of the continuous batch: admit/resume what fits,
        preempt on budget exhaustion, then advance every running
        request one token. Returns the number of tokens produced."""
        fresh: List[Request] = []
        # join: policy order, bounded by max_batch + kv budget (the
        # selected candidate not fitting blocks further admission —
        # no fill-around, so an almost-admitted request cannot starve)
        while self.waiting and len(self.running) < self.max_batch:
            idx = self._next_index()
            if not self._fits(self.waiting[idx]):
                break
            req = self.waiting[idx]
            del self.waiting[idx]
            resumed = req.state == PREEMPTED
            self._close_phase(req, WAITING if not resumed else PREEMPTED)
            t0 = self._now()
            if resumed:
                self.engine.scheduler_rebuild(req)
            else:
                tok = self.engine.scheduler_prefill(req)
                req.tokens.append(tok)
            self._span(req, "prefill", t0, self._now(),
                       resumed=resumed)
            self._enter_phase(req, RUNNING)
            self.running.append(req)
            self.counters["admitted"] += 1
            fresh.append(req)
        self.counters["peak_running"] = max(
            self.counters["peak_running"], len(self.running))
        # evict-by-recompute: decode growth is about to write one more
        # token per row; shed the most recent joiners until it fits
        while self.kv_blocks is not None and len(self.running) > 1 \
                and self.used_blocks(extra=1) > self.kv_blocks:
            victim = self.running.pop()
            self._close_phase(victim, "decode")
            victim.runtime = None
            if victim in fresh:
                fresh.remove(victim)
            self._enter_phase(victim, PREEMPTED)
            self.waiting.appendleft(victim)
            self.counters["preempted"] += 1
            self.counters["requeued"] += 1
        produced = 0
        for req in list(self.running):
            if req not in fresh:     # joiners produced theirs at prefill
                req.tokens.append(self.engine.scheduler_decode(req))
            produced += 1
            if req.generated >= req.max_new_tokens:
                self._close_phase(req, "decode")
                self.running.remove(req)
                req.runtime = None
                req.state = FINISHED
                self.counters["finished"] += 1
        if produced:
            self.counters["steps"] += 1
        return produced

    # consumers --------------------------------------------------------
    def stream_tokens(self, req: Request) -> Iterator[np.ndarray]:
        """Per-request token stream: yields each (B,) step vector in
        order, driving :meth:`step` when starved — the generator the
        rpc ``generate_stream`` pump wraps. Closing the generator
        early (cancelled call) evicts the request."""
        try:
            while True:
                if req.emitted < len(req.tokens):
                    tok = req.tokens[req.emitted]
                    req.emitted += 1
                    yield tok
                elif req.finished:
                    return
                elif req.state == CANCELLED:
                    return
                else:
                    self.step()
        finally:
            if not req.finished:
                self.cancel(req)

    def run(self, req: Request) -> np.ndarray:
        """Drive the loop until ``req`` finishes (other in-flight
        requests advance alongside); returns the (B, new) block."""
        while not req.finished:
            assert req.state != CANCELLED, "request was cancelled"
            self.step()
        req.emitted = req.generated
        return np.stack(req.tokens, axis=1)

    # reporting --------------------------------------------------------
    def stats(self) -> Dict[str, int]:
        """Counters + live load, JSON-ready — surfaced in
        ``rpc_metrics`` via ``MetricsInterceptor.attach_gauges``."""
        out = dict(self.counters)
        out["running"] = len(self.running)
        out["waiting"] = len(self.waiting)
        out["used_blocks"] = self.used_blocks()
        out["policy"] = self.policy
        if self.kv_blocks is not None:
            out["kv_blocks"] = self.kv_blocks
        return out


__all__ = ["CANCELLED", "FINISHED", "PREEMPTED", "RUNNING", "Request",
           "SCHED_POLICIES", "ServeScheduler", "WAITING",
           "blocks_per_seq"]
