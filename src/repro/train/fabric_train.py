"""Data-parallel training steps over the RPC fabric — the paper's
training workload (§3: tensor updates between PS and workers) on the
same datapath the micro-benchmarks measure.

Two gradient-synchronization modes, one step API:

  ps         the paper's deployment: parameters sharded across
             ``n_ps`` server endpoints (balanced, like the sharded
             serving dispatch); every worker pushes its gradient
             shard to the owning PS (one tagged push flight — the PS
             ingress of that flight is exactly
             ``netmodel.ps_round_time`` of the shard payload), each
             PS applies the SGD update in ascending worker order,
             then fans the updated shards back out (the fetch
             flight).
  allreduce  no servers: every endpoint is a worker and the gradient
             is reduced with an ``rpc.collectives`` schedule
             (``ring`` / ``tree`` / ``rsag``), then applied locally.

Gradients come from :class:`SyntheticGradEngine` — a numpy-only
stand-in mirroring ``workload.driver.SyntheticEngine``: the local
gradient is a pure function of ``(seed, worker, step, params)``, so
two runs of the same config produce bit-identical parameters (the
fault tier retries a push and nothing changes) and tier-1 never
imports jax. A real ``train.trainer`` step plugs in through the same
``grad_fn(params, worker, step)`` hook.

``ps_train_step_time`` / ``allreduce_train_step_time`` are the closed
forms the simulated transport matches exactly;
``launch.bench_comm --benchmark train_step --train-mode ps|allreduce``
sweeps them against each other to find the PS -> allreduce crossover
as workers grow.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional

import numpy as np

from repro.core.netmodel import (ALLREDUCE_TAG_BYTES, NetworkModel,
                                 allreduce_chunk_sizes,
                                 resolve_wire_mode)
from repro.rpc.collectives import (CollectiveReport, _inboxes,
                                   _read_tagged, _stub, _tag)

_DTYPE = np.float32
_ITEMSIZE = 4


class SyntheticGradEngine:
    """Numpy-only synthetic gradient source (quadratic loss).

    Worker ``w`` at step ``t`` pulls toward a seeded target vector
    ``target(w, t)``: ``grad = params - target``, ``loss = 0.5 *
    mean((params - target)^2)``. Like ``SyntheticEngine``'s token
    stream, every value is a pure function of ``(seed, worker, step)``
    — replaying a run reproduces it byte-for-byte."""

    def __init__(self, n_params: int, *, seed: int = 0):
        assert n_params >= 1, n_params
        self.n_params = int(n_params)
        self.seed = int(seed)
        self.grads_computed = 0

    def init_params(self) -> np.ndarray:
        rng = np.random.default_rng([self.seed, 0xA11])
        return rng.standard_normal(self.n_params).astype(_DTYPE)

    def target(self, worker: int, step: int) -> np.ndarray:
        rng = np.random.default_rng([self.seed, worker, step])
        return rng.standard_normal(self.n_params).astype(_DTYPE)

    def grad(self, params: np.ndarray, worker: int,
             step: int) -> np.ndarray:
        self.grads_computed += 1
        return (params - self.target(worker, step)).astype(_DTYPE)

    def loss(self, params: np.ndarray, worker: int, step: int) -> float:
        d = params - self.target(worker, step)
        return float(0.5 * np.mean(d * d))


@dataclass
class TrainStepReport:
    """One data-parallel step: its comm cost and training signals."""
    step: int
    mode: str
    loss: float                  # mean pre-update loss across workers
    grad_norm: float             # L2 of the mean gradient
    elapsed_s: float = 0.0       # modeled comm time (0 on loopback)
    wall_s: float = 0.0
    flights: int = 0
    messages: int = 0
    modeled: bool = False


@dataclass
class FabricTrainConfig:
    mode: str = "allreduce"           # "ps" | "allreduce"
    algo: str = "ring"                # allreduce schedule
    n_ps: int = 2                     # PS endpoints (ps mode)
    n_params: int = 4096
    lr: float = 0.1
    seed: int = 0
    serialized: bool = False
    wire_mode: Optional[str] = None


class FabricTrainStep:
    """Run data-parallel SGD steps over one fabric.

    ``ps`` mode expects endpoints ``0..n_ps-1`` to be parameter
    servers and the rest workers (the ``ps_worker_cluster`` layout);
    ``allreduce`` mode treats every endpoint as a worker. All worker
    replicas start identical and must stay bit-identical after every
    step — :meth:`step` asserts it."""

    def __init__(self, fabric, cfg: FabricTrainConfig = None, *,
                 grad_fn: Optional[Callable] = None,
                 engine: Optional[SyntheticGradEngine] = None):
        self.cfg = cfg if cfg is not None else FabricTrainConfig()
        cfg = self.cfg
        if cfg.mode not in ("ps", "allreduce"):
            raise ValueError(f"unknown train mode {cfg.mode!r}; "
                             f"expected 'ps' or 'allreduce'")
        if not fabric.transport.dispatches:
            raise ValueError("FabricTrainStep needs a dispatching "
                             "transport (loopback/simulated/cluster)")
        self.fabric = fabric
        n = fabric.n_endpoints
        if cfg.mode == "ps":
            if not 1 <= cfg.n_ps < n:
                raise ValueError(
                    f"ps mode needs 1 <= n_ps < n_endpoints: "
                    f"n_ps={cfg.n_ps}, endpoints={n}")
            self.n_ps = cfg.n_ps
            self.n_workers = n - cfg.n_ps
        else:
            if n < 2:
                raise ValueError("allreduce mode needs >= 2 endpoints")
            self.n_ps = 0
            self.n_workers = n
        if cfg.n_params < max(1, self.n_workers, self.n_ps):
            raise ValueError(
                f"n_params ({cfg.n_params}) must cover every shard: "
                f"needs >= {max(self.n_workers, self.n_ps)}")
        self.engine = engine if engine is not None \
            else SyntheticGradEngine(cfg.n_params, seed=cfg.seed)
        self.grad_fn = grad_fn if grad_fn is not None else self.engine.grad
        p0 = self.engine.init_params()
        #: per-worker parameter replicas (all bit-identical)
        self.replicas: List[np.ndarray] = [p0.copy()
                                           for _ in range(self.n_workers)]
        if cfg.mode == "ps":
            self._shard_sizes = allreduce_chunk_sizes(
                cfg.n_params * _ITEMSIZE, self.n_ps,
                itemsize=_ITEMSIZE)
            offs = [0]
            for s in self._shard_sizes:
                offs.append(offs[-1] + s // _ITEMSIZE)
            self._offs = offs
            #: the PS-side authoritative shards
            self.shards: List[np.ndarray] = [
                p0[offs[p]:offs[p + 1]].copy() for p in range(self.n_ps)]
        self.step_count = 0

    @property
    def params(self) -> np.ndarray:
        return self.replicas[0]

    def _worker_endpoint(self, w: int) -> int:
        return self.n_ps + w

    def step(self) -> TrainStepReport:
        cfg, t = self.cfg, self.step_count
        grads = [self.grad_fn(self.replicas[w], w, t)
                 for w in range(self.n_workers)]
        loss = float(np.mean([self.engine.loss(self.replicas[w], w, t)
                              for w in range(self.n_workers)]))
        if cfg.mode == "allreduce":
            rep = self._allreduce_step(grads)
        else:
            rep = self._ps_step(grads)
        mean_grad = np.sum(grads, axis=0) / self.n_workers
        out = TrainStepReport(
            step=t, mode=cfg.mode, loss=loss,
            grad_norm=float(np.linalg.norm(mean_grad)),
            elapsed_s=rep.elapsed_s, wall_s=rep.wall_s,
            flights=rep.flights, messages=rep.messages,
            modeled=rep.modeled)
        self.step_count += 1
        first = self.replicas[0]
        assert all((r == first).all() for r in self.replicas[1:]), \
            "worker replicas diverged"
        return out

    # one step per mode -------------------------------------------------
    def _allreduce_step(self, grads) -> CollectiveReport:
        from repro.rpc.collectives import allreduce
        rep = allreduce(self.fabric, self.cfg.algo, data=grads,
                        itemsize=_ITEMSIZE,
                        serialized=self.cfg.serialized,
                        wire_mode=self.cfg.wire_mode)
        scale = _DTYPE(self.cfg.lr / self.n_workers)
        for w in range(self.n_workers):
            self.replicas[w] = (self.replicas[w]
                                - scale * rep.result[w]).astype(_DTYPE)
        return rep

    def _ps_step(self, grads) -> CollectiveReport:
        fab, cfg = self.fabric, self.cfg
        boxes = _inboxes(fab)
        offs = self._offs
        rep = CollectiveReport(algo="ps",
                               modeled=fab.transport.modeled)
        # push flight: worker-major, shard-minor (the closed form
        # replays this order)
        for w in range(self.n_workers):
            ep = self._worker_endpoint(w)
            for p in range(self.n_ps):
                seg = np.ascontiguousarray(
                    grads[w][offs[p]:offs[p + 1]])
                _stub(fab, ep, p, cfg.serialized, cfg.wire_mode).chunk(
                    [_tag(ep), seg.view(np.uint8)], one_way=True)
        rep.merge(fab.flush())
        scale = _DTYPE(cfg.lr / self.n_workers)
        for p in range(self.n_ps):
            got = {}
            for entry in boxes[p]:
                src, vals = _read_tagged(entry)
                got[src] = vals
            boxes[p].clear()
            assert len(got) == self.n_workers, \
                f"ps {p}: pushes from {sorted(got)}"
            acc = None
            for src in sorted(got):         # fixed summation order
                acc = got[src] if acc is None else acc + got[src]
            self.shards[p] = (self.shards[p] - scale * acc).astype(_DTYPE)
        # fetch flight: shard-major, worker-minor
        for p in range(self.n_ps):
            for w in range(self.n_workers):
                ep = self._worker_endpoint(w)
                _stub(fab, p, ep, cfg.serialized, cfg.wire_mode).chunk(
                    [_tag(p), np.ascontiguousarray(self.shards[p])
                     .view(np.uint8)], one_way=True)
        rep.merge(fab.flush())
        for w in range(self.n_workers):
            ep = self._worker_endpoint(w)
            assert len(boxes[ep]) == self.n_ps
            for entry in boxes[ep]:
                src, vals = _read_tagged(entry)
                self.replicas[w][offs[src]:offs[src + 1]] = vals
            boxes[ep].clear()
        return rep


# ---------------------------------------------------------------------------
# closed forms (exactness held by tests/test_fabric_train.py)
# ---------------------------------------------------------------------------

def ps_train_step_time(net: NetworkModel, total_bytes: int, n_ps: int,
                       n_workers: int, *, itemsize: int = _ITEMSIZE,
                       serialized: bool = False,
                       mode: Optional[str] = None) -> float:
    """One PS step on the simulated transport: the tagged push flight
    (each PS ingests ``n_workers`` shard pushes — per PS this is
    exactly ``netmodel.ps_round_time`` of the tagged shard payload,
    racing the workers' own egress) plus the mirrored fetch flight."""
    mode = resolve_wire_mode(serialized, mode)
    shards = allreduce_chunk_sizes(total_bytes, n_ps, itemsize=itemsize)
    tag = ALLREDUCE_TAG_BYTES
    push = [(n_ps + w, p, (tag, shards[p]))
            for w in range(n_workers) for p in range(n_ps)]
    fetch = [(p, n_ps + w, (tag, shards[p]))
             for p in range(n_ps) for w in range(n_workers)]
    return (net._flight_elapsed(push, mode)
            + net._flight_elapsed(fetch, mode))


def allreduce_train_step_time(net: NetworkModel, total_bytes: int,
                              n_workers: int, *, algo: str = "ring",
                              itemsize: int = _ITEMSIZE,
                              serialized: bool = False,
                              mode: Optional[str] = None) -> float:
    """One allreduce step: the collective's closed form."""
    return net.allreduce_time(algo, total_bytes, n_workers,
                              itemsize=itemsize, serialized=serialized,
                              mode=mode)


def train_step_time(net: NetworkModel, train_mode: str,
                    total_bytes: int, n_workers: int, *,
                    n_ps: int = 2, algo: str = "ring",
                    itemsize: int = _ITEMSIZE, serialized: bool = False,
                    mode: Optional[str] = None) -> float:
    """Dispatch on the train mode (the ``bench_comm`` crossover axis:
    PS cost grows quadratically with workers through the host-copy
    contention term, ring allreduce stays near-flat)."""
    if train_mode == "ps":
        return ps_train_step_time(net, total_bytes, n_ps, n_workers,
                                  itemsize=itemsize,
                                  serialized=serialized, mode=mode)
    if train_mode == "allreduce":
        return allreduce_train_step_time(net, total_bytes, n_workers,
                                         algo=algo, itemsize=itemsize,
                                         serialized=serialized,
                                         mode=mode)
    raise ValueError(f"unknown train mode {train_mode!r}; "
                     f"expected 'ps' or 'allreduce'")


__all__ = [
    "FabricTrainConfig", "FabricTrainStep", "SyntheticGradEngine",
    "TrainStepReport", "allreduce_train_step_time",
    "ps_train_step_time", "train_step_time",
]
