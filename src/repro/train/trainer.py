"""Trainer: checkpoint/restart, straggler watchdog, failure recovery,
elastic mesh restore — the fault-tolerance layer (DESIGN.md §3.3).

Single-controller design: at 1000+ nodes this process is the per-slice
controller; the launcher (launch/train.py) handles process-level
restart, and everything the step needs (params, opt state, data cursor)
is reconstructable from (checkpoint, step index) because the data
pipeline is step-indexed and deterministic.
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import checkpoint as ckpt_lib
from repro.configs.base import ArchConfig, ShapeSpec
from repro.data.pipeline import DataConfig, device_batch, host_batch
from repro.launch import steps as steps_lib
from repro.models import model as M
from repro.optim import optimizer as O
from repro.parallel.sharding import ParallelCtx

log = logging.getLogger("repro.trainer")

#: The step fault boundary: what counts as a *node failure* the retry /
#: restart-from-checkpoint path may absorb. Device and runtime faults
#: surface as RuntimeError (jaxlib's XlaRuntimeError subclasses it) and
#: host-side checkpoint/data I/O as OSError. Programming errors
#: (TypeError, ValueError, ...) propagate — retrying them would loop a
#: bug through max_step_retries and then "recover" into the same bug
#: from the checkpoint. This is the one broad catch in
#: src/repro/train/ — the CI deprecation gate (mirrored in
#: tests/test_service_api.py) rejects inline blanket Exception handlers
#: here, exactly like inside src/repro/rpc/.
STEP_FAULTS = (RuntimeError, OSError)


@dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 25
    keep_ckpts: int = 3
    # straggler watchdog: a step slower than ema * factor is "straggling"
    straggler_factor: float = 3.0
    straggler_patience: int = 3
    max_step_retries: int = 2
    log_every: int = 10


@dataclass
class StepRecord:
    step: int
    loss: float
    wall_s: float
    straggler: bool = False
    retried: int = 0


class Trainer:
    def __init__(self, ctx: ParallelCtx, acfg: ArchConfig, shape: ShapeSpec,
                 tcfg: Optional[TrainerConfig] = None,
                 dcfg: Optional[DataConfig] = None,
                 fault_hook: Optional[Callable[[int], None]] = None):
        """fault_hook(step): test-injection point — raises to simulate a
        node failure at a given step."""
        self.ctx, self.acfg, self.shape = ctx, acfg, shape
        # None -> a fresh instance per Trainer. A dataclass-instance
        # default (``tcfg=TrainerConfig()``) is evaluated once at class
        # definition, so every Trainer would share — and mutate — the
        # same config object.
        self.tcfg = TrainerConfig() if tcfg is None else tcfg
        self.dcfg = DataConfig() if dcfg is None else dcfg
        self.fault_hook = fault_hook
        # checkpoint `extra` metadata restored by resume_or_init; saved
        # back with every checkpoint so a resume->save cycle preserves
        # whatever the launcher recorded (run id, data cursor, ...)
        self.resume_extra: Dict[str, Any] = {}
        self.step_fn = steps_lib.make_train_step(ctx, acfg, donate=False)
        self.history: List[StepRecord] = []
        self.straggler_events: List[int] = []
        self._ema: Optional[float] = None

    # ------------------------------------------------------------------
    def init_state(self, seed: int = 0):
        params = M.init_params(jax.random.PRNGKey(seed), self.acfg)
        if self.ctx.mesh is not None:
            shs = jax.tree.map(
                lambda sp: jax.NamedSharding(self.ctx.mesh, sp),
                steps_lib.param_shardings(self.ctx, self.acfg))
            params = jax.tree.map(jax.device_put, params, shs)
        opt = O.init_opt_state(self.acfg.train, params)
        return params, opt, 0

    def resume_or_init(self, seed: int = 0):
        d = self.tcfg.ckpt_dir
        if d:
            last = ckpt_lib.latest_step(d)
            if last is not None:
                params, opt, _ = self.init_state(seed)
                (params, opt), extra = ckpt_lib.restore(
                    d, last, (params, opt))
                self.resume_extra = dict(extra or {})
                log.info("resumed from step %d", last)
                return params, opt, last
        return self.init_state(seed)

    # ------------------------------------------------------------------
    def _one_step(self, params, opt, step: int):
        batch = device_batch(self.ctx, host_batch(self.acfg, self.shape,
                                                  step, self.dcfg))
        # the hook simulates in-step behaviour (failure OR slowness), so it
        # must run inside the timed window or stragglers are invisible
        t0 = time.perf_counter()
        if self.fault_hook is not None:
            self.fault_hook(step)
        params, opt, metrics = self.step_fn(params, opt, batch)
        jax.block_until_ready(metrics["loss"])
        wall = time.perf_counter() - t0
        return params, opt, float(metrics["loss"]), wall

    def train(self, params=None, opt=None, start_step: Optional[int] = None,
              seed: int = 0):
        if params is None:
            params, opt, start_step = self.resume_or_init(seed)
        step = start_step or 0
        slow_streak = 0
        while step < self.tcfg.total_steps:
            retries = 0
            while True:
                try:
                    params_n, opt_n, loss, wall = self._one_step(
                        params, opt, step)
                    break
                except STEP_FAULTS as e:    # node-failure boundary
                    retries += 1
                    log.warning("step %d failed (%s); retry %d", step, e,
                                retries)
                    if retries > self.tcfg.max_step_retries:
                        # unrecoverable in-process: restart from last ckpt
                        if self.tcfg.ckpt_dir and \
                                ckpt_lib.latest_step(self.tcfg.ckpt_dir) \
                                is not None:
                            params, opt, step = self.resume_or_init(seed)
                            retries = 0
                            continue
                        raise
            params, opt = params_n, opt_n

            # straggler watchdog
            straggler = False
            if self._ema is not None and \
                    wall > self._ema * self.tcfg.straggler_factor:
                straggler = True
                slow_streak += 1
                self.straggler_events.append(step)
                if slow_streak >= self.tcfg.straggler_patience:
                    log.warning(
                        "straggling %d consecutive steps at step %d — "
                        "checkpointing for preemptive migration",
                        slow_streak, step)
                    if self.tcfg.ckpt_dir:
                        ckpt_lib.save(self.tcfg.ckpt_dir, step + 1,
                                      (params, opt),
                                      extra=self.resume_extra)
                    slow_streak = 0
            else:
                slow_streak = 0
            if self._ema is None:
                # seed the EMA from the SECOND step: the first includes
                # compilation and would mask real stragglers for many steps
                if self.history:
                    self._ema = wall
            else:
                self._ema = 0.9 * self._ema + 0.1 * wall

            self.history.append(StepRecord(step, loss, wall, straggler,
                                           retries))
            if self.tcfg.log_every and step % self.tcfg.log_every == 0:
                log.info("step %d loss %.4f (%.1f ms)", step, loss,
                         wall * 1e3)
            step += 1
            if self.tcfg.ckpt_dir and step % self.tcfg.ckpt_every == 0:
                ckpt_lib.save(self.tcfg.ckpt_dir, step, (params, opt),
                              extra=self.resume_extra)
                ckpt_lib.prune(self.tcfg.ckpt_dir, self.tcfg.keep_ckpts)
        if self.tcfg.ckpt_dir:
            ckpt_lib.save(self.tcfg.ckpt_dir, step, (params, opt),
                          extra=self.resume_extra)
        return params, opt
