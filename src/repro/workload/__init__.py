"""Trace-driven workload generation + SLO harness.

Open-loop companion to the closed-loop micro-benchmarks: seeded
arrival processes (``arrivals``), heavy-tailed request shapes
(``lengths``), replayable schema-versioned traces (``trace``), an
open-loop driver over the serve stack on the modeled clock
(``driver``), and SLO reports folding the run's telemetry (``slo``).
See ``docs/WORKLOAD.md``.
"""
from repro.workload.arrivals import (ARRIVALS, bursty_arrivals,
                                     diurnal_arrivals, make_arrivals,
                                     poisson_arrivals)
from repro.workload.driver import (SyntheticEngine, WorkloadRecorder,
                                   WorkloadRun, materialize_prompts,
                                   run_trace, serve_workload)
from repro.workload.lengths import (LENGTHS, SIZE_CATEGORIES,
                                    fixed_lengths, lognormal_lengths,
                                    make_lengths,
                                    sample_request_shapes,
                                    zipf_lengths)
from repro.workload.slo import (SloReport, build_slo_report,
                                format_slo_table)
from repro.workload.trace import (TRACE_SCHEMA, Trace, TraceEvent,
                                  correlated_burst_windows,
                                  synthesize_trace)

__all__ = [
    "ARRIVALS", "LENGTHS", "SIZE_CATEGORIES", "SloReport",
    "SyntheticEngine", "TRACE_SCHEMA", "Trace", "TraceEvent",
    "WorkloadRecorder", "WorkloadRun", "build_slo_report",
    "bursty_arrivals", "correlated_burst_windows", "diurnal_arrivals",
    "fixed_lengths", "format_slo_table", "lognormal_lengths",
    "make_arrivals", "make_lengths", "materialize_prompts",
    "poisson_arrivals", "run_trace", "sample_request_shapes",
    "serve_workload", "synthesize_trace", "zipf_lengths",
]
