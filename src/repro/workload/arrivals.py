"""Seeded open-loop arrival processes on the modeled clock.

Every generator here returns a sorted float64 array of arrival times in
``[0, duration_s)`` — the event stream an open-loop driver fires at the
serve stack regardless of completion progress (the regime where
queueing, shedding, and tail latency actually show up; the paper's
closed-loop benchmarks by construction cannot). All randomness comes
from one ``numpy`` Generator seeded by the caller, so a schedule is a
pure function of its parameters: the same seed replays the same
arrivals, which is what makes recorded traces deterministic.

Three processes:

  poisson   homogeneous Poisson at ``rate`` req/s (exponential
            inter-arrivals) — the memoryless baseline.
  bursty    MMPP-style on-off modulation: dwell times in the ON/OFF
            states are exponential (``on_s`` / ``off_s`` means) and
            arrivals are Poisson at ``rate * burst_factor`` while ON,
            ``rate * idle_factor`` while OFF. Mean rate matches
            ``rate`` when the factors are chosen duty-cycle-neutral;
            the point is correlated load, not a different mean.
  diurnal   inhomogeneous Poisson with a sinusoidal rate curve
            ``rate * (1 + depth*sin(2*pi*t/period_s))``, sampled by
            thinning — the day/night load shape scaled down to a
            benchmark window.

This module never reads the wall clock (CI grep gate): times are
coordinates on the modeled timeline, not timestamps.
"""
from __future__ import annotations

from typing import Dict

import numpy as np


def _rng(seed) -> np.random.Generator:
    return np.random.default_rng(seed)


def poisson_arrivals(rate: float, duration_s: float, *,
                     seed: int = 0) -> np.ndarray:
    """Homogeneous Poisson arrivals at ``rate`` req/s over
    ``[0, duration_s)``."""
    assert rate > 0 and duration_s > 0, (rate, duration_s)
    rng = _rng(seed)
    # draw in blocks until the horizon is crossed; E[n] = rate*duration
    times = []
    t = 0.0
    block = max(16, int(rate * duration_s * 1.2) + 1)
    while t < duration_s:
        gaps = rng.exponential(1.0 / rate, size=block)
        ts = t + np.cumsum(gaps)
        times.append(ts)
        t = float(ts[-1])
    out = np.concatenate(times)
    return out[out < duration_s]


def bursty_arrivals(rate: float, duration_s: float, *, seed: int = 0,
                    burst_factor: float = 4.0,
                    idle_factor: float = 0.25,
                    on_s: float = 1.0, off_s: float = 1.0
                    ) -> np.ndarray:
    """MMPP on-off arrivals: Poisson at ``rate*burst_factor`` during
    exponential ON dwells (mean ``on_s``), ``rate*idle_factor`` during
    OFF dwells (mean ``off_s``). Starts ON."""
    assert rate > 0 and duration_s > 0, (rate, duration_s)
    assert burst_factor > 0 and idle_factor >= 0
    assert on_s > 0 and off_s > 0
    rng = _rng(seed)
    times = []
    t, on = 0.0, True
    while t < duration_s:
        dwell = rng.exponential(on_s if on else off_s)
        r = rate * (burst_factor if on else idle_factor)
        if r > 0:
            seg_t = t
            end = min(t + dwell, duration_s)
            while True:
                seg_t += rng.exponential(1.0 / r)
                if seg_t >= end:
                    break
                times.append(seg_t)
        t += dwell
        on = not on
    return np.asarray(times, dtype=np.float64)


def diurnal_arrivals(rate: float, duration_s: float, *, seed: int = 0,
                     period_s: float = 10.0, depth: float = 0.8
                     ) -> np.ndarray:
    """Inhomogeneous Poisson with rate
    ``rate * (1 + depth*sin(2*pi*t/period_s))``, by thinning against
    the peak rate — the diurnal load curve on a modeled timescale."""
    assert rate > 0 and duration_s > 0, (rate, duration_s)
    assert 0.0 <= depth <= 1.0, depth
    assert period_s > 0, period_s
    rng = _rng(seed)
    peak = rate * (1.0 + depth)
    candidates = poisson_arrivals(peak, duration_s,
                                  seed=rng.integers(2**32))
    lam = rate * (1.0 + depth * np.sin(
        2.0 * np.pi * candidates / period_s))
    keep = rng.random(len(candidates)) < lam / peak
    return candidates[keep]


#: arrival-process registry: kind -> generator(rate, duration_s, ...)
ARRIVALS: Dict[str, object] = {
    "poisson": poisson_arrivals,
    "bursty": bursty_arrivals,
    "diurnal": diurnal_arrivals,
}


def make_arrivals(kind: str, rate: float, duration_s: float, *,
                  seed: int = 0, **kw) -> np.ndarray:
    """Dispatch on the registry; unknown kinds fail loudly with the
    valid choices (CLI-facing)."""
    if kind not in ARRIVALS:
        raise ValueError(f"unknown arrival process {kind!r}; choose "
                         f"from {tuple(sorted(ARRIVALS))}")
    return ARRIVALS[kind](rate, duration_s, seed=seed, **kw)


__all__ = ["ARRIVALS", "bursty_arrivals", "diurnal_arrivals",
           "make_arrivals", "poisson_arrivals"]
