"""Open-loop trace driver over the serve stack.

The paper's benchmarks are closed-loop: each client waits for its
reply before issuing the next request, so the offered load collapses
to whatever the server sustains and queueing never builds. This driver
is the opposite regime — it fires every :class:`TraceEvent` at its
scheduled arrival time on the modeled clock *without waiting for
completions*, which is the only way to observe tail latency, shedding,
admission rejection, and preemption under overload.

Pacing rides the fabric's bounded flush: for each event the driver
runs ``fabric.flush(until_s=t)`` (drive in-flight work up to the
arrival time, leave the rest pending), advances the modeled clock
across any idle gap, submits through the ordinary serve stubs
(:class:`ShardedServeStub` over a cluster transport — the same
dispatch, failover, retry, and admission path production calls take),
then moves on. One final unbounded flush drains the tail. On a
non-modeled transport there is no clock to pace against, so the driver
degrades to immediate mode: submit everything in arrival order, flush
once (arrival time := scheduled time still, so SLO numbers remain
comparable).

Serving is model-free: :class:`SyntheticEngine` implements the
scheduler's model ops (prefill/decode/rebuild) in pure numpy, with
token t of a request a deterministic function of its prompt — the same
recipe the scheduler's own test double uses, so replay identity can be
asserted to the byte without touching jax.

Per-request ground truth comes from :class:`WorkloadRecorder`, a
client interceptor installed *outermost* so it sees exactly one
terminal event per request (inner retry/failover interceptors consume
non-terminal failures first). Records are keyed through
``ctx.meta["workload_event"]``, which survives retries and shard
re-routes because the fabric reuses one ``CallContext`` across
attempts.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.rpc.interceptors import ClientInterceptor

from .slo import SloReport, build_slo_report
from .trace import Trace, TraceEvent


class SyntheticEngine:
    """Numpy-only stand-in for ``ServeEngine``'s scheduler ops.

    Token ``t`` of a request is ``prompts.sum() % 997 + 7*t`` — a pure
    function of the prompt, so any two runs of the same trace must
    produce byte-identical token streams (the replay-identity check),
    and ``expected_tokens`` can verify a completed request without
    rerunning anything.
    """

    class _Cfg:
        def __init__(self, max_seq: int, max_new_tokens: int):
            self.max_seq = max_seq
            self.max_new_tokens = max_new_tokens

    def __init__(self, *, max_seq: int = 4096,
                 max_new_tokens: int = 4):
        self.cfg = self._Cfg(max_seq, max_new_tokens)
        self.prefills = self.decodes = self.rebuilds = 0

    def _tok(self, req, t: int) -> np.ndarray:
        base = int(req.prompts.sum()) % 997
        return np.full(req.rows, base + 7 * t, dtype=np.int32)

    def scheduler_prefill(self, req) -> np.ndarray:
        self.prefills += 1
        req.runtime = ("state", 0)
        return self._tok(req, 0)

    def scheduler_decode(self, req) -> np.ndarray:
        self.decodes += 1
        req.runtime = ("state", len(req.tokens))
        return self._tok(req, len(req.tokens))

    def scheduler_rebuild(self, req) -> None:
        self.rebuilds += 1
        req.runtime = ("state", len(req.tokens) - 1)

    @staticmethod
    def expected_tokens(prompts: np.ndarray, n: int) -> np.ndarray:
        """The (n,) per-step token values a request with this prompt
        block must stream (all rows carry the same value)."""
        base = int(prompts.sum()) % 997
        return base + 7 * np.arange(n, dtype=np.int64)


def materialize_prompts(seed: int, event: TraceEvent) -> np.ndarray:
    """The (rows, prompt_len) int32 prompt block for one event —
    seeded per-event off the trace seed, so replaying a trace presents
    byte-identical payloads without the trace storing them."""
    rng = np.random.default_rng([seed, event.id])
    return rng.integers(1, 997, size=(event.rows, event.prompt_len),
                        dtype=np.int32)


class WorkloadRecorder(ClientInterceptor):
    """Outermost client interceptor: one record per workload event,
    stamped on the fabric clock. Non-workload calls (anything without
    ``ctx.meta['workload_event']``) pass through untouched."""

    def __init__(self, fabric):
        self.fabric = fabric
        self.records: Dict[int, dict] = {}

    def expect(self, event: TraceEvent, submit_s: float) -> None:
        self.records[event.id] = {
            "id": event.id, "arrival_s": event.t_s,
            "submit_s": submit_s, "first_chunk_s": None,
            "end_s": None, "chunks": 0, "attempts": 1,
            "ok": None, "outcome": "pending",
        }

    def _rec(self, ctx) -> Optional[dict]:
        eid = ctx.meta.get("workload_event")
        return None if eid is None else self.records.get(eid)

    def on_event(self, ctx, event) -> None:
        rec = self._rec(ctx)
        if rec is None:
            return
        if event.kind == "stream_chunk":
            rec["chunks"] += 1
            if rec["first_chunk_s"] is None:
                rec["first_chunk_s"] = self.fabric.now()

    def on_complete(self, ctx, event):
        rec = self._rec(ctx)
        if rec is None:
            return None
        rec["end_s"] = ctx.end_s if ctx.end_s is not None \
            else self.fabric.now()
        rec["attempts"] = ctx.attempts
        rec["ok"] = bool(event.ok)
        rec["outcome"] = ("deadline_exceeded"
                          if event.kind == "deadline_exceeded"
                          else "ok" if event.ok else "error")
        return None


@dataclass
class WorkloadRun:
    """Everything a caller needs after a run: the per-request ground
    truth, the folded SLO report, and the live fabric (metrics,
    tracer, schedulers) for deeper digging."""
    trace: Trace
    records: List[dict]
    report: SloReport
    fabric: object
    metrics: object
    schedulers: Dict[str, object] = field(default_factory=dict)
    stubs: Dict[str, object] = field(default_factory=dict)

    def completion_times(self) -> Dict[int, Optional[float]]:
        """event id -> completion time on the modeled clock (None for
        requests that never completed) — the replay-identity probe."""
        return {r["id"]: r["end_s"] for r in self.records}


def _check_fits(trace: Trace, engine: SyntheticEngine) -> None:
    worst = max((e.prompt_len
                 + (e.max_new_tokens or engine.cfg.max_new_tokens)
                 for e in trace.events), default=0)
    if worst > engine.cfg.max_seq:
        raise ValueError(
            f"trace needs sequences up to {worst} tokens but the "
            f"synthetic engine caps at max_seq={engine.cfg.max_seq}; "
            f"regenerate with shorter lengths or raise max_seq")


def run_trace(trace: Trace, fabric, stubs: Dict[str, object], *,
              deadline_s: Optional[float] = None,
              stream: bool = True) -> WorkloadRecorder:
    """Fire the trace open-loop: ``stubs`` maps submitting worker
    names to serve stubs (``ShardedServeStub`` or a generated serve
    stub); ``event.worker`` picks the submitter (-1 = round-robin by
    event id). Returns the recorder holding per-event records."""
    recorder = WorkloadRecorder(fabric)
    fabric.client_interceptors.insert(0, recorder)
    workers = sorted(stubs)
    transport = fabric.transport
    modeled = bool(getattr(transport, "modeled", False)) \
        and hasattr(transport, "clock_s")
    try:
        for ev in trace.events:
            if modeled:
                fabric.flush(until_s=ev.t_s)
                if transport.clock_s < ev.t_s:
                    # idle gap: nothing in flight reaches the arrival,
                    # so jump the modeled clock to it
                    transport.clock_s = ev.t_s
            stub = stubs[workers[ev.worker if ev.worker >= 0
                                 else ev.id % len(workers)]]
            prompts = materialize_prompts(trace.seed, ev)
            method = (stub.generate_stream if stream
                      else stub.generate)
            handle = method(prompts, ev.max_new_tokens,
                            deadline_s=deadline_s)
            recorder.expect(ev, fabric.now())
            ctx = fabric.context(handle.call_id)
            assert ctx is not None, "submit must create a context"
            ctx.meta["workload_event"] = ev.id
        fabric.flush()           # drain the tail, unbounded
    finally:
        fabric.client_interceptors.remove(recorder)
    return recorder


def serve_workload(trace: Trace, *,
                   cluster=None, n_ps: int = 1, n_workers: int = 2,
                   dispatch_policy: str = "round_robin",
                   sched_policy: str = "fifo",
                   starvation_age_s: Optional[float] = None,
                   max_batch: int = 8,
                   kv_blocks: Optional[int] = None,
                   block_size: int = 16,
                   deadline_s: Optional[float] = None,
                   retry_attempts: int = 4,
                   stream: bool = True,
                   max_seq: int = 4096,
                   max_new_tokens: int = 4,
                   fault: Optional[dict] = None,
                   tracer=None) -> WorkloadRun:
    """One-call workload run: build a PS/worker cluster fabric serving
    a :class:`SyntheticEngine` through real per-endpoint
    ``ServeScheduler``\\ s, fire ``trace`` open-loop, and fold the SLO
    report.

    ``cluster`` is any ``rpc.ClusterSpec``-coercible (default: a
    ``ps_worker_cluster(n_ps, n_workers)``). ``fault`` passes
    ``FaultInjectionTransport`` kwargs; the trace's own
    ``fault_windows`` are merged in as correlated burst-loss windows,
    so a recorded trace replays its fault schedule too.
    """
    from repro import rpc as rpclib
    from repro.rpc.cluster import as_cluster_spec
    from repro.serve.engine import ShardedServeStub, bind_scheduler
    from repro.serve.scheduler import ServeScheduler

    spec = as_cluster_spec(cluster) if cluster is not None \
        else rpclib.ps_worker_cluster(n_ps, n_workers)
    ps = spec.job_endpoints("ps")
    workers = spec.job_endpoints("worker")
    if not ps or not workers:
        raise ValueError(
            f"workload serving needs >= 1 ps and >= 1 worker "
            f"endpoint; cluster jobs: "
            f"{ {j: len(e) for j, e in spec.jobs.items()} }")

    transport = rpclib.make_transport("cluster", cluster=spec)
    fault_kw = dict(fault or {})
    if trace.fault_windows:
        fault_kw.setdefault("burst_windows", [])
        fault_kw["burst_windows"] = (list(fault_kw["burst_windows"])
                                     + list(trace.fault_windows))
    if fault_kw:
        transport = rpclib.make_transport("fault", inner=transport,
                                          **fault_kw)

    metrics = rpclib.MetricsInterceptor(per_endpoint=True,
                                        endpoint_name=spec.name_of)
    fabric = rpclib.RpcFabric(
        transport,
        client_interceptors=[
            metrics,
            rpclib.RetryInterceptor(max_attempts=retry_attempts)],
        server_interceptors=[metrics],
        tracer=tracer)
    limits = spec.admission_limits()
    if limits:
        fabric.server_interceptors.append(
            rpclib.AdmissionInterceptor(limits=limits,
                                        metrics=metrics))

    engine = SyntheticEngine(max_seq=max_seq,
                             max_new_tokens=max_new_tokens)
    _check_fits(trace, engine)
    schedulers: Dict[str, ServeScheduler] = {}
    for name in ps:
        sched = ServeScheduler(engine, max_batch=max_batch,
                               kv_blocks=kv_blocks,
                               block_size=block_size,
                               policy=sched_policy,
                               starvation_age_s=starvation_age_s)
        schedulers[name] = bind_scheduler(fabric.add_server(name),
                                          sched)
    stubs = {w: ShardedServeStub(fabric, w, ps,
                                 policy=dispatch_policy)
             for w in workers}

    recorder = run_trace(trace, fabric, stubs,
                         deadline_s=deadline_s, stream=stream)
    records = [recorder.records[k]
               for k in sorted(recorder.records)]
    span = max(trace.duration_s, 1e-9)
    report = build_slo_report(
        records, span_s=span, deadline_s=deadline_s,
        metrics=metrics,
        scheduler_stats=[s.stats() for s in schedulers.values()])
    return WorkloadRun(trace=trace, records=records, report=report,
                       fabric=fabric, metrics=metrics,
                       schedulers=schedulers, stubs=stubs)


__all__ = ["SyntheticEngine", "WorkloadRecorder", "WorkloadRun",
           "materialize_prompts", "run_trace", "serve_workload"]
