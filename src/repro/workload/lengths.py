"""Heavy-tailed request length samplers.

Real serving traffic does not have the neat fixed payload sizes of the
paper's Table 1 micro-benchmark categories: prompt and decode lengths
are heavy-tailed, and the tail is precisely what stresses batching,
KV-block admission, and preemption. This module samples (prompt_len,
max_new_tokens) pairs from seeded distributions, with the paper's
fixed size categories available as the degenerate case so the
micro-benchmark grid and the workload generator share one vocabulary.

  lognormal   int-rounded lognormal clipped to [lo, hi] — the standard
              fit for production prompt-length histograms.
  zipf        bounded Zipf over [lo, hi]: P(k) propto 1/k**alpha.
              Heavier tail, exercises the SJF/starvation trade-off.
  fixed       every request identical — the paper's Table 1 categories
              expressed in the same interface.

All samplers take a numpy Generator (or seed) and return int64 arrays,
never touching the wall clock.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

#: paper Table 1 payload categories, expressed as token lengths for the
#: serve path (small/medium/large prompt regimes).
SIZE_CATEGORIES: Dict[str, int] = {
    "small": 8,
    "medium": 32,
    "large": 128,
}


def _rng(seed) -> np.random.Generator:
    if isinstance(seed, np.random.Generator):
        return seed
    return np.random.default_rng(seed)


def lognormal_lengths(n: int, *, seed=0, mean: float = 3.0,
                      sigma: float = 0.6, lo: int = 1,
                      hi: int = 256) -> np.ndarray:
    """``n`` int lengths from exp(N(mean, sigma)) clipped to
    ``[lo, hi]``."""
    assert n >= 0 and lo >= 1 and hi >= lo, (n, lo, hi)
    rng = _rng(seed)
    raw = np.exp(rng.normal(mean, sigma, size=n))
    return np.clip(np.rint(raw), lo, hi).astype(np.int64)


def zipf_lengths(n: int, *, seed=0, alpha: float = 1.3, lo: int = 1,
                 hi: int = 256) -> np.ndarray:
    """``n`` int lengths from a bounded Zipf over ``[lo, hi]``:
    P(k) propto 1/k**alpha after shifting so ``lo`` maps to rank 1."""
    assert n >= 0 and lo >= 1 and hi >= lo, (n, lo, hi)
    assert alpha > 0, alpha
    rng = _rng(seed)
    ks = np.arange(1, hi - lo + 2, dtype=np.float64)
    p = ks ** -alpha
    p /= p.sum()
    return (lo - 1 + rng.choice(ks, size=n, p=p)).astype(np.int64)


def fixed_lengths(n: int, *, seed=0, value: int = 32) -> np.ndarray:
    """Degenerate sampler: every length is ``value`` (paper Table 1
    categories). ``seed`` is accepted for interface uniformity."""
    assert n >= 0 and value >= 1, (n, value)
    return np.full(n, value, dtype=np.int64)


#: length-sampler registry: kind -> sampler(n, seed=..., **kw)
LENGTHS: Dict[str, object] = {
    "lognormal": lognormal_lengths,
    "zipf": zipf_lengths,
    "fixed": fixed_lengths,
}


def make_lengths(kind: str, n: int, *, seed=0, **kw) -> np.ndarray:
    if kind not in LENGTHS:
        if kind in SIZE_CATEGORIES:  # paper category name as shorthand
            return fixed_lengths(n, seed=seed,
                                 value=SIZE_CATEGORIES[kind])
        raise ValueError(
            f"unknown length sampler {kind!r}; choose from "
            f"{tuple(sorted(LENGTHS))} or a size category "
            f"{tuple(sorted(SIZE_CATEGORIES))}")
    return LENGTHS[kind](n, seed=seed, **kw)


def sample_request_shapes(n: int, *, seed=0,
                          prompt_kind: str = "lognormal",
                          decode_kind: str = "fixed",
                          prompt_kw: dict = None,
                          decode_kw: dict = None
                          ) -> Tuple[np.ndarray, np.ndarray]:
    """Sample ``n`` (prompt_len, max_new_tokens) pairs with independent
    substreams so changing one sampler never perturbs the other."""
    root = _rng(seed)
    p_seed, d_seed = root.integers(2**32, size=2)
    prompts = make_lengths(prompt_kind, n, seed=int(p_seed),
                           **(prompt_kw or {}))
    decodes = make_lengths(decode_kind, n, seed=int(d_seed),
                           **(decode_kw or {"value": 4}))
    return prompts, decodes


__all__ = ["LENGTHS", "SIZE_CATEGORIES", "fixed_lengths",
           "lognormal_lengths", "make_lengths",
           "sample_request_shapes", "zipf_lengths"]
