"""SLO reports over a workload run.

The paper's micro-benchmarks report closed-loop throughput; an SLO
report answers the question operators actually ask of a serving stack:
*under this offered load, what fraction of requests met their latency
target, and where did the rest go?* This module folds three telemetry
sources the fabric already produces — the driver's per-request records
(arrival/first-chunk/completion on the modeled clock), the
``MetricsInterceptor`` snapshot (retries, sheds, admission rejections,
per-endpoint queue peaks), and the serve schedulers' counters
(preemptions) — into one :class:`SloReport`.

Latency tails come from :class:`repro.rpc.telemetry.BoundedHistogram`
(exact percentiles for benchmark-sized runs, conservative log-bucketed
folding past ``EXACT_CAP``), so p999 here has the same semantics as
everywhere else in the telemetry tier.

Definitions (all on the modeled clock, relative to the *scheduled*
arrival — open-loop latency includes the queueing a closed-loop
harness hides):

  TTFT        first streamed token minus arrival (unary: completion
              minus arrival — the whole block is the first "token").
  per-token   (completion - first token) / (chunks - 1); only defined
              for streams that delivered >= 2 chunks.
  e2e         completion minus arrival.
  goodput     completed-ok requests that also met ``deadline_s``
              end-to-end, per second of trace span.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from repro.rpc.telemetry import BoundedHistogram

#: percentile set every latency block reports
_QS = (50.0, 99.0, 99.9)


def _tail(hist: BoundedHistogram) -> Dict[str, float]:
    if hist.count == 0:
        return {"n": 0}
    p50, p99, p999 = hist.percentiles(_QS)
    return {"n": hist.count, "mean": hist.mean, "p50": p50,
            "p99": p99, "p999": p999, "max": hist.max}


@dataclass
class SloReport:
    """One workload run, summarised. ``to_dict`` is the JSON shape the
    bench CLI embeds; ``format_slo_table`` renders it for terminals."""
    offered: int                 # events in the trace
    completed_ok: int
    errors: int
    deadline_exceeded: int
    span_s: float                # trace span the rates normalise over
    offered_rps: float
    goodput_rps: float
    slo_attainment: float        # ok-and-within-deadline / offered
    deadline_s: Optional[float]
    ttft: Dict[str, float]
    per_token: Dict[str, float]
    e2e: Dict[str, float]
    retries: int = 0
    shed: int = 0
    rejected: int = 0
    preempted: int = 0
    queue_peaks: Dict[str, int] = field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "offered": self.offered,
            "completed_ok": self.completed_ok,
            "errors": self.errors,
            "deadline_exceeded": self.deadline_exceeded,
            "span_s": self.span_s,
            "offered_rps": self.offered_rps,
            "goodput_rps": self.goodput_rps,
            "slo_attainment": self.slo_attainment,
            "deadline_s": self.deadline_s,
            "ttft_s": self.ttft,
            "per_token_s": self.per_token,
            "e2e_s": self.e2e,
            "retries": self.retries,
            "shed": self.shed,
            "rejected": self.rejected,
            "preempted": self.preempted,
            "queue_peaks": self.queue_peaks,
        }


def build_slo_report(records: List[dict], *,
                     span_s: float,
                     deadline_s: Optional[float] = None,
                     metrics=None,
                     scheduler_stats: Optional[List[dict]] = None
                     ) -> SloReport:
    """Fold per-request records (the driver recorder's dicts) plus the
    run's ``MetricsInterceptor`` and scheduler counters into a report.

    ``span_s`` is the window rates normalise over — the trace duration
    for open-loop runs (NOT the completion time of the last request,
    which would flatter an overloaded system by stretching its
    denominator).
    """
    assert span_s > 0, span_s
    ttft = BoundedHistogram()
    per_token = BoundedHistogram()
    e2e = BoundedHistogram()
    ok = errors = exceeded = good = 0
    for rec in records:
        if rec.get("outcome") == "deadline_exceeded":
            exceeded += 1
            continue
        if not rec.get("ok"):
            errors += 1
            continue
        ok += 1
        arrival, end = rec["arrival_s"], rec["end_s"]
        first = rec.get("first_chunk_s")
        ttft.record((first if first is not None else end) - arrival)
        e2e.record(end - arrival)
        chunks = rec.get("chunks", 0)
        if first is not None and chunks >= 2:
            per_token.record((end - first) / (chunks - 1))
        if deadline_s is None or end - arrival <= deadline_s:
            good += 1

    retries = shed = rejected = 0
    queue_peaks: Dict[str, int] = {}
    if metrics is not None:
        for key, rec in metrics.snapshot().items():
            if key.startswith("server:"):
                shed += rec.get("shed", 0)
                rejected += rec.get("rejected", 0)
                if "@" in key and "queue_peak" in rec:
                    ep = key.split("@", 1)[1]
                    queue_peaks[ep] = max(queue_peaks.get(ep, 0),
                                          rec["queue_peak"])
            else:
                retries += rec.get("retries", 0)

    preempted = sum(s.get("preempted", 0)
                    for s in (scheduler_stats or []))

    offered = len(records)
    return SloReport(
        offered=offered, completed_ok=ok, errors=errors,
        deadline_exceeded=exceeded, span_s=span_s,
        offered_rps=offered / span_s, goodput_rps=good / span_s,
        slo_attainment=(good / offered) if offered else 0.0,
        deadline_s=deadline_s, ttft=_tail(ttft),
        per_token=_tail(per_token), e2e=_tail(e2e),
        retries=retries, shed=shed, rejected=rejected,
        preempted=preempted, queue_peaks=queue_peaks)


def _fmt_tail(tail: Dict[str, float]) -> str:
    if not tail.get("n"):
        return "(no samples)"
    return (f"p50 {tail['p50'] * 1e3:8.3f}  "
            f"p99 {tail['p99'] * 1e3:8.3f}  "
            f"p999 {tail['p999'] * 1e3:8.3f}  "
            f"max {tail['max'] * 1e3:8.3f}")


def format_slo_table(report: SloReport) -> str:
    """Terminal rendering (latencies in ms)."""
    r = report
    lines = [
        "SLO summary "
        f"(deadline {r.deadline_s * 1e3:.1f} ms)" if r.deadline_s
        else "SLO summary (no deadline)",
        f"  offered   {r.offered:6d} req   "
        f"{r.offered_rps:8.2f} req/s over {r.span_s:.3f} s",
        f"  goodput   {r.goodput_rps:8.2f} req/s   "
        f"attainment {r.slo_attainment * 100:6.2f} %",
        f"  outcomes  ok {r.completed_ok}  errors {r.errors}  "
        f"deadline_exceeded {r.deadline_exceeded}",
        f"  pressure  retries {r.retries}  shed {r.shed}  "
        f"rejected {r.rejected}  preempted {r.preempted}",
        f"  ttft      [ms] {_fmt_tail(r.ttft)}",
        f"  per-token [ms] {_fmt_tail(r.per_token)}",
        f"  e2e       [ms] {_fmt_tail(r.e2e)}",
    ]
    if r.queue_peaks:
        peaks = "  ".join(f"{ep}={v}" for ep, v in
                          sorted(r.queue_peaks.items()))
        lines.append(f"  queue-peaks {peaks}")
    return "\n".join(lines)


__all__ = ["SloReport", "build_slo_report", "format_slo_table"]
