"""Replayable workload traces.

A :class:`Trace` is the frozen artifact between generation and
execution: a schema-versioned, JSON-serialisable list of
:class:`TraceEvent` rows plus optional correlated fault windows. The
split matters for reproducibility — "run Poisson at 40 req/s" is a
recipe, but a trace is the *exact* workload: record one with
``--trace-out``, attach it to a bug report, and ``--trace-in`` replays
the identical arrival times, shapes, and fault schedule on any
machine. Round-tripping through JSON is exact (Python's ``json``
preserves float64 bit patterns), so replayed runs are bit-identical.

Events are stored as compact arrays ``[id, t, prompt_len,
max_new_tokens, rows, worker]`` rather than objects — traces at
realistic rates hold thousands of events and the compact form keeps
them diff-able and small. ``worker`` is the shard hint (-1 = let the
dispatch policy pick). Fault windows are ``[t0, t1, src, dst]`` with
nulls for link wildcards, feeding straight into
``FaultInjectionTransport(burst_windows=...)``.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .arrivals import make_arrivals
from .lengths import sample_request_shapes

#: bump when the on-disk layout changes; loaders reject unknown values.
TRACE_SCHEMA = 1


@dataclass(frozen=True)
class TraceEvent:
    """One request in the workload: fire at ``t_s`` on the modeled
    clock with the given shape."""
    id: int
    t_s: float
    prompt_len: int
    max_new_tokens: int
    rows: int = 1
    worker: int = -1  # shard hint; -1 = policy decides

    def to_row(self) -> list:
        return [self.id, self.t_s, self.prompt_len,
                self.max_new_tokens, self.rows, self.worker]

    @classmethod
    def from_row(cls, row: Sequence) -> "TraceEvent":
        i, t, p, m, r, w = row
        return cls(id=int(i), t_s=float(t), prompt_len=int(p),
                   max_new_tokens=int(m), rows=int(r), worker=int(w))


@dataclass
class Trace:
    """An ordered, replayable workload."""
    events: List[TraceEvent]
    seed: int = 0
    meta: Dict[str, object] = field(default_factory=dict)
    #: correlated burst-loss windows (t0, t1, link) where link is a
    #: (src, dst) rank pair or None for all links.
    fault_windows: List[Tuple[float, float,
                              Optional[Tuple[int, int]]]] = \
        field(default_factory=list)

    def __post_init__(self) -> None:
        self.events = sorted(self.events, key=lambda e: (e.t_s, e.id))
        ids = [e.id for e in self.events]
        assert len(ids) == len(set(ids)), "duplicate event ids"

    def __len__(self) -> int:
        return len(self.events)

    @property
    def duration_s(self) -> float:
        return self.events[-1].t_s if self.events else 0.0

    # -- serialisation -------------------------------------------------
    def to_json(self) -> str:
        doc = {
            "schema": TRACE_SCHEMA,
            "seed": self.seed,
            "meta": self.meta,
            "fault_windows": [
                [t0, t1, None if link is None else list(link)]
                for t0, t1, link in self.fault_windows],
            "events": [e.to_row() for e in self.events],
        }
        return json.dumps(doc, indent=None, separators=(",", ":"))

    @classmethod
    def from_json(cls, text: str) -> "Trace":
        doc = json.loads(text)
        schema = doc.get("schema")
        if schema != TRACE_SCHEMA:
            raise ValueError(
                f"trace schema {schema!r} not supported (this build "
                f"reads schema {TRACE_SCHEMA})")
        windows = [
            (float(t0), float(t1),
             None if link is None else (int(link[0]), int(link[1])))
            for t0, t1, link in doc.get("fault_windows", [])]
        return cls(events=[TraceEvent.from_row(r)
                           for r in doc["events"]],
                   seed=int(doc.get("seed", 0)),
                   meta=doc.get("meta", {}),
                   fault_windows=windows)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())
            f.write("\n")

    @classmethod
    def load(cls, path: str) -> "Trace":
        with open(path) as f:
            return cls.from_json(f.read())


def synthesize_trace(kind: str, rate: float, duration_s: float, *,
                     seed: int = 0,
                     prompt_kind: str = "lognormal",
                     decode_kind: str = "fixed",
                     prompt_kw: dict = None,
                     decode_kw: dict = None,
                     arrival_kw: dict = None) -> Trace:
    """Generate a trace from an arrival process + length samplers.

    Arrivals and shapes draw from independent substreams of ``seed``,
    so the same seed always yields the same trace regardless of how
    either sampler's internal draw count changes.
    """
    root = np.random.default_rng(seed)
    a_seed, s_seed = (int(x) for x in root.integers(2**32, size=2))
    times = make_arrivals(kind, rate, duration_s, seed=a_seed,
                          **(arrival_kw or {}))
    prompts, decodes = sample_request_shapes(
        len(times), seed=s_seed, prompt_kind=prompt_kind,
        decode_kind=decode_kind, prompt_kw=prompt_kw,
        decode_kw=decode_kw)
    events = [TraceEvent(id=i, t_s=float(t), prompt_len=int(p),
                         max_new_tokens=int(m))
              for i, (t, p, m) in enumerate(zip(times, prompts,
                                                decodes))]
    meta = {"kind": kind, "rate": rate, "duration_s": duration_s,
            "prompt_kind": prompt_kind, "decode_kind": decode_kind}
    return Trace(events=events, seed=seed, meta=meta)


def correlated_burst_windows(trace: Trace, *, n_windows: int = 1,
                             width_s: float = 0.5,
                             link: Optional[Tuple[int, int]] = None,
                             seed: Optional[int] = None
                             ) -> List[Tuple[float, float,
                                             Optional[Tuple[int,
                                                            int]]]]:
    """Attach ``n_windows`` burst-loss windows of ``width_s`` each,
    placed uniformly over the trace's span (seeded off the trace seed
    by default so the fault schedule is as replayable as the
    arrivals). Returns the windows and records them on the trace."""
    assert n_windows >= 1 and width_s > 0, (n_windows, width_s)
    span = max(trace.duration_s, width_s)
    rng = np.random.default_rng(
        trace.seed + 0x5F0 if seed is None else seed)
    starts = np.sort(rng.uniform(0.0, max(span - width_s, 1e-9),
                                 size=n_windows))
    windows = [(float(t0), float(t0 + width_s), link)
               for t0 in starts]
    trace.fault_windows.extend(windows)
    return windows


__all__ = ["TRACE_SCHEMA", "Trace", "TraceEvent",
           "correlated_burst_windows", "synthesize_trace"]
