"""Degrade gracefully when `hypothesis` is not installed.

Test modules do ``from _hypothesis_support import given, settings, st``
instead of importing hypothesis directly. With hypothesis present this
re-exports the real API; without it, ``@given`` wraps the test in a
``pytest.importorskip("hypothesis")`` guard so only the property tests
skip — the rest of each module still collects and runs.
"""
from __future__ import annotations

import functools

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
    # the "ci" profile (--hypothesis-profile=ci) is registered in
    # tests/conftest.py: profile lookup happens at pytest configure
    # time, before this module is ever imported
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Accepts any strategy-construction call at decoration time."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    class _StrategyModule:
        def __getattr__(self, name):
            return _Strategy()

    st = _StrategyModule()

    def given(*_args, **_kwargs):
        def deco(fn):
            # zero-arg wrapper (no functools.wraps): pytest must not see
            # the property-test's strategy parameters as fixture requests
            def skipper():
                pytest.importorskip("hypothesis")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn
