"""Hypothesis settings profiles must be registered before pytest
resolves --hypothesis-profile (the hypothesis pytest plugin loads the
named profile at configure time, before any test module is imported),
so they live in conftest rather than tests/_hypothesis_support.py.

CI runs the property tests deterministically on every push:
`pytest --hypothesis-profile=ci --hypothesis-seed=0` (see
.github/workflows/ci.yml). derandomize makes the examples a pure
function of the test, so a red CI reproduces locally with the same
flags.
"""
try:
    from hypothesis import settings

    settings.register_profile("ci", settings(max_examples=100,
                                             deadline=None,
                                             derandomize=True))
except ImportError:      # tests degrade via tests/_hypothesis_support
    pass
