"""Per-architecture smoke tests (deliverable f): reduced same-family
config, one forward/train step on CPU, output shapes + finite values."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_reduced_config, list_archs
from repro.data.pipeline import DataConfig, device_batch, host_batch
from repro.launch import steps as steps_lib
from repro.models import forward, init_params, logits_fn
from repro.optim import optimizer as O
from repro.parallel import NO_MESH

B, S = 2, 32


def _batch(cfg, seed=0):
    key = jax.random.PRNGKey(seed)
    m = cfg.model
    out = {"labels": jax.random.randint(key, (B, S), 0, m.vocab_size)}
    if m.frontend:
        out["embeds"] = jax.random.normal(key, (B, S, m.d_model))
    else:
        out["tokens"] = jax.random.randint(key, (B, S), 0, m.vocab_size)
    return out


@pytest.mark.parametrize("arch", list_archs())
def test_forward_shapes_and_finite(arch):
    cfg = get_reduced_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    b = _batch(cfg)
    h, states, aux = forward(NO_MESH, cfg, params,
                             tokens=b.get("tokens"),
                             embeds=b.get("embeds"), mode="train")
    assert h.shape == (B, S, cfg.model.d_model)
    assert bool(jnp.all(jnp.isfinite(h)))
    logits = logits_fn(NO_MESH, cfg, params, h)
    assert logits.shape == (B, S, cfg.model.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", list_archs())
def test_one_train_step(arch):
    cfg = get_reduced_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = O.init_opt_state(cfg.train, params)
    step = steps_lib.make_train_step(NO_MESH, cfg, donate=False)
    p2, o2, metrics = step(params, opt, _batch(cfg))
    assert bool(jnp.isfinite(metrics["loss"]))
    # params actually changed
    changed = jax.tree.map(
        lambda a, b2: bool(jnp.any(a != b2)), params, p2)
    assert any(jax.tree.leaves(changed))


@pytest.mark.parametrize("arch", [a for a in list_archs()
                                  if not get_reduced_config(a).model
                                  .is_encoder])
def test_prefill_decode_consistency(arch):
    cfg = get_reduced_config(arch)
    m = cfg.model
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    if m.frontend:
        emb = jax.random.normal(key, (B, S + 2, m.d_model))
        fk = dict(embeds=emb)
        pk = dict(embeds=emb[:, :S])
        dks = [dict(embeds=emb[:, S + i:S + i + 1]) for i in range(2)]
    else:
        toks = jax.random.randint(key, (B, S + 2), 0, m.vocab_size)
        fk = dict(tokens=toks)
        pk = dict(tokens=toks[:, :S])
        dks = [dict(tokens=toks[:, S + i:S + i + 1]) for i in range(2)]
    h_full, _, _ = forward(NO_MESH, cfg, params, mode="train", **fk)
    _, states, _ = forward(NO_MESH, cfg, params, mode="prefill",
                           max_seq=S + 4, **pk)
    for i in range(2):
        ref = logits_fn(NO_MESH, cfg, params, h_full)[:, S + i]
        h_dec, states, _ = forward(NO_MESH, cfg, params, mode="decode",
                                   states=states, **dks[i])
        got = logits_fn(NO_MESH, cfg, params, h_dec)[:, 0]
        err = float(jnp.max(jnp.abs(ref - got))
                    / (jnp.max(jnp.abs(ref)) + 1e-9))
        assert err < 2e-3, (arch, i, err)


@pytest.mark.parametrize("arch", ["qwen3-8b", "rwkv6-1.6b"])
def test_data_pipeline_determinism(arch):
    cfg = get_reduced_config(arch)
    shape = dataclasses.replace(
        __import__("repro.configs", fromlist=["get_shape"]).get_shape(
            "train_4k"), seq_len=16, global_batch=2)
    a = host_batch(cfg, shape, 3, DataConfig(seed=9))
    b = host_batch(cfg, shape, 3, DataConfig(seed=9))
    c = host_batch(cfg, shape, 4, DataConfig(seed=9))
    for k in a:
        assert (a[k] == b[k]).all()
    assert any((a[k] != c[k]).any() for k in a)
