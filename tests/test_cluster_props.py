"""Property tests for the cluster transport: random ClusterSpecs
(1-8 endpoints, random per-link parameters) must round-trip through
serialization exactly, and a flight priced by stepping the transport
must land on the per-link netmodel closed form — identical round time
either way. Wired into the CI hypothesis profile alongside the framing
properties."""
import numpy as np
import pytest

from _hypothesis_support import given, settings, st
from repro import rpc
from repro.core.netmodel import NETWORKS, LinkLoad, cluster_flight_time
from repro.core.payload import PayloadSpec, classify

NET_NAMES = sorted(NETWORKS)


@st.composite
def cluster_specs(draw):
    """1-8 endpoints with random networks/jobs/windows, random link
    overrides on a subset of the directed pairs."""
    n = draw(st.integers(min_value=1, max_value=8))
    endpoints = []
    for i in range(n):
        window = draw(st.one_of(
            st.none(),
            st.builds(rpc.WindowConfig,
                      st.integers(min_value=1024, max_value=1 << 26),
                      st.integers(min_value=1, max_value=256))))
        endpoints.append(rpc.EndpointSpec(
            name=f"ep{i}",
            job=draw(st.sampled_from(["ps", "worker", "eval"])),
            network=draw(st.sampled_from(NET_NAMES)),
            window=window))
    pairs = [(a, b) for a in range(n) for b in range(n) if a != b]
    chosen = draw(st.lists(st.sampled_from(pairs), unique=True,
                           max_size=min(len(pairs), 6))
                  if pairs else st.just([]))
    links = tuple(
        rpc.LinkSpec(
            src=f"ep{a}", dst=f"ep{b}",
            bandwidth_Bps=draw(st.one_of(
                st.none(),
                st.floats(min_value=1e7, max_value=1e11))),
            latency_s=draw(st.one_of(
                st.none(),
                st.floats(min_value=1e-7, max_value=1e-2))))
        for a, b in chosen)
    return rpc.ClusterSpec(endpoints=tuple(endpoints), links=links)


@given(spec=cluster_specs())
@settings(max_examples=50, deadline=None)
def test_cluster_spec_serialization_roundtrip(spec):
    assert rpc.ClusterSpec.from_json(spec.to_json()) == spec
    assert rpc.as_cluster_spec(spec.to_dict()) == spec


@given(spec=cluster_specs(),
       nbytes=st.integers(min_value=0, max_value=4 << 20),
       n_msgs=st.integers(min_value=1, max_value=4))
@settings(max_examples=50, deadline=None)
def test_flight_time_closed_form_matches_transport(spec, nbytes,
                                                   n_msgs):
    """One flight — every directed pair carries n_msgs spec-only frames
    plus a local message per endpoint — priced by stepping the
    transport must equal the closed form on the same link loads."""
    transport = rpc.ClusterTransport(spec)
    n = spec.n_endpoints
    payload = PayloadSpec(sizes=(nbytes,), scheme="t",
                          categories=(classify(nbytes),))
    messages, loads = [], []
    for src in range(n):
        for dst in range(n):
            if src == dst:
                continue
            frame = rpc.make_frame(1 + len(messages), "m", None,
                                   sizes=[nbytes])
            messages.extend(rpc.Message(src, dst, frame)
                            for _ in range(n_msgs))
            loads.append(LinkLoad(src, dst, spec.link_model(src, dst),
                                  (payload,) * n_msgs))
    for e in range(n):                     # local messages stay cheap
        frame = rpc.make_frame(10_000 + e, "m", None, sizes=[nbytes])
        messages.append(rpc.Message(e, e, frame))
        loads.append(LinkLoad(e, e, spec.base_model(e), (payload,)))
    delivery = transport.deliver(messages)
    want = cluster_flight_time(loads)
    assert delivery.elapsed_s == pytest.approx(want, rel=1e-9, abs=0.0) \
        or (delivery.elapsed_s == 0.0 and want == 0.0)
    # stepping accumulates the modeled clock flight by flight
    before = transport.clock_s
    transport.deliver(messages)
    assert transport.clock_s == pytest.approx(before + want, rel=1e-9) \
        or (before == 0.0 and want == 0.0)


@given(nbytes=st.integers(min_value=1, max_value=1 << 20),
       chunks=st.integers(min_value=1, max_value=4),
       n=st.integers(min_value=2, max_value=8),
       net=st.sampled_from(["eth40g", "eth10g", "ipoib_fdr",
                            "rdma_edr"]))
@settings(max_examples=25, deadline=None)
def test_homogeneous_cluster_ring_equals_netmodel(nbytes, chunks, n,
                                                  net):
    """Any uniform cluster must collapse to the single-model closed
    forms — the per-link refinement cannot drift the degenerate
    case."""
    spec = PayloadSpec(sizes=(nbytes,), scheme="t",
                       categories=(classify(nbytes),))
    cluster = rpc.homogeneous(n, net)
    got = rpc.cluster_ring_round_time(cluster, [nbytes],
                                      n_chunks=chunks)
    want = NETWORKS[net].ring_round_time(spec, n, n_chunks=chunks)
    assert got == pytest.approx(want, rel=1e-9)
