"""Fabric conformance tier for the multi-endpoint cluster transport:
ClusterSpec validation/serialization, endpoint-named addressing, every
MethodSpec kind exercised across endpoints, deadline expiry on a
cross-endpoint stalled stream, retry-on-transient across endpoints,
exact simulated-vs-netmodel cross-checks (verified by mutation:
zeroing the per-link contention term must break them), per-endpoint
interceptor metrics under interleaved multi-client load, PS-style
sharded serve dispatch, and the bench/CLI integration."""
import importlib.util
import json
import pathlib
import sys

import numpy as np
import pytest

from repro import rpc
from repro.configs.tfgrpc_bench import BenchConfig
from repro.core.netmodel import NETWORKS, LinkLoad, cluster_flight_time
from repro.core.payload import PayloadSpec

ROOT = pathlib.Path(__file__).resolve().parents[1]

SIZES = [65536] * 4
SPEC = PayloadSpec(sizes=tuple(SIZES), scheme="t",
                   categories=("medium",) * 4)


def _bufs(sizes, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 255, s, dtype=np.uint8) for s in sizes]


def _hetero_cluster():
    """PS on RDMA, workers on kernel-TCP, two overridden links."""
    return rpc.ClusterSpec(
        endpoints=(rpc.EndpointSpec("ps0", job="ps", network="rdma_edr"),
                   rpc.EndpointSpec("w0", network="eth10g"),
                   rpc.EndpointSpec("w1", network="eth40g")),
        links=(rpc.LinkSpec("w0", "ps0", bandwidth_Bps=1e9,
                            latency_s=2e-4),
               rpc.LinkSpec("ps0", "w1", bandwidth_Bps=5e8)))


def _slow_link_cluster():
    """5 endpoints on one network with one very slow directed link."""
    return rpc.ClusterSpec(
        endpoints=tuple(rpc.EndpointSpec(f"n{i}", network="ipoib_fdr")
                        for i in range(5)),
        links=(rpc.LinkSpec("n1", "n2", bandwidth_Bps=1e8,
                            latency_s=1e-3),))


#: the >= 3 cluster specs of the exact-match cross-checks
CLUSTERS = {
    "homogeneous": rpc.homogeneous(4, "eth40g"),
    "hetero_ps": _hetero_cluster(),
    "slow_link": _slow_link_cluster(),
}


def _cluster_fabric(cluster, **kw):
    kw.setdefault("window_bytes", 64 << 20)
    kw.setdefault("window_msgs", 256)
    return rpc.RpcFabric(rpc.make_transport("cluster", cluster=cluster),
                         **kw)


# ---------------------------------------------------------------------------
# ClusterSpec: validation + serialization
# ---------------------------------------------------------------------------

def test_cluster_spec_validation():
    ep = rpc.EndpointSpec
    with pytest.raises(ValueError, match="at least one endpoint"):
        rpc.ClusterSpec(endpoints=())
    with pytest.raises(ValueError, match="duplicate endpoint"):
        rpc.ClusterSpec(endpoints=(ep("a"), ep("a")))
    with pytest.raises(ValueError, match="unknown network"):
        rpc.ClusterSpec(endpoints=(ep("a", network="warp"),))
    with pytest.raises(ValueError, match="unknown endpoint 'b'"):
        rpc.ClusterSpec(endpoints=(ep("a"),),
                        links=(rpc.LinkSpec("a", "b"),))
    with pytest.raises(ValueError, match="duplicate link"):
        rpc.ClusterSpec(endpoints=(ep("a"), ep("b")),
                        links=(rpc.LinkSpec("a", "b"),
                               rpc.LinkSpec("a", "b")))
    with pytest.raises(ValueError, match="self-link"):
        # local calls are memcpys — a self-link override is dead config
        rpc.ClusterSpec(endpoints=(ep("a"),),
                        links=(rpc.LinkSpec("a", "a",
                                            latency_s=1.0),))
    with pytest.raises(ValueError, match="unknown endpoint 'zz'"):
        CLUSTERS["hetero_ps"].index("zz")


@pytest.mark.parametrize("name", sorted(CLUSTERS))
def test_cluster_spec_json_roundtrip(name):
    spec = CLUSTERS[name]
    again = rpc.ClusterSpec.from_json(spec.to_json())
    assert again == spec
    # and through plain dicts / as_cluster_spec coercion
    assert rpc.as_cluster_spec(spec.to_dict()) == spec
    assert rpc.as_cluster_spec(spec.to_json()) == spec
    assert rpc.as_cluster_spec(spec) is spec


def test_cluster_spec_jobs_and_windows_roundtrip():
    spec = rpc.ClusterSpec(endpoints=(
        rpc.EndpointSpec("ps0", job="ps",
                         window=rpc.WindowConfig(1 << 16, 8)),
        rpc.EndpointSpec("w0"), rpc.EndpointSpec("w1")))
    assert spec.job_endpoints("ps") == ("ps0",)
    assert spec.job_endpoints("worker") == ("w0", "w1")
    assert spec.jobs == {"ps": ("ps0",), "worker": ("w0", "w1")}
    assert rpc.ClusterSpec.from_json(spec.to_json()) == spec


def test_ps_worker_cluster_puts_server_first():
    spec = rpc.ps_worker_cluster(2, 3)
    assert spec.endpoints[0].name == "ps0"
    assert spec.endpoints[0].job == "ps"
    assert spec.n_endpoints == 5
    assert spec.job_endpoints("worker") == ("worker0", "worker1",
                                            "worker2")


# ---------------------------------------------------------------------------
# endpoint-addressed channels + per-endpoint windows
# ---------------------------------------------------------------------------

def test_named_endpoint_addressing():
    fab = _cluster_fabric(CLUSTERS["hetero_ps"])
    srv = fab.add_server("ps0")
    assert 0 in fab.servers     # resolved to the spec index
    srv.add_service(rpc.CONFORMANCE_SERVICE, rpc.conformance_handlers())
    stub = fab.stub(rpc.CONFORMANCE_SERVICE, "w0", "ps0")
    assert stub is fab.stub(rpc.CONFORMANCE_SERVICE, 1, 0)  # same cache
    out = stub.echo([np.arange(16, dtype=np.uint8)]).result()
    assert np.array_equal(out[0], np.arange(16, dtype=np.uint8))
    with pytest.raises(ValueError, match="unknown endpoint"):
        fab.channel("nope", "ps0")


def test_named_addressing_needs_named_transport():
    fab = rpc.RpcFabric(rpc.make_transport("loopback", 2))
    with pytest.raises(ValueError, match="named endpoint addressing"):
        fab.channel("a", "b")


def test_per_endpoint_windows_size_channels():
    spec = rpc.ClusterSpec(endpoints=(
        rpc.EndpointSpec("a", window=rpc.WindowConfig(1024, 4)),
        rpc.EndpointSpec("b")))
    fab = rpc.RpcFabric(rpc.make_transport("cluster", cluster=spec))
    ch = fab.channel("b", "a")
    # forward gated by the receiver's advertised window, reverse by
    # the client's (which advertises none -> fabric default)
    assert (ch.window.window_bytes, ch.window.window_msgs) == (1024, 4)
    assert ch.rwindow.window_bytes == fab.window_bytes
    back = fab.channel("a", "b")
    assert back.window.window_bytes == fab.window_bytes
    assert (back.rwindow.window_bytes, back.rwindow.window_msgs) \
        == (1024, 4)


# ---------------------------------------------------------------------------
# conformance: every MethodSpec kind, across endpoints
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cluster_name", sorted(CLUSTERS))
def test_all_four_kinds_across_endpoints(cluster_name):
    """unary / client-stream / server-stream / bidi each exercised
    from every non-server endpoint, real payload bytes end to end."""
    cluster = CLUSTERS[cluster_name]
    fab = _cluster_fabric(cluster)
    server = cluster.endpoints[0].name
    fab.add_server(server).add_service(rpc.CONFORMANCE_SERVICE,
                                       rpc.conformance_handlers())
    for client in (ep.name for ep in cluster.endpoints[1:]):
        stub = fab.stub(rpc.CONFORMANCE_SERVICE, client, server)
        payload = _bufs([300, 40], seed=cluster.index(client))

        out = stub.echo(payload).result()                  # unary
        assert [b.tolist() for b in out] \
            == [b.tolist() for b in payload]

        total = stub.gather([payload, payload]).result()   # client-stream
        assert int(np.ascontiguousarray(total[0]).view("<u4")[0]) == 680

        chunks = stub.split(payload).result()              # server-stream
        got = np.concatenate([np.asarray(c[0]) for c in chunks])
        want = np.concatenate([b.reshape(-1) for b in payload])
        assert np.array_equal(got, want)
        assert len(chunks) == -(-340 // 128)

        h = stub.relay([[payload[0]], [payload[1]]])       # bidi
        echoed = h.result()
        assert len(echoed) == 2
        assert np.array_equal(np.asarray(echoed[0][0]), payload[0])
        assert np.array_equal(np.asarray(echoed[1][0]), payload[1])
    assert fab.transport.clock_s > 0.0     # everything was priced


def test_same_endpoint_calls_are_loopback_fast():
    """A local (src == dst) unary call never pays link alpha / rpc
    overhead — only the host memcpy (zero on the RDMA-class model)."""
    cluster = rpc.ClusterSpec(endpoints=(
        rpc.EndpointSpec("a", network="rdma_edr"),
        rpc.EndpointSpec("b", network="rdma_edr")))
    fab = _cluster_fabric(cluster)
    for name in ("a", "b"):
        fab.add_server(name).add_service(rpc.CONFORMANCE_SERVICE,
                                         rpc.conformance_handlers())
    local = fab.stub(rpc.CONFORMANCE_SERVICE, "a", "a")
    local.echo([np.zeros(1 << 20, np.uint8)]).result()
    assert fab.transport.clock_s == 0.0    # rdma copy rate is inf
    remote = fab.stub(rpc.CONFORMANCE_SERVICE, "a", "b")
    remote.echo([np.zeros(1 << 20, np.uint8)]).result()
    assert fab.transport.clock_s > 0.0     # the cross link is priced


# ---------------------------------------------------------------------------
# deadline expiry + retry, across endpoints
# ---------------------------------------------------------------------------

def test_deadline_on_cross_endpoint_stalled_stream():
    """A server stream stalled behind a zero-credit reverse window on a
    cross-endpoint cluster channel cancels at its deadline on the
    modeled clock (deterministically), instead of deadlocking."""
    fab = _cluster_fabric(CLUSTERS["hetero_ps"], window_bytes=1024,
                          window_msgs=4)
    fab.add_server("ps0").add_service(rpc.CONFORMANCE_SERVICE,
                                      rpc.conformance_handlers())
    ch = fab.channel("w1", "ps0")
    assert ch.rwindow.try_acquire(ch.rwindow.window_bytes)  # drain
    h = fab.stub(rpc.CONFORMANCE_SERVICE, "w1", "ps0").split(
        [np.zeros(800, np.uint8)], deadline_s=5.0)
    fab.flush()
    assert h.done
    with pytest.raises(rpc.RpcError, match="deadline exceeded"):
        h.chunk_bufs()
    assert fab.transport.clock_s >= 5.0    # advanced, not slept
    assert len(ch.rx_gate) == 0            # gated chunks dropped


def test_retry_on_transient_across_endpoints():
    """Transient faults at the PS are retried per client endpoint; both
    clients' calls succeed on the second attempt."""
    failures = {"w0": True, "w1": True}    # first call per client fails

    def flaky(req):
        key = "w0" if req[0][0] == 0 else "w1"
        if failures[key]:
            failures[key] = False
            raise rpc.TransientError(f"{key} hiccup")
        return [np.array(req[0], copy=True)]

    svc = rpc.ServiceDef("Flaky", (rpc.MethodSpec("get", rpc.UNARY),))
    retry = rpc.RetryInterceptor(max_attempts=3)
    fab = _cluster_fabric(CLUSTERS["hetero_ps"],
                          client_interceptors=[retry])
    fab.add_server("ps0").add_service(svc, {"get": flaky})
    calls = [
        fab.stub(svc, "w0", "ps0").get([np.full(8, 0, np.uint8)]),
        fab.stub(svc, "w1", "ps0").get([np.full(8, 1, np.uint8)]),
    ]
    fab.flush()
    assert retry.retries == 2
    assert [int(c.result()[0][0]) for c in calls] == [0, 1]


# ---------------------------------------------------------------------------
# exact-match cross-checks vs the per-link netmodel closed forms
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cluster_name", sorted(CLUSTERS))
def test_cluster_fc_matches_closed_form(cluster_name):
    cluster = CLUSTERS[cluster_name]
    fab = _cluster_fabric(cluster)
    rep = rpc.fully_connected_exchange(fab, SIZES)
    assert rep.modeled
    assert rep.elapsed_s == pytest.approx(
        rpc.cluster_fc_round_time(cluster, SIZES), rel=1e-9)


@pytest.mark.parametrize("cluster_name", sorted(CLUSTERS))
@pytest.mark.parametrize("chunks", [1, 3])
def test_cluster_ring_matches_closed_form(cluster_name, chunks):
    cluster = CLUSTERS[cluster_name]
    fab = _cluster_fabric(cluster)
    rep = rpc.ring_exchange(fab, SIZES, n_chunks=chunks)
    assert rep.elapsed_s == pytest.approx(
        rpc.cluster_ring_round_time(cluster, SIZES, n_chunks=chunks),
        rel=1e-9)


@pytest.mark.parametrize("cluster_name", sorted(CLUSTERS))
@pytest.mark.parametrize("chunks,ratio", [(1, 1.0), (2, 0.25)])
def test_cluster_incast_matches_closed_form(cluster_name, chunks,
                                            ratio):
    cluster = CLUSTERS[cluster_name]
    fab = _cluster_fabric(cluster)
    rep = rpc.incast_exchange(fab, SIZES, n_chunks=chunks,
                              fetch_ratio=ratio)
    assert rep.elapsed_s == pytest.approx(
        rpc.cluster_incast_round_time(cluster, SIZES, n_chunks=chunks,
                                      fetch_ratio=ratio), rel=1e-9)


@pytest.mark.parametrize("family,n", [("ring", 4), ("incast", 3)])
def test_homogeneous_cluster_reproduces_simulated_transport(family, n):
    """The degenerate (uniform, no-override) cluster must price every
    family exactly like the single-NetworkModel SimulatedTransport —
    the per-link decomposition is a refinement, not a different
    model."""
    net = NETWORKS["eth40g"]
    cluster = rpc.homogeneous(n + (1 if family == "incast" else 0),
                              "eth40g")
    fab = _cluster_fabric(cluster)
    if family == "ring":
        rep = rpc.ring_exchange(fab, SIZES, n_chunks=3)
        want = net.ring_round_time(SPEC, n, n_chunks=3)
    else:
        rep = rpc.incast_exchange(fab, SIZES, n_chunks=2)
        want = net.incast_round_time(SPEC, n, n_chunks=2)
    assert rep.elapsed_s == pytest.approx(want, rel=1e-9)


def test_mutation_removing_per_link_contention_fails_cross_check(
        monkeypatch):
    """The conformance cross-checks must actually depend on the
    per-link contention term: zeroing it in the transport breaks the
    ring and incast matches on kernel-TCP clusters."""
    monkeypatch.setattr(rpc.ClusterTransport, "_link_contention",
                        staticmethod(lambda model, k, nbytes: 0.0))
    for cluster, run, want in [
        (CLUSTERS["homogeneous"],
         lambda f: rpc.ring_exchange(f, SIZES, n_chunks=3),
         rpc.cluster_ring_round_time(CLUSTERS["homogeneous"], SIZES,
                                     n_chunks=3)),
        (CLUSTERS["slow_link"],
         lambda f: rpc.incast_exchange(f, SIZES, n_chunks=2),
         rpc.cluster_incast_round_time(CLUSTERS["slow_link"], SIZES,
                                       n_chunks=2)),
    ]:
        rep = run(_cluster_fabric(cluster))
        assert rep.elapsed_s != pytest.approx(want, rel=1e-9), \
            "cross-check insensitive to the per-link contention term"


def test_closed_form_flight_decomposition():
    """cluster_flight_time couples links at endpoints: the max over
    endpoints of summed link ingress (+ cross-link contention) and
    egress — spot-checked against a hand computation."""
    net = NETWORKS["eth10g"]
    spec = PayloadSpec(sizes=(1 << 20,), scheme="t",
                       categories=("large",))
    # two links into endpoint 0, one message each, plus a local load
    loads = [
        LinkLoad(1, 0, net, (spec,)),
        LinkLoad(2, 0, net, (spec,)),
        LinkLoad(0, 0, net, (spec,)),
    ]
    per_msg = net.payload_time(spec, serialized=False) + net.msg_time(64)
    cross = 2 * 1 * spec.total_bytes / net.cpu_copy_Bps   # 2 links, k=1
    local = spec.total_bytes / net.cpu_copy_Bps
    egress = spec.total_bytes / net.beta_Bps
    want = max(2 * per_msg + cross + local, egress)
    assert cluster_flight_time(loads) == pytest.approx(want, rel=1e-12)


# ---------------------------------------------------------------------------
# per-endpoint interceptor metrics under interleaved multi-client load
# ---------------------------------------------------------------------------

def test_metrics_per_endpoint_with_interleaved_clients():
    """Percentiles and counts are kept per-method AND per-endpoint:
    three client endpoints interleave unary and streaming calls to one
    PS, and each (src -> dst) pair gets its own record whose counts
    sum to the per-method totals."""
    cluster = rpc.ps_worker_cluster(1, 3, ps_network="eth40g")
    transport = rpc.make_transport("cluster", cluster=cluster)
    metrics = rpc.MetricsInterceptor(
        per_endpoint=True, endpoint_name=transport.endpoint_name)
    fab = rpc.RpcFabric(transport, window_bytes=64 << 20,
                        window_msgs=256, client_interceptors=[metrics],
                        server_interceptors=[metrics])
    fab.add_server("ps0").add_service(rpc.CONFORMANCE_SERVICE,
                                      rpc.conformance_handlers())
    workers = ("worker0", "worker1", "worker2")
    n_calls = {"worker0": 1, "worker1": 2, "worker2": 3}
    # interleave: round-robin the workers, one echo + one split each
    for rnd in range(max(n_calls.values())):
        for w in workers:
            if rnd < n_calls[w]:
                stub = fab.stub(rpc.CONFORMANCE_SERVICE, w, "ps0")
                stub.echo([np.zeros(256, np.uint8)])
                stub.split([np.zeros(256, np.uint8)])
    fab.flush()
    snap = metrics.snapshot()
    for method in ("Conformance/echo", "Conformance/split"):
        assert snap[method]["calls"] == 6
        per_ep = {w: snap[f"{method}@{w}->ps0"] for w in workers}
        for w in workers:
            rec = per_ep[w]
            assert rec["calls"] == n_calls[w]
            assert rec["ok"] == n_calls[w]
            assert len(rec["latency_us"]) == 4      # percentiles present
        assert sum(r["calls"] for r in per_ep.values()) \
            == snap[method]["calls"]
    # stream chunks attributed per endpoint too (256B -> 2 chunks)
    assert snap["Conformance/split@worker2->ps0"]["chunks"] == 6
    # server-side dispatch counts carry the endpoint label
    assert snap["server:Conformance/echo@ps0"]["calls"] == 6
    # latencies differ per endpoint pair when links differ — all on the
    # modeled clock, so records are deterministic
    assert snap["Conformance/echo"]["ok"] == 6


def test_metrics_per_endpoint_off_by_default():
    fab = rpc.RpcFabric(rpc.make_transport("loopback", 2))
    metrics = rpc.MetricsInterceptor()
    fab.client_interceptors.append(metrics)
    fab.add_server(1).add_service(rpc.CONFORMANCE_SERVICE,
                                  rpc.conformance_handlers())
    fab.stub(rpc.CONFORMANCE_SERVICE, 0, 1).echo(
        [np.zeros(8, np.uint8)]).result()
    assert all("@" not in k for k in metrics.snapshot())


# ---------------------------------------------------------------------------
# PS-style sharded serve dispatch (fake Serve handlers: policy logic
# only — the real-engine path is covered by the serve smoke below)
# ---------------------------------------------------------------------------

def _fake_serve_fabric(n_ps=2, n_workers=2, policy="round_robin"):
    from repro.serve.engine import (SERVE_SERVICE, ShardedServeStub,
                                    decode_generate_request,
                                    encode_generate_reply)

    served = {f"ps{i}": 0 for i in range(n_ps)}

    def make_handlers(name):
        def generate(bufs):
            served[name] += 1
            prompts, mnt = decode_generate_request(bufs)
            return encode_generate_reply(
                np.full((prompts.shape[0], max(mnt, 1)),
                        int(name[-1]), np.int32))

        def generate_stream(bufs):
            served[name] += 1
            prompts, mnt = decode_generate_request(bufs)
            from repro.serve.engine import _i32_buf
            return [[_i32_buf(np.full(prompts.shape[0], int(name[-1]),
                                      np.int32))]
                    for _ in range(max(mnt, 1))]

        return {"generate": generate, "generate_stream": generate_stream}

    cluster = rpc.ps_worker_cluster(n_ps, n_workers)
    fab = _cluster_fabric(cluster)
    for i in range(n_ps):
        fab.add_server(f"ps{i}").add_service(SERVE_SERVICE,
                                             make_handlers(f"ps{i}"))
    stubs = {f"worker{w}": ShardedServeStub(
        fab, f"worker{w}", cluster.job_endpoints("ps"), policy=policy)
        for w in range(n_workers)}
    return fab, stubs, served


def test_sharded_dispatch_round_robin_across_clients():
    fab, stubs, served = _fake_serve_fabric(n_ps=2, n_workers=2)
    prompts = np.zeros((2, 4), np.int32)
    calls = []
    for _ in range(2):                      # 2 rounds x 2 workers
        for stub in stubs.values():
            calls.append(stub.generate(prompts, 3))
    fab.flush()
    outs = [c.result() for c in calls]
    assert all(o.shape == (2, 3) for o in outs)
    # each worker alternated its own round-robin: ps0 then ps1
    assert [int(o[0, 0]) for o in outs] == [0, 0, 1, 1]
    assert served == {"ps0": 2, "ps1": 2}


def test_sharded_dispatch_least_loaded_avoids_busy_shard():
    fab, stubs, served = _fake_serve_fabric(n_ps=2, n_workers=1,
                                            policy="least_loaded")
    stub = stubs["worker0"]
    prompts = np.zeros((1, 4), np.int32)
    first = stub.generate(prompts, 1)       # ties -> ps0
    second = stub.generate(prompts, 1)      # ps0 busy -> ps1
    third = stub.generate(prompts, 1)       # both busy (1 each) -> ps0
    fab.flush()
    assert [int(c.result()[0, 0]) for c in (first, second, third)] \
        == [0, 1, 0]
    after = stub.generate(prompts, 1)       # all drained -> ps0 again
    fab.flush()
    assert int(after.result()[0, 0]) == 0
    assert served == {"ps0": 3, "ps1": 1}


def test_sharded_dispatch_rejects_unknown_policy():
    fab, stubs, _ = _fake_serve_fabric()
    from repro.serve.engine import ShardedServeStub
    with pytest.raises(ValueError, match="unknown dispatch policy"):
        ShardedServeStub(fab, "worker0", ["ps0"], policy="random")


def test_serve_cluster_real_engine_concurrent_workers():
    """The acceptance path: a real (reduced) engine bound on the PS
    endpoints of a cluster serves concurrent generation requests from
    two client endpoints, matching direct generation bit-for-bit."""
    import jax
    from repro.configs import get_reduced_config
    from repro.models import init_params
    from repro.parallel import NO_MESH
    from repro.serve.engine import ServeConfig, ServeEngine

    cfg = get_reduced_config("qwen3-8b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(NO_MESH, cfg, params,
                      ServeConfig(max_seq=64, max_new_tokens=4))
    prompts = np.random.default_rng(0).integers(
        0, cfg.model.vocab_size, (2, 8), dtype=np.int32)
    direct = eng.generate(prompts)

    cluster = rpc.ps_worker_cluster(2, 2, ps_network="rdma_edr")
    fabric, stubs = eng.serve_cluster(cluster)
    assert sorted(stubs) == ["worker0", "worker1"]
    calls = {w: stub.generate(prompts) for w, stub in stubs.items()}
    fabric.flush()                          # both served in one loop
    for call in calls.values():
        assert np.array_equal(call.result(), direct)
    # round robin: worker0 -> ps0, worker1 -> ps0 (each stub's own
    # cycle starts at the first shard)
    assert all(s.outstanding(0) == 0 for s in stubs.values())


def test_serve_cluster_needs_both_jobs():
    import jax
    from repro.configs import get_reduced_config
    from repro.models import init_params
    from repro.parallel import NO_MESH
    from repro.serve.engine import ServeConfig, ServeEngine
    cfg = get_reduced_config("qwen3-8b", n_layers=1)
    eng = ServeEngine(NO_MESH, cfg,
                      init_params(jax.random.PRNGKey(0), cfg),
                      ServeConfig(max_seq=32))
    with pytest.raises(ValueError, match="serve_cluster needs"):
        eng.serve_cluster(rpc.homogeneous(3))


# ---------------------------------------------------------------------------
# bench + CLI integration
# ---------------------------------------------------------------------------

def test_bench_incast_cluster_reports_per_endpoint_metrics():
    from repro.core import bench
    cfg = BenchConfig(benchmark="incast", transport="cluster",
                      num_workers=2, stream_chunks=2, warmup_s=0.0,
                      duration_s=0.0, iovec_count=4)
    st = bench.run(cfg)
    assert "Incast/push_fetch@ep1->ep0" in st.rpc_metrics
    assert "Incast/push_fetch@ep2->ep0" in st.rpc_metrics
    # and the per-link closed form projection matches the measured
    # (modeled) round exactly
    spec = st.spec
    want = rpc.cluster_incast_round_time(
        rpc.homogeneous(3, "eth40g"), list(spec.sizes), n_chunks=2)
    assert st.mean_s == pytest.approx(want, rel=1e-9)
    assert st.model_projection["cluster"] == pytest.approx(
        st.derived["rpcs_per_round"] / want, rel=1e-9)


def test_bench_cluster_projection_skipped_with_advertised_windows():
    """Endpoint windows split streams across flights, so the one-flight
    closed form no longer describes the run — the projection must be
    withheld, not published wrong."""
    from repro.core import bench
    spec = rpc.ClusterSpec(endpoints=(
        rpc.EndpointSpec("s", window=rpc.WindowConfig(4096, 1)),
        rpc.EndpointSpec("w0"), rpc.EndpointSpec("w1")))
    cfg = BenchConfig(benchmark="incast", transport="cluster",
                      num_workers=2, stream_chunks=4, warmup_s=0.0,
                      duration_s=0.0, iovec_count=4,
                      cluster_spec=spec)
    st = bench.run(cfg)
    assert "cluster" not in st.model_projection
    assert st.mean_s > 0.0          # the run itself still completes


def test_bench_cluster_spec_endpoint_count_mismatch_errors():
    from repro.core import bench
    cfg = BenchConfig(benchmark="ring", transport="cluster",
                      num_workers=4, cluster_spec=rpc.homogeneous(3),
                      warmup_s=0.0, duration_s=0.0)
    with pytest.raises(RuntimeError, match="exactly 4 endpoints"):
        bench.run(cfg)


def test_bench_comm_cluster_cli_json(tmp_path):
    from repro.launch import bench_comm
    spec_path = tmp_path / "cluster.json"
    spec_path.write_text(rpc.ClusterSpec(
        endpoints=(rpc.EndpointSpec("ps0", job="ps",
                                    network="rdma_edr"),
                   rpc.EndpointSpec("w0", network="eth10g"),
                   rpc.EndpointSpec("w1", network="eth10g")),
        links=(rpc.LinkSpec("w0", "ps0", bandwidth_Bps=2e9),)
    ).to_json())
    out = tmp_path / "rows.json"
    bench_comm.main(["--benchmark", "incast", "--num-workers", "2",
                     "--transport", "cluster", "--cluster-spec",
                     str(spec_path), "--stream-chunks", "2",
                     "--warmup", "0", "--duration", "0",
                     "--json", str(out)])
    rows = json.loads(out.read_text())["rows"]
    assert rows[0]["transport"] == "cluster"
    assert rows[0]["network"] == "cluster"
    keys = rows[0]["rpc_metrics"].keys()
    assert "Incast/push_fetch@w0->ps0" in keys
    assert "Incast/push_fetch@w1->ps0" in keys


def test_bench_comm_cluster_spec_requires_cluster_transport(capsys):
    from repro.launch import bench_comm
    with pytest.raises(SystemExit):
        bench_comm.main(["--benchmark", "incast", "--transport",
                         "simulated", "--cluster-spec", '{"endpoints":'
                         ' [{"name": "a"}]}'])
    assert "--cluster-spec needs --transport cluster" \
        in capsys.readouterr().err


def test_transport_factory_kinds():
    t = rpc.make_transport("loopback", 2)
    assert isinstance(t, rpc.LoopbackTransport)
    t = rpc.make_transport("simulated", 3, network="eth40g")
    assert isinstance(t, rpc.SimulatedTransport)
    assert t.network is NETWORKS["eth40g"]
    t = rpc.make_transport("cluster", cluster=rpc.homogeneous(2))
    assert isinstance(t, rpc.ClusterTransport)
    with pytest.raises(ValueError, match="unknown network"):
        rpc.make_transport("simulated", 2, network="warp")
    with pytest.raises(ValueError, match="unknown transport kind"):
        rpc.make_transport("pigeon", 2)


# ---------------------------------------------------------------------------
# examples/comm_benchmark_sweep.py rides the sweep CLI now
# ---------------------------------------------------------------------------

def test_example_sweep_smoke(tmp_path, capsys):
    """The example must import cleanly and run its tiny (--quick)
    config end to end through bench_comm --sweep."""
    path = ROOT / "examples" / "comm_benchmark_sweep.py"
    spec = importlib.util.spec_from_file_location(
        "comm_benchmark_sweep", path)
    mod = importlib.util.module_from_spec(spec)
    sys.modules[spec.name] = mod
    try:
        spec.loader.exec_module(mod)
        out = tmp_path / "rows.json"
        mod.main(["--quick", "--json", str(out)])
        rows = json.loads(out.read_text())["rows"]
    finally:
        sys.modules.pop(spec.name, None)
    # benchmark x workers x stream_chunks cross-product, ring + incast
    assert len(rows) == 2 * 4 * 4
    assert {r["benchmark"] for r in rows} == {"ring", "incast"}
    assert {r["workers"] for r in rows} == {2, 4, 8, 16}
    assert {r["stream_chunks"] for r in rows} == {1, 2, 4, 8}
    assert all("error" not in r for r in rows)
    text = capsys.readouterr().out
    assert "stream_chunks" in text          # the one-table report
