"""Allreduce collectives: closed-form exactness on simulated + cluster
transports (all three algorithms), real-data correctness on loopback,
chunk-partition properties, and bit-identical gradients under seeded
link faults with retry."""
import numpy as np
import pytest

import repro.rpc as rpc
from _hypothesis_support import given, settings, st
from repro.core.netmodel import (ALLREDUCE_ALGOS, NETWORKS,
                                 allreduce_chunk_sizes,
                                 ring_allreduce_send_chunk,
                                 tree_reduce_rounds)

TOTAL = 262144
ALGOS = ALLREDUCE_ALGOS


def _fabric(transport, total_bytes=TOTAL, **kw):
    return rpc.RpcFabric(transport, window_bytes=4 * total_bytes,
                         window_msgs=256, **kw)


# ---------------------------------------------------------------------------
# exactness: simulated transport == netmodel closed forms
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("net_name", ["eth40g", "rdma_edr", "eth10g"])
@pytest.mark.parametrize("algo", ALGOS)
@pytest.mark.parametrize("n", [2, 3, 4, 8])
def test_simulated_matches_closed_form(net_name, algo, n):
    net = NETWORKS[net_name]
    for mode in rpc.WIRE_MODES:
        fab = _fabric(rpc.SimulatedTransport(n, net))
        rep = rpc.allreduce(fab, algo, TOTAL, wire_mode=mode)
        assert rep.modeled
        want = net.allreduce_time(algo, TOTAL, n, mode=mode)
        assert rep.elapsed_s == want, (mode, rep.elapsed_s, want)
        assert rep.replies == 0          # one-way: no reply flights


@pytest.mark.parametrize("algo", ALGOS)
def test_simulated_real_data_still_exact(algo):
    """Real buffers ride the simulated transport unencoded: numerics
    AND the modeled clock must both be exact in one run."""
    rng = np.random.default_rng(0)
    n, elems = 4, 1000
    net = NETWORKS["eth40g"]
    data = [rng.standard_normal(elems).astype(np.float32)
            for _ in range(n)]
    fab = _fabric(rpc.SimulatedTransport(n, net), elems * 4)
    rep = rpc.allreduce(fab, algo, data=data, itemsize=4)
    assert rep.elapsed_s == net.allreduce_time(algo, elems * 4, n,
                                               itemsize=4)
    expect = np.sum(data, axis=0)
    for r in rep.result:
        np.testing.assert_allclose(r, expect, rtol=1e-5)
        assert (r == rep.result[0]).all()


# ---------------------------------------------------------------------------
# exactness: cluster transport == cluster closed forms (2 specs)
# ---------------------------------------------------------------------------

def _homog_spec():
    return rpc.homogeneous(4, "eth40g")


def _hetero_spec():
    return rpc.ps_worker_cluster(
        1, 3, ps_network="rdma_edr", worker_network="eth10g",
        links=[rpc.LinkSpec("worker0", "ps0", bandwidth_Bps=5e8,
                            latency_s=2e-4)])


@pytest.mark.parametrize("spec_fn", [_homog_spec, _hetero_spec],
                         ids=["homogeneous", "heterogeneous"])
@pytest.mark.parametrize("algo", ALGOS)
def test_cluster_matches_closed_form(spec_fn, algo):
    cs = spec_fn()
    for mode in rpc.WIRE_MODES:
        fab = _fabric(rpc.ClusterTransport(cs))
        rep = rpc.allreduce(fab, algo, TOTAL, wire_mode=mode)
        want = rpc.cluster_allreduce_time(cs, algo, TOTAL, mode=mode)
        assert rep.elapsed_s == want, (mode, rep.elapsed_s, want)


@pytest.mark.parametrize("algo", ALGOS)
def test_homogeneous_cluster_form_equals_simulated_form(algo):
    cs = _homog_spec()
    net = NETWORKS["eth40g"]
    assert rpc.cluster_allreduce_time(cs, algo, TOTAL) \
        == net.allreduce_time(algo, TOTAL, cs.n_endpoints)


def test_cluster_form_sensitive_to_link_override():
    """The per-link override must actually reach the closed form (a
    dead-config guard, like the fc/ring by-mutation checks)."""
    base = rpc.ps_worker_cluster(1, 3)
    # ps0 -> worker0 (0 -> 1) is on every schedule: the ring successor
    # hop, the final tree broadcast round, and the rsag all-to-all
    slow = rpc.ps_worker_cluster(
        1, 3, links=[rpc.LinkSpec("ps0", "worker0", bandwidth_Bps=1e7)])
    for algo in ALGOS:
        assert rpc.cluster_allreduce_time(slow, algo, TOTAL) \
            > rpc.cluster_allreduce_time(base, algo, TOTAL)


# ---------------------------------------------------------------------------
# loopback: real reduction, every wire mode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("wire_mode", rpc.WIRE_MODES)
@pytest.mark.parametrize("algo", ALGOS)
def test_loopback_reduction(algo, wire_mode):
    rng = np.random.default_rng(1)
    n, elems = 3, 301
    data = [rng.standard_normal(elems).astype(np.float32)
            for _ in range(n)]
    fab = _fabric(rpc.LoopbackTransport(n), elems * 4)
    rep = rpc.allreduce(fab, algo, data=data, itemsize=4,
                        wire_mode=wire_mode)
    assert not rep.modeled
    expect = np.sum(data, axis=0)
    for r in rep.result:
        np.testing.assert_allclose(r, expect, rtol=1e-5)
        assert (r == rep.result[0]).all()


def test_single_endpoint_is_a_no_op():
    data = [np.arange(8, dtype=np.float32)]
    fab = _fabric(rpc.LoopbackTransport(1), 32)
    rep = rpc.ring_allreduce(fab, data=data, itemsize=4)
    assert rep.steps == 0 and rep.elapsed_s == 0.0
    np.testing.assert_array_equal(rep.result[0], data[0])
    for algo in ALGOS:
        assert NETWORKS["eth40g"].allreduce_time(algo, TOTAL, 1) == 0.0


def test_driver_argument_validation():
    fab = _fabric(rpc.LoopbackTransport(2))
    with pytest.raises(ValueError, match="exactly one"):
        rpc.ring_allreduce(fab)
    with pytest.raises(ValueError, match="exactly one"):
        rpc.ring_allreduce(fab, TOTAL, data=[np.zeros(2), np.zeros(2)])
    with pytest.raises(ValueError, match="unknown allreduce algo"):
        rpc.allreduce(fab, "butterfly", TOTAL)
    with pytest.raises(ValueError, match="one vector per endpoint"):
        rpc.ring_allreduce(fab, data=[np.zeros(4, np.float32)])
    with pytest.raises(ValueError, match="element per worker"):
        rpc.rsag_allreduce(_fabric(rpc.LoopbackTransport(3)),
                           data=[np.zeros(2, np.float32)] * 3,
                           itemsize=4)


# ---------------------------------------------------------------------------
# chunk-partition properties
# ---------------------------------------------------------------------------

@given(elems=st.integers(min_value=0, max_value=10000),
       n=st.integers(min_value=1, max_value=64),
       itemsize=st.sampled_from([1, 2, 4, 8]))
@settings(max_examples=200)
def test_partition_props(elems, n, itemsize):
    total = elems * itemsize
    chunks = allreduce_chunk_sizes(total, n, itemsize=itemsize)
    assert len(chunks) == n
    assert sum(chunks) == total                      # exact cover
    assert all(c % itemsize == 0 for c in chunks)    # element-aligned
    assert max(chunks) - min(chunks) <= itemsize     # balanced
    assert sorted(chunks, reverse=True) == list(chunks)  # big-first


@given(n=st.integers(min_value=2, max_value=33))
@settings(max_examples=60)
def test_ring_schedule_props(n):
    """Every step is a permutation send (one chunk out, one in per
    worker) and each worker ends having been sent every chunk index
    exactly twice across the 2(n-1) steps except its own start/end."""
    for step in range(2 * (n - 1)):
        sent = [ring_allreduce_send_chunk(i, step, n) for i in range(n)]
        assert sorted(sent) == list(range(n))    # distinct chunks move
    # after reduce-scatter, worker i last accumulated chunk (i+1) % n
    last = [ring_allreduce_send_chunk((i - 1) % n, n - 2, n)
            for i in range(n)]
    assert last == [(i + 1) % n for i in range(n)]


@given(n=st.integers(min_value=2, max_value=70))
@settings(max_examples=60)
def test_tree_schedule_props(n):
    rounds = tree_reduce_rounds(n)
    assert len(rounds) == max(1, (n - 1).bit_length())
    seen_senders = set()
    for pairs in rounds:
        eps = [e for p in pairs for e in p]
        assert len(eps) == len(set(eps))         # disjoint pairs
        for s, d in pairs:
            assert 0 <= d < s < n
            assert s not in seen_senders         # reduced once, stays
            seen_senders.add(s)
    assert seen_senders == set(range(1, n))      # all roads lead to 0


def test_partition_rejects_bad_args():
    with pytest.raises(ValueError):
        allreduce_chunk_sizes(10, 0)
    with pytest.raises(ValueError):
        allreduce_chunk_sizes(10, 4, itemsize=0)
    with pytest.raises(ValueError):
        allreduce_chunk_sizes(10, 4, itemsize=4)   # not a multiple
    with pytest.raises(ValueError):
        ring_allreduce_send_chunk(0, 6, 4)         # step out of range


# ---------------------------------------------------------------------------
# seeded faults: a retried allreduce is bit-identical
# ---------------------------------------------------------------------------

def _run_all(data, fault_rate, seed=11):
    n = len(data)
    inner = rpc.LoopbackTransport(n)
    transport = rpc.FaultInjectionTransport(
        inner, seed=seed, fault_rate=fault_rate, max_faults=24) \
        if fault_rate else inner
    fab = rpc.RpcFabric(
        transport, window_bytes=1 << 20, window_msgs=256,
        client_interceptors=[rpc.RetryInterceptor(max_attempts=8)])
    out = {}
    for algo in ALGOS:
        rep = rpc.allreduce(fab, algo, data=[d.copy() for d in data],
                            itemsize=4)
        out[algo] = rep.result
    faults = transport.faults_injected if fault_rate else 0
    return out, faults


def test_retried_allreduce_bit_identical_under_faults():
    rng = np.random.default_rng(3)
    data = [rng.standard_normal(512).astype(np.float32)
            for _ in range(4)]
    clean, _ = _run_all(data, 0.0)
    faulty, n_faults = _run_all(data, 0.15)
    assert n_faults > 0, "fault schedule never fired — vacuous test"
    for algo in ALGOS:
        for a, b in zip(clean[algo], faulty[algo]):
            assert (a == b).all(), f"{algo}: gradients diverged"
