"""Extra coverage: skew bias variants, serialization vs kernel parity,
resource monitor, elastic mesh restore, roofline helpers."""
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_support import given, settings, st

from repro.configs.tfgrpc_bench import BenchConfig
from repro.core import serialization as ser
from repro.core.payload import generate_spec
from repro.core.resource import ResourceMonitor


@pytest.mark.parametrize("bias,heavy", [("large", "large"),
                                        ("medium", "medium"),
                                        ("small", "small")])
def test_skew_bias_variants(bias, heavy):
    spec = generate_spec(BenchConfig(scheme="skew", skew_bias=bias))
    counts = {c: spec.categories.count(c) for c in set(spec.categories)}
    assert counts[heavy] == 6  # 60% of 10 buffers


def test_skew_bias_ordering():
    sizes = {b: generate_spec(BenchConfig(scheme="skew",
                                          skew_bias=b)).total_bytes
             for b in ("small", "medium", "large")}
    assert sizes["small"] < sizes["medium"] < sizes["large"]


@given(sizes=st.lists(st.integers(1, 2048), min_size=1, max_size=6))
@settings(max_examples=25, deadline=None)
def test_jnp_serialization_roundtrip(sizes):
    rng = np.random.default_rng(0)
    bufs = [jnp.asarray(rng.integers(0, 255, s, dtype=np.uint8))
            for s in sizes]
    packed, meta = ser.pack(bufs)
    assert packed.shape[-1] == sum(sizes)
    outs = ser.unpack(packed, meta)
    for a, b in zip(bufs, outs):
        assert bool(jnp.array_equal(a, b))


def test_serialization_matches_kernel_ref():
    from repro.kernels.payload_pack import pack_ref
    rng = np.random.default_rng(1)
    bufs = [jnp.asarray(rng.integers(0, 255, s, dtype=np.uint8))
            for s in (128, 384, 256)]
    packed, _ = ser.pack(bufs)
    assert bool(jnp.array_equal(packed, pack_ref(bufs)))


def test_resource_monitor_measures():
    with ResourceMonitor(interval_s=0.01) as mon:
        x = np.zeros(4 << 20, dtype=np.uint8)  # touch some memory
        t0 = time.perf_counter()
        while time.perf_counter() - t0 < 0.1:
            x.sum()
    r = mon.report
    assert r.duration_s >= 0.1
    assert r.cpu_time_s > 0
    assert r.rss_peak_bytes > 1e6
    assert r.samples >= 2


@pytest.mark.slow
def test_elastic_restore_to_different_mesh(tmp_path):
    """Checkpoint on a (2,2) mesh, restore onto a (4,1) mesh (elastic
    restart after losing model-parallel peers)."""
    code = f"""
import dataclasses, jax, numpy as np
from repro.configs import get_reduced_config, get_shape
from repro.models import init_params
from repro.optim import optimizer as O
from repro.checkpoint import checkpoint as ckpt
from repro.launch.mesh import make_test_mesh
from repro.launch import steps as S
from repro.parallel import make_ctx
from repro.data.pipeline import host_batch, device_batch

cfg = get_reduced_config('qwen3-8b', n_layers=2)
shape = dataclasses.replace(get_shape('train_4k'), seq_len=32,
                            global_batch=4)
params = init_params(jax.random.PRNGKey(0), cfg)
opt = O.init_opt_state(cfg.train, params)

mesh1 = make_test_mesh(2, 2)
ctx1 = make_ctx(cfg, mesh1)
with mesh1:
    step = S.make_train_step(ctx1, cfg, donate=False)
    b = device_batch(ctx1, host_batch(cfg, shape, 0))
    params, opt, m1 = step(params, opt, b)
    jax.block_until_ready(m1['loss'])
ckpt.save(r'{tmp_path}', 1, (params, opt))

# restore onto a DIFFERENT mesh shape
mesh2 = make_test_mesh(4, 1)
ctx2 = make_ctx(cfg, mesh2)
from repro.parallel import tree_shardings
from repro.models.model import param_logical_axes
with mesh2:
    psh = tree_shardings(ctx2, param_logical_axes(cfg))
    (params2, opt2), _ = ckpt.restore(r'{tmp_path}', 1, (params, opt),
                                      shardings=(psh, None))
    step2 = S.make_train_step(ctx2, cfg, donate=False)
    b2 = device_batch(ctx2, host_batch(cfg, shape, 1))
    params2, opt2, m2 = step2(params2, opt2, b2)
    jax.block_until_ready(m2['loss'])
assert np.isfinite(float(m2['loss']))
print('ELASTIC_OK', float(m1['loss']), float(m2['loss']))
"""
    import os
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-2500:]
    assert "ELASTIC_OK" in out.stdout


def test_roofline_extrapolation_math():
    from repro.launch.hlo import CollectiveStats
    from repro.launch.roofline import SegmentCost, extrapolate_two_point

    def seg(flops, wire):
        c = CollectiveStats()
        c.wire_bytes["all-reduce"] = wire
        return SegmentCost("s", flops, flops * 2, c, 0.0)

    # fixed = 100, per-chunk = 10 -> at S1: 110, at 2*S1: 120
    c1, c2 = seg(110, 110), seg(120, 120)
    out = extrapolate_two_point(c1, c2, ratio=512)
    assert out.flops == pytest.approx(100 + 10 * 512)
    assert out.collectives.wire_bytes["all-reduce"] == pytest.approx(
        100 + 10 * 512)
    # pure per-token segments (no fixed part) scale linearly
    c1, c2 = seg(10, 10), seg(20, 20)
    out = extrapolate_two_point(c1, c2, ratio=512)
    assert out.flops == pytest.approx(10 * 512)


def test_model_flops_formula():
    from repro.configs import get_config, get_shape
    from repro.launch.roofline import model_flops
    cfg = get_config("qwen3-8b")
    mf = model_flops(cfg, get_shape("train_4k"))
    n, d = cfg.model.num_params(), 256 * 4096
    assert mf == pytest.approx(6 * n * d, rel=1e-6)
    # MoE: active params only
    kimi = get_config("kimi-k2-1t-a32b")
    mfk = model_flops(kimi, get_shape("train_4k"))
    assert mfk < 6 * kimi.model.num_params() * d * 0.05
