"""FabricTrainStep: data-parallel steps over the fabric in PS and
allreduce modes — closed-form exactness on the simulated transport,
run-to-run bit-determinism, PS/allreduce numerical agreement,
bit-identical training under seeded link faults with retry, and the
PS -> allreduce crossover along the workers axis."""
import numpy as np
import pytest

import repro.rpc as rpc
from repro.core.netmodel import ALLREDUCE_TAG_BYTES, NETWORKS
from repro.rpc.cluster import _payload_spec
from repro.train.fabric_train import (FabricTrainConfig, FabricTrainStep,
                                      SyntheticGradEngine,
                                      allreduce_train_step_time,
                                      ps_train_step_time, train_step_time)

N_PARAMS = 1024


def _fabric(transport, **kw):
    return rpc.RpcFabric(transport, window_bytes=1 << 20,
                         window_msgs=256, **kw)


def _run(transport, cfg, steps=3):
    trainer = FabricTrainStep(_fabric(transport), cfg)
    reports = [trainer.step() for _ in range(steps)]
    return trainer, reports


# ---------------------------------------------------------------------------
# closed-form exactness on the simulated transport
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", rpc.ALLREDUCE_ALGOS)
@pytest.mark.parametrize("n", [2, 4])
def test_simulated_allreduce_step_matches_closed_form(algo, n):
    net = NETWORKS["eth40g"]
    for mode in rpc.WIRE_MODES:
        cfg = FabricTrainConfig(mode="allreduce", algo=algo,
                                n_params=N_PARAMS, wire_mode=mode)
        trainer, reports = _run(rpc.SimulatedTransport(n, net), cfg,
                                steps=2)
        want = allreduce_train_step_time(net, N_PARAMS * 4, n,
                                         algo=algo, mode=mode)
        for rep in reports:
            assert rep.modeled
            assert rep.elapsed_s == want, (mode, rep.elapsed_s, want)


@pytest.mark.parametrize("n_ps,n_workers", [(1, 2), (2, 3), (2, 4)])
def test_simulated_ps_step_matches_closed_form(n_ps, n_workers):
    net = NETWORKS["rdma_edr"]
    for mode in rpc.WIRE_MODES:
        cfg = FabricTrainConfig(mode="ps", n_ps=n_ps,
                                n_params=N_PARAMS, wire_mode=mode)
        trainer, reports = _run(
            rpc.SimulatedTransport(n_ps + n_workers, net), cfg, steps=2)
        want = ps_train_step_time(net, N_PARAMS * 4, n_ps, n_workers,
                                  mode=mode)
        for rep in reports:
            assert rep.modeled
            assert rep.elapsed_s == want, (mode, rep.elapsed_s, want)
            assert rep.flights == 2          # one push + one fetch


def test_ps_push_flight_is_ps_round_time():
    """The push flight's PS ingress is exactly the paper's PS-round
    model: with one PS, the flight elapsed IS ps_round_time of the
    tagged shard payload (the PS is the bottleneck endpoint)."""
    net = NETWORKS["eth40g"]
    total, n_workers = 65536, 4
    sizes = (ALLREDUCE_TAG_BYTES, total)
    push = [(1 + w, 0, sizes) for w in range(n_workers)]
    for mode in rpc.WIRE_MODES:
        got = net._flight_elapsed(push, mode)
        want = net.ps_round_time(_payload_spec(sizes), 1, n_workers,
                                 mode=mode)
        assert got == pytest.approx(want, rel=1e-12), (mode, got, want)


def test_train_step_time_dispatch():
    net = NETWORKS["eth40g"]
    assert train_step_time(net, "ps", 4096, 4, n_ps=2) \
        == ps_train_step_time(net, 4096, 2, 4)
    assert train_step_time(net, "allreduce", 4096, 4, algo="tree") \
        == allreduce_train_step_time(net, 4096, 4, algo="tree")
    with pytest.raises(ValueError, match="unknown train mode"):
        train_step_time(net, "hogwild", 4096, 4)


def test_ps_allreduce_crossover_on_workers_axis():
    """The bench_comm crossover claim: at a 64 KiB gradient on eth40g
    with 2 PS, the PS layout wins in the mid-worker band but its
    quadratic host-copy contention hands the lead to ring allreduce as
    workers grow."""
    net = NETWORKS["eth40g"]
    total = 65536

    def ps(w):
        return train_step_time(net, "ps", total, w, n_ps=2)

    def ar(w):
        return train_step_time(net, "allreduce", total, w, algo="ring")

    assert ps(16) < ar(16)           # PS band
    assert ar(64) < ps(64)           # allreduce takes over
    assert ar(128) < ps(128)         # ... and the gap keeps growing
    assert ps(128) / ar(128) > ps(64) / ar(64)


# ---------------------------------------------------------------------------
# training semantics on loopback (real bytes)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cfg", [
    FabricTrainConfig(mode="allreduce", algo="ring", n_params=257),
    FabricTrainConfig(mode="allreduce", algo="rsag", n_params=257),
    FabricTrainConfig(mode="ps", n_ps=2, n_params=257),
], ids=["ring", "rsag", "ps"])
def test_two_runs_bit_identical(cfg):
    n = 4 if cfg.mode == "allreduce" else cfg.n_ps + 3
    _, reports_a = _run(rpc.LoopbackTransport(n), cfg)
    trainer_a, _ = _run(rpc.LoopbackTransport(n), cfg, steps=0)
    trainer_b, reports_b = _run(rpc.LoopbackTransport(n), cfg)
    for _ in range(3):
        trainer_a.step()
    assert (trainer_a.params == trainer_b.params).all()
    for ra, rb in zip(reports_a, reports_b):
        assert ra.loss == rb.loss and ra.grad_norm == rb.grad_norm


def test_ps_and_allreduce_agree_numerically():
    """Same synthetic engine, same worker count: both modes apply
    params -= lr * mean(grad) — different summation orders, so
    allclose rather than bitwise."""
    n_workers, steps = 3, 3
    ar = FabricTrainStep(
        _fabric(rpc.LoopbackTransport(n_workers)),
        FabricTrainConfig(mode="allreduce", algo="tree", n_params=301))
    ps = FabricTrainStep(
        _fabric(rpc.LoopbackTransport(2 + n_workers)),
        FabricTrainConfig(mode="ps", n_ps=2, n_params=301))
    for _ in range(steps):
        ar.step()
        ps.step()
    np.testing.assert_allclose(ar.params, ps.params, rtol=1e-5,
                               atol=1e-6)


def test_convergence_with_fixed_target():
    """With a step-independent quadratic target the replicas descend
    monotonically toward the mean target in every mode."""
    rng = np.random.default_rng(7)
    targets = [rng.standard_normal(200).astype(np.float32)
               for _ in range(3)]
    goal = np.mean(targets, axis=0)
    for cfg, n in [
            (FabricTrainConfig(mode="allreduce", n_params=200, lr=0.4), 3),
            (FabricTrainConfig(mode="ps", n_ps=1, n_params=200,
                               lr=0.4), 4)]:
        trainer = FabricTrainStep(
            _fabric(rpc.LoopbackTransport(n)), cfg,
            grad_fn=lambda p, w, t: (p - targets[w]).astype(np.float32))
        dists = [float(np.linalg.norm(trainer.params - goal))]
        for _ in range(6):
            trainer.step()
            dists.append(float(np.linalg.norm(trainer.params - goal)))
        assert all(b < a for a, b in zip(dists, dists[1:])), dists


def test_engine_is_a_pure_function():
    a, b = (SyntheticGradEngine(64, seed=5) for _ in range(2))
    assert (a.init_params() == b.init_params()).all()
    assert (a.target(1, 3) == b.target(1, 3)).all()
    assert not (a.target(1, 3) == a.target(2, 3)).all()
    assert not (a.target(1, 3) == a.target(1, 4)).all()
    p = a.init_params()
    assert (a.grad(p, 0, 0) == p - a.target(0, 0)).all()
    assert a.loss(a.target(0, 0), 0, 0) == 0.0


# ---------------------------------------------------------------------------
# seeded faults: a retried step trains bit-identically
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cfg,n", [
    (FabricTrainConfig(mode="allreduce", algo="rsag", n_params=256), 4),
    (FabricTrainConfig(mode="ps", n_ps=2, n_params=256), 6),
], ids=["allreduce", "ps"])
def test_faulty_training_is_bit_identical(cfg, n):
    clean, _ = _run(rpc.LoopbackTransport(n), cfg)
    transport = rpc.FaultInjectionTransport(
        rpc.LoopbackTransport(n), seed=13, fault_rate=0.2, max_faults=16)
    fab = _fabric(transport, client_interceptors=[
        rpc.RetryInterceptor(max_attempts=8)])
    faulty = FabricTrainStep(fab, cfg)
    for _ in range(3):
        faulty.step()
    assert transport.faults_injected > 0, "no faults fired — vacuous"
    assert (clean.params == faulty.params).all()


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------

def test_config_validation():
    fab = _fabric(rpc.LoopbackTransport(4))
    with pytest.raises(ValueError, match="unknown train mode"):
        FabricTrainStep(fab, FabricTrainConfig(mode="hogwild"))
    with pytest.raises(ValueError, match="n_ps < n_endpoints"):
        FabricTrainStep(fab, FabricTrainConfig(mode="ps", n_ps=4))
    with pytest.raises(ValueError, match="n_ps < n_endpoints"):
        FabricTrainStep(fab, FabricTrainConfig(mode="ps", n_ps=0))
    with pytest.raises(ValueError, match=">= 2 endpoints"):
        FabricTrainStep(_fabric(rpc.LoopbackTransport(1)),
                        FabricTrainConfig(mode="allreduce"))
    with pytest.raises(ValueError, match="cover every shard"):
        FabricTrainStep(fab, FabricTrainConfig(mode="allreduce",
                                               n_params=3))


def test_report_shape():
    cfg = FabricTrainConfig(mode="allreduce", algo="ring", n_params=64)
    trainer, reports = _run(rpc.LoopbackTransport(3), cfg, steps=2)
    assert [r.step for r in reports] == [0, 1]
    for r in reports:
        assert r.mode == "allreduce" and not r.modeled
        assert r.elapsed_s >= 0.0            # loopback: wall time, not modeled
        assert np.isfinite(r.loss) and np.isfinite(r.grad_norm)
        assert r.flights == 2 * (3 - 1)      # one flight per ring step
    assert trainer.step_count == 2
