"""Fault-injection test tier: the fabric's failure semantics under a
seeded FaultInjectionTransport, on loopback, simulated, and cluster
transports alike — deadline propagation (budget in the frame header,
server-side shedding, ServerContext.time_remaining), admission control
(queue-depth-fed AdmissionInterceptor, ResourceExhausted rejections,
ShardedServeStub failover), and transparent server-stream retry. Every
scenario ends with the credit invariant: windows fully refunded, chunk
gates drained, no leaked server stream state. Mutation checks prove the
dedicated tests actually depend on each mechanism: disabling budget
stamping, admission, or stream retry flips a dedicated assertion."""
import numpy as np
import pytest

from repro import rpc
from repro.rpc import fabric as fabric_mod

SIZES = [4096, 512]


def _bufs(sizes, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 255, s, dtype=np.uint8) for s in sizes]


#: the three dispatching transports the tier runs on, by factory
TRANSPORTS = {
    "loopback": lambda n: rpc.make_transport("loopback", n),
    "simulated": lambda n: rpc.make_transport("simulated", n,
                                              network="eth40g"),
    "cluster": lambda n: rpc.make_transport(
        "cluster", cluster=rpc.homogeneous(n, "eth40g")),
}


def _faulty_fabric(transport_name, n, *, fault_kw, **fabric_kw):
    inner = TRANSPORTS[transport_name](n)
    transport = rpc.make_transport("fault", inner=inner, **fault_kw)
    return rpc.RpcFabric(transport, **fabric_kw)


def assert_credits_balanced(fab):
    """The conformance invariant after every scenario: every window
    back at full size, every gate drained, nothing backlogged, no
    partial-stream state left on any server."""
    for ch in fab._channels.values():
        assert ch.window.bytes_avail == ch.window.window_bytes
        assert ch.window.msgs_avail == ch.window.window_msgs
        assert ch.rwindow.bytes_avail == ch.rwindow.window_bytes
        assert ch.rwindow.msgs_avail == ch.rwindow.window_msgs
        assert len(ch.rx_gate) == 0
        assert ch.backlogged == 0
    assert not fab._backlog and not fab._pending
    assert not fab._awaiting_grant
    for srv in fab.servers.values():
        assert srv._streams == {} and srv._bidi_seq == {}
        assert srv._pumps == {}
        assert srv._dead_streams == set()


# ---------------------------------------------------------------------------
# the FaultInjectionTransport itself
# ---------------------------------------------------------------------------

def test_fault_transport_delegates_to_inner():
    inner = rpc.make_transport("cluster",
                               cluster=rpc.ps_worker_cluster(1, 2))
    t = rpc.make_transport("fault", inner=inner, seed=0, fault_rate=0.5)
    assert t.n_endpoints == 3 and t.modeled and t.dispatches
    assert t.resolve("ps0") == 0                  # name hook delegates
    assert t.endpoint_name(1) == "worker0"
    t.clock_s = 2.5                               # setter reaches inner
    assert inner.clock_s == 2.5
    loop = rpc.make_transport("fault",
                              inner=rpc.make_transport("loopback", 2))
    assert not hasattr(loop, "clock_s")           # loopback has none


def test_fault_transport_validation():
    with pytest.raises(ValueError, match="needs inner="):
        rpc.make_transport("fault", fault_rate=0.5)
    inner = rpc.make_transport("loopback", 2)
    with pytest.raises(AssertionError, match="sum"):
        rpc.make_transport("fault", inner=inner, fault_rate=0.8,
                           stall_rate=0.8)


def test_fault_schedule_is_seeded_and_link_scoped():
    """Same seed -> same schedule; faults restricted to the configured
    directed links never touch other traffic."""
    def run(seed):
        inner = rpc.make_transport("simulated", 3, network="eth40g")
        t = rpc.make_transport("fault", inner=inner, seed=seed,
                               fault_rate=1.0, max_faults=2,
                               links=[(1, 0)])
        retry = rpc.RetryInterceptor(max_attempts=8)
        fab = rpc.RpcFabric(t, client_interceptors=[retry])
        fab.add_server(0).add_service(rpc.CONFORMANCE_SERVICE,
                                      rpc.conformance_handlers())
        calls = [fab.stub(rpc.CONFORMANCE_SERVICE, w, 0)
                 .echo(None, sizes=SIZES) for w in (1, 2)]
        fab.flush()
        assert all(c.done and c.error is None for c in calls)
        return t.faults_injected, retry.retries

    a, b = run(7), run(7)
    assert a == b                     # reproducible schedule
    faults, retries = a
    # only endpoint 1's link is in the schedule: its call absorbed both
    # faults; endpoint 2's call (same dst, different link) saw none
    assert faults == 2 and retries == 2


def test_max_faults_bounds_the_schedule():
    inner = rpc.make_transport("simulated", 2, network="eth40g")
    t = rpc.make_transport("fault", inner=inner, seed=0, fault_rate=1.0,
                           max_faults=3)
    retry = rpc.RetryInterceptor(max_attempts=10)
    fab = rpc.RpcFabric(t, client_interceptors=[retry])
    fab.add_server(1).add_service(rpc.CONFORMANCE_SERVICE,
                                  rpc.conformance_handlers())
    c = fab.stub(rpc.CONFORMANCE_SERVICE, 0, 1).echo(None, sizes=SIZES)
    fab.flush()
    assert c.error is None and t.faults_injected == 3
    assert retry.retries == 3
    assert_credits_balanced(fab)


# ---------------------------------------------------------------------------
# conformance under faults: all four method kinds x three transports
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("transport_name", sorted(TRANSPORTS))
def test_conformance_under_request_faults(transport_name):
    """CONFORMANCE_SERVICE under a bounded fault schedule on the
    client->server links: the retryable kinds (unary, server-stream
    with zero chunks delivered) recover transparently; the
    non-retryable stream kinds fail cleanly with a transient error —
    and either way every credit is refunded."""
    fab = _faulty_fabric(
        transport_name, 4,
        fault_kw=dict(seed=3, fault_rate=0.35, max_faults=6,
                      links=[(w, 0) for w in (1, 2, 3)]),
        client_interceptors=[rpc.MetricsInterceptor(),
                             rpc.RetryInterceptor(max_attempts=8)])
    transport = fab.transport
    fab.add_server(0).add_service(rpc.CONFORMANCE_SERVICE,
                                  rpc.conformance_handlers())
    handles = []
    for w in (1, 2, 3):
        stub = fab.stub(rpc.CONFORMANCE_SERVICE, w, 0)
        payload = _bufs([300, 40], seed=w)
        handles.append(("echo", w, payload, stub.echo(payload)))
        handles.append(("split", w, payload, stub.split(payload)))
        handles.append(("gather", w, payload,
                        stub.gather([payload, payload])))
        handles.append(("relay", w, payload,
                        stub.relay([[payload[0]]])))
    fab.flush()
    assert transport.faults_injected >= 1     # the schedule fired
    for kind, w, payload, h in handles:
        assert h.done
        if h.error is not None:
            # only the non-retryable stream kinds may surface faults,
            # and only as transient errors
            assert kind in ("gather", "relay"), (kind, h.error)
            assert rpc.is_transient(h.error), h.error
        elif kind == "echo":
            got = h.result()
            assert [b.tolist() for b in got] \
                == [b.tolist() for b in payload]
        elif kind == "split":
            got = np.concatenate([np.asarray(c[0])
                                  for c in h.result()])
            want = np.concatenate([b.reshape(-1) for b in payload])
            assert np.array_equal(got, want)
    assert_credits_balanced(fab)


@pytest.mark.parametrize("transport_name", sorted(TRANSPORTS))
def test_faulted_response_chunk_fails_stream_cleanly(transport_name):
    """A fault on the server->client link with NO retry installed
    kills the handle with a transient error — and the reverse-window
    credits still come back, the gate drains, no server state leaks."""
    fab = _faulty_fabric(
        transport_name, 2,
        fault_kw=dict(seed=1, fault_rate=1.0, max_faults=1,
                      links=[(1, 0)]))   # only the response direction
    fab.add_server(1).add_service(rpc.CONFORMANCE_SERVICE,
                                  rpc.conformance_handlers())
    h = fab.stub(rpc.CONFORMANCE_SERVICE, 0, 1).split(
        _bufs([600], seed=0))
    fab.flush()
    assert h.done and h.error is not None
    assert rpc.is_transient(h.error)
    assert fab.transport.faults_injected == 1
    assert_credits_balanced(fab)


def test_first_chunk_fault_is_transparently_retried():
    """A response-direction fault on the FIRST chunk leaves the caller
    with zero observed chunks, so a RetryInterceptor may transparently
    re-issue the whole stream — every chunk still arrives exactly
    once."""
    retry = rpc.RetryInterceptor(max_attempts=4)
    fab = _faulty_fabric(
        "simulated", 2,
        fault_kw=dict(seed=1, fault_rate=1.0, max_faults=1,
                      links=[(1, 0)]),
        client_interceptors=[retry])
    fab.add_server(1).add_service(rpc.CONFORMANCE_SERVICE,
                                  rpc.conformance_handlers())
    payload = _bufs([600], seed=0)
    h = fab.stub(rpc.CONFORMANCE_SERVICE, 0, 1).split(payload)
    fab.flush()
    assert h.done and h.error is None, h.error
    assert retry.retries == 1
    got = np.concatenate([np.asarray(c[0]) for c in h.chunk_bufs()])
    assert np.array_equal(got, payload[0])    # exactly once, in order
    assert_credits_balanced(fab)


# ---------------------------------------------------------------------------
# transparent server-stream retry (mutation target: stream retry)
# ---------------------------------------------------------------------------

def _stream_retry_scenario(max_attempts=4):
    """One server-stream whose request frame is faulted exactly once:
    a retrying client must deliver every chunk exactly once."""
    fab = _faulty_fabric(
        "simulated", 2,
        fault_kw=dict(seed=0, fault_rate=1.0, max_faults=1,
                      links=[(0, 1)]),
        client_interceptors=[rpc.MetricsInterceptor(),
                             rpc.RetryInterceptor(
                                 max_attempts=max_attempts)])
    invocations = {"n": 0}

    def split(req):
        invocations["n"] += 1
        return [(128,), (128,), (64,)]

    svc = rpc.ServiceDef("S", (rpc.MethodSpec("split",
                                              rpc.SERVER_STREAM),))
    fab.add_server(1).add_service(svc, {"split": split})
    h = fab.stub(svc, 0, 1).split(None, sizes=[512], deadline_s=60.0)
    fab.flush()
    return fab, h, invocations


def test_server_stream_retry_delivers_chunks_exactly_once():
    fab, h, invocations = _stream_retry_scenario()
    assert h.done and h.error is None, h.error
    assert len(h.chunks) == 3               # each chunk exactly once
    assert invocations["n"] == 1            # handler ran once, post-retry
    assert fab.transport.faults_injected == 1
    assert_credits_balanced(fab)


def test_mutation_disabling_stream_retry_breaks_recovery(monkeypatch):
    """Disabling server-stream retry (the pre-hardening, unary-only
    behavior) must break test_server_stream_retry_*: the handle fails
    instead of recovering."""
    real = rpc.RetryInterceptor.on_complete

    def unary_only(self, ctx, event):
        if ctx.kind == rpc.SERVER_STREAM:
            return None
        return real(self, ctx, event)

    monkeypatch.setattr(rpc.RetryInterceptor, "on_complete", unary_only)
    fab, h, invocations = _stream_retry_scenario()
    assert h.done and h.error is not None   # the dedicated test's
    assert invocations["n"] == 0            # assertions now fail
    assert_credits_balanced(fab)            # ...but credits still hold


def test_stream_retry_not_attempted_after_first_chunk():
    """The transparency guard: once a chunk has been DELIVERED to the
    caller, a transient failure surfaces instead of re-issuing (which
    would duplicate the observed chunk). A tiny reverse window forces
    one chunk per flight, so chunk 0 is observed in an earlier flight
    than the fault: seed 0 at rate 0.5 passes the first response chunk
    (draw 0.637) and faults the second (0.270)."""
    retry = rpc.RetryInterceptor(max_attempts=4)
    fab = _faulty_fabric(
        "simulated", 2,
        fault_kw=dict(seed=0, fault_rate=0.5, max_faults=1,
                      links=[(1, 0)]),     # fault a RESPONSE chunk
        window_bytes=150, window_msgs=1,   # one 128B chunk per flight
        client_interceptors=[retry])
    fab.add_server(1).add_service(rpc.CONFORMANCE_SERVICE,
                                  rpc.conformance_handlers())
    h = fab.stub(rpc.CONFORMANCE_SERVICE, 0, 1).split(
        _bufs([600], seed=1))              # 5 chunks; #2 gets faulted
    fab.flush()
    assert fab.transport.faults_injected == 1
    assert h.done and h.error is not None
    assert len(h.chunks) == 1              # the chunk that landed
    assert retry.retries == 0              # never re-issued mid-stream
    assert_credits_balanced(fab)


# ---------------------------------------------------------------------------
# deadline propagation (mutation target: budget stamping)
# ---------------------------------------------------------------------------

def _shed_scenario():
    """A one-shot wire stall eats the whole budget: with propagation
    the server sheds before invoking the handler."""
    metrics = rpc.MetricsInterceptor()
    fab = _faulty_fabric(
        "simulated", 2,
        fault_kw=dict(seed=0, stall_rate=1.0, stall_s=2.0,
                      max_faults=1),
        client_interceptors=[metrics], server_interceptors=[metrics])
    served = {"n": 0}

    def echo(req):
        served["n"] += 1
        return [(8,)]

    svc = rpc.ServiceDef("E", (rpc.MethodSpec("echo", rpc.UNARY),))
    srv = fab.add_server(1)
    srv.add_service(svc, {"echo": echo})
    call = fab.stub(svc, 0, 1).echo(None, sizes=[64], deadline_s=1.0)
    fab.flush()
    return fab, srv, call, served, metrics


def test_server_sheds_expired_work_before_handler():
    fab, srv, call, served, metrics = _shed_scenario()
    assert call.done
    with pytest.raises(rpc.RpcError, match="deadline exceeded"):
        call.result()
    assert served["n"] == 0 and srv.calls_shed == 1
    snap = metrics.snapshot()
    assert snap["server:E/echo"]["shed"] == 1
    # the client counts it as a deadline outcome, not a generic error
    assert snap["E/echo"]["deadline_exceeded"] == 1
    assert fab.transport.stalls_injected == 1
    assert_credits_balanced(fab)


def test_mutation_disabling_budget_stamping_breaks_shedding(monkeypatch):
    """Zeroing deadline propagation (no budget stamped into the header)
    must break test_server_sheds_*: the handler runs on doomed work."""
    monkeypatch.setattr(rpc.RpcFabric, "_stamp_budget",
                        lambda self, msg, now: msg)
    fab, srv, call, served, metrics = _shed_scenario()
    assert served["n"] == 1 and srv.calls_shed == 0   # doomed work ran
    assert_credits_balanced(fab)


def test_budget_header_visible_to_server_time_remaining():
    """ServerContext.time_remaining() exposes the propagated budget
    minus what the wire consumed, on the fabric clock."""
    seen = {}

    class Probe(rpc.ServerInterceptor):
        def on_receive(self, ctx):
            seen["remaining"] = ctx.time_remaining()
            seen["deadline"] = ctx.deadline_s

    net_fab = rpc.RpcFabric(
        rpc.make_transport("simulated", 2, network="eth40g"),
        server_interceptors=[Probe()])
    net_fab.add_server(1).add_service(rpc.CONFORMANCE_SERVICE,
                                      rpc.conformance_handlers())
    c = net_fab.stub(rpc.CONFORMANCE_SERVICE, 0, 1).echo(
        None, sizes=[1 << 20], deadline_s=10.0)
    net_fab.flush()
    assert c.error is None
    wire = net_fab.transport.clock_s     # what the flight cost
    assert wire > 0.0
    assert seen["remaining"] is not None
    assert seen["remaining"] == pytest.approx(10.0 - wire, abs=1e-3)
    # a call without a deadline propagates none
    seen.clear()
    net_fab.stub(rpc.CONFORMANCE_SERVICE, 0, 1).echo(
        None, sizes=[64]).result()
    assert seen["remaining"] is None and seen["deadline"] is None


def test_shed_mid_stream_drops_remaining_chunks():
    """A client stream whose budget expires mid-wire is shed at its
    first chunk; the later chunks (riding the same flight) are consumed
    without re-creating server state."""
    metrics = rpc.MetricsInterceptor()
    fab = _faulty_fabric(
        "simulated", 2,
        fault_kw=dict(seed=0, stall_rate=1.0, stall_s=5.0,
                      max_faults=1),
        client_interceptors=[metrics], server_interceptors=[metrics])
    gathered = {"n": 0}

    def gather(req):
        gathered["n"] += 1
        return [(4,)]

    svc = rpc.ServiceDef("G", (rpc.MethodSpec("gather",
                                              rpc.CLIENT_STREAM),))
    srv = fab.add_server(1)
    srv.add_service(svc, {"gather": gather})
    c = fab.stub(svc, 0, 1).gather(None, sizes=[256], n_chunks=3,
                                   deadline_s=1.0)
    fab.flush()
    assert c.done and gathered["n"] == 0
    assert srv.calls_shed == 1           # shed once, at the opener
    with pytest.raises(rpc.RpcError, match="deadline exceeded"):
        c.result()
    assert_credits_balanced(fab)


@pytest.mark.parametrize("transport_name", sorted(TRANSPORTS))
def test_faulted_unary_reply_fails_transiently_and_retries(
        transport_name):
    """A fault on the RESPONSE of a unary call (the reply sub-flight)
    must surface as a transient failure — never as a phantom success —
    and a RetryInterceptor re-runs the call (at-least-once, like
    gRPC): the handler executes once per attempt."""
    retry = rpc.RetryInterceptor(max_attempts=4)
    fab = _faulty_fabric(
        transport_name, 2,
        fault_kw=dict(seed=1, fault_rate=1.0, max_faults=1,
                      links=[(1, 0)]),   # only the reply direction
        client_interceptors=[retry])
    served = {"n": 0}

    def echo(req):
        served["n"] += 1
        return [np.array(b, copy=True) for b in req]

    svc = rpc.ServiceDef("U", (rpc.MethodSpec("echo", rpc.UNARY),))
    fab.add_server(1).add_service(svc, {"echo": echo})
    payload = _bufs([256], seed=2)
    c = fab.stub(svc, 0, 1).echo(payload)
    fab.flush()
    assert c.done and c.error is None, c.error
    assert np.array_equal(c.reply_bufs()[0], payload[0])
    assert fab.transport.faults_injected == 1
    assert retry.retries == 1
    assert served["n"] == 2              # the request WAS handled twice
    assert_credits_balanced(fab)


def test_faulted_unary_reply_without_retry_fails_not_succeeds():
    """Without a retry chain the lost reply is a transient error — the
    regression was a phantom success carrying the 'lost' payload."""
    fab = _faulty_fabric(
        "loopback", 2,
        fault_kw=dict(seed=1, fault_rate=1.0, max_faults=1,
                      links=[(1, 0)]))
    fab.add_server(1).add_service(rpc.CONFORMANCE_SERVICE,
                                  rpc.conformance_handlers())
    c = fab.stub(rpc.CONFORMANCE_SERVICE, 0, 1).echo(_bufs([64], seed=0))
    fab.flush()
    assert c.done and c.error is not None
    assert rpc.is_transient(c.error)
    assert_credits_balanced(fab)


def test_stall_is_real_wall_time_on_measured_transports():
    """On loopback a stall actually sleeps, so deadline propagation
    sheds on the wall clock exactly like on the modeled clock."""
    fab = _faulty_fabric(
        "loopback", 2,
        fault_kw=dict(seed=0, stall_rate=1.0, stall_s=0.05,
                      max_faults=1))
    served = {"n": 0}

    def echo(req):
        served["n"] += 1
        return req

    svc = rpc.ServiceDef("W", (rpc.MethodSpec("echo", rpc.UNARY),))
    srv = fab.add_server(1)
    srv.add_service(svc, {"echo": echo})
    c = fab.stub(svc, 0, 1).echo(_bufs([64], seed=0), deadline_s=0.02)
    fab.flush()
    assert c.done and served["n"] == 0 and srv.calls_shed == 1
    with pytest.raises(rpc.RpcError, match="deadline exceeded"):
        c.result()
    assert_credits_balanced(fab)


def test_shed_one_way_call_returns_no_reply():
    """A shed one-way call produces no error reply (there is nobody
    waiting for one) — it still counts as shed and its credits
    return."""
    fab = _faulty_fabric(
        "simulated", 2,
        fault_kw=dict(seed=0, stall_rate=1.0, stall_s=3.0,
                      max_faults=1))
    served = {"n": 0}

    def fire(req):
        served["n"] += 1
        return None

    svc = rpc.ServiceDef("F", (rpc.MethodSpec("fire", rpc.UNARY),))
    srv = fab.add_server(1)
    srv.add_service(svc, {"fire": fire})
    c = fab.stub(svc, 0, 1).fire(None, sizes=[64], one_way=True,
                                 deadline_s=1.0)
    fab.flush()
    assert c.done and served["n"] == 0 and srv.calls_shed == 1
    assert_credits_balanced(fab)


def test_retry_backoff_sleeps_on_measured_transports():
    """On a non-modeled (loopback) transport the retry backoff is a
    real wall-clock wait — tiny here, but the path must work."""
    import time as _t
    retry = rpc.RetryInterceptor(max_attempts=3, backoff_s=0.01)
    fab = _faulty_fabric(
        "loopback", 2,
        fault_kw=dict(seed=0, fault_rate=1.0, max_faults=1),
        client_interceptors=[retry])
    fab.add_server(1).add_service(rpc.CONFORMANCE_SERVICE,
                                  rpc.conformance_handlers())
    t0 = _t.perf_counter()
    c = fab.stub(rpc.CONFORMANCE_SERVICE, 0, 1).echo(
        _bufs([64], seed=0))
    fab.flush()
    assert c.error is None and retry.retries == 1
    assert _t.perf_counter() - t0 >= 0.01
    assert_credits_balanced(fab)


# ---------------------------------------------------------------------------
# admission control (mutation target: AdmissionInterceptor)
# ---------------------------------------------------------------------------

def _admission_scenario():
    """A flight of 4 unary calls into one endpoint capped at 2: two are
    rejected with ResourceExhausted and recover via retry on the next
    (drained) flight."""
    metrics = rpc.MetricsInterceptor()
    admission = rpc.AdmissionInterceptor(2, metrics=metrics)
    fab = rpc.RpcFabric(
        rpc.make_transport("simulated", 5, network="eth40g"),
        client_interceptors=[metrics,
                             rpc.RetryInterceptor(max_attempts=4)],
        server_interceptors=[metrics, admission])
    fab.add_server(0).add_service(rpc.CONFORMANCE_SERVICE,
                                  rpc.conformance_handlers())
    calls = [fab.stub(rpc.CONFORMANCE_SERVICE, w, 0)
             .echo(None, sizes=[1024]) for w in (1, 2, 3, 4)]
    fab.flush()
    return fab, calls, admission, metrics


def test_admission_rejects_over_limit_and_retries_recover():
    fab, calls, admission, metrics = _admission_scenario()
    assert all(c.done and c.error is None for c in calls)
    assert admission.rejected == 2
    snap = metrics.snapshot()
    srv_rec = snap["server:Conformance/echo"]
    assert srv_rec["rejected"] == 2
    assert srv_rec["queue_peak"] == 4    # the metrics fed the signal
    assert srv_rec["calls"] == 4         # every call served eventually
    assert snap["Conformance/echo"]["retries"] == 2
    assert_credits_balanced(fab)


def test_mutation_disabling_admission_control_breaks_rejection(
        monkeypatch):
    """Neutering AdmissionInterceptor.on_admit must break
    test_admission_rejects_*: nothing is rejected, nothing retries."""
    monkeypatch.setattr(rpc.AdmissionInterceptor, "on_admit",
                        lambda self, ctx: None)
    fab, calls, admission, metrics = _admission_scenario()
    assert all(c.error is None for c in calls)
    assert admission.rejected == 0                      # gate is gone
    assert metrics.snapshot()["Conformance/echo"]["retries"] == 0
    assert_credits_balanced(fab)


def test_handler_raised_resource_exhausted_is_transient():
    """A handler may apply its own admission policy by raising
    ResourceExhausted — the reply is transient AND recognizably
    resource-exhaustion (the failover trigger)."""
    def refuse(req):
        raise rpc.ResourceExhausted("busy")

    fab = rpc.RpcFabric(rpc.make_transport("loopback", 2))
    svc = rpc.ServiceDef("R", (rpc.MethodSpec("get", rpc.UNARY),))
    fab.add_server(1).add_service(svc, {"get": refuse})
    c = fab.stub(svc, 0, 1).get([np.zeros(4, np.uint8)])
    fab.flush()
    assert rpc.is_transient(c.error)
    assert rpc.is_resource_exhausted(c.error)


def test_admission_limits_per_endpoint_from_cluster_spec():
    """EndpointSpec.admission_limit round-trips through JSON and feeds
    AdmissionInterceptor.limits via ClusterSpec.admission_limits()."""
    spec = rpc.ClusterSpec(endpoints=(
        rpc.EndpointSpec("ps0", job="ps", admission_limit=2),
        rpc.EndpointSpec("ps1", job="ps"),
        rpc.EndpointSpec("w0"),))
    again = rpc.ClusterSpec.from_json(spec.to_json())
    assert again == spec
    assert spec.admission_limits() == {0: 2}
    with pytest.raises(ValueError, match="admission_limit"):
        rpc.ClusterSpec(endpoints=(
            rpc.EndpointSpec("a", admission_limit=0),))


# ---------------------------------------------------------------------------
# ShardedServeStub failover on ResourceExhausted
# ---------------------------------------------------------------------------

def _serve_handlers(name, served):
    from repro.serve.engine import (_i32_buf, decode_generate_request,
                                    encode_generate_reply)

    def generate(bufs):
        served[name] += 1
        prompts, mnt = decode_generate_request(bufs)
        return encode_generate_reply(
            np.full((prompts.shape[0], max(mnt, 1)), int(name[-1]),
                    np.int32))

    def generate_stream(bufs):
        served[name] += 1
        prompts, mnt = decode_generate_request(bufs)
        return [[_i32_buf(np.full(prompts.shape[0], int(name[-1]),
                                  np.int32))]
                for _ in range(max(mnt, 1))]

    return {"generate": generate, "generate_stream": generate_stream}


def test_sharded_stub_fails_over_on_admission_rejection():
    """ps0 caps at 1 outstanding call; the third round-robin dispatch
    (2nd onto ps0) is rejected and transparently re-issued on ps1 —
    the PS-style failover the admission signal exists for."""
    from repro.serve.engine import SERVE_SERVICE, ShardedServeStub
    cluster = rpc.ClusterSpec(endpoints=(
        rpc.EndpointSpec("ps0", job="ps", admission_limit=1),
        rpc.EndpointSpec("ps1", job="ps"),
        rpc.EndpointSpec("worker0"),))
    metrics = rpc.MetricsInterceptor()
    admission = rpc.AdmissionInterceptor(
        limits=cluster.admission_limits(), metrics=metrics)
    fab = rpc.RpcFabric(rpc.make_transport("cluster", cluster=cluster),
                        client_interceptors=[metrics],
                        server_interceptors=[metrics, admission])
    served = {"ps0": 0, "ps1": 0}
    for name in ("ps0", "ps1"):
        fab.add_server(name).add_service(SERVE_SERVICE,
                                         _serve_handlers(name, served))
    stub = ShardedServeStub(fab, "worker0", ("ps0", "ps1"))
    prompts = np.zeros((1, 4), np.int32)
    calls = [stub.generate(prompts, 1) for _ in range(3)]
    fab.flush()
    outs = [int(c.result()[0, 0]) for c in calls]
    assert outs == [0, 1, 1]             # the rejected call moved shards
    assert admission.rejected == 1
    assert stub._failover is not None and stub._failover.failovers == 1
    assert served == {"ps0": 1, "ps1": 2}
    assert_credits_balanced(fab)


def test_failover_also_carries_server_streams():
    """A generate_stream rejected at its opener (zero chunks delivered)
    fails over like a unary call."""
    from repro.serve.engine import SERVE_SERVICE, ShardedServeStub
    cluster = rpc.ClusterSpec(endpoints=(
        rpc.EndpointSpec("ps0", job="ps", admission_limit=1),
        rpc.EndpointSpec("ps1", job="ps"),
        rpc.EndpointSpec("worker0"),))
    metrics = rpc.MetricsInterceptor()
    fab = rpc.RpcFabric(
        rpc.make_transport("cluster", cluster=cluster),
        client_interceptors=[metrics],
        server_interceptors=[metrics, rpc.AdmissionInterceptor(
            limits=cluster.admission_limits(), metrics=metrics)])
    served = {"ps0": 0, "ps1": 0}
    for name in ("ps0", "ps1"):
        fab.add_server(name).add_service(SERVE_SERVICE,
                                         _serve_handlers(name, served))
    stub = ShardedServeStub(fab, "worker0", ("ps0", "ps1"))
    prompts = np.zeros((2, 4), np.int32)
    a = stub.generate_stream(prompts, 2)     # rr -> ps0
    b = stub.generate_stream(prompts, 2)     # rr -> ps1
    c = stub.generate_stream(prompts, 2)     # rr -> ps0: rejected
    fab.flush()
    for h in (a, b, c):
        assert h.done and h.error is None, h.error
    assert [int(h.chunks[0][0].view("<i4")[0]) for h in (a, b, c)] \
        == [0, 1, 1]
    assert stub._failover.failovers == 1
    assert_credits_balanced(fab)


# ---------------------------------------------------------------------------
# the acceptance scenario: serve_cluster, 1 ps / 3 workers, seeded
# faults, admission control + stream retry, zero deadline violations
# ---------------------------------------------------------------------------

def test_serve_cluster_under_faults_completes_all_requests():
    import jax
    from repro.configs import get_reduced_config
    from repro.models import init_params
    from repro.parallel import NO_MESH
    from repro.serve.engine import ServeConfig, ServeEngine

    cfg = get_reduced_config("qwen3-8b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(NO_MESH, cfg, params,
                      ServeConfig(max_seq=64, max_new_tokens=4))
    prompts = np.random.default_rng(0).integers(
        0, cfg.model.vocab_size, (2, 8), dtype=np.int32)
    direct = eng.generate(prompts)

    cluster = rpc.ClusterSpec(endpoints=(
        rpc.EndpointSpec("ps0", job="ps", network="rdma_edr",
                         admission_limit=2),
        rpc.EndpointSpec("worker0", network="rdma_edr"),
        rpc.EndpointSpec("worker1", network="rdma_edr"),
        rpc.EndpointSpec("worker2", network="rdma_edr")))
    metrics = rpc.MetricsInterceptor(per_endpoint=True)
    deadline = rpc.DeadlineInterceptor(default_deadline_s=30.0)
    retry = rpc.RetryInterceptor(max_attempts=6)
    fabric, stubs = eng.serve_cluster(
        cluster,
        client_interceptors=[metrics, deadline, retry],
        server_interceptors=[metrics],
        fault=dict(seed=11, fault_rate=0.3, max_faults=4,
                   links=[(w, 0) for w in (1, 2, 3)]))
    assert sorted(stubs) == ["worker0", "worker1", "worker2"]
    # 3 workers: a unary generate AND a token stream each — with an
    # admission cap of 2 at the single PS, at least one dispatch per
    # flight is rejected and must recover by retry on a later flight
    calls = {w: stub.generate(prompts) for w, stub in stubs.items()}
    streams = {w: stub.generate_stream(prompts)
               for w, stub in stubs.items()}
    fabric.flush()
    for w, call in calls.items():
        assert np.array_equal(call.result(), direct), w
    from repro.serve.engine import decode_token_chunk
    for w, h in streams.items():
        assert h.done and h.error is None, (w, h.error)
        got = np.stack([decode_token_chunk(c) for c in h.chunk_bufs()],
                       axis=1)
        assert np.array_equal(got, direct), w
    # the schedule fired and the hardening absorbed all of it
    assert fabric.transport.faults_injected >= 1
    assert retry.retries >= 1
    snap = metrics.snapshot()
    for rec in snap.values():
        assert rec["deadline_exceeded"] == 0      # zero violations
    assert snap["server:Serve/generate"]["shed"] == 0
    assert fabric.servers[0].calls_shed == 0
    assert_credits_balanced(fabric)


# ---------------------------------------------------------------------------
# the closed retry matrix: client-stream + bidi retried exactly-once
# (bounded client-side chunk buffering in RetryInterceptor)
# ---------------------------------------------------------------------------

def _client_stream_retry_scenario(n_chunks=3, **fabric_kw):
    """A client-stream whose opening chunk frame is faulted once: with
    buffered request chunks the whole stream is transparently
    re-issued under a fresh call id."""
    retry = rpc.RetryInterceptor(max_attempts=4)
    fab = _faulty_fabric(
        "simulated", 2,
        fault_kw=dict(seed=0, fault_rate=1.0, max_faults=1,
                      links=[(0, 1)]),
        client_interceptors=[retry], **fabric_kw)
    invocations = {"n": 0, "chunks": 0}

    def concat(bufs):
        invocations["n"] += 1
        invocations["chunks"] = len(bufs)
        return [np.concatenate(bufs)]

    svc = rpc.ServiceDef("CS", (rpc.MethodSpec("concat",
                                               rpc.CLIENT_STREAM),))
    fab.add_server(1).add_service(svc, {"concat": concat})
    chunks = [[np.full(64, i, np.uint8)] for i in range(n_chunks)]
    call = fab.stub(svc, 0, 1).concat.client_stream(chunks,
                                                    deadline_s=60.0)
    fab.flush()
    return fab, call, retry, invocations


def test_client_stream_retried_exactly_once_under_faults():
    fab, call, retry, invocations = _client_stream_retry_scenario()
    assert call.done and call.error is None, call.error
    (out,) = call.result()
    expected = np.concatenate([np.full(64, i, np.uint8)
                               for i in range(3)])
    assert np.array_equal(out, expected)     # every chunk, in order
    assert invocations["n"] == 1             # handler ran once
    assert invocations["chunks"] == 3        # with the full stream
    assert retry.retries == 1
    assert fab.transport.faults_injected == 1
    assert_credits_balanced(fab)


def test_client_stream_retry_gives_up_past_buffer_bound():
    """Streams longer than retry_buffer_chunks cannot be replayed: the
    fault surfaces as an error and gave_up_buffer counts the give-up
    (the bounded-memory contract — no unbounded chunk retention)."""
    fab, call, retry, invocations = _client_stream_retry_scenario(
        n_chunks=4, retry_buffer_chunks=2)
    assert call.done and call.error is not None
    assert rpc.is_transient(call.error)
    assert invocations["n"] == 0             # nothing reached the handler
    assert retry.retries == 0                # no partial re-issue
    assert retry.gave_up_buffer == 1
    assert_credits_balanced(fab)


def test_bidi_retried_exactly_once_under_faults():
    retry = rpc.RetryInterceptor(max_attempts=4)
    fab = _faulty_fabric(
        "simulated", 2,
        fault_kw=dict(seed=0, fault_rate=1.0, max_faults=1,
                      links=[(0, 1)]),
        client_interceptors=[retry])
    echoed = {"n": 0}

    def mirror(chunk, end):
        if chunk:
            echoed["n"] += 1
            return [chunk]
        return None

    svc = rpc.ServiceDef("BD", (rpc.MethodSpec("mirror", rpc.BIDI),))
    fab.add_server(1).add_service(svc, {"mirror": mirror})
    h = fab.stub(svc, 0, 1).mirror(
        [[np.full(32, i, np.uint8)] for i in range(2)], deadline_s=60.0)
    fab.flush()
    assert h.done and h.error is None, h.error
    assert echoed["n"] == 2                  # each chunk handled once
    assert len(h.chunks) == 2
    for i, bufs in enumerate(h.chunks):
        assert np.array_equal(bufs[0], np.full(32, i, np.uint8))
    assert retry.retries == 1
    assert fab.transport.faults_injected == 1
    assert_credits_balanced(fab)


def test_failover_moves_outstanding_call_accounting():
    """Regression: a failed-over call used to stay booked against the
    REJECTING shard's outstanding count, permanently biasing
    least_loaded dispatch away from it. The re-route must move the
    handle to the shard that actually serves it."""
    from repro.serve.engine import SERVE_SERVICE, ShardedServeStub
    cluster = rpc.ClusterSpec(endpoints=(
        rpc.EndpointSpec("ps0", job="ps", admission_limit=1),
        rpc.EndpointSpec("ps1", job="ps"),
        rpc.EndpointSpec("worker0"),))
    metrics = rpc.MetricsInterceptor()
    fab = rpc.RpcFabric(
        rpc.make_transport("cluster", cluster=cluster),
        client_interceptors=[metrics],
        server_interceptors=[metrics, rpc.AdmissionInterceptor(
            limits=cluster.admission_limits(), metrics=metrics)])
    served = {"ps0": 0, "ps1": 0}
    for name in ("ps0", "ps1"):
        fab.add_server(name).add_service(SERVE_SERVICE,
                                         _serve_handlers(name, served))
    stub = ShardedServeStub(fab, "worker0", ("ps0", "ps1"))
    prompts = np.zeros((1, 4), np.int32)
    calls = [stub.generate(prompts, 1) for _ in range(3)]
    assert [len(b) for b in stub._inflight] == [2, 1]   # rr booking
    fab.flush()
    assert stub._failover.failovers == 1
    moved = calls[2].call_id
    # the re-routed call's handle now loads ps1's book, not ps0's
    assert all(h.call_id != moved for h in stub._inflight[0])
    assert any(h.call_id == moved for h in stub._inflight[1])
    assert stub.outstanding(0) == 0 and stub.outstanding(1) == 0
    assert_credits_balanced(fab)


def test_shed_plus_failover_mid_decode_keeps_trace_and_phases():
    """Fault-tier serve scenario: admission sheds a unary generate off
    ps0 (two calls land there in one flight, limit 1) and failover
    re-routes it to ps1, whose scheduler is mid-decode on a stream —
    the re-routed request JOINS that running batch. The call keeps ONE
    trace id across the shed + re-route, and every call's phase spans
    still partition its end-to-end latency exactly."""
    import jax
    from repro.configs import get_reduced_config
    from repro.models import init_params
    from repro.parallel import NO_MESH
    from repro.serve.engine import (ServeConfig, ServeEngine,
                                    ShardedServeStub,
                                    decode_token_chunk)

    cfg = get_reduced_config("qwen3-8b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(NO_MESH, cfg, params,
                      ServeConfig(max_seq=64, max_new_tokens=4))
    prompts = np.random.default_rng(3).integers(
        0, cfg.model.vocab_size, (1, 8), dtype=np.int32)
    direct = eng.generate(prompts)

    cluster = rpc.ClusterSpec(endpoints=(
        rpc.EndpointSpec("ps0", job="ps", admission_limit=1),
        rpc.EndpointSpec("ps1", job="ps"),
        rpc.EndpointSpec("worker0")))
    tracer = rpc.Tracer()
    metrics = rpc.MetricsInterceptor()
    fab = rpc.RpcFabric(
        rpc.make_transport("cluster", cluster=cluster),
        client_interceptors=[metrics],
        server_interceptors=[metrics, rpc.AdmissionInterceptor(
            limits=cluster.admission_limits(), metrics=metrics)],
        tracer=tracer)
    for name in ("ps0", "ps1"):
        eng.attach(fab.add_server(name))
    stub = ShardedServeStub(fab, "worker0", ("ps0", "ps1"))
    # round-robin: stream -> ps0, stream -> ps1, unary -> ps0; the
    # unary is the SECOND call landing on ps0 that flight, so it is
    # shed and re-routed to ps1 mid-decode of ps1's stream
    s0 = stub.generate_stream(prompts)
    s1 = stub.generate_stream(prompts)
    call = stub.generate(prompts)
    fab.flush()
    for h in (s0, s1):
        assert h.done and h.error is None, h.error
        got = np.stack([decode_token_chunk(c) for c in h.chunk_bufs()],
                       axis=1)
        assert np.array_equal(got, direct)
    assert np.array_equal(call.result(), direct)
    assert stub._failover.failovers >= 1
    # the re-routed unary joined ps1's batch while the stream decoded
    sched_ps1 = eng.schedulers[fab.resolve_endpoint("ps1")]
    assert sched_ps1.counters["peak_running"] >= 2
    roots = tracer.calls()
    assert len(roots) == 3
    rerouted = [r for r in roots if len(r.attempt_spans()) > 1]
    assert rerouted
    for root in rerouted:
        dsts = [a.attrs["dst"] for a in root.attempt_spans()]
        assert dsts[0] == "ps0" and dsts[-1] == "ps1"
    for root in roots:
        # one trace id survives the shed + failover...
        assert {s.trace_id for s in root.walk()} == {root.trace_id}
        # ...and the phases stay a contiguous partition of e2e
        phases = sorted((s for s in root.phase_spans() if s.closed),
                        key=lambda s: (s.start_s, s.span_id))
        assert phases
        assert phases[0].start_s == root.start_s
        assert phases[-1].end_s == root.end_s
        for a, b in zip(phases, phases[1:]):
            assert a.end_s == b.start_s
        assert sum(p.duration_s for p in phases) == pytest.approx(
            root.duration_s, rel=1e-9, abs=0.0)
    assert_credits_balanced(fab)
