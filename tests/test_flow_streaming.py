"""Streaming call types (server-streaming, bidi) and the per-direction
credit windows behind them: exhaustion stalls (never drops), interleaved
window-limited bidi streams make progress, and credits come back on
stream close. These tests are deliberately sensitive to the credit
accounting — flipping a grant breaks window-restoration asserts, and
dropping a stalled chunk breaks the content asserts."""
import numpy as np
import pytest

from repro import rpc
from repro.core.netmodel import NETWORKS


def _bufs(sizes, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 255, s, dtype=np.uint8) for s in sizes]


# ---------------------------------------------------------------------------
# server streaming
# ---------------------------------------------------------------------------

def test_server_stream_basic():
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2))
    fab.add_server(1).register_server_stream(
        "range", lambda req: [[np.full(8, i, np.uint8)] for i in range(4)])
    h = fab.channel(0, 1).server_stream("range", [np.zeros(1, np.uint8)])
    fab.flush()
    got = h.chunk_bufs()
    assert len(got) == 4
    for i, c in enumerate(got):
        assert np.array_equal(c[0], np.full(8, i, np.uint8))


def test_server_stream_empty_response_sends_bare_end():
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2))
    fab.add_server(1).register_server_stream("none", lambda req: [])
    h = fab.channel(0, 1).server_stream("none", [np.zeros(1, np.uint8)])
    fab.flush()
    assert h.done and h.chunk_bufs() == []


def test_server_stream_chunk_seqs_are_ordered():
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2))
    fab.add_server(1).register_server_stream(
        "r", lambda req: [[np.full(4, i, np.uint8)] for i in range(3)])
    fab.channel(0, 1).server_stream("r", [np.zeros(1, np.uint8)])
    fab.flush()
    seqs = [e.payload.seq for e in fab.cq.drain()
            if e.kind == "stream_chunk"]
    assert seqs == [0, 1, 2]


def test_server_stream_unknown_method_errors_handle():
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2))
    fab.add_server(1)
    h = fab.channel(0, 1).server_stream("nosuch", [np.zeros(1, np.uint8)])
    fab.flush()
    assert h.done
    with pytest.raises(rpc.RpcError, match="unimplemented"):
        h.chunk_bufs()


def test_server_stream_handler_fault_errors_handle():
    def boom(req):
        raise ValueError("nope")
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2))
    fab.add_server(1).register_server_stream("boom", boom)
    ch = fab.channel(0, 1)
    h = ch.server_stream("boom", [np.zeros(1, np.uint8)])
    fab.flush()
    with pytest.raises(rpc.RpcError, match="nope"):
        h.chunk_bufs()
    # the error reply still restored the request's forward credits
    assert ch.window.bytes_avail == ch.window.window_bytes


def test_cardinality_stream_call_to_server_stream_method():
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2))
    fab.add_server(1).register_server_stream("ss", lambda req: [])
    h = fab.channel(0, 1).bidi_stream("ss", [[np.ones(4, np.uint8)]])
    fab.flush()
    with pytest.raises(rpc.RpcError, match="cardinality mismatch"):
        h.chunk_bufs()


# ---------------------------------------------------------------------------
# flow control: exhaustion stalls, never drops
# ---------------------------------------------------------------------------

def test_reverse_window_exhaustion_stalls_stream_not_drops():
    """5 chunks of 800 B through a 1 KB reverse window: one chunk per
    flight, every chunk arrives, in order, with the stalls counted."""
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2),
                        window_bytes=1024, window_msgs=8)
    fab.add_server(1).register_server_stream(
        "big", lambda req: [[np.full(800, i, np.uint8)]
                            for i in range(5)])
    ch = fab.channel(0, 1)
    h = ch.server_stream("big", [np.zeros(1, np.uint8)])
    rep = fab.flush()
    got = h.chunk_bufs()
    assert [int(c[0][0]) for c in got] == [0, 1, 2, 3, 4]  # none dropped
    assert ch.rwindow.stats.stalled == 4       # all but the first waited
    assert rep.flights >= 5                    # window forced extra flights
    # credits returned on stream close: window fully restored
    assert ch.rwindow.bytes_avail == ch.rwindow.window_bytes
    assert ch.rwindow.msgs_avail == ch.rwindow.window_msgs


def test_stream_resumes_on_credit_grant_not_force():
    """With a window that fits exactly one chunk, every admission after
    the first must come from a *grant* (delivery of the previous chunk),
    not the deadlock-breaker: byte credits never go negative-equivalent,
    i.e. the window is exactly restored and stalls == chunks - 1."""
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2),
                        window_bytes=1000, window_msgs=1)
    fab.add_server(1).register_server_stream(
        "s", lambda req: [[np.full(1000, i, np.uint8)] for i in range(3)])
    ch = fab.channel(0, 1)
    h = ch.server_stream("s", [np.zeros(1, np.uint8)])
    fab.flush()
    assert len(h.chunk_bufs()) == 3
    assert ch.rwindow.stats.stalled == 2
    assert ch.rwindow.stats.acquired >= 3
    assert ch.rwindow.bytes_avail == 1000
    assert ch.rwindow.msgs_avail == 1


def test_forward_window_stalls_bidi_sends():
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2),
                        window_bytes=512, window_msgs=2)
    fab.add_server(1).register_bidi("sink", lambda c, end: None)
    ch = fab.channel(0, 1)
    h = ch.bidi_stream("sink")
    for i in range(6):
        h.send([np.full(400, i, np.uint8)], end=(i == 5))
    fab.flush()
    assert h.done and h.chunks == []           # sink: END trailer only
    assert ch.window.stats.stalled >= 4
    assert ch.window.bytes_avail == 512        # all forward credits back


# ---------------------------------------------------------------------------
# bidi: interleaving and both-direction window limits
# ---------------------------------------------------------------------------

def test_bidi_echo_roundtrip():
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2))
    fab.add_server(1).register_bidi(
        "inc", lambda c, end: [[(c[0] + 1).astype(np.uint8)]]
        if c else None)
    h = fab.channel(0, 1).bidi_stream(
        "inc", [[np.full(4, i, np.uint8)] for i in range(3)])
    fab.flush()
    assert [int(c[0][0]) for c in h.chunk_bufs()] == [1, 2, 3]


def test_interleaved_bidi_streams_no_deadlock_when_window_limited():
    """Two bidi streams share one channel whose windows (both
    directions) admit a single 400 B chunk at a time; both streams must
    drain completely with their data intact."""
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2),
                        window_bytes=512, window_msgs=1)
    fab.add_server(1).register_bidi(
        "echo", lambda c, end: [c] if c else None)
    ch = fab.channel(0, 1)
    h1, h2 = ch.bidi_stream("echo"), ch.bidi_stream("echo")
    for i in range(3):
        h1.send([np.full(400, i, np.uint8)], end=(i == 2))
        h2.send([np.full(400, 10 + i, np.uint8)], end=(i == 2))
    fab.flush()
    assert [int(c[0][0]) for c in h1.chunk_bufs()] == [0, 1, 2]
    assert [int(c[0][0]) for c in h2.chunk_bufs()] == [10, 11, 12]
    assert ch.window.stats.stalled > 0         # both directions were
    assert ch.window.bytes_avail == 512        # limited, and both
    assert ch.rwindow.bytes_avail == 512       # fully recovered


def test_bidi_incremental_send_close_with_trailer():
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2))
    fab.add_server(1).register_bidi(
        "echo", lambda c, end: [c] if c else None)
    h = fab.channel(0, 1).bidi_stream("echo")
    h.send([np.full(4, 1, np.uint8)])
    h.send([np.full(4, 2, np.uint8)])
    h.close()                                  # bare END trailer
    fab.flush()
    assert [int(c[0][0]) for c in h.chunk_bufs()] == [1, 2]
    with pytest.raises(AssertionError):
        h.send([np.zeros(1, np.uint8)])        # closed is closed


def test_stream_events_on_completion_queue():
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2))
    fab.add_server(1).register_server_stream(
        "r", lambda req: [[np.full(4, i, np.uint8)] for i in range(2)])
    h = fab.channel(0, 1).server_stream("r", [np.zeros(1, np.uint8)])
    fab.flush()
    kinds = [e.kind for e in fab.cq.drain() if e.tag == h.call_id]
    assert kinds.count("stream_chunk") == 2
    assert kinds[-1] == "stream_end"


def test_streaming_state_does_not_accumulate():
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2))
    srv = fab.add_server(1)
    srv.register_server_stream(
        "r", lambda req: [[np.full(8, i, np.uint8)] for i in range(3)])
    srv.register_bidi("e", lambda c, end: [c] if c else None)
    ch = fab.channel(0, 1)
    for i in range(20):
        ch.server_stream("r", [np.zeros(1, np.uint8)])
        ch.bidi_stream("e", [[np.full(16, i % 250, np.uint8)]])
        fab.flush()
    assert len(fab._handles) == 0
    assert len(fab._calls) == 0
    assert srv._streams == {} and srv._bidi_seq == {}
    assert srv._pumps == {}
    assert len(ch.rx_gate) == 0


# ---------------------------------------------------------------------------
# ring / incast exchanges over the fabric
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,chunks", [(2, 1), (3, 4), (8, 2)])
def test_ring_exchange_simulated_counts(n, chunks):
    fab = rpc.RpcFabric(rpc.SimulatedTransport(n, NETWORKS["rdma_edr"]))
    rep = rpc.ring_exchange(fab, [1024, 64], n_chunks=chunks)
    assert rep.messages == n * chunks
    assert rep.rounds == chunks        # rotation rounds, independent of n
    assert rep.modeled and rep.elapsed_s > 0


def test_ring_exchange_loopback_delivers_chunks():
    fab = rpc.RpcFabric(rpc.LoopbackTransport(3))
    rep = rpc.ring_exchange(fab, [256], n_chunks=2, bufs=_bufs([256]))
    assert not rep.modeled
    assert rep.messages == 6
    # every endpoint's sink saw one complete 2-chunk stream
    assert all(s.calls_served == 1 for s in fab.servers.values())


def test_incast_exchange_pushes_and_fetches():
    n_workers, chunks = 3, 2
    fab = rpc.RpcFabric(rpc.LoopbackTransport(n_workers + 1))
    bufs = _bufs([512, 128])
    rep = rpc.incast_exchange(fab, [512, 128], n_chunks=chunks,
                              bufs=bufs)
    # push (workers->server) + fetch (server->workers), both streamed
    assert rep.messages == 2 * n_workers * chunks
    assert fab.servers[0].calls_served == n_workers


def test_incast_single_worker_degenerates_to_p2p_stream():
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2))
    rep = rpc.incast_exchange(fab, [256], n_chunks=3, bufs=_bufs([256]))
    assert rep.messages == 6                   # 3 push + 3 fetch


def test_incast_fetch_respects_reverse_window():
    """The fetch half (server->worker chunks) is gated by the reverse
    window: a tiny window forces per-chunk flights but loses nothing."""
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2),
                        window_bytes=600, window_msgs=4)
    rep = rpc.incast_exchange(fab, [512], n_chunks=3, bufs=_bufs([512]))
    ch = fab._channels[(1, 0, "scatter_gather")]
    assert rep.messages == 6
    assert ch.rwindow.stats.stalled >= 2
    assert ch.rwindow.bytes_avail == 600
