"""Property-based framing tests: encode -> decode must round-trip
byte-identically for ARBITRARY iovec lists — zero-length and max-size
buffers included — in all three wire modes (serialized /
scatter_gather / zero_copy), for unary and stream-chunk frames. Runs
under the numpy backend (the kernel path is pinned byte-identical to
it by tests/test_rpc.py); skips cleanly when hypothesis is absent and
runs with --hypothesis-profile=ci in CI."""
import numpy as np
import pytest
from _hypothesis_support import given, settings, st

from repro.rpc import bufpool, framing

# the 128-byte pack-lane boundaries: where off-by-one padding bugs in
# _pack_numpy/_unpack_numpy and descriptor placement live
_LANE_EDGES = [0, 1, 127, 128, 129]

# size strategy: bias toward the interesting boundaries of the 128-byte
# lane besides arbitrary sizes; 0 is legal (empty iovec / END trailer)
_SIZES = st.lists(
    st.one_of(st.integers(0, 4096),
              st.sampled_from([0, 1, 127, 128, 129, 255, 256, 4095])),
    min_size=0, max_size=12)


def _bufs(sizes, seed):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 255, s, dtype=np.uint8) for s in sizes]


def _assert_roundtrip(f: framing.Frame) -> None:
    g = framing.decode(framing.encode(f))
    assert (g.call_id, g.method, g.flags, g.seq, g.sizes) == \
        (f.call_id, f.method, f.flags, f.seq, f.sizes)
    assert len(g.bufs) == len(f.bufs)
    for a, b in zip(f.bufs, g.bufs):
        assert np.array_equal(a, b)


@given(sizes=_SIZES, serialized=st.booleans(), seed=st.integers(0, 999),
       one_way=st.booleans())
@settings(max_examples=60, deadline=None)
def test_unary_frame_roundtrip(sizes, serialized, seed, one_way):
    f = framing.make_frame(3, "prop", _bufs(sizes, seed),
                           serialized=serialized, one_way=one_way)
    if serialized:
        assert len(framing.encode(f)) == 1
    else:
        assert len(framing.encode(f)) == len(sizes) + 1
    _assert_roundtrip(f)


@given(sizes=_SIZES, serialized=st.booleans(), seed=st.integers(0, 999),
       seq=st.integers(0, 2**31 - 1), end=st.booleans(),
       reply=st.booleans())
@settings(max_examples=60, deadline=None)
def test_stream_chunk_roundtrip(sizes, serialized, seed, seq, end, reply):
    f = framing.stream_chunk(11, "chunk", _bufs(sizes, seed), seq=seq,
                             end=end, serialized=serialized, reply=reply)
    assert f.is_stream and f.seq == seq
    assert f.stream_end == end and f.is_reply == reply
    _assert_roundtrip(f)


@given(sizes=_SIZES, seq=st.integers(0, 2**31 - 1),
       flags=st.integers(0, 63))
@settings(max_examples=60, deadline=None)
def test_header_roundtrip(sizes, seq, flags):
    f = framing.Frame(99, framing.method_id("h"), flags, tuple(sizes),
                      None, seq=seq)
    g, hdr_len = framing.parse_header(framing.header_bytes(f))
    assert hdr_len % framing.LANE == 0
    assert (g.call_id, g.method, g.flags, g.seq, g.sizes) == \
        (f.call_id, f.method, f.flags, f.seq, f.sizes)


@pytest.mark.parametrize("serialized", [False, True])
@pytest.mark.parametrize("stream", [False, True])
def test_max_size_chunk_roundtrip(serialized, stream):
    """The paper's Large-category ceiling (10 MB) in one iovec."""
    big = np.random.default_rng(0).integers(
        0, 255, 10 * 1024 * 1024, dtype=np.uint8)
    if stream:
        f = framing.stream_chunk(1, "big", [big], seq=0, end=True,
                                 serialized=serialized)
    else:
        f = framing.make_frame(1, "big", [big], serialized=serialized)
    _assert_roundtrip(f)


@given(sizes=st.lists(st.sampled_from(_LANE_EDGES), min_size=0,
                      max_size=8),
       wire_mode=st.sampled_from(framing.WIRE_MODES),
       seed=st.integers(0, 999))
@settings(max_examples=60, deadline=None)
def test_lane_boundary_roundtrip_all_modes(sizes, wire_mode, seed):
    """Pack/unpack and descriptor placement at the exact lane edges
    (0/1/127/128/129 bytes), for every wire mode — the zero_copy path
    must hand back byte-identical views out of the shared pool."""
    f = framing.make_frame(7, "edge", _bufs(sizes, seed),
                           wire_mode=wire_mode)
    assert f.wire_mode == wire_mode
    _assert_roundtrip(f)


@given(sizes=st.lists(st.sampled_from(_LANE_EDGES), min_size=1,
                      max_size=6),
       seed=st.integers(0, 999))
@settings(max_examples=40, deadline=None)
def test_zero_copy_descriptor_roundtrip(sizes, seed):
    """The zero_copy wire carries (pool, offset, size) descriptors, not
    payload bytes: the encoded descriptor block is one lane-aligned
    message of 3 little-endian u64s per iovec, and resolving it reads
    the exact placed bytes back out of the pool."""
    bufs = _bufs(sizes, seed)
    f = framing.make_frame(9, "desc", bufs, wire_mode="zero_copy")
    msgs = framing.encode(f)
    assert len(msgs) == 2                     # header + descriptor block
    desc = msgs[1].view("<u8").reshape(-1, 3)
    assert desc.shape[0] == len(sizes)
    pool = bufpool.get_pool()
    for (pid, off, size), buf in zip(desc, bufs):
        assert pid == pool.pool_id and size == buf.size
        assert np.array_equal(pool.read(int(off), int(size)), buf)
    g = framing.decode(msgs)
    for a, b in zip(bufs, g.bufs):
        assert np.array_equal(a, b)


@pytest.mark.parametrize("serialized", [False, True])
def test_bare_end_trailer_roundtrip(serialized):
    """A stream END with no payload at all is a legal, encodable frame."""
    f = framing.stream_chunk(5, "t", None, seq=7, end=True,
                             serialized=serialized)
    assert f.sizes == () and f.total_bytes == 0
    g = framing.decode(framing.encode(f))
    assert g.stream_end and g.seq == 7 and g.bufs == []
