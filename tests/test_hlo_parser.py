"""HLO collective parser: shapes, groups, wire factors, dtype chasing."""
from repro.launch import hlo


HLO = """
HloModule jit_f

%fused_computation.1 (param_0.3: f32[512,64]) -> f32[512,64] {
  %param_0.3 = f32[512,64]{1,0} parameter(0)
  %convert.5 = bf16[512,64]{1,0} convert(%param_0.3)
  ROOT %convert.6 = f32[512,64]{1,0} convert(%convert.5)
}

ENTRY %main (p0: f32[512,64], p1: bf16[8,64]) -> f32[512,64] {
  %p0 = f32[512,64]{1,0} parameter(0)
  %convert_convert_fusion.1 = f32[512,64]{1,0} fusion(%p0), kind=kLoop, calls=%fused_computation.1
  %all-gather.1 = f32[512,256]{1,0} all-gather(%convert_convert_fusion.1), replica_groups={{0,1,2,3}}, dimensions={1}
  %p1 = bf16[8,64]{1,0} parameter(1)
  %all-reduce.1 = bf16[8,64]{1,0} all-reduce(%p1), replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%add
  %rs = f32[4,64]{1,0} reduce-scatter(%all-gather.1), replica_groups=[2,8]<=[16], dimensions={0}
  %cp = f32[8,64]{1,0} collective-permute(%p1), source_target_pairs={{0,1}}
}
"""


def test_counts_and_kinds():
    st = hlo.parse_collectives(HLO)
    assert st.counts == {"all-gather": 1, "all-reduce": 1,
                         "reduce-scatter": 1, "collective-permute": 1}


def test_dtype_correction_through_fusion():
    st = hlo.parse_collectives(HLO)
    # f32 512x256 = 524288 B, but the operand is a bf16 round-trip fusion
    # -> halved to 262144; wire factor (n-1)/n with n=4
    assert st.result_bytes["all-gather"] == 524288 // 2
    assert abs(st.wire_bytes["all-gather"] - 262144 * 3 / 4) < 1


def test_native_bf16_untouched():
    st = hlo.parse_collectives(HLO)
    assert st.result_bytes["all-reduce"] == 8 * 64 * 2
    # 2(n-1)/n with n=8
    assert abs(st.wire_bytes["all-reduce"] - 1024 * 2 * 7 / 8) < 1


def test_iota_replica_groups():
    st = hlo.parse_collectives(HLO)
    # reduce-scatter groups=[2,8] -> group size 8, factor (n-1)
    ops = [o for o in st.ops if o[0] == "reduce-scatter"]
    assert ops[0][2] == 8


def test_wire_factors():
    assert hlo._wire_factor("all-reduce", 4) == 2 * 3 / 4
    assert hlo._wire_factor("all-gather", 4) == 3 / 4
    assert hlo._wire_factor("reduce-scatter", 4) == 3
    assert hlo._wire_factor("collective-permute", 2) == 1.0


def test_shape_bytes():
    assert hlo._shape_bytes("bf16[2,3,4]") == 48
    assert hlo._shape_bytes("f32[10]") == 40
    assert hlo._shape_bytes("pred[7]") == 7
    assert hlo._shape_bytes("s32[]") == 4
