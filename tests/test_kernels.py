"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_support import given, settings, st

from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.payload_pack import pack, pack_ref, unpack
from repro.kernels.rwkv6_scan import rwkv6_ref, rwkv6_scan

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# flash attention: shape x dtype x flavor sweep
# ---------------------------------------------------------------------------

FA_CASES = [
    # B, Sq, H, KV, dh, causal, window, softcap
    (2, 128, 4, 2, 64, True, None, None),
    (1, 256, 4, 4, 64, True, 64, None),
    (2, 128, 8, 2, 32, True, None, 50.0),
    (1, 192, 4, 1, 128, True, None, None),     # MQA, non-pow2 seq
    (2, 64, 4, 2, 64, False, None, None),      # bidirectional (encoder)
    (1, 320, 6, 2, 64, True, 128, 30.0),       # window + softcap
]


@pytest.mark.parametrize("case", FA_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(case, dtype):
    B, Sq, H, KV, dh, causal, window, cap = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, dh), dtype)
    k = jax.random.normal(ks[1], (B, Sq, KV, dh), dtype)
    v = jax.random.normal(ks[2], (B, Sq, KV, dh), dtype)
    out = flash_attention(q, k, v, causal, window, cap)
    ref = attention_ref(q, k, v, causal=causal, window=window, softcap=cap,
                        scale=1.0 / dh ** 0.5)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_grad_matches_ref():
    B, S, H, KV, dh = 1, 64, 4, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, dh))
    k = jax.random.normal(ks[1], (B, S, KV, dh))
    v = jax.random.normal(ks[2], (B, S, KV, dh))

    def f_kernel(q, k, v):
        return jnp.sum(flash_attention(q, k, v, True, None, None) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(attention_ref(q, k, v, causal=True, window=None,
                                     softcap=None,
                                     scale=1 / dh ** 0.5) ** 2)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# rwkv6 scan
# ---------------------------------------------------------------------------

RWKV_CASES = [
    (4, 128, 64, 32, True), (2, 64, 32, 16, False),
    (3, 96, 64, 32, True), (1, 250, 64, 64, True),
]


@pytest.mark.parametrize("case", RWKV_CASES)
def test_rwkv6_scan_sweep(case):
    BH, S, hs, chunk, with_u = case
    ks = jax.random.split(KEY, 6)
    r = jax.random.normal(ks[0], (BH, S, hs))
    k = jax.random.normal(ks[1], (BH, S, hs)) * 0.5
    v = jax.random.normal(ks[2], (BH, S, hs))
    lw = -jnp.exp(jax.random.normal(ks[3], (BH, S, hs)) - 1.0)
    s0 = jax.random.normal(ks[4], (BH, hs, hs)) * 0.1
    u = jax.random.normal(ks[5], (BH, hs)) * 0.5 if with_u else None
    y, sT = rwkv6_scan(r, k, v, lw, s0, u, chunk=chunk)
    yr, sTr = rwkv6_ref(r, k, v, lw, s0, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sTr),
                               atol=1e-4, rtol=1e-4)


def test_rwkv6_strong_decay_stability():
    """Strong decays (log_w << 0) must not overflow the chunked form."""
    BH, S, hs = 2, 64, 32
    r = jnp.ones((BH, S, hs))
    k = jnp.ones((BH, S, hs))
    v = jnp.ones((BH, S, hs))
    lw = jnp.full((BH, S, hs), -30.0)  # near-total decay per step
    s0 = jnp.zeros((BH, hs, hs))
    y, sT = rwkv6_scan(r, k, v, lw, s0, None, chunk=16)
    assert bool(jnp.all(jnp.isfinite(y))) and bool(jnp.all(jnp.isfinite(sT)))


# ---------------------------------------------------------------------------
# payload pack
# ---------------------------------------------------------------------------

@given(sizes=st.lists(st.integers(1, 4096), min_size=1, max_size=8),
       seed=st.integers(0, 999))
@settings(max_examples=25, deadline=None)
def test_pack_roundtrip_property(sizes, seed):
    rng = np.random.default_rng(seed)
    bufs = [jnp.asarray(rng.integers(0, 255, s, dtype=np.uint8))
            for s in sizes]
    packed, meta = pack(bufs)
    outs = unpack(packed, meta)
    for a, b in zip(bufs, outs):
        assert bool(jnp.array_equal(a, b))


def test_pack_matches_ref_when_aligned():
    rng = np.random.default_rng(0)
    bufs = [jnp.asarray(rng.integers(0, 255, s, dtype=np.uint8))
            for s in (128, 512, 1024, 128)]
    packed, _ = pack(bufs)
    assert bool(jnp.array_equal(packed, pack_ref(bufs)))
