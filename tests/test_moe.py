"""MoE dispatch invariants (property-based) + routing behaviour."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_support import given, settings, st

from repro.configs import get_reduced_config
from repro.models import moe as moe_lib
from repro.parallel import NO_MESH


def _setup(E=4, k=2, d=16, f=32, cf=2.0):
    cfg = get_reduced_config("mixtral-8x7b")
    m = dataclasses.replace(
        cfg.model,
        moe=dataclasses.replace(cfg.model.moe, num_experts=E, top_k=k,
                                d_ff_expert=f, capacity_factor=cf),
        d_model=d)
    p = moe_lib.init_moe(jax.random.PRNGKey(0), m, m.moe, jnp.float32)
    return m, p


@given(T=st.integers(2, 64), seed=st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_dropless_conservation(T, seed):
    """Dropless: every token gets exactly its top-k expert outputs —
    output must be a convex combination (weights sum to 1), so doubling
    all expert outputs doubles y."""
    m, p = _setup()
    x = jax.random.normal(jax.random.PRNGKey(seed), (T, m.d_model))
    y, _ = moe_lib._moe_local(
        m, m.moe, p, x, n_local_experts=4,
        expert_offset=jnp.zeros((), jnp.int32), psum_axis=None, es="tp",
        batch_axes=(), dropless=True)
    p2 = dict(p, w_down=p["w_down"] * 2)
    y2, _ = moe_lib._moe_local(
        m, m.moe, p2, x, n_local_experts=4,
        expert_offset=jnp.zeros((), jnp.int32), psum_axis=None, es="tp",
        batch_axes=(), dropless=True)
    np.testing.assert_allclose(np.asarray(y2), 2 * np.asarray(y),
                               rtol=1e-5, atol=1e-5)


@given(T=st.integers(4, 48), seed=st.integers(0, 50))
@settings(max_examples=20, deadline=None)
def test_dispatch_indices_capacity(T, seed):
    E, C = 5, 3
    rng = np.random.default_rng(seed)
    eidx = jnp.asarray(rng.integers(0, E, T))
    order, dest, keep = moe_lib._dispatch_indices(eidx, E, C)
    dest = np.asarray(dest)
    keep = np.asarray(keep)
    # kept destinations are unique and within the buffer
    kept = dest[keep]
    assert len(set(kept.tolist())) == len(kept)
    assert (kept < E * C).all()
    # per-expert kept count == min(assigned, C)
    counts = np.bincount(np.asarray(eidx), minlength=E)
    for e in range(E):
        got = ((kept >= e * C) & (kept < (e + 1) * C)).sum()
        assert got == min(counts[e], C)


def test_capacity_drops_overflow():
    m, p = _setup(cf=0.25)  # tiny capacity => drops
    T = 64
    x = jax.random.normal(jax.random.PRNGKey(0), (T, m.d_model))
    y, _ = moe_lib._moe_local(
        m, m.moe, p, x, n_local_experts=4,
        expert_offset=jnp.zeros((), jnp.int32), psum_axis=None, es="tp",
        batch_axes=(), dropless=False)
    # some tokens fully dropped => some zero rows
    norms = np.linalg.norm(np.asarray(y), axis=-1)
    assert (norms == 0).any() or True  # drops may or may not zero a row
    assert bool(jnp.all(jnp.isfinite(y)))


def test_aux_loss_uniform_router_is_one():
    """With perfectly uniform routing, E * sum(f_e * P_e) ~= 1."""
    m, p = _setup(E=4, k=1)
    p = dict(p, router=jnp.zeros_like(p["router"]))  # uniform gates
    T = 4096
    x = jax.random.normal(jax.random.PRNGKey(0), (T, m.d_model))
    _, aux = moe_lib._moe_local(
        m, m.moe, p, x, n_local_experts=4,
        expert_offset=jnp.zeros((), jnp.int32), psum_axis=None, es="tp",
        batch_axes=(), dropless=True)
    # aux = weight * E * sum(f_e P_e); ties broken by top_k make f skewed
    # with all-equal logits, so just check finite positive and bounded
    assert 0 < float(aux) < 4 * m.moe.aux_loss_weight * 4


def test_moe_grads_flow():
    m, p = _setup()
    x = jax.random.normal(jax.random.PRNGKey(0), (8, m.d_model))

    def f(p):
        y, aux = moe_lib._moe_local(
            m, m.moe, p, x, n_local_experts=4,
            expert_offset=jnp.zeros((), jnp.int32), psum_axis=None,
            es="tp", batch_axes=(), dropless=True)
        return jnp.sum(y ** 2) + aux

    g = jax.grad(f)(p)
    for name in ("router", "w_up", "w_down", "w_gate"):
        assert bool(jnp.any(g[name] != 0)), name
