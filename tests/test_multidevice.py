"""Multi-device behaviour (channels, sharded training parity, small-mesh
dry-run) — run in subprocesses so the 8-device XLA flag never leaks into
this process (smoke tests must see 1 device)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_bipartite_schedule_pure():
    from repro.core.channels import bipartite_schedule
    for srcs, dsts in [([0, 1], [2, 3, 4]), ([0, 1, 2], [3, 4]),
                       ([0], [1, 2, 3, 4, 5]), ([0, 1, 2, 3], [4, 5, 6, 7])]:
        rounds = bipartite_schedule(srcs, dsts)
        pairs = [p for r in rounds for p in r]
        assert len(pairs) == len(set(pairs)) == len(srcs) * len(dsts)
        assert set(pairs) == {(s, d) for s in srcs for d in dsts}
        for r in rounds:
            ss = [s for s, _ in r]
            dd = [d for _, d in r]
            assert len(set(ss)) == len(ss) and len(set(dd)) == len(dd)


@pytest.mark.slow
def test_p2p_echo_moves_data():
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import channels as ch
from repro.core.payload import generate_spec
from repro.configs.tfgrpc_bench import BenchConfig
mesh = ch.make_net_mesh(4)
spec = generate_spec(BenchConfig(iovec_count=3))
bufs = ch.device_payload(mesh, spec, seed=3)
for ser in (False, True):
    fn = ch.p2p_echo_fn(mesh, spec.n_buffers, serialized=ser)
    out = jax.block_until_ready(fn(*bufs))
    # row 0's payload went 0->1->0: row 0 of output == row 0 of input
    for a, b in zip(bufs, out):
        assert np.array_equal(np.asarray(a)[0], np.asarray(b)[0]), ser
print("OK")
""")
    assert "OK" in out


@pytest.mark.slow
def test_ring_fn_rotates_payload():
    out = _run("""
import jax, numpy as np
from repro.core import channels as ch
from repro.core.payload import generate_spec
from repro.configs.tfgrpc_bench import BenchConfig
mesh = ch.make_net_mesh(4)
spec = generate_spec(BenchConfig(iovec_count=3))
bufs = ch.device_payload(mesh, spec, seed=3)
for ser in (False, True):
    for chunks in (1, 3):
        fn = ch.ring_fn(mesh, spec.n_buffers, 4, n_chunks=chunks,
                        serialized=ser)
        out = jax.block_until_ready(fn(*bufs))
        # chunks successor hops: row i's payload lands on (i+chunks)%4
        for a, b in zip(bufs, out):
            a, b = np.asarray(a), np.asarray(b)
            for i in range(4):
                assert np.array_equal(a[i], b[(i + chunks) % 4]), \
                    (ser, chunks, i)
print("OK")
""")
    assert "OK" in out


@pytest.mark.slow
def test_ps_round_and_benches():
    out = _run("""
import jax, numpy as np
from repro.configs.tfgrpc_bench import BenchConfig
from repro.core import bench
st = bench.run(BenchConfig(benchmark='ps_throughput', num_ps=2,
                           num_workers=3, warmup_s=0.1, duration_s=0.3))
assert st.derived['rpcs_per_s'] > 0
assert st.n_iters >= 5
assert st.resources is not None and st.resources.rss_peak_bytes > 0
assert set(st.model_projection) >= {'rdma_edr', 'eth40g', 'tpu_ici'}
st2 = bench.run(BenchConfig(benchmark='p2p_bandwidth', warmup_s=0.1,
                            duration_s=0.3))
assert st2.derived['MBps'] > 0
print("OK")
""")
    assert "OK" in out


@pytest.mark.slow
def test_sharded_training_matches_single_device():
    """Same seed, same data: a (2,2) mesh train step must match the
    single-device step (SPMD correctness)."""
    out = _run("""
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro.configs import get_reduced_config, get_shape
from repro.models import init_params
from repro.optim import optimizer as O
from repro.launch import steps as S
from repro.launch.mesh import make_test_mesh
from repro.parallel import NO_MESH, make_ctx
from repro.data.pipeline import host_batch, device_batch

cfg = get_reduced_config('qwen3-8b', n_layers=2)
shape = dataclasses.replace(get_shape('train_4k'), seq_len=32,
                            global_batch=4)
params = init_params(jax.random.PRNGKey(0), cfg)
opt = O.init_opt_state(cfg.train, params)
b = host_batch(cfg, shape, 0)

# single device
s1 = S.make_train_step(NO_MESH, cfg, donate=False)
p1, o1, m1 = s1(params, opt, device_batch(NO_MESH, b))

# (2,2) mesh
mesh = make_test_mesh(2, 2)
ctx = make_ctx(cfg, mesh)
with mesh:
    s2 = S.make_train_step(ctx, cfg, donate=False)
    p2, o2, m2 = s2(params, opt, device_batch(ctx, b))
    jax.block_until_ready(m2['loss'])

assert abs(float(m1['loss']) - float(m2['loss'])) < 1e-4, (
    float(m1['loss']), float(m2['loss']))
for a, b2 in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b2, np.float32),
                               atol=2e-3, rtol=2e-3)
print('OK', float(m1['loss']), float(m2['loss']))
""")
    assert "OK" in out


@pytest.mark.slow
def test_moe_ep_matches_tp_sharding():
    out = _run("""
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro.configs import get_reduced_config
from repro.models import init_params, forward
from repro.launch.mesh import make_test_mesh
from repro.parallel import make_ctx, NO_MESH

cfg = get_reduced_config('kimi-k2-1t-a32b', n_layers=2)
params = init_params(jax.random.PRNGKey(0), cfg)
toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                          cfg.model.vocab_size)
h_ref, _, _ = forward(NO_MESH, cfg, params, tokens=toks, mode='train')
mesh = make_test_mesh(2, 2)
for es in ('tp', 'ep'):
    cfg2 = cfg.replace(parallel=dataclasses.replace(cfg.parallel,
                                                    expert_sharding=es))
    ctx = make_ctx(cfg2, mesh)
    with mesh:
        h, _, _ = jax.jit(lambda p, t: forward(ctx, cfg2, p, tokens=t,
                                               mode='train'))(params, toks)
        jax.block_until_ready(h)
    err = float(jnp.max(jnp.abs(h_ref - h)))
    assert err < 2e-3, (es, err)
    print(es, 'err', err)
print('OK')
""")
    assert "OK" in out


@pytest.mark.slow
def test_dryrun_small_mesh_all_kinds():
    """Lower+compile the three step kinds on a (2,2) and a (2,2,2) mesh
    (mini version of the production dry-run)."""
    out = _run("""
import dataclasses, jax, jax.numpy as jnp
from repro.configs import get_reduced_config, get_shape
from repro.launch import steps as S, specs as SP
from repro.launch.mesh import make_test_mesh
from repro.parallel import make_ctx

for mesh in (make_test_mesh(2, 2), make_test_mesh(2, 2, pod=2)):
    for arch in ('qwen3-8b', 'mixtral-8x7b', 'rwkv6-1.6b'):
        cfg = get_reduced_config(arch, n_layers=2)
        ctx = make_ctx(cfg, mesh)
        with mesh:
            for shape_name, kind in (('train_4k', 'train'),
                                     ('prefill_32k', 'prefill'),
                                     ('decode_32k', 'decode')):
                shape = dataclasses.replace(
                    get_shape(shape_name), seq_len=64,
                    global_batch=8 if kind != 'prefill' else 4)
                if kind == 'train':
                    step = S.make_train_step(ctx, cfg, donate=False)
                elif kind == 'prefill':
                    step = S.make_prefill_step(ctx, cfg)
                else:
                    step = S.make_decode_step(ctx, cfg, shape.global_batch)
                args = SP.input_specs(ctx, cfg, shape)
                compiled = step.lower(*args).compile()
                assert compiled.cost_analysis() is not None
print('OK')
""", devices=8)
    assert "OK" in out


@pytest.mark.slow
def test_fully_connected_collective_bench():
    out = _run("""
import jax
from repro.configs.tfgrpc_bench import BenchConfig
from repro.core import bench
for mode in ('non_serialized', 'serialized'):
    st = bench.run(BenchConfig(benchmark='fully_connected', num_workers=4,
                               transport='collective', mode=mode,
                               iovec_count=4, warmup_s=0.1,
                               duration_s=0.3))
    assert st.derived['rpcs_per_s'] > 0
    assert st.derived['rpcs_per_round'] == 12
    assert st.model_projection['rdma_edr'] > 0
print("OK")
""")
    assert "OK" in out
