"""Network models must reproduce the paper's measured claims (the
reproduction gate for §4 of the paper) and behave physically."""
import pytest
from _hypothesis_support import given, settings, st

from repro.configs.tfgrpc_bench import BenchConfig
from repro.core.netmodel import NETWORKS, paper_ratio_report
from repro.core.payload import generate_spec

TOLERANCE = 0.12  # max relative error vs the paper's reported ratios


def test_paper_claims_within_tolerance():
    rep = paper_ratio_report()
    bad = {k: v for k, v in rep.items()
           if k != "fig7_serialization_constant"
           and v["rel_err"] > TOLERANCE}
    assert not bad, f"model misses paper claims: {bad}"


def test_fig7_serialization_overhead_roughly_constant():
    # paper fig 7: serialization cost is constant across networks
    v = paper_ratio_report()["fig7_serialization_constant"]
    assert 0.5 < v["model"] < 2.0


def test_rdma_always_fastest_within_cluster():
    spec = generate_spec(BenchConfig(scheme="skew"))
    for cluster in (("eth40g", "ipoib_edr", "rdma_edr"),
                    ("eth10g", "ipoib_fdr", "rdma_fdr")):
        rtts = [NETWORKS[n].rtt(spec) for n in cluster]
        assert rtts[2] == min(rtts)


def test_tpu_ici_beats_all_nics():
    spec = generate_spec(BenchConfig(scheme="skew"))
    ici = NETWORKS["tpu_ici"].rtt(spec)
    assert all(ici < NETWORKS[n].rtt(spec) for n in NETWORKS
               if n != "tpu_ici")


@given(nbytes=st.integers(1, 10 * 1024 * 1024),
       extra=st.integers(1, 1024 * 1024))
@settings(max_examples=50, deadline=None)
def test_monotone_in_bytes(nbytes, extra):
    for net in NETWORKS.values():
        assert net.msg_time(nbytes + extra) > net.msg_time(nbytes)


@given(seed=st.integers(0, 100),
       scheme=st.sampled_from(["uniform", "random", "skew"]))
@settings(max_examples=30, deadline=None)
def test_rtt_is_twice_oneway(seed, scheme):
    spec = generate_spec(BenchConfig(scheme=scheme, seed=seed))
    for net in NETWORKS.values():
        assert net.rtt(spec) == pytest.approx(
            2 * net.payload_time(spec, serialized=False))


def test_ps_throughput_scales_with_ps():
    spec = generate_spec(BenchConfig())
    n = NETWORKS["rdma_edr"]
    # PSes work in parallel: more PS => more RPCs/s
    assert n.ps_throughput(spec, 4, 3) > n.ps_throughput(spec, 2, 3)
    # more workers => more aggregate RPCs but each PS serializes
    assert n.ps_throughput(spec, 2, 6) <= 2 * n.ps_throughput(spec, 2, 3)
