"""Optimizer + checkpoint behaviour: convergence on a quadratic,
compression error feedback, atomic commit, resume, elastic restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_support import given, settings, st

from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import TrainConfig
from repro.optim import optimizer as O


def _minimize(opt_name, compression=None, steps=120):
    cfg = TrainConfig(optimizer=opt_name, learning_rate=0.1,
                      weight_decay=0.0, warmup_steps=5,
                      grad_compression=compression)
    params = {"w": jnp.full((8, 8), 3.0), "b": jnp.full((8,), -2.0)}
    target = {"w": jnp.ones((8, 8)), "b": jnp.zeros((8,))}
    state = O.init_opt_state(cfg, params)

    def loss(p):
        return sum(jnp.sum((p[k] - target[k]) ** 2) for k in p)

    for _ in range(steps):
        g = jax.grad(loss)(params)
        params, state, _ = O.apply_updates(cfg, params, g, state,
                                           total_steps=steps)
    return float(loss(params))


@pytest.mark.parametrize("opt", ["adamw", "adafactor", "sgd"])
def test_optimizers_converge(opt):
    assert _minimize(opt) < 0.8


@pytest.mark.parametrize("comp", ["bf16", "int8"])
def test_compressed_training_converges(comp):
    assert _minimize("adamw", compression=comp) < 0.8


@given(seed=st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_error_feedback_preserves_signal(seed):
    """quantized + residual == original (error feedback is lossless in
    aggregate)."""
    g = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * 1e-3
    r0 = jnp.zeros((64,))
    q, r1 = O.compress_grads({"g": g}, {"g": r0}, "int8")
    np.testing.assert_allclose(np.asarray(q["g"] + r1["g"]),
                               np.asarray(g), rtol=1e-5, atol=1e-7)


def test_grad_clip():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, gn = O.clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(200.0)
    assert float(O.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"layer": {"w": jax.random.normal(k, (4, 8)),
                      "b": jnp.arange(3.0)},
            "step_count": jnp.asarray(7, jnp.int32)}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 5, t, extra={"note": "hi"})
    assert ckpt.latest_step(str(tmp_path)) == 5
    restored, extra = ckpt.restore(str(tmp_path), 5, jax.tree.map(
        lambda x: jnp.zeros_like(x), t))
    assert extra == {"note": "hi"}
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_partial_write_invisible(tmp_path):
    """A crash mid-save (tmp dir left behind) must not be visible."""
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    # simulate crash: handcraft a stale tmp dir for step 2
    os.makedirs(tmp_path / "step_2.tmp")
    (tmp_path / "step_2.tmp" / "garbage.npy").write_bytes(b"xx")
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_structure_mismatch_rejected(tmp_path):
    ckpt.save(str(tmp_path), 1, _tree())
    wrong = {"other": jnp.zeros((2,))}
    with pytest.raises(AssertionError):
        ckpt.restore(str(tmp_path), 1, wrong)


def test_prune_keeps_latest(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, t)
    ckpt.prune(str(tmp_path), keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 5
    remaining = sorted(d for d in os.listdir(tmp_path)
                       if d.startswith("step_"))
    assert remaining == ["step_4", "step_5"]
