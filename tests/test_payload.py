"""Payload generator: paper Table-1 ranges, scheme semantics, and
hypothesis property tests."""
import numpy as np
import pytest
from _hypothesis_support import given, settings, st

from repro.configs import get_config, list_archs
from repro.configs.tfgrpc_bench import (BenchConfig, LARGE_RANGE,
                                        MEDIUM_RANGE, SKEW_FRACTIONS,
                                        SMALL_RANGE)
from repro.core.payload import (classify, from_arch, generate_spec,
                                materialize)


def test_uniform_default_composition():
    spec = generate_spec(BenchConfig(scheme="uniform"))
    assert spec.n_buffers == 10
    # cycling small/medium/large over 10 slots: 4/3/3
    counts = {c: spec.categories.count(c) for c in set(spec.categories)}
    assert counts == {"small": 4, "medium": 3, "large": 3}
    assert spec.total_bytes == 4 * 10 + 3 * 10240 + 3 * 1048576


def test_skew_is_large_biased():
    spec = generate_spec(BenchConfig(scheme="skew"))
    counts = {c: spec.categories.count(c) for c in set(spec.categories)}
    assert counts["large"] == 6 and counts["medium"] == 3 \
        and counts["small"] == 1  # 60/30/10 of 10
    uni = generate_spec(BenchConfig(scheme="uniform"))
    assert spec.total_bytes > uni.total_bytes  # paper: skew is largest


def test_random_needs_two_categories():
    with pytest.raises(AssertionError):
        generate_spec(BenchConfig(scheme="random", categories=("small",)))


def test_random_deterministic_per_seed():
    a = generate_spec(BenchConfig(scheme="random", seed=7))
    b = generate_spec(BenchConfig(scheme="random", seed=7))
    c = generate_spec(BenchConfig(scheme="random", seed=8))
    assert a.sizes == b.sizes
    assert a.sizes != c.sizes or a.categories != c.categories


@given(n=st.integers(1, 64),
       scheme=st.sampled_from(["uniform", "random", "skew"]),
       small=st.integers(*SMALL_RANGE).filter(lambda x: x < SMALL_RANGE[1]),
       medium=st.integers(MEDIUM_RANGE[0], MEDIUM_RANGE[1] - 1),
       large=st.integers(*LARGE_RANGE),
       seed=st.integers(0, 2**31 - 1))
@settings(max_examples=60, deadline=None)
def test_spec_invariants(n, scheme, small, medium, large, seed):
    cfg = BenchConfig(scheme=scheme, iovec_count=n, small_bytes=small,
                      medium_bytes=medium, large_bytes=large, seed=seed)
    spec = generate_spec(cfg)
    assert spec.n_buffers == n
    assert spec.total_bytes == sum(spec.sizes)
    assert len(spec.categories) == n
    size_of = {"small": small, "medium": medium, "large": large}
    for sz, cat in zip(spec.sizes, spec.categories):
        assert sz == size_of[cat]
    # classification ranges (Table 1)
    assert classify(small) == "small"
    assert classify(medium) == "medium"
    assert classify(large) == "large"


@given(seed=st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_materialize_alignment(seed):
    spec = generate_spec(BenchConfig(scheme="skew", seed=seed))
    bufs = materialize(spec, tpu_align=True, seed=seed)
    for b, sz in zip(bufs, spec.sizes):
        assert b.shape[0] >= sz and b.shape[0] % 128 == 0
    raw = materialize(spec, tpu_align=False, seed=seed)
    for b, sz in zip(raw, spec.sizes):
        assert b.shape[0] == sz


@pytest.mark.parametrize("arch", list_archs())
def test_from_arch_payloads(arch):
    spec = from_arch(get_config(arch))
    assert spec.n_buffers == 10
    assert all(1 <= s <= LARGE_RANGE[1] for s in spec.sizes)
    assert spec.scheme == f"arch:{arch}"


def test_payload_spec_override_plumbed_through():
    """--arch fix: an explicit payload_spec on BenchConfig must win over
    the S/M/L generator."""
    from repro.core.payload import PayloadSpec
    spec = PayloadSpec(sizes=(123, 4567), scheme="arch:test",
                       categories=("small", "medium"))
    cfg = BenchConfig(payload_spec=spec, scheme="skew")
    assert generate_spec(cfg) is spec
    assert generate_spec(BenchConfig(scheme="skew")).scheme == "skew"
