"""Property tests for the fabric's failure semantics: the deadline
budget header round-trips through framing for arbitrary budgets, and —
under random transient fault schedules — a transparently retried
server-stream delivers each chunk exactly once, in order, never after
its deadline, with every credit refunded. Skips cleanly when hypothesis
is absent and runs with --hypothesis-profile=ci in CI."""
import numpy as np
import pytest
from _hypothesis_support import given, settings, st

from repro import rpc
from repro.rpc import framing

# ---------------------------------------------------------------------------
# deadline header round-trip
# ---------------------------------------------------------------------------


@given(budget_us=st.integers(0, framing.MAX_BUDGET_US),
       sizes=st.lists(st.integers(0, 2048), min_size=0, max_size=8),
       seq=st.integers(0, 2**31 - 1), serialized=st.booleans())
@settings(max_examples=60, deadline=None)
def test_budget_header_roundtrip(budget_us, sizes, seq, serialized):
    """budget_us survives header encode/parse AND the full wire
    encode/decode, for random budgets on unary and stream frames."""
    rng = np.random.default_rng(0)
    bufs = [rng.integers(0, 255, s, dtype=np.uint8) for s in sizes]
    f = framing.make_frame(9, "prop", bufs, serialized=serialized,
                           stream=seq > 0, seq=seq, budget_us=budget_us)
    parsed, _ = framing.parse_header(framing.header_bytes(f))
    assert parsed.budget_us == budget_us
    assert (parsed.call_id, parsed.method, parsed.seq, parsed.sizes) \
        == (f.call_id, f.method, f.seq, f.sizes)
    wired = framing.decode(framing.encode(f))
    assert wired.budget_us == budget_us


@given(budget_s=st.floats(1e-6, 3600.0, allow_nan=False,
                          allow_infinity=False))
@settings(max_examples=40, deadline=None)
def test_stamped_budget_is_positive_and_bounded(budget_s):
    """The fabric's stamp of a random remaining budget always lands in
    the header's representable range (>= 1us, saturating)."""
    stamped = max(1, min(framing.MAX_BUDGET_US, int(budget_s * 1e6)))
    f = framing.make_frame(1, "m", [], sizes=[], budget_us=stamped)
    parsed, _ = framing.parse_header(framing.header_bytes(f))
    assert 1 <= parsed.budget_us <= framing.MAX_BUDGET_US


# ---------------------------------------------------------------------------
# retried server-streams: exactly-once, never past the deadline
# ---------------------------------------------------------------------------


def _windows_restored(fab):
    for ch in fab._channels.values():
        assert ch.window.bytes_avail == ch.window.window_bytes
        assert ch.rwindow.bytes_avail == ch.rwindow.window_bytes
        assert len(ch.rx_gate) == 0 and ch.backlogged == 0
    for srv in fab.servers.values():
        assert srv._streams == {} and srv._bidi_seq == {}
        assert srv._pumps == {}


@given(n_faults=st.integers(0, 3), n_chunks=st.integers(1, 4),
       seed=st.integers(0, 10_000), deadline_s=st.floats(5.0, 50.0))
@settings(max_examples=25, deadline=None)
def test_retried_stream_delivers_each_chunk_exactly_once(
        n_faults, n_chunks, seed, deadline_s):
    """Random fault schedule on the request link: the first n_faults
    attempts of a server-stream are lost and transparently re-issued.
    The surviving attempt delivers every chunk exactly once, in order,
    strictly before the call's deadline on the modeled clock — and the
    handler body ran exactly once."""
    inner = rpc.make_transport("simulated", 2, network="eth40g")
    transport = rpc.make_transport("fault", inner=inner, seed=seed,
                                   fault_rate=1.0, max_faults=n_faults,
                                   links=[(0, 1)])
    retry = rpc.RetryInterceptor(max_attempts=n_faults + 2)
    fab = rpc.RpcFabric(transport, client_interceptors=[retry])
    invocations = {"n": 0}

    def split(req):
        invocations["n"] += 1
        return [(64 * (i + 1),) for i in range(n_chunks)]

    svc = rpc.ServiceDef("P", (rpc.MethodSpec("split",
                                              rpc.SERVER_STREAM),))
    fab.add_server(1).add_service(svc, {"split": split})
    h = fab.stub(svc, 0, 1).split(None, sizes=[256],
                                  deadline_s=deadline_s)
    fab.flush()
    assert h.done and h.error is None, h.error
    assert transport.faults_injected == n_faults
    assert retry.retries == n_faults
    assert invocations["n"] == 1
    # exactly once, in order: the spec-only chunk sizes identify each
    assert [c[0] for c in h.chunks] == [64 * (i + 1)
                                        for i in range(n_chunks)]
    # never after the deadline: the modeled clock at completion is
    # strictly inside the budget (else the fabric would have cancelled)
    assert fab.transport.clock_s < deadline_s
    _windows_restored(fab)


@given(n_faults=st.integers(1, 3), seed=st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_exhausted_attempts_surface_transient_error_cleanly(
        n_faults, seed):
    """When the schedule outlasts max_attempts the failure surfaces as
    a transient error — never a hang, never leaked credits."""
    inner = rpc.make_transport("simulated", 2, network="eth40g")
    transport = rpc.make_transport("fault", inner=inner, seed=seed,
                                   fault_rate=1.0, links=[(0, 1)])
    retry = rpc.RetryInterceptor(max_attempts=n_faults)
    fab = rpc.RpcFabric(transport, client_interceptors=[retry])
    fab.add_server(1).add_service(rpc.CONFORMANCE_SERVICE,
                                  rpc.conformance_handlers())
    h = fab.stub(rpc.CONFORMANCE_SERVICE, 0, 1).split(None, sizes=[256])
    fab.flush()
    assert h.done and h.error is not None
    assert rpc.is_transient(h.error)
    assert retry.retries == n_faults - 1     # max_attempts total tries
    with pytest.raises(rpc.RpcError):
        h.chunk_bufs()
    _windows_restored(fab)
