"""repro.rpc fabric: framing (both wire modes, kernel + numpy paths),
flow control, completion queue, loopback/simulated transports, unary +
streaming calls, serve-over-rpc, and the fully-connected driver."""
import numpy as np
import pytest

from repro import rpc
from repro.core.netmodel import NETWORKS
from repro.core.payload import PayloadSpec
from repro.rpc import framing


def _bufs(sizes, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 255, s, dtype=np.uint8) for s in sizes]


SIZES = (10, 300, 1024, 7, 128, 4096)


# ---------------------------------------------------------------------------
# framing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("serialized", [False, True])
@pytest.mark.parametrize("backend", ["numpy", "kernel"])
def test_frame_roundtrip_byte_identical(serialized, backend):
    bufs = _bufs(SIZES)
    f = framing.make_frame(42, "echo", bufs, serialized=serialized)
    wire = framing.encode(f, backend=backend)
    if serialized:
        assert len(wire) == 1          # one coalesced wire message
    else:
        assert len(wire) == len(bufs) + 1
    g = framing.decode(wire, backend=backend)
    assert (g.call_id, g.method, g.flags, g.sizes) == \
        (f.call_id, f.method, f.flags, f.sizes)
    for a, b in zip(bufs, g.bufs):
        assert np.array_equal(a, b)


def test_serialized_kernel_and_numpy_wires_identical():
    """The Pallas payload_pack path and the host numpy path must produce
    the same bytes — the wire format is backend-independent."""
    f = framing.make_frame(1, "echo", _bufs(SIZES), serialized=True)
    w_np = framing.encode(f, backend="numpy")[0]
    w_k = framing.encode(f, backend="kernel")[0]
    assert np.array_equal(w_np, w_k)


def test_cross_backend_decode():
    """Kernel-encoded wire decodes on the numpy path and vice versa."""
    bufs = _bufs(SIZES, seed=3)
    f = framing.make_frame(9, "x", bufs, serialized=True)
    for enc, dec in (("kernel", "numpy"), ("numpy", "kernel")):
        g = framing.decode(framing.encode(f, backend=enc), backend=dec)
        for a, b in zip(bufs, g.bufs):
            assert np.array_equal(a, b)


def test_header_many_buffers():
    """Headers longer than one 128-byte lane (n > 27 sizes) round-trip."""
    bufs = _bufs([8] * 40)
    for serialized in (False, True):
        f = framing.make_frame(5, "m", bufs, serialized=serialized)
        g = framing.decode(framing.encode(f))
        assert g.sizes == f.sizes
        assert all(np.array_equal(a, b) for a, b in zip(bufs, g.bufs))


def test_bad_magic_rejected():
    with pytest.raises(AssertionError, match="magic"):
        framing.parse_header(np.zeros(128, dtype=np.uint8))


def test_method_id_stable():
    assert framing.method_id("generate") == framing.method_id("generate")
    assert framing.method_id("generate") != framing.method_id("exchange")


def test_framing_lane_matches_kernel_lane():
    """framing.LANE is duplicated (not imported) to keep repro.rpc
    jax-free; it must stay pinned to the kernel's lane width."""
    from repro.kernels import payload_pack
    assert framing.LANE == payload_pack.LANE


def test_rpc_import_is_jax_free():
    """Simulated-transport users (hundreds of endpoints, analytics
    only) must not pay the jax import."""
    import subprocess
    import sys
    code = ("import sys; import repro.rpc; "
            "from repro.core.netmodel import NETWORKS; "
            "f = repro.rpc.RpcFabric(repro.rpc.SimulatedTransport("
            "8, NETWORKS['rdma_edr'])); "
            "repro.rpc.fully_connected_exchange(f, [1024]); "
            "sys.exit(1 if 'jax' in sys.modules else 0)")
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True)
    assert out.returncode == 0, out.stderr[-2000:]


# ---------------------------------------------------------------------------
# flow control
# ---------------------------------------------------------------------------

def test_credit_window_accounting():
    w = rpc.CreditWindow(window_bytes=1000, window_msgs=2)
    assert w.try_acquire(600)
    assert not w.try_acquire(600)          # byte credits exhausted
    assert w.stats.stalled == 1
    assert w.try_acquire(100)
    assert not w.try_acquire(100)          # msg credits exhausted
    assert w.stats.stalled == 2
    w.grant(600)
    w.grant(100)
    w.grant(9999)                          # grants clamp at the window
    assert w.bytes_avail == 1000 and w.msgs_avail == 2
    assert w.stats.acquired == 2
    assert w.stats.bytes_in_flight_peak == 700


def test_oversized_message_admitted_alone():
    w = rpc.CreditWindow(window_bytes=100, window_msgs=4)
    assert w.try_acquire(5000)             # occupies the whole window
    assert not w.try_acquire(1)
    w.grant(5000)
    assert w.try_acquire(1)


def test_flow_control_backpressure_multiflight():
    """A burst larger than the window drains over several flights and
    the stalls are counted."""
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2),
                        window_bytes=2048, window_msgs=2)
    srv = fab.add_server(1)
    srv.register("echo", lambda req: req)
    ch = fab.channel(0, 1)
    calls = [ch.call("echo", _bufs([512], seed=i)) for i in range(8)]
    rep = fab.flush()
    assert all(c.done for c in calls)
    assert rep.flights > 2                 # forced into multiple flights
    # one stall per blocked call (2-msg window admits 2 of 8 up front);
    # backlog retries must NOT inflate the count
    assert ch.window.stats.stalled == 6


def test_credits_granted_by_request_size():
    """Replies smaller than requests must still restore the REQUEST's
    byte credits, or the window leaks shut."""
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2),
                        window_bytes=1 << 20, window_msgs=4)
    fab.add_server(1).register("tiny",
                               lambda req: [np.zeros(1, np.uint8)])
    ch = fab.channel(0, 1)
    for i in range(10):
        c = ch.call("tiny", _bufs([600_000], seed=i))
        fab.flush()
        assert c.done and c.error is None
    assert ch.window.bytes_avail == 1 << 20
    assert ch.window.msgs_avail == 4
    assert ch.window.stats.stalled == 0


# ---------------------------------------------------------------------------
# completion queue
# ---------------------------------------------------------------------------

def test_completion_queue_fifo_and_drain():
    cq = rpc.CompletionQueue()
    for i in range(3):
        cq.push(rpc.Event(i, "sent"))
    assert cq.poll().tag == 0
    assert [e.tag for e in cq.drain()] == [1, 2]
    assert cq.poll() is None and len(cq) == 0


def test_completion_queue_bounded_when_undrained():
    cq = rpc.CompletionQueue(maxlen=4)
    for i in range(10):
        cq.push(rpc.Event(i, "sent"))
    assert len(cq) == 4 and cq.dropped == 6
    assert [e.tag for e in cq.drain()] == [6, 7, 8, 9]


def test_fabric_state_does_not_accumulate():
    """Benchmark loops must not grow fabric-internal state: completed
    calls are pruned and the cq stays bounded."""
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2))
    fab.add_server(1).register("echo", lambda req: req)
    ch = fab.channel(0, 1)
    for i in range(50):
        c = ch.call("echo", _bufs([256], seed=i))
        fab.flush()
        assert c.done
    assert len(fab._calls) == 0
    assert len(fab._awaiting_grant) == 0
    assert len(fab.cq) <= 4096


def test_fabric_pushes_completion_events():
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2))
    fab.add_server(1).register("echo", lambda req: req)
    c = fab.channel(0, 1).call("echo", _bufs([64]))
    fab.flush()
    events = fab.cq.drain()
    kinds = {e.kind for e in events}
    assert "received" in kinds and "replied" in kinds
    assert c.done
    # events carry frame metadata only — payload stays with the Call
    for e in events:
        if e.payload is not None:
            assert e.payload.bufs is None
            assert e.payload.sizes == (64,)
    assert c.reply_bufs()[0].size == 64


# ---------------------------------------------------------------------------
# transports + rounds
# ---------------------------------------------------------------------------

def test_schedule_rounds_unique_ports():
    msgs = [rpc.Message(s, d, framing.make_frame(0, "x", None,
                                                 sizes=[8]))
            for s in range(4) for d in range(4) if s != d]
    rounds = rpc.schedule_rounds(msgs)
    assert sum(len(r) for r in rounds) == 12
    for rnd in rounds:
        ss, dd = [m.src for m in rnd], [m.dst for m in rnd]
        assert len(set(ss)) == len(ss) and len(set(dd)) == len(dd)


@pytest.mark.parametrize("serialized", [False, True])
def test_loopback_unary_and_error(serialized):
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2))
    srv = fab.add_server(1)
    srv.register("inc", lambda req: [(req[0] + 1).astype(np.uint8)])

    def boom(req):
        raise ValueError("nope")
    srv.register("boom", boom)
    ch = fab.channel(0, 1, serialized=serialized)
    ok = ch.call("inc", [np.zeros(16, dtype=np.uint8)])
    bad = ch.call("boom", [np.zeros(4, dtype=np.uint8)])
    missing = ch.call("nosuch", [np.zeros(4, dtype=np.uint8)])
    fab.flush()
    assert np.array_equal(ok.reply_bufs()[0],
                          np.ones(16, dtype=np.uint8))
    with pytest.raises(rpc.RpcError, match="nope"):
        bad.reply_bufs()
    with pytest.raises(rpc.RpcError, match="unimplemented"):
        missing.reply_bufs()
    assert srv.calls_served == 1


def test_streaming_cardinality_enforced():
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2))
    srv = fab.add_server(1)
    srv.register("uni", lambda req: req)
    srv.register("str", lambda req: req, streaming=True)
    bad_stream = fab.channel(0, 1).stream(
        "uni", [[np.ones(4, dtype=np.uint8)]] * 2)
    bad_unary = fab.channel(0, 1).call("str",
                                       [np.ones(4, dtype=np.uint8)])
    fab.flush()
    for c in (bad_stream, bad_unary):
        with pytest.raises(rpc.RpcError, match="cardinality mismatch"):
            c.reply_bufs()


def test_stream_chunks_keep_order_under_backpressure():
    """A stalled middle chunk must not be overtaken by the END chunk:
    per-channel FIFO holds even when a later, smaller message would fit
    the window."""
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2),
                        window_bytes=1024, window_msgs=8)
    srv = fab.add_server(1)
    srv.register("concat", lambda req: [np.concatenate(req)],
                 streaming=True)
    chunks = [[np.full(800, 1, dtype=np.uint8)],
              [np.full(800, 2, dtype=np.uint8)],
              [np.full(100, 3, dtype=np.uint8)]]   # END fits; middle not
    call = fab.channel(0, 1).stream("concat", chunks)
    fab.flush()
    got = call.reply_bufs()[0]
    want = np.concatenate([c[0] for c in chunks])
    assert np.array_equal(got, want)
    assert len(srv._streams) == 0              # no leaked partial stream


def test_stream_error_replies_do_not_leak_credits():
    """Every chunk of a stream to a missing method draws its own error
    reply; each must restore its own request credits."""
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2))
    fab.add_server(1)
    ch = fab.channel(0, 1)
    call = ch.stream("nosuch", [[np.ones(1000, dtype=np.uint8)]
                                for _ in range(3)])
    fab.flush()
    with pytest.raises(rpc.RpcError):
        call.reply_bufs()
    assert ch.window.bytes_avail == ch.window.window_bytes
    assert ch.window.msgs_avail == ch.window.window_msgs


def test_loopback_streaming():
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2))
    srv = fab.add_server(1)
    srv.register("concat",
                 lambda req: [np.concatenate(req)], streaming=True)
    chunks = [[_bufs([5], seed=i)[0]] for i in range(4)]
    call = fab.channel(0, 1).stream("concat", chunks)
    fab.flush()
    want = np.concatenate([c[0] for c in chunks])
    assert np.array_equal(call.reply_bufs()[0], want)


def test_simulated_matches_netmodel_ps_pattern():
    """The simulated transport prices an n_workers->1 incast exactly
    like netmodel.ps_round_time's receiver model (minus the ack+pull
    terms it shares): sanity that the two stay coupled."""
    net = NETWORKS["eth10g"]
    sizes = [4096] * 4
    n_workers = 5
    tr = rpc.SimulatedTransport(8, net)
    msgs = [rpc.Message(i + 1, 0, framing.make_frame(i, "push", None,
                                                     sizes=sizes))
            for i in range(n_workers)]
    d = tr.deliver(msgs)
    spec = rpc.spec_of(msgs[0].frame)
    per_rpc = net.payload_time(spec, serialized=False) + net.msg_time(64)
    contention = (n_workers * (n_workers - 1) * spec.total_bytes
                  / net.cpu_copy_Bps)
    assert d.modeled
    assert d.elapsed_s == pytest.approx(per_rpc * n_workers + contention)


def test_simulated_serialized_costs_more_on_slow_cpu_nets():
    net = NETWORKS["eth40g"]
    tr = rpc.SimulatedTransport(2, net)
    f_ns = framing.make_frame(0, "x", None, sizes=[1 << 20])
    f_s = framing.make_frame(0, "x", None, sizes=[1 << 20])
    t_ns = tr.price(f_ns)
    t_s = tr.price(framing.Frame(0, f_s.method,
                                 f_s.flags | framing.FLAG_SERIALIZED,
                                 f_s.sizes))
    assert t_s > t_ns                      # serialization copy is extra


# ---------------------------------------------------------------------------
# fully-connected driver
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [2, 3, 8, 64])
def test_fully_connected_simulated_round_count(n):
    fab = rpc.RpcFabric(rpc.SimulatedTransport(n, NETWORKS["rdma_edr"]))
    rep = rpc.fully_connected_exchange(fab, [1024, 64])
    assert rep.messages == n * (n - 1)
    assert rep.rounds == n - 1             # perfect edge coloring
    assert rep.modeled and rep.elapsed_s > 0


def test_fully_connected_simulated_matches_netmodel():
    spec = PayloadSpec(sizes=(65536,) * 4, scheme="t",
                       categories=("medium",) * 4)
    for name in ("eth40g", "rdma_edr"):
        net = NETWORKS[name]
        fab = rpc.RpcFabric(rpc.SimulatedTransport(16, net))
        rep = rpc.fully_connected_exchange(fab, list(spec.sizes))
        assert rep.elapsed_s == pytest.approx(
            net.fc_round_time(spec, 16), rel=1e-9), name


def test_bench_fully_connected_simulated():
    """bench.run end-to-end on the simulated transport: the measured
    stat IS the netmodel projection for the chosen network."""
    from repro.configs.tfgrpc_bench import BenchConfig
    from repro.core import bench
    st = bench.run(BenchConfig(benchmark="fully_connected",
                               num_workers=16, transport="simulated",
                               network="rdma_edr"))
    assert st.derived["rpcs_per_s"] > 0
    assert st.model_projection["rdma_edr"] == pytest.approx(
        st.derived["rpcs_per_s"], rel=1e-6)
    # more endpoints than host devices is exactly the point
    assert st.derived["rpcs_per_round"] == 16 * 15


def test_bench_fully_connected_needs_two_workers():
    from repro.configs.tfgrpc_bench import BenchConfig
    from repro.core import bench
    with pytest.raises(RuntimeError, match="num-workers"):
        bench.run(BenchConfig(benchmark="fully_connected",
                              num_workers=1, transport="simulated"))


def test_fully_connected_loopback_measured():
    fab = rpc.RpcFabric(rpc.LoopbackTransport(3))
    rep = rpc.fully_connected_exchange(fab, [256, 256],
                                       bufs=_bufs([256, 256]))
    assert not rep.modeled
    assert rep.messages == 6 and rep.elapsed_s > 0


# ---------------------------------------------------------------------------
# ring / incast drivers + sweep CLI
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("benchmark", ["ring", "incast"])
def test_bench_streaming_simulated_matches_projection(benchmark):
    """bench.run end-to-end on the simulated transport: the measured
    stat IS the netmodel projection for the chosen network."""
    from repro.configs.tfgrpc_bench import BenchConfig
    from repro.core import bench
    st = bench.run(BenchConfig(benchmark=benchmark, num_workers=12,
                               transport="simulated", network="eth10g",
                               stream_chunks=3))
    assert st.derived["rpcs_per_s"] > 0
    assert st.derived["rpcs_per_round"] == 12 * 3
    assert st.model_projection["eth10g"] == pytest.approx(
        st.derived["rpcs_per_s"], rel=1e-6)


def test_bench_ring_needs_two_workers():
    from repro.configs.tfgrpc_bench import BenchConfig
    from repro.core import bench
    with pytest.raises(RuntimeError, match="num-workers"):
        bench.run(BenchConfig(benchmark="ring", num_workers=1,
                              transport="simulated"))


@pytest.mark.parametrize("benchmark", ["ring", "incast"])
def test_bench_streaming_loopback_measured(benchmark):
    from repro.configs.tfgrpc_bench import BenchConfig
    from repro.core import bench
    st = bench.run(BenchConfig(
        benchmark=benchmark, num_workers=2, transport="loopback",
        stream_chunks=2, iovec_count=2, large_bytes=1 << 20,
        categories=("small", "medium"), warmup_s=0.05, duration_s=0.1))
    assert st.derived["rpcs_per_s"] > 0
    assert st.derived["chunks_per_stream"] == 2.0


def test_bench_comm_sweep_single_table(capsys, tmp_path):
    """--sweep runs the cross-product in one invocation and emits one
    table plus one JSON row list."""
    import json as _json

    from repro.launch import bench_comm
    out = tmp_path / "rows.json"
    bench_comm.main(["--sweep", "scheme,mode", "--benchmark", "incast",
                     "--transport", "simulated", "--network", "eth40g",
                     "--num-workers", "4", "--json", str(out)])
    table = capsys.readouterr().out
    doc = _json.loads(out.read_text())
    assert doc["schema"] == 3
    rows = doc["rows"]
    assert len(rows) == 3 * 2              # schemes x modes
    combos = {(r["scheme"], r["mode"]) for r in rows}
    assert combos == {(s, m) for s in ("uniform", "random", "skew")
                      for m in ("non_serialized", "serialized")}
    assert all(r["value"] > 0 for r in rows)
    for s in ("uniform", "random", "skew"):
        assert table.count(s) >= 2


def test_bench_comm_rejects_unknown_category(capsys):
    from repro.launch import bench_comm
    with pytest.raises(SystemExit):
        bench_comm.main(["--categories", "small,mediun"])
    err = capsys.readouterr().err
    assert "mediun" in err and "choose from" in err


def test_bench_comm_rejects_transport_sweep_of_paper_benchmarks():
    from repro.launch import bench_comm
    with pytest.raises(SystemExit):
        bench_comm.main(["--sweep", "transport",
                         "--benchmark", "p2p_latency"])


# ---------------------------------------------------------------------------
# serve over rpc
# ---------------------------------------------------------------------------

def test_generate_codec_roundtrip():
    from repro.serve import engine as E
    prompts = np.arange(12, dtype=np.int32).reshape(3, 4)
    p2, mnt = E.decode_generate_request(
        E.encode_generate_request(prompts, 7))
    assert mnt == 7 and np.array_equal(prompts, p2)
    toks = np.arange(6, dtype=np.int32).reshape(2, 3)
    assert np.array_equal(
        toks, E.decode_generate_reply(E.encode_generate_reply(toks)))


@pytest.mark.parametrize("serialized", [False, True])
def test_serve_engine_over_rpc_matches_direct(serialized):
    import jax
    from repro.configs import get_reduced_config
    from repro.models import init_params
    from repro.parallel import NO_MESH
    from repro.serve.engine import (ServeConfig, ServeEngine,
                                    serve_stub)

    cfg = get_reduced_config("qwen3-8b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(NO_MESH, cfg, params,
                      ServeConfig(max_seq=64, max_new_tokens=4))
    prompts = np.random.default_rng(0).integers(
        0, cfg.model.vocab_size, (2, 8), dtype=np.int32)
    direct = eng.generate(prompts)
    _, channel = eng.serve_loopback(serialized=serialized)
    via_rpc = serve_stub(channel).generate((prompts, 0)).result()
    assert np.array_equal(direct, via_rpc)
