"""Pure schedule math: bipartite edge coloring (PS rounds) edge cases
and the all-to-all schedule behind the fully_connected family."""
import pytest

from repro.core.channels import (all_to_all_schedule, bipartite_schedule,
                                 fc_rpcs_per_round)


def _check_rounds(rounds, srcs, dsts):
    pairs = [p for r in rounds for p in r]
    # every (src, dst) pair exactly once
    assert len(pairs) == len(set(pairs)) == len(srcs) * len(dsts)
    assert set(pairs) == {(s, d) for s in srcs for d in dsts}
    # unique sources and destinations within every round
    for r in rounds:
        ss, dd = [s for s, _ in r], [d for _, d in r]
        assert len(set(ss)) == len(ss)
        assert len(set(dd)) == len(dd)


@pytest.mark.parametrize("srcs,dsts", [
    ([0, 1, 2, 3, 4], [5, 6]),        # more sources than destinations
    ([0, 1, 2], [3, 4, 5]),           # equal counts
    ([0], [1]),                       # single endpoint each side
    ([0], [1, 2, 3, 4, 5, 6, 7]),     # single source, many dsts
    ([1, 2, 3, 4, 5, 6, 7], [0]),     # many sources, single dst
    ([7, 3], [1, 5, 0, 2]),           # unordered, non-contiguous ids
])
def test_bipartite_schedule_edge_cases(srcs, dsts):
    rounds = bipartite_schedule(srcs, dsts)
    _check_rounds(rounds, srcs, dsts)
    # minimal coloring: rounds == max(|srcs|, |dsts|)
    assert len(rounds) == max(len(srcs), len(dsts))


@pytest.mark.parametrize("n", [2, 3, 4, 7, 8, 64])
def test_all_to_all_schedule(n):
    rounds = all_to_all_schedule(n)
    assert len(rounds) == n - 1           # minimal for K_n
    pairs = [p for r in rounds for p in r]
    assert len(pairs) == len(set(pairs)) == fc_rpcs_per_round(n)
    assert set(pairs) == {(s, d) for s in range(n) for d in range(n)
                          if s != d}
    for r in rounds:
        ss, dd = [s for s, _ in r], [d for _, d in r]
        assert len(set(ss)) == len(ss) == n
        assert len(set(dd)) == len(dd) == n


def test_all_to_all_rejects_singleton():
    with pytest.raises(AssertionError):
        all_to_all_schedule(1)
