"""Pure schedule math: bipartite edge coloring (PS rounds) edge cases,
the all-to-all schedule behind the fully_connected family, and the
ring/incast rotation schedules — cross-checked against the netmodel
closed forms through the simulated transport."""
import pytest

from repro.core.channels import (all_to_all_schedule, bipartite_schedule,
                                 fc_rpcs_per_round, incast_rpcs_per_round,
                                 incast_schedule, ring_rpcs_per_round,
                                 ring_schedule)


def _check_rounds(rounds, srcs, dsts):
    pairs = [p for r in rounds for p in r]
    # every (src, dst) pair exactly once
    assert len(pairs) == len(set(pairs)) == len(srcs) * len(dsts)
    assert set(pairs) == {(s, d) for s in srcs for d in dsts}
    # unique sources and destinations within every round
    for r in rounds:
        ss, dd = [s for s, _ in r], [d for _, d in r]
        assert len(set(ss)) == len(ss)
        assert len(set(dd)) == len(dd)


@pytest.mark.parametrize("srcs,dsts", [
    ([0, 1, 2, 3, 4], [5, 6]),        # more sources than destinations
    ([0, 1, 2], [3, 4, 5]),           # equal counts
    ([0], [1]),                       # single endpoint each side
    ([0], [1, 2, 3, 4, 5, 6, 7]),     # single source, many dsts
    ([1, 2, 3, 4, 5, 6, 7], [0]),     # many sources, single dst
    ([7, 3], [1, 5, 0, 2]),           # unordered, non-contiguous ids
])
def test_bipartite_schedule_edge_cases(srcs, dsts):
    rounds = bipartite_schedule(srcs, dsts)
    _check_rounds(rounds, srcs, dsts)
    # minimal coloring: rounds == max(|srcs|, |dsts|)
    assert len(rounds) == max(len(srcs), len(dsts))


@pytest.mark.parametrize("n", [2, 3, 4, 7, 8, 64])
def test_all_to_all_schedule(n):
    rounds = all_to_all_schedule(n)
    assert len(rounds) == n - 1           # minimal for K_n
    pairs = [p for r in rounds for p in r]
    assert len(pairs) == len(set(pairs)) == fc_rpcs_per_round(n)
    assert set(pairs) == {(s, d) for s in range(n) for d in range(n)
                          if s != d}
    for r in rounds:
        ss, dd = [s for s, _ in r], [d for _, d in r]
        assert len(set(ss)) == len(ss) == n
        assert len(set(dd)) == len(dd) == n


def test_all_to_all_rejects_singleton():
    with pytest.raises(AssertionError):
        all_to_all_schedule(1)


# ---------------------------------------------------------------------------
# ring / incast schedules
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [2, 3, 5, 7, 8])
@pytest.mark.parametrize("chunks", [1, 3])
def test_ring_schedule_rounds_are_successor_permutations(n, chunks):
    rounds = ring_schedule(n, chunks)
    assert len(rounds) == chunks
    want = {(i, (i + 1) % n) for i in range(n)}
    for r in rounds:
        assert set(r) == want
        ss, dd = [s for s, _ in r], [d for _, d in r]
        assert len(set(ss)) == len(ss) == n     # a full permutation:
        assert len(set(dd)) == len(dd) == n     # unique src AND dst
    assert ring_rpcs_per_round(n, chunks) == n * chunks


def test_ring_schedule_two_workers_is_the_swap():
    """n == 2: the successor rotation degenerates to the 0<->1 swap and
    must still be a legal (unique-port) round."""
    rounds = ring_schedule(2, 2)
    assert rounds == [[(0, 1), (1, 0)], [(0, 1), (1, 0)]]


def test_ring_schedule_rejects_singleton():
    with pytest.raises(AssertionError):
        ring_schedule(1)


@pytest.mark.parametrize("n_workers,chunks", [(1, 1), (1, 4), (3, 2),
                                              (7, 1)])
def test_incast_schedule_serializes_on_the_server(n_workers, chunks):
    rounds = incast_schedule(n_workers, n_chunks=chunks)
    # one destination => one message per round, nothing lost
    assert len(rounds) == n_workers * chunks
    assert all(len(r) == 1 for r in rounds)
    pairs = [p for r in rounds for p in r]
    assert all(d == 0 for _, d in pairs)
    assert {s for s, _ in pairs} == set(range(1, n_workers + 1))
    # chunk-major: each worker appears once per chunk wave
    for c in range(chunks):
        wave = pairs[c * n_workers:(c + 1) * n_workers]
        assert {s for s, _ in wave} == set(range(1, n_workers + 1))
    assert incast_rpcs_per_round(n_workers, chunks) == n_workers * chunks


def test_incast_schedule_single_worker_is_chunked_p2p():
    assert incast_schedule(1, n_chunks=3) == [[(1, 0)]] * 3


# ---------------------------------------------------------------------------
# cross-check: the simulated transport driving these schedules must land
# exactly on the netmodel closed forms (the analytic counterparts)
# ---------------------------------------------------------------------------

def _stream_fabric(n_endpoints, net, total_bytes, chunks):
    from repro import rpc
    return rpc.RpcFabric(
        rpc.SimulatedTransport(n_endpoints, net),
        window_bytes=(chunks + 1) * total_bytes,
        window_msgs=chunks + 1)


@pytest.mark.parametrize("net_name", ["eth40g", "rdma_edr", "eth10g"])
@pytest.mark.parametrize("n,chunks", [(2, 1), (6, 3), (16, 4)])
def test_simulated_ring_matches_netmodel(net_name, n, chunks):
    from repro import rpc
    from repro.core.netmodel import NETWORKS
    from repro.core.payload import PayloadSpec
    spec = PayloadSpec(sizes=(65536,) * 4, scheme="t",
                       categories=("medium",) * 4)
    net = NETWORKS[net_name]
    fab = _stream_fabric(n, net, spec.total_bytes, chunks)
    rep = rpc.ring_exchange(fab, list(spec.sizes), n_chunks=chunks)
    assert rep.modeled
    assert rep.elapsed_s == pytest.approx(
        net.ring_round_time(spec, n, n_chunks=chunks), rel=1e-9)


@pytest.mark.parametrize("net_name", ["eth40g", "rdma_edr", "eth10g"])
@pytest.mark.parametrize("n_workers,chunks", [(1, 1), (4, 3), (32, 2)])
def test_simulated_incast_matches_netmodel(net_name, n_workers, chunks):
    from repro import rpc
    from repro.core.netmodel import NETWORKS
    from repro.core.payload import PayloadSpec
    spec = PayloadSpec(sizes=(65536,) * 4, scheme="t",
                       categories=("medium",) * 4)
    net = NETWORKS[net_name]
    fab = _stream_fabric(n_workers + 1, net, spec.total_bytes, chunks)
    rep = rpc.incast_exchange(fab, list(spec.sizes), n_chunks=chunks)
    assert rep.modeled
    assert rep.elapsed_s == pytest.approx(
        net.incast_round_time(spec, n_workers, n_chunks=chunks),
        rel=1e-9)


def test_incast_contends_where_ring_does_not():
    """The signature of the two families: ring time is flat in the
    worker count, incast time grows superlinearly on kernel-TCP
    networks (quadratic host-copy contention at the one server)."""
    from repro.core.netmodel import NETWORKS
    from repro.core.payload import PayloadSpec
    spec = PayloadSpec(sizes=(1 << 20,), scheme="t",
                       categories=("large",))
    net = NETWORKS["eth10g"]
    assert net.ring_round_time(spec, 32) == pytest.approx(
        net.ring_round_time(spec, 4))
    t4 = net.incast_round_time(spec, 4)
    t32 = net.incast_round_time(spec, 32)
    assert t32 > 8 * t4                  # 8x workers, > 8x round time
    # and the fetch egress term keeps even zero-copy (RDMA) incast
    # scaling at least linearly with the fan-in
    r = NETWORKS["rdma_edr"]
    assert r.incast_round_time(spec, 32) > 7 * r.incast_round_time(spec, 4)
